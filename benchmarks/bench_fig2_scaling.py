"""Paper Figure 2: LM-head-only latency + peak memory scaling across
batch size, sequence length, and vocabulary size, for naive vs tiled
vs sparton (CPU-scaled; |V| axis keeps the paper's 30522 point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import compiled_peak_bytes, csv_print, time_fn
from repro.core.head_api import HeadSpec, make_head

D = 64
HEADS = [
    (impl, make_head(HeadSpec(impl=impl, vocab_tile=4096)))
    for impl in ("naive", "tiled", "sparton")
]


def _inputs(B, S, V, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    H = jax.random.normal(ks[0], (B, S, D))
    E = jax.random.normal(ks[1], (V, D)) * 0.2
    b = jnp.zeros((V,))
    mask = jnp.ones((B, S), jnp.int32)
    return H, E, b, mask


def _bwd(head_fn, mask):
    def loss(H, E, b):
        return jnp.sum(head_fn(H, E, b, mask) ** 2)
    return jax.grad(loss, argnums=(0, 1))


def run(csv: bool = True):
    rows = []
    # the paper's three sweeps (CPU-scaled)
    sweeps = [
        ("batch", [(b, 64, 30522) for b in (2, 4, 8, 16)]),
        ("seqlen", [(4, s, 30522) for s in (64, 128, 256, 512)]),
        ("vocab", [(8, 64, v) for v in (8192, 30522, 65536, 131072)]),
    ]
    for sweep, points in sweeps:
        for B, S, V in points:
            H, E, b, mask = _inputs(B, S, V)
            habs = (jax.ShapeDtypeStruct(H.shape, H.dtype),
                    jax.ShapeDtypeStruct(E.shape, E.dtype),
                    jax.ShapeDtypeStruct(b.shape, b.dtype))
            for name, fn in HEADS:
                g = _bwd(fn, mask)
                t = time_fn(jax.jit(g), H, E, b, warmup=1, iters=3)
                m = compiled_peak_bytes(g, *habs)
                rows.append((sweep, B, S, V, name, round(t, 1),
                             round(m / 2**20, 1)))
    if csv:
        csv_print(("sweep", "B", "S", "V", "impl", "bwd_time_ms",
                   "peak_mib"), rows)
    return rows


if __name__ == "__main__":
    run()
