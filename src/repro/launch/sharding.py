"""Per-architecture sharding rules (DESIGN.md §5).

Conventions:
* activations / token batches — sharded over the batch axes
  (``pod`` × ``data``), or the largest prefix that divides the batch;
* attention heads, ffn hidden, experts, vocabulary rows, embedding
  table rows — sharded over ``model``;
* decode KV caches — batch over batch axes, sequence over ``model``
  (flash-decoding style; the 500k cell additionally spreads sequence
  over ``pod``);
* optimizer moments — param sharding *plus* one extra large dim over
  the batch axes (ZeRO-1): XLA turns the gradient reshard into a
  reduce-scatter and the param update into an all-gather.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (DimeNetConfig, RecSysConfig,
                                TransformerConfig)
from repro.launch.mesh import batch_axes

PyTree = Any


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def batch_axes_for(mesh: Mesh, n: int) -> Tuple[str, ...]:
    """Largest contiguous batch-axis combination whose product divides
    n (prefers more shards: ("pod","data") > ("data",) > ("pod",))."""
    baxes = batch_axes(mesh)
    candidates = []
    for i in range(len(baxes)):
        for j in range(i + 1, len(baxes) + 1):
            sub = baxes[i:j]
            prod = 1
            for ax in sub:
                prod *= mesh.shape[ax]
            candidates.append((prod, sub))
    candidates.sort(key=lambda t: -t[0])
    for prod, sub in candidates:
        if n % prod == 0:
            return sub
    return ()


def batch_spec(mesh: Mesh, n: int, rank: int) -> P:
    """P((batch axes), None, ...) for an (n, ...) batch array."""
    axes = batch_axes_for(mesh, n)
    lead = axes if axes else None
    return P(lead, *([None] * (rank - 1)))


# ---------------------------------------------------------------------------
# transformer params
# ---------------------------------------------------------------------------

def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh.shape[axis] == 0


def transformer_param_specs(cfg: TransformerConfig, mesh: Mesh
                            ) -> Dict[str, Any]:
    """PartitionSpec pytree matching models.transformer.init_params."""
    m = "model"

    def tp(dim_ok: bool, spec: P, fallback: P) -> P:
        return spec if dim_ok else fallback

    # §Perf (llama/phi3.5: kv=8 < model=16): shard k/v projections only
    # when KV HEADS divide the axis — a flat KV*dh split crosses head
    # boundaries and forces GSPMD reshards around the attention einsum.
    # Replicating the (small) k/v projections instead measured
    # -46% per-layer wire for +26% per-device flops on llama train
    # (EXPERIMENTS.md §Perf C.6).
    kv_aligned = cfg.n_kv_heads % mesh.shape[m] == 0
    attn = {
        "wq": tp(_divisible(cfg.n_heads * cfg.d_head, mesh, m),
                 P(None, None, m), P(None, None, None)),
        "wk": tp(kv_aligned, P(None, None, m), P(None, None, None)),
        "wv": tp(kv_aligned, P(None, None, m), P(None, None, None)),
        "wo": tp(_divisible(cfg.n_heads * cfg.d_head, mesh, m),
                 P(None, m, None), P(None, None, None)),
    }
    if cfg.is_moe:
        mlp = {
            "router": P(None, None, None),
            "w_gate": tp(_divisible(cfg.n_experts, mesh, m),
                         P(None, m, None, None), P(None, None, None, None)),
            "w_up": tp(_divisible(cfg.n_experts, mesh, m),
                       P(None, m, None, None), P(None, None, None, None)),
            "w_down": tp(_divisible(cfg.n_experts, mesh, m),
                         P(None, m, None, None), P(None, None, None, None)),
        }
    else:
        mlp = {
            "w_gate": tp(_divisible(cfg.d_ff, mesh, m),
                         P(None, None, m), P(None, None, None)),
            "w_up": tp(_divisible(cfg.d_ff, mesh, m),
                       P(None, None, m), P(None, None, None)),
            "w_down": tp(_divisible(cfg.d_ff, mesh, m),
                         P(None, m, None), P(None, None, None)),
        }
    vocab_ok = _divisible(cfg.vocab_size, mesh, m)
    specs: Dict[str, Any] = {
        "embed": P(m, None) if vocab_ok else P(None, None),
        "layers": {
            "attn": attn,
            "mlp": mlp,
            "ln1": P(None, None),
            "ln2": P(None, None),
        },
        "final_norm": P(None),
    }
    if cfg.tie_embeddings:
        specs["lm_head"] = {"b": P(m) if vocab_ok else P(None)}
    else:
        specs["lm_head"] = {
            "E": P(m, None) if vocab_ok else P(None, None),
            "b": P(m) if vocab_ok else P(None),
        }
    return specs


# ---------------------------------------------------------------------------
# GNN / recsys params
# ---------------------------------------------------------------------------

def dimenet_param_specs(cfg: DimeNetConfig, mesh: Mesh) -> Any:
    """DimeNet params are small (<10M) — replicate everything."""
    from repro.models import dimenet as dn
    params = jax.eval_shape(
        lambda k: dn.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree.map(lambda l: P(*([None] * l.ndim)), params)


def recsys_param_specs(cfg: RecSysConfig, mesh: Mesh) -> Any:
    """Embedding-table rows shard over model (+data when huge);
    MLPs replicate."""
    from repro.models.recsys import padded_rows

    m = "model"
    baxes = batch_axes(mesh)
    row_shards_model = mesh.shape[m]

    def table_spec(raw_rows: int) -> P:
        # §Perf hillclimb (wide-deep/serve_bulk): REPLICATE small tables
        # (< ~128k rows -> < 16 MB at dim 32) so their lookups are
        # local and collective-free; only genuinely large tables shard
        # rows (model, +data when huge). Before: every lookup on a
        # sharded table costs a (batch, dim) psum — 40 psums/step of
        # 1.3 GB total on serve_bulk. After: 3 psums.
        rows = padded_rows(raw_rows)
        if rows < 131_072:
            return P(None, None)
        total = row_shards_model
        for ax in baxes:
            total *= mesh.shape[ax]
        if rows >= 1_000_000 and rows % total == 0:
            return P((m,) + baxes, None)
        if rows % row_shards_model == 0:
            return P(m, None)
        return P(None, None)

    def mlp_spec(layers):
        return [{"w": P(None, None), "b": P(None)} for _ in layers]

    if cfg.interaction == "dot":
        return {
            "tables": [table_spec(r) for r in cfg.table_sizes],
            "bot_mlp": mlp_spec(cfg.bot_mlp[:-1]),
            "top_mlp": mlp_spec(cfg.top_mlp),
        }
    if cfg.interaction == "cin":
        return {
            "tables": [table_spec(r) for r in cfg.table_sizes],
            "linear": [table_spec(r) for r in cfg.table_sizes],
            "cin": [P(None, None) for _ in cfg.cin_layers],
            "dnn": mlp_spec(cfg.mlp),
            "out": mlp_spec((1,)),
        }
    if cfg.interaction == "augru":
        gru = {"w": P(None, None), "u": P(None, None), "b": P(None)}
        return {
            "item_table": table_spec(cfg.table_sizes[0]),
            "gru1": dict(gru),
            "augru": dict(gru),
            "att": mlp_spec((1, 2)),
            "item_proj": mlp_spec((1,)),
            "mlp": mlp_spec(cfg.mlp + (1,)),
        }
    if cfg.interaction == "concat":
        return {
            "tables": [table_spec(r) for r in cfg.table_sizes],
            "wide": [table_spec(r) for r in cfg.table_sizes],
            "deep": mlp_spec(cfg.mlp + (1,)),
        }
    raise ValueError(cfg.interaction)


# ---------------------------------------------------------------------------
# optimizer state (ZeRO-1) + state assembly
# ---------------------------------------------------------------------------

def zero_spec(param_spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Param spec + the first free large dim sharded over the batch axes.

    Applied to optimizer moments: grads arrive param-sharded, XLA
    reshards to this with a reduce-scatter; params come back with an
    all-gather — ZeRO-1 without manual collectives.
    """
    baxes = batch_axes_for(mesh, 1 << 30)  # all batch axes
    if not baxes:
        return param_spec
    n_shards = 1
    for ax in baxes:
        n_shards *= mesh.shape[ax]
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (cur, dim) in enumerate(zip(entries, shape)):
        if cur is None and dim % n_shards == 0 and dim >= 512:
            entries[i] = baxes if len(baxes) > 1 else baxes[0]
            return P(*entries)
    return param_spec


def opt_state_specs(param_specs: PyTree, params_shape: PyTree,
                    mesh: Mesh) -> PyTree:
    """Map a param-spec pytree to moment specs (same treedef per moment
    dict level is handled by the caller wrapping in the opt layout)."""
    return jax.tree.map(
        lambda spec, leaf: zero_spec(spec, leaf.shape, mesh),
        param_specs, params_shape,
        is_leaf=lambda x: isinstance(x, P))


def state_shardings(param_specs: PyTree, params_shape: PyTree,
                    opt_layout: str, mesh: Mesh) -> Dict[str, Any]:
    """Build NamedShardings for the full train state
    {params, opt, step}."""
    zspecs = opt_state_specs(param_specs, params_shape, mesh)
    p_sh = jax.tree.map(lambda s: _ns(mesh, s), param_specs,
                        is_leaf=lambda x: isinstance(x, P))
    z_sh = jax.tree.map(lambda s: _ns(mesh, s), zspecs,
                        is_leaf=lambda x: isinstance(x, P))
    if opt_layout == "adamw":
        opt = {"mu": z_sh, "nu": z_sh}
    elif opt_layout == "adagrad":
        opt = {"acc": z_sh}
    elif opt_layout == "sgd":
        opt = {"v": z_sh}
    else:
        raise ValueError(opt_layout)
    return {
        "params": p_sh,
        "opt": opt,
        "step": _ns(mesh, P()),
    }


def batch_shardings(mesh: Mesh, batch_specs: Dict[str, Any],
                    overrides: Optional[Dict[str, P]] = None
                    ) -> Dict[str, NamedSharding]:
    """Default: shard dim 0 over the divisible batch-axis prefix."""
    out = {}
    for name, sds in batch_specs.items():
        if overrides and name in overrides:
            out[name] = _ns(mesh, overrides[name])
        else:
            out[name] = _ns(mesh, batch_spec(mesh, sds.shape[0], sds.ndim))
    return out
