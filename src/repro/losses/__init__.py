from repro.losses.contrastive import (
    flops_regularizer,
    gathered_infonce,
    infonce_from_scores,
    infonce_loss,
    l1_regularizer,
    margin_mse_loss,
    splade_loss,
)
