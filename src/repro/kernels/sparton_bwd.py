"""Sparton fused LM-head backward v2 — Pallas TPU kernels.

The paper's Alg. 3 computes, per (b, v), the activation-derivative
factor ``g`` and scatters ``g*E[v]`` into ``dH[b, i_max]`` / gathers
``H[b, i_max]`` into ``dE[v]`` using *atomic* accumulation across GPU
thread blocks. TPU Pallas has no atomics; instead we exploit the
sequential grid to accumulate deterministically (DESIGN.md §3):

* ``dH`` kernel — grid ``(B/bb, S/bs, V/bv)``, vocab innermost: each
  ``(b, s)`` tile of ``dH`` accumulates
  ``sum_v g[b,v] * onehot(i_max[b,v], s) * E[v]``.
* ``dE`` kernel — grid ``(V/bv, B/bb, S/bs)``, batch/seq innermost:
  each vocab tile of ``dE`` accumulates
  ``sum_b g[b,v] * onehot(i_max[b,v], s) * H[b,s]``.

v2 over v1 (DESIGN.md §"Kernel v2"):

* **Fused epilogue** — the kernels take the raw upstream cotangent
  ``dy`` and the stored post-activation ``y`` and evaluate ``g = dy *
  f'(y)`` per VMEM tile (``_common.bwd_factor``). v1 materialized ``g``
  with a standalone ``(B, V)`` elementwise pass: one full HBM write +
  two reads of a ``(B, V)`` f32 tensor, gone. The factor is recomputed
  by both kernels — a few VPU ops per tile versus a ``(B, V)`` HBM
  round-trip.
* **Fused bias gradient** — ``db = sum_b g`` accumulates in the dE
  kernel's scratch (one extra ``(1, bv)`` vector), so the wrapper's
  separate ``jnp.sum`` over a re-read ``g`` is gone too.
* **VMEM scratch accumulators** — both kernels accumulate into
  ``scratch_shapes`` and store each output tile to HBM exactly once at
  their finalize step, mirroring the forward's single-store guarantee.
* The weighted one-hot tile construction is shared between the two
  contractions via ``_common.onehot_weights``. (The contractions
  themselves must stay in separate kernels: dH tiles are indexed by
  (b, s) and dE tiles by (v), so no single grid order visits both
  accumulators in consecutive steps — the precondition for
  deterministic revisit-accumulation on Mosaic pipelines.)

Gather/scatter by ``i_max`` is re-expressed as a *one-hot contraction*
(``onehot(i_max) @ E`` / ``(onehot*g)^T @ H``) so the irregular memory
access becomes an MXU matmul — the TPU-native replacement for GPU
scattered atomics.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import bwd_factor, onehot_weights, pad_to


def _dh_kernel(
    dy_ref,    # (bb, bv) f32 — raw upstream cotangent
    y_ref,     # (bb, bv) f32 — stored post-activation
    i_ref,     # (bb, bv) i32 — argmax sequence index
    e_ref,     # (bv, D)
    dh_ref,    # (bb, bs, D) out — written once, at finalize
    acc_ref,   # (bb, bs, D) f32 VMEM scratch
    *,
    n_v_blocks: int,
    block_s: int,
    softcap: Optional[float],
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    bb, bs, d = dh_ref.shape
    k = pl.program_id(1)

    g = bwd_factor(y_ref[...], dy_ref[...], softcap)     # fused epilogue
    local_i = i_ref[...] - k * block_s          # (bb, bv); in-range => hit
    w = onehot_weights(g, local_i, bs)          # (bb, bs, bv)
    # dH[b, s, :] += sum_v w[b, s, v] * E[v, :]  — one MXU contraction.
    contrib = jax.lax.dot_general(
        w.reshape(bb * bs, -1), e_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).reshape(bb, bs, d)
    acc_ref[...] += contrib

    @pl.when(j == n_v_blocks - 1)
    def _finalize():
        dh_ref[...] = acc_ref[...]


def _de_kernel(
    dy_ref,    # (bb, bv) f32
    y_ref,     # (bb, bv) f32
    i_ref,     # (bb, bv) i32
    h_ref,     # (bb, bs, D)
    de_ref,    # (bv, D) out — written once, at finalize
    db_ref,    # (1, bv) f32 out — fused bias gradient
    de_acc,    # (bv, D) f32 VMEM scratch
    db_acc,    # (1, bv) f32 VMEM scratch
    *,
    n_b_blocks: int,
    n_s_blocks: int,
    block_s: int,
    softcap: Optional[float],
):
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        de_acc[...] = jnp.zeros(de_acc.shape, jnp.float32)
        db_acc[...] = jnp.zeros(db_acc.shape, jnp.float32)

    bb, bs, _ = h_ref.shape

    g = bwd_factor(y_ref[...], dy_ref[...], softcap)     # fused epilogue
    local_i = i_ref[...] - k * block_s
    w = onehot_weights(g, local_i, bs).reshape(bb * bs, -1)
    # dE[v, :] += sum_{b,s} w[bs, v] * H[bs, :]
    contrib = jax.lax.dot_general(
        w, h_ref[...].reshape(bb * bs, -1).astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    de_acc[...] += contrib

    # db[v] = sum_b g[b, v] — independent of s, so add once per b block.
    @pl.when(k == 0)
    def _db():
        db_acc[...] += jnp.sum(g, axis=0, keepdims=True)

    @pl.when((i == n_b_blocks - 1) & (k == n_s_blocks - 1))
    def _finalize():
        de_ref[...] = de_acc[...]
        db_ref[...] = db_acc[...]


# The two backward contractions are separately-jitted calls with their
# OWN block triples: dH tiles are indexed by (b, s) and dE tiles by
# (v), so the best blocks differ (the autotuner times them apart —
# ROADMAP per-kernel item). Padding invariant shared by both: padded
# rows/cols must not route anywhere real — y == 0 there, so bwd_factor
# yields g == 0 and any index is safe.

@functools.partial(
    jax.jit,
    static_argnames=("seq_len", "block_b", "block_s", "block_v",
                     "softcap", "interpret"),
)
def _dh_call(
    dy, y, i_max, E, *, seq_len, block_b, block_s, block_v, softcap,
    interpret
):
    B, V = dy.shape
    D = E.shape[1]

    dyp = pad_to(pad_to(dy.astype(jnp.float32), 0, block_b), 1, block_v)
    yp = pad_to(pad_to(y.astype(jnp.float32), 0, block_b), 1, block_v)
    ip = pad_to(pad_to(i_max, 0, block_b), 1, block_v)
    Ep = pad_to(E, 0, block_v)

    Bp = dyp.shape[0]
    Vp = Ep.shape[0]
    Sp = -(-seq_len // block_s) * block_s
    nb, ns, nv = Bp // block_b, Sp // block_s, Vp // block_v

    bv_spec = pl.BlockSpec((block_b, block_v), lambda i, k, j: (i, j))
    dH = pl.pallas_call(
        functools.partial(_dh_kernel, n_v_blocks=nv, block_s=block_s,
                          softcap=softcap),
        grid=(nb, ns, nv),
        in_specs=[
            bv_spec,
            bv_spec,
            bv_spec,
            pl.BlockSpec((block_v, D), lambda i, k, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, block_s, D), lambda i, k, j: (i, k, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((Bp, Sp, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b, block_s, D), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dyp, yp, ip, Ep)
    return dH[:B, :seq_len]


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_s", "block_v", "softcap",
                     "interpret"),
)
def _de_call(
    dy, y, i_max, H, *, block_b, block_s, block_v, softcap, interpret
):
    B, V = dy.shape
    S, D = H.shape[1], H.shape[2]

    dyp = pad_to(pad_to(dy.astype(jnp.float32), 0, block_b), 1, block_v)
    yp = pad_to(pad_to(y.astype(jnp.float32), 0, block_b), 1, block_v)
    ip = pad_to(pad_to(i_max, 0, block_b), 1, block_v)
    Hp = pad_to(pad_to(H, 0, block_b), 1, block_s)

    Bp, Sp, _ = Hp.shape
    Vp = dyp.shape[1]
    nb, ns, nv = Bp // block_b, Sp // block_s, Vp // block_v

    vb_spec = pl.BlockSpec((block_b, block_v), lambda j, i, k: (i, j))
    dE, db = pl.pallas_call(
        functools.partial(
            _de_kernel, n_b_blocks=nb, n_s_blocks=ns, block_s=block_s,
            softcap=softcap,
        ),
        grid=(nv, nb, ns),
        in_specs=[
            vb_spec,
            vb_spec,
            vb_spec,
            pl.BlockSpec((block_b, block_s, D), lambda j, i, k: (i, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_v, D), lambda j, i, k: (j, 0)),
            pl.BlockSpec((1, block_v), lambda j, i, k: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Vp, D), jnp.float32),
            jax.ShapeDtypeStruct((1, Vp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_v, D), jnp.float32),
            pltpu.VMEM((1, block_v), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(dyp, yp, ip, Hp)
    return dE[:V], db[0, :V]


Blocks = Tuple[int, int, int]


def _resolve(shape, V, dtype, kernel, block_b, block_s, block_v) -> Blocks:
    """Autotune-cache resolution. The cache's dtype component keys on
    the kernel's own weight/activation operand (dy/y are always f32):
    E for the dH kernel, H for dE — the same rule every entry point
    (ops.sparton_head, the standalone wrappers) applies, so one tuning
    sweep serves them all."""
    if block_b is not None and block_s is not None and block_v is not None:
        return (block_b, block_s, block_v)
    from repro.kernels.autotune import resolve_blocks  # avoids cycle

    B, S, D = shape
    return resolve_blocks(B, S, D, V, dtype, block_b, block_s,
                          block_v, kernel=kernel)


def sparton_backward_dh(
    dy: jax.Array,      # (B, V) — raw upstream cotangent
    y: jax.Array,       # (B, V) f32 — stored post-activation
    i_max: jax.Array,   # (B, V) i32
    E: jax.Array,       # (V, D) f32 or bf16
    seq_len: int,
    *,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """The dH contraction alone — the unit the autotuner times."""
    B, V = dy.shape
    blocks = _resolve((B, seq_len, E.shape[1]), V, E.dtype, "dh",
                      block_b, block_s, block_v)
    return _dh_call(dy, y, i_max, E, seq_len=seq_len, block_b=blocks[0],
                    block_s=blocks[1], block_v=blocks[2],
                    softcap=softcap, interpret=interpret)


def sparton_backward_de(
    dy: jax.Array,      # (B, V)
    y: jax.Array,       # (B, V) f32
    i_max: jax.Array,   # (B, V) i32
    H: jax.Array,       # (B, S, D) f32 or bf16
    *,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """The dE (+ fused db) contraction alone — the autotuner's unit."""
    blocks = _resolve(H.shape, dy.shape[1], H.dtype, "de",
                      block_b, block_s, block_v)
    return _de_call(dy, y, i_max, H, block_b=blocks[0],
                    block_s=blocks[1], block_v=blocks[2],
                    softcap=softcap, interpret=interpret)


def sparton_backward(
    dy: jax.Array,      # (B, V) — raw upstream cotangent (any float dtype)
    y: jax.Array,       # (B, V) f32 — stored post-activation
    i_max: jax.Array,   # (B, V) i32
    H: jax.Array,       # (B, S, D) f32 or bf16
    E: jax.Array,       # (V, D) f32 or bf16
    *,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    dh_blocks: Optional[Blocks] = None,
    de_blocks: Optional[Blocks] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused backward. Returns (dH (B,S,D), dE (V,D), db (V,)) in f32.

    The activation-derivative factor and the bias gradient are fused
    into the kernels — no standalone elementwise pass over ``(B, V)``.

    Block resolution is **per kernel**: explicit ``dh_blocks`` /
    ``de_blocks`` triples win; else ``block_b/s/v`` pins apply to both
    contractions (the legacy joint behavior); unset components come
    from the autotuner's per-kernel cache ("dh" / "de" entries, falling
    back to a legacy joint entry when only that exists).
    """
    V = E.shape[0]
    if dh_blocks is None:
        dh_blocks = _resolve(H.shape, V, E.dtype, "dh",
                             block_b, block_s, block_v)
    if de_blocks is None:
        de_blocks = _resolve(H.shape, V, H.dtype, "de",
                             block_b, block_s, block_v)
    dH = _dh_call(dy, y, i_max, E, seq_len=H.shape[1],
                  block_b=dh_blocks[0], block_s=dh_blocks[1],
                  block_v=dh_blocks[2], softcap=softcap,
                  interpret=interpret)
    dE, db = _de_call(dy, y, i_max, H, block_b=de_blocks[0],
                      block_s=de_blocks[1], block_v=de_blocks[2],
                      softcap=softcap, interpret=interpret)
    return dH, dE, db
