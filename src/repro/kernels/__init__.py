"""Pallas TPU kernels for the framework's compute hot-spots.

* ``sparton`` / ``sparton_bwd`` — the paper's fused LM head (fwd + bwd).
* ``topk_score`` — beyond-paper transfer: fused streaming top-k
  retrieval scoring (never materializes the (B, N) score matrix).
* ``ops`` — jit'd differentiable wrappers (``custom_vjp``).
* ``ref`` — pure-jnp oracles for the allclose sweeps.
* ``autotune`` — block-size selection: VMEM-budgeted candidate
  enumeration, timing, JSON winner cache.
"""

from repro.kernels import autotune
from repro.kernels.autotune import (autotune_blocks,
                                    autotune_kernel_blocks, get_blocks)
from repro.kernels.ops import sparton_head, sparton_lm_head_kernel
from repro.kernels.sparton import sparton_forward
from repro.kernels.sparton_bwd import (sparton_backward,
                                       sparton_backward_de,
                                       sparton_backward_dh)
from repro.kernels.topk_score import merge_topk, topk_score
