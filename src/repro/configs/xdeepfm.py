"""xDeepFM — Compressed Interaction Network [arXiv:1803.05170].

n_sparse=39 embed_dim=10 cin=200-200-200 mlp=400-400. Criteo layout:
13 discretized dense + 26 categorical = 39 fields; cardinalities below
follow the paper's Criteo preprocessing (hashed large fields).
"""

from repro.configs.base import RecSysConfig, SHAPES_RECSYS

# 13 discretized numeric fields (small) + 26 categorical (Criteo-like)
TABLE_SIZES = tuple([64] * 13 + [
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
    5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
    7046547, 18, 15, 286181, 105, 142572,
])

CONFIG = RecSysConfig(
    name="xdeepfm",
    interaction="cin",
    n_dense=0,
    n_sparse=39,
    embed_dim=10,
    table_sizes=TABLE_SIZES,
    cin_layers=(200, 200, 200),
    mlp=(400, 400),
)

SMOKE = RecSysConfig(
    name="xdeepfm-smoke",
    interaction="cin",
    n_sparse=5,
    embed_dim=8,
    table_sizes=(50, 100, 20, 80, 40),
    cin_layers=(16, 16),
    mlp=(32, 16),
)

SHAPES = SHAPES_RECSYS
