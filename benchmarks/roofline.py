import os
if "512" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (EXPERIMENTS.md §Roofline).

The dry-run lowers every cell with scans ROLLED (fast compile, exact
memory analysis, true collective schedule) — but ``cost_analysis()``
counts a scan body once, so its FLOP/byte totals undercount by the
trip counts. This module recovers exact per-device totals by
compiling small *probes* with their scans fully unrolled and composing
them analytically:

  LM train   total = n_micro x (2 x Σ_layers P_layer + P_head+loss)
                     + P_opt
  LM prefill total = Σ_layers P_layer_fwd + P_head_fwd
  decode / GNN / recsys(-DIEN) / retrieval — already scan-free or
  unrolled in the step itself => dry-run numbers are exact.
  DIEN       re-lowered with its GRU scans unrolled (cheap model).

Each probe is lowered UNDER THE MESH with the same shardings as the
full step, so per-layer collectives (TP all-reduces, EP psums, head
psum) are captured per-device, exactly.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline \
      --dryrun dryrun_single_pod.json --out roofline_single_pod.json
"""

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import set_mesh
from repro.configs import get_config
from repro.configs.base import RecSysConfig, TransformerConfig
from repro.configs.specs import cell_spec
from repro.core.head_api import make_head
from repro.core.sharded import sharded_flops_reg, sharded_infonce
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.sharding import batch_axes_for, transformer_param_specs
from repro.launch.steps import _moe_shard, arch_config_for_cell
from repro.losses.contrastive import flops_regularizer, infonce_loss
from repro.models import transformer as tfm
from repro.models.transformer import _layer

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.wire + o.wire)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k, self.wire * k)
    __rmul__ = __mul__


def _measure(fn, args_abs, mesh, static_argnums=()) -> Cost:
    with set_mesh(mesh):
        compiled = jax.jit(fn).lower(*args_abs).compile()
    flops, byts = hlo.cost_analysis_terms(compiled)
    coll = hlo.parse_collectives(compiled.as_text())
    return Cost(flops, byts, coll.total_wire_bytes)


def _layer_param_abs(cfg: TransformerConfig, mesh):
    """Abstract one-layer params with the (L-stripped) shardings."""
    m = "model"
    dt = jnp.dtype(cfg.param_dtype)
    D, H, KV, dh, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                       cfg.d_head, cfg.d_ff)

    def ns(spec):
        return NamedSharding(mesh, spec)

    def ok(dim):
        return dim % mesh.shape[m] == 0

    kv_aligned = KV % mesh.shape[m] == 0   # see launch/sharding.py
    attn = {
        "wq": S((D, H * dh), dt, sharding=ns(
            P(None, m) if ok(H * dh) else P(None, None))),
        "wk": S((D, KV * dh), dt, sharding=ns(
            P(None, m) if kv_aligned else P(None, None))),
        "wv": S((D, KV * dh), dt, sharding=ns(
            P(None, m) if kv_aligned else P(None, None))),
        "wo": S((H * dh, D), dt, sharding=ns(
            P(m, None) if ok(H * dh) else P(None, None))),
    }
    if cfg.is_moe:
        E = cfg.n_experts
        espec = P(m, None, None) if E % mesh.shape[m] == 0 \
            else P(None, None, None)
        mlp = {
            "router": S((D, E), dt, sharding=ns(P(None, None))),
            "w_gate": S((E, D, F), dt, sharding=ns(espec)),
            "w_up": S((E, D, F), dt, sharding=ns(espec)),
            "w_down": S((E, F, D), dt, sharding=ns(espec)),
        }
    else:
        mlp = {
            "w_gate": S((D, F), dt, sharding=ns(
                P(None, m) if ok(F) else P(None, None))),
            "w_up": S((D, F), dt, sharding=ns(
                P(None, m) if ok(F) else P(None, None))),
            "w_down": S((F, D), dt, sharding=ns(
                P(m, None) if ok(F) else P(None, None))),
        }
    return {
        "attn": attn, "mlp": mlp,
        "ln1": S((D,), dt, sharding=ns(P(None))),
        "ln2": S((D,), dt, sharding=ns(P(None))),
    }


def _probe_layer(cfg: TransformerConfig, mesh, B_local_total: int,
                 seq: int, *, train: bool, window, causal: bool) -> Cost:
    """Per-device cost of ONE transformer layer at the (micro)batch
    shape, attention chunk scan fully unrolled."""
    n_chunks = max(1, seq // min(cfg.attn_chunk, seq))
    cfg_u = dataclasses.replace(cfg, attn_unroll=n_chunks)
    moe_shard = _moe_shard(cfg, mesh)
    baxes = batch_axes_for(mesh, B_local_total)
    cdt = jnp.dtype(cfg.compute_dtype)
    lp_abs = _layer_param_abs(cfg, mesh)
    x_abs = S((B_local_total, seq, cfg.d_model), cdt,
              sharding=NamedSharding(mesh, P(baxes, None, None)))
    mask_abs = S((B_local_total, seq), jnp.int32,
                 sharding=NamedSharding(mesh, P(baxes, None)))

    positions = jnp.arange(seq, dtype=jnp.int32)

    def layer_fn(lp, x, mask):
        return _layer(x, lp, cfg_u, positions=positions, mask=mask,
                      causal=causal, window=window, moe_shard=moe_shard)

    if train and cfg.remat:
        # the real step remats every layer: the probe must count the
        # recompute forward too
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def fwd(lp, x, mask):
        out, aux = layer_fn(lp, x, mask)
        return jnp.sum(out.astype(jnp.float32)) + aux

    if train:
        fn = jax.value_and_grad(fwd, argnums=(0, 1))
    else:
        fn = fwd
    return _measure(fn, (lp_abs, x_abs, mask_abs), mesh)


def _probe_head_loss(cfg: TransformerConfig, mesh, pairs_local_total: int,
                     seq: int, *, train: bool) -> Cost:
    """Per-device cost of both encoders' Sparton heads + the InfoNCE
    and FLOPS losses at the micro shape (vocab scan fully unrolled)."""
    m = "model"
    vocab_ok = cfg.vocab_size % mesh.shape[m] == 0
    baxes = batch_axes_for(mesh, pairs_local_total)
    cdt = jnp.dtype(cfg.compute_dtype)
    V, D = cfg.vocab_size, cfg.d_model
    v_local = V // mesh.shape[m] if vocab_ok else V
    n_tiles = max(1, v_local // cfg.head_vocab_tile)
    n_shards = 1
    for ax in baxes:
        n_shards *= mesh.shape[ax]
    b_local = max(1, pairs_local_total // n_shards)

    # The probe always counts the pure-JAX scan body (pallas_call has
    # no cost_analysis), with the scans fully unrolled for exact totals.
    spec = cfg.head_spec(impl="sparton", unroll=n_tiles,
                         bwd_batch_chunk=max(8, b_local))
    if vocab_ok:
        head = make_head(spec, mesh=mesh, batch_axes=baxes)
        infonce = sharded_infonce(mesh, batch_axes=baxes)
        flops_r = sharded_flops_reg(mesh, batch_axes=baxes)
    else:
        head = make_head(spec)
        infonce = infonce_loss
        flops_r = flops_regularizer

    e_spec = P(m, None) if vocab_ok else P(None, None)
    b_spec = P(m) if vocab_ok else P(None)
    Hq = S((pairs_local_total, seq, D), cdt,
           sharding=NamedSharding(mesh, P(baxes, None, None)))
    E_abs = S((V, D), cdt, sharding=NamedSharding(mesh, e_spec))
    b_abs = S((V,), jnp.float32, sharding=NamedSharding(mesh, b_spec))
    mask_abs = S((pairs_local_total, seq), jnp.int32,
                 sharding=NamedSharding(mesh, P(baxes, None)))

    def headloss(Hq_, Hd_, E_, bb, mq, md):
        yq = head(Hq_, E_, bb, mq)
        yd = head(Hd_, E_, bb, md)
        if vocab_ok:
            loss = infonce(yq, yd)
        else:
            loss = infonce(yq, yd)
        return loss + cfg.lambda_q * flops_r(yq) \
            + cfg.lambda_d * flops_r(yd)

    if train:
        fn = jax.value_and_grad(headloss, argnums=(0, 1, 2, 3))
    else:
        def fn(Hq_, Hd_, E_, bb, mq, md):
            return head(Hq_, E_, bb, mq)
    return _measure(fn, (Hq, Hq, E_abs, b_abs, mask_abs, mask_abs), mesh)


def _probe_opt(arch_id, cfg, mesh, cell) -> Cost:
    """Optimizer update cost (incl. ZeRO reduce-scatter/all-gather)."""
    from repro.launch.dryrun import _abstract_state
    from repro.optim.optimizers import adamw, apply_updates

    state_abs, param_sh, zero_sh = _abstract_state(arch_id, mesh, cell)
    params_abs = state_abs["params"]
    grads_abs = jax.tree.map(
        lambda l: S(l.shape, l.dtype, sharding=l.sharding), params_abs)
    opt = adamw(1e-4)

    def optstep(params, mu, nu, grads):
        grads = jax.lax.with_sharding_constraint(grads, zero_sh)
        updates, st = opt.update(grads, {"mu": mu, "nu": nu}, params,
                                 jnp.zeros((), jnp.int32))
        updates = jax.lax.with_sharding_constraint(updates, param_sh)
        return apply_updates(params, updates), st

    return _measure(
        fn=optstep,
        args_abs=(params_abs, state_abs["opt"]["mu"],
                  state_abs["opt"]["nu"], grads_abs),
        mesh=mesh)


def corrected_lm_cost(arch_id: str, shape_name: str, mesh) -> Cost:
    cell = cell_spec(arch_id, shape_name)
    cfg = arch_config_for_cell(arch_id, cell)
    L = cfg.n_layers

    if cell.step_kind == "lsr_train":
        pairs, seq = cell.batch["q_tokens"].shape
        micro_pairs = max(1, pairs // cell.n_micro)
        causal = not cfg.bidirectional_encoder
        if cfg.local_global_alternating and cfg.sliding_window:
            p_local = _probe_layer(cfg, mesh, micro_pairs, seq, train=True,
                                   window=cfg.sliding_window, causal=causal)
            p_global = _probe_layer(cfg, mesh, micro_pairs, seq,
                                    train=True, window=None, causal=causal)
            layers = (L // 2 + L % 2) * p_local + (L // 2) * p_global
        else:
            p = _probe_layer(cfg, mesh, micro_pairs, seq, train=True,
                             window=cfg.sliding_window, causal=causal)
            layers = L * p
        headloss = _probe_head_loss(cfg, mesh, micro_pairs, seq,
                                    train=True)
        opt = _probe_opt(arch_id, cfg, mesh, cell)
        return cell.n_micro * (2 * layers + headloss) + opt

    if cell.step_kind == "lsr_prefill":
        B, seq = cell.batch["tokens"].shape
        causal = not cfg.bidirectional_encoder
        if cfg.local_global_alternating and cfg.sliding_window:
            p_local = _probe_layer(cfg, mesh, B, seq, train=False,
                                   window=cfg.sliding_window, causal=causal)
            p_global = _probe_layer(cfg, mesh, B, seq, train=False,
                                    window=None, causal=causal)
            layers = (L // 2 + L % 2) * p_local + (L // 2) * p_global
        else:
            p = _probe_layer(cfg, mesh, B, seq, train=False,
                             window=cfg.sliding_window, causal=causal)
            layers = L * p
        head = _probe_head_loss(cfg, mesh, B, seq, train=False)
        return layers + head

    raise ValueError(cell.step_kind)


def corrected_dien_cost(arch_id: str, shape_name: str, mesh) -> Cost:
    """Re-lower the DIEN step with its GRU scans unrolled (T=100)."""
    from repro.launch import dryrun as dr
    from repro.launch.sharding import batch_shardings
    from repro.models import recsys as recsys_model
    from repro.optim.optimizers import adagrad, apply_updates

    cell = cell_spec(arch_id, shape_name)
    cfg = get_config(arch_id).CONFIG
    state_abs, param_sh, zero_sh = dr._abstract_state(arch_id, mesh, cell)
    bsh = batch_shardings(mesh, cell.batch,
                          dr._batch_overrides(arch_id, cell, mesh))
    batch_abs = {k: S(v.shape, v.dtype, sharding=bsh[k])
                 for k, v in cell.batch.items()}
    opt = adagrad(1e-2)
    T = cfg.seq_len

    if cell.step_kind == "recsys_train":
        def loss_fn(params, batch):
            logits = recsys_model.forward(params, cfg, batch, unroll=T)
            label = batch["label"]
            l = jnp.maximum(logits, 0) - logits * label \
                + jnp.log1p(jnp.exp(-jnp.abs(logits)))
            return jnp.mean(l)

        def step(state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(state["params"],
                                                      batch)
            grads = jax.lax.with_sharding_constraint(grads, zero_sh)
            updates, st = opt.update(grads, state["opt"],
                                     state["params"], state["step"])
            return loss
        return _measure(step, (state_abs, batch_abs), mesh)

    def serve(params, batch):
        return jax.nn.sigmoid(
            recsys_model.forward(params, cfg, batch, unroll=T))
    return _measure(serve, (state_abs["params"], batch_abs), mesh)


def fused_hbm_estimate(arch_id: str, shape_name: str, mesh) -> float:
    """Analytic LOWER bound on per-device HBM traffic per step, assuming
    perfect elementwise fusion (TPU-like): weights are read once per
    (micro x fwd+bwd use), opt state read+written once, saved
    activations written+read once. cost_analysis() bytes are the
    UNFUSED upper bound; the truth lies between.
    """
    cell = cell_spec(arch_id, shape_name)
    cfg = arch_config_for_cell(arch_id, cell)
    n_dev = mesh.devices.size
    if not isinstance(cfg, TransformerConfig):
        return 0.0
    p_bytes = cfg.n_params * jnp.dtype(cfg.param_dtype).itemsize / \
        mesh.shape["model"]
    cdt = jnp.dtype(cfg.compute_dtype).itemsize
    if cell.step_kind == "lsr_train":
        pairs, seq = cell.batch["q_tokens"].shape
        tokens_local = 2 * pairs * seq / max(
            1, n_dev // mesh.shape["model"])
        act = cfg.n_layers * tokens_local * cfg.d_model * cdt
        opt = 2 * cfg.n_params * 4 / n_dev * 2      # mu+nu r/w (ZeRO)
        grads = cfg.n_params * 4 / n_dev * 2 * cell.n_micro
        # fwd read + bwd read (+ remat fwd re-read) per micro
        weights = 3 * p_bytes * cell.n_micro
        return weights + act * 3 + opt + grads
    if cell.step_kind == "lsr_prefill":
        B, seq = cell.batch["tokens"].shape
        tokens_local = B * seq / max(1, n_dev // mesh.shape["model"])
        act = cfg.n_layers * tokens_local * cfg.d_model * cdt
        return p_bytes + act * 2
    if cell.step_kind == "decode":
        B = cell.batch["tokens"].shape[0]
        cache = (2 * cfg.n_layers * B * cell.cache_len * cfg.n_kv_heads
                 * cfg.d_head * cdt) / n_dev
        return p_bytes + cache
    return 0.0


# (B, Q, L, N, k) operating points for the fused impact-scorer probe:
# a serving batch against a CI-sized, a mid, and a paper-scale corpus.
_IMPACT_PROBE_SHAPES = (
    (16, 32, 256, 16384, 100),
    (16, 32, 1024, 131072, 100),
    (16, 32, 4096, 1 << 20, 100),
)


def impact_probe(shapes=_IMPACT_PROBE_SHAPES) -> list:
    """Analytic bytes-moved vs FLOPs for the impact scorer, unfused vs
    fused (kernels/impact_score), per variant.

    The unfused path reads the gathered posting windows, materializes
    the (B, N) score matrix in HBM (one write + one read back by
    top_k), and writes (B, k); the u4 variant additionally writes and
    re-reads the dequantized window. The fused kernel reads the same
    windows once and writes (B, k) — but pays the one-hot contraction:
    every posting lane is multiplied against every doc column of its
    tile, 2*B*W*N_pad MACs of MXU work. The probe makes that trade
    explicit: fused swaps O(B*N) HBM traffic for O(B*W*N) cheap MXU
    FLOPs, which wins whenever the unfused path is memory-bound —
    exactly the Sparton argument on the encode side.
    """
    from repro.kernels.autotune import heuristic_impact_blocks
    from repro.kernels.impact_score import fused_window_bytes

    out = []
    for B, Q, L, N, k in shapes:
        W = Q * L
        topk_out = B * k * 8
        for variant in ("f32", "u4"):
            window = fused_window_bytes(B, Q, L, variant)
            unfused = window + 2 * B * N * 4 + topk_out
            if variant == "u4":
                unfused += 2 * B * W * 8   # dequant materialization
            bn, bw = heuristic_impact_blocks(B, Q, L, N,
                                             variant=variant)
            n_pad = -(-N // bn) * bn
            fused = window + topk_out
            flops_unfused = 2.0 * B * W + float(B) * N
            flops_fused = 2.0 * B * W * n_pad
            for path, byts, flops in (
                    ("unfused", unfused, flops_unfused),
                    ("fused", fused, flops_fused)):
                mem_s = byts / hlo.HBM_BW
                compute_s = flops / hlo.PEAK_FLOPS
                out.append({
                    "probe": "impact_scorer",
                    "shape": {"B": B, "Q": Q, "L": L, "N": N, "k": k},
                    "variant": variant,
                    "path": path,
                    "blocks": ([bn, bw] if path == "fused" else None),
                    "hbm_bytes": int(byts),
                    "flops": flops,
                    "intensity_flops_per_byte": round(flops / byts, 3),
                    "roof_memory_s": mem_s,
                    "roof_compute_s": compute_s,
                    "roof_bottleneck": ("memory" if mem_s >= compute_s
                                        else "compute"),
                })
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default=None,
                    help="dry-run json (rolled lowering records)")
    ap.add_argument("--out", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--only-arch", default=None)
    ap.add_argument("--impact-probe", action="store_true",
                    help="emit the analytic fused-impact-scorer "
                         "bytes/FLOPs records instead of correcting a "
                         "dry-run (no mesh, no lowering)")
    args = ap.parse_args(argv)

    if args.impact_probe:
        recs = impact_probe()
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)
        for r in recs:
            s = r["shape"]
            print(f"N={s['N']} {r['variant']:>3} {r['path']:>7}: "
                  f"{r['hbm_bytes'] / 1e6:9.1f} MB, "
                  f"{r['flops'] / 1e9:9.2f} GFLOP "
                  f"-> {r['roof_bottleneck']}")
        print(f"wrote {args.out}")
        return 0
    if not args.dryrun:
        ap.error("--dryrun is required unless --impact-probe is set")

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    records = json.load(open(args.dryrun))
    out = []
    for rec in records:
        if rec.get("status") != "ok":
            out.append(rec)
            continue
        arch, shape = rec["arch"], rec["shape"]
        if args.only_arch and arch != args.only_arch:
            out.append(rec)
            continue
        kind = rec["step_kind"]
        try:
            if kind in ("lsr_train", "lsr_prefill"):
                cost = corrected_lm_cost(arch, shape, mesh)
            elif arch == "dien":
                cost = corrected_dien_cost(arch, shape, mesh)
            else:
                cost = Cost(rec["flops_per_device"],
                            rec["hbm_bytes_per_device"],
                            rec["collective_wire_bytes"])
        except Exception as e:  # record + keep going
            rec["roofline_error"] = repr(e)
            out.append(rec)
            print(f"PROBE FAILED {arch}/{shape}: {e!r}", flush=True)
            continue

        stats = hlo.CollectiveStats({}, {}, {})
        stats.total_wire_bytes = cost.wire
        roof = hlo.roofline_terms(
            cost.flops, cost.bytes, stats,
            model_flops=rec.get("model_flops_per_device", 0.0))
        fused = fused_hbm_estimate(arch, shape, mesh)
        rec.update({
            "corrected_flops_per_device": cost.flops,
            "corrected_hbm_bytes_per_device": cost.bytes,
            "corrected_collective_wire_bytes": cost.wire,
            "roof_compute_s": roof.compute_s,
            "roof_memory_s": roof.memory_s,
            "roof_memory_s_fused_est": fused / hlo.HBM_BW if fused else None,
            "roof_collective_s": roof.collective_s,
            "roof_bottleneck": roof.bottleneck,
            "roof_useful_ratio": roof.useful_ratio,
        })
        print(f"{arch}/{shape}: compute {roof.compute_s:.3e}s "
              f"memory {roof.memory_s:.3e}s coll {roof.collective_s:.3e}s"
              f" -> {roof.bottleneck} (useful {roof.useful_ratio:.2f})",
              flush=True)
        out.append(rec)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
