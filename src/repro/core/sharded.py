"""Vocabulary-sharded Sparton head — the technique at pod scale.

The paper is single-GPU. At |V| = 256k (gemma-2) even the *reduced*
``(B, V)`` output is large, and the head weights ``E (V, D)`` dominate
HBM on one chip. We shard the vocabulary dimension over the ``model``
mesh axis with ``shard_map`` (DESIGN.md §3):

* ``E``, ``b`` row-sharded on ``model`` — each device holds V/n rows.
* ``H`` replicated over ``model`` (it is batch-sharded over ``data``).
* Each device runs the *local* Sparton head over its vocab shard —
  the streaming max is per-vocab-column independent, so the forward
  needs **zero collectives**, and ``∇E`` is computed shard-locally.
* ``∇H = Σ_v g·E[v]`` sums over the vocab => one ``psum`` over
  ``model`` in the backward. That is the entire communication cost.

The InfoNCE similarity ``q · dᵀ = Σ_v q_v d_v`` is likewise a
vocab-sum: computed shard-locally and ``psum``-reduced, so the full
``(B, V)`` sparse vectors are never gathered on any device
(``sharded_similarity``). Sparsity regularizers (FLOPS, L1) are also
vocab-sums and follow the same pattern.

All functions here are *shard_map bodies* plus factory wrappers binding
a mesh. The train step in ``launch/train.py`` composes them under
``jax.jit`` with explicit in/out shardings.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import axis_size, shard_map

Array = jax.Array


def sharded_sparton_head(
    mesh: Mesh,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
    vocab_tile: int = 4096,
    logit_softcap: Optional[float] = None,
    unroll: int = 1,
    bwd_batch_chunk: int = 8,
):
    """Returns head(H, E, b, mask) -> Y with E/b/Y vocab-sharded.

    Shardings (global view):
      H    (B, S, D)  — batch over ``batch_axes``, replicated over model
      E    (V, D)     — rows over ``axis_name``
      b    (V,)       — over ``axis_name``
      Y    (B, V)     — batch over ``batch_axes``, vocab over ``axis_name``

    Thin wrapper over the unified factory: equivalent to
    ``make_head(HeadSpec(impl="sparton", ...), mesh=mesh, ...)``. The
    shard_map body construction (and the kernel-capable variant) lives
    in ``core.head_api``; each device differentiates its local head,
    ∇E stays shard-local, and shard_map's transpose inserts the single
    ∇H psum over ``axis_name``.
    """
    from repro.core.head_api import HeadSpec, make_head

    spec = HeadSpec(impl="sparton", vocab_tile=vocab_tile,
                    logit_softcap=logit_softcap, unroll=unroll,
                    bwd_batch_chunk=bwd_batch_chunk)
    return make_head(spec, mesh=mesh, axis_name=axis_name,
                     batch_axes=batch_axes)


def sharded_similarity(
    mesh: Mesh,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
):
    """(Bq, V)·(Bd, V)ᵀ with V sharded: local matmul + psum over model.

    Queries/documents stay batch-sharded; the (Bq, Bd) score matrix is
    small (batch²) and comes out replicated over ``model``. The in-batch
    InfoNCE denominator needs *global* batch scores, so the batch axes
    are all-gathered for the document side only (Bd × V_local slab per
    device — still 1/n of the full sparse matrix).
    """

    def body(q, d):
        # q: (Bq_local, V_local); d: (Bd_local, V_local)
        d_full = d
        if batch_axes:
            d_full = jax.lax.all_gather(d_full, batch_axes, axis=0,
                                        tiled=True)
        scores = jnp.einsum("qv,dv->qd", q, d_full,
                            preferred_element_type=jnp.float32)
        return jax.lax.psum(scores, axis_name)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes, axis_name), P(batch_axes, axis_name)),
        out_specs=P(batch_axes, None),
    )


def sharded_infonce(
    mesh: Mesh,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
    temperature: float = 1.0,
):
    """In-batch InfoNCE over vocab-sharded sparse reps, fully fused.

    Each device scores its local query rows against the *globally
    gathered* documents on its vocab shard, psums the partial scores
    over ``model``, and picks the diagonal label at the query's global
    row offset. Only the (Bd_global, V_local) doc slab and the
    (Bq_local, Bd_global) score block ever exist per device.
    """

    def body(q, d):
        bq_local = q.shape[0]
        d_full = d
        if batch_axes:
            d_full = jax.lax.all_gather(d_full, batch_axes, axis=0,
                                        tiled=True)
        scores = jnp.einsum("qv,dv->qd", q, d_full,
                            preferred_element_type=jnp.float32)
        scores = jax.lax.psum(scores, axis_name) / temperature

        # global row offset of this shard's queries
        offset = jnp.zeros((), jnp.int32)
        for ax in batch_axes:  # row-major over batch_axes (gather order)
            offset = offset * axis_size(ax) + jax.lax.axis_index(ax)
        labels = offset * bq_local + jnp.arange(bq_local)

        logp = jax.nn.log_softmax(scores, axis=-1)
        local = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
        if batch_axes:
            local = jax.lax.pmean(local, batch_axes)
        return local

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes, axis_name), P(batch_axes, axis_name)),
        out_specs=P(),
    )


def sharded_flops_reg(
    mesh: Mesh,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
):
    """SPLADE FLOPS regularizer sum_v (mean_b Y[b,v])² over sharded V."""

    def body(y):
        mean_b = jnp.mean(jnp.abs(y), axis=0)     # local batch mean
        if batch_axes:
            mean_b = jax.lax.pmean(mean_b, batch_axes)
        local = jnp.sum(mean_b * mean_b)
        total = jax.lax.psum(local, axis_name)
        return total

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes, axis_name),),
        out_specs=P(),
    )


def sharded_l1_reg(
    mesh: Mesh,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
):
    """L1 regularizer mean_b sum_v |Y[b,v]| over sharded V — the row
    sum psums over ``model``, the batch mean pmeans over the batch
    axes, matching ``losses.l1_regularizer`` on the gathered array."""

    def body(y):
        local = jnp.mean(jnp.sum(jnp.abs(y.astype(jnp.float32)), axis=-1))
        total = jax.lax.psum(local, axis_name)
        if batch_axes:
            total = jax.lax.pmean(total, batch_axes)
        return total

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes, axis_name),),
        out_specs=P(),
    )


def sharded_row_dots(
    mesh: Mesh,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
):
    """Per-row dots ``s[b] = sum_v a[b,v]·c[b,v]`` over sharded V —
    the score primitive MarginMSE distillation needs (aligned q/doc
    pairs, no cross-batch matrix): shard-local einsum + one psum, the
    ``(B, V)`` reps never gather anywhere."""

    def body(a, c):
        local = jnp.einsum("bv,bv->b", a, c,
                           preferred_element_type=jnp.float32)
        return jax.lax.psum(local, axis_name)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes, axis_name), P(batch_axes, axis_name)),
        out_specs=P(batch_axes),
    )


def head_shardings(mesh: Mesh, *, axis_name: str = "model",
                   batch_axes: Tuple[str, ...] = ("pod", "data")):
    """NamedShardings for (H, E, b, mask, Y) used by jit'd callers."""
    return {
        "H": NamedSharding(mesh, P(batch_axes, None, None)),
        "E": NamedSharding(mesh, P(axis_name, None)),
        "b": NamedSharding(mesh, P(axis_name)),
        "mask": NamedSharding(mesh, P(batch_axes, None)),
        "Y": NamedSharding(mesh, P(batch_axes, axis_name)),
    }
