"""EmbeddingBag in JAX — gather + segment-reduce.

JAX has no ``nn.EmbeddingBag`` and no CSR sparse; multi-hot categorical
lookups are expressed as ``jnp.take`` over a dense table followed by
``jax.ops.segment_sum`` over bag ids — this IS the recsys hot path and
is built here as real system code (not a stub), per the assignment.

Two layouts:
* fixed single-hot: ``(batch, n_fields)`` index matrix, one id per
  field (DLRM Criteo layout) — a plain gather.
* ragged multi-hot: flat ``values`` + ``bag_ids`` (offsets-style),
  reduced per bag with sum/mean/max.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sparse.segment import segment_max, segment_mean, segment_sum

Array = jax.Array


def embedding_lookup(table: Array, idx: Array) -> Array:
    """Single-hot lookup: (..., ) int32 -> (..., dim)."""
    return jnp.take(table, idx, axis=0)


def embedding_bag(
    table: Array,          # (rows, dim)
    values: Array,         # (nnz,) int32 flat indices
    bag_ids: Array,        # (nnz,) int32 which bag each value belongs to
    n_bags: int,
    *,
    combiner: str = "sum",
    weights: Optional[Array] = None,  # (nnz,) per-sample weights
) -> Array:
    emb = jnp.take(table, values, axis=0)      # (nnz, dim)
    if weights is not None:
        emb = emb * weights[:, None]
    if combiner == "sum":
        return segment_sum(emb, bag_ids, n_bags)
    if combiner == "mean":
        return segment_mean(emb, bag_ids, n_bags)
    if combiner == "max":
        return segment_max(emb, bag_ids, n_bags)
    raise ValueError(f"unknown combiner {combiner!r}")


def multi_table_lookup(tables, idx: Array) -> Array:
    """DLRM-style: one id per field, one table per field.

    tables: list of (rows_f, dim); idx: (batch, n_fields).
    Returns (batch, n_fields, dim).
    """
    outs = [jnp.take(t, idx[:, f], axis=0) for f, t in enumerate(tables)]
    return jnp.stack(outs, axis=1)
