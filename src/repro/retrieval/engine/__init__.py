"""Index engine — pruned, quantized, device-sharded inverted retrieval
with an incremental builder (DESIGN.md §8).

Four pieces over the PR-3 ``InvertedIndex``:

* ``pruning``       — MaxScore/WAND-style two-tier scoring: a cheap
                      per-term-upper-bound pass selects candidate docs,
                      exact rescoring runs only on the survivors.
* ``quantize``      — posting-list compression: nibble-packed u4
                      impacts with per-term affine scales + u8
                      delta-encoded doc ids; the scorer dequantizes on
                      the fly.
* ``sharded_index`` — doc-sharded index over a mesh via ``shard_map``
                      (or a single-device vmap fallback), merged with
                      the same running top-k the kernels use.
* ``term_sharded``  — term-partitioned (vocab-sharded) index: each
                      device owns the full posting lists of a vocab
                      range; per-shard PARTIAL sums are all-reduced
                      (``psum``) before one global top-k — the merge
                      algebra for corpora whose posting arrays
                      outgrow one HBM (DESIGN.md §9).
* ``shard2d``       — the (doc × term) composition of both axes on a
                      2D mesh, plus the ``ShardPlan`` placement API:
                      ``plan_placement(stats, n_devices, hbm)`` picks
                      (doc_shards, term_shards, replicas) from posting
                      mass, the O(V) directory and forward-row
                      storage (DESIGN.md §14).
* ``builder``       — incremental ``IndexBuilder``: add/remove/flush
                      of document batches with tombstones, a base +
                      delta segment pair, and periodic compaction.

Everything threads through ``repro.retrieval.retrieve`` (methods
``pruned`` / ``quantized`` / ``fused`` / ``sharded`` /
``term_sharded`` / ``shard2d``; ``fused`` scores either index flavor
inside one Pallas kernel — ``kernels/impact_score.py``).
"""

from repro.retrieval.engine.builder import IndexBuilder
from repro.retrieval.engine.pruning import (default_candidates,
                                            pruned_retrieve,
                                            select_and_rescore,
                                            upper_bound_scores)
from repro.retrieval.engine.quantize import (QuantizedIndex,
                                             fused_quantized_retrieve,
                                             quantize_index,
                                             quantized_retrieve,
                                             quantized_scores)
from repro.retrieval.engine.shard2d import (CorpusStats, Shard2DIndex,
                                            ShardPlan,
                                            choose_shard_axis,
                                            mass_balanced_boundaries,
                                            plan_placement,
                                            shard2d_index,
                                            shard2d_retrieve)
from repro.retrieval.engine.sharded_index import (ShardedIndex,
                                                  resolve_mesh_axes,
                                                  resolve_shard_axis,
                                                  shard_index,
                                                  shard_mapped,
                                                  sharded_retrieve)
from repro.retrieval.engine.term_sharded import (TermShardedIndex,
                                                 term_shard_index,
                                                 term_sharded_retrieve)

__all__ = [
    "CorpusStats",
    "IndexBuilder",
    "QuantizedIndex",
    "Shard2DIndex",
    "ShardPlan",
    "ShardedIndex",
    "TermShardedIndex",
    "choose_shard_axis",
    "default_candidates",
    "fused_quantized_retrieve",
    "mass_balanced_boundaries",
    "plan_placement",
    "pruned_retrieve",
    "quantize_index",
    "quantized_retrieve",
    "quantized_scores",
    "resolve_mesh_axes",
    "resolve_shard_axis",
    "select_and_rescore",
    "shard2d_index",
    "shard2d_retrieve",
    "shard_index",
    "shard_mapped",
    "sharded_retrieve",
    "term_shard_index",
    "term_sharded_retrieve",
    "upper_bound_scores",
]
