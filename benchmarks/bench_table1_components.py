"""Paper Table 1: runtime + peak memory of backbone vs backbone+head,
for eager-equivalent (naive), tiled, and Sparton heads.

The paper measures SPLADE-V3 (bert-base, |V|=30522) at B=320, S=512 on
an H100. On this CPU container we keep the architecture shape faithful
but scale B/S down (CPU-feasible) — the *comparison structure*
(naive vs tiled vs sparton; fwd vs fwd+bwd; time and peak memory) is
the paper's; columns scale with the workload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import compiled_peak_bytes, csv_print, time_fn
from repro.configs import get_config
from repro.core.lm_head import (lm_head_naive, lm_head_sparton,
                                lm_head_tiled)
from repro.launch.steps import init_state
from repro.models import transformer as tfm

B, S = 16, 128  # CPU-scaled stand-ins for the paper's 320 x 512


def run(csv: bool = True):
    cfg = get_config("splade_bert").SMOKE
    # widen the smoke config toward bert-base proportions but CPU-sized
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, n_heads=8,
                              n_kv_heads=8, d_head=32, d_ff=1024,
                              vocab_size=30522)
    state, _ = init_state("splade_bert", jax.random.PRNGKey(0), smoke=True)
    # re-init at the widened config
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1,
                              cfg.vocab_size)
    mask = jnp.ones((B, S), jnp.int32)

    def backbone(params, toks, mask):
        H, _ = tfm.forward_hidden(params, cfg, toks, mask)
        return H

    def full(head_fn, head_kw):
        def f(params, toks, mask):
            H, _ = tfm.forward_hidden(params, cfg, toks, mask)
            E, b = tfm.head_weights(params, cfg)
            return head_fn(H, E.astype(H.dtype), b, mask, **head_kw)
        return f

    def train(head_fn, head_kw):
        def loss(params, toks, mask):
            H, _ = tfm.forward_hidden(params, cfg, toks, mask)
            E, b = tfm.head_weights(params, cfg)
            y = head_fn(H, E.astype(H.dtype), b, mask, **head_kw)
            return jnp.sum(y * y) * 1e-3
        return jax.grad(loss)

    heads = [
        ("naive", lm_head_naive, {}),
        ("tiled", lm_head_tiled, {"vocab_tile": 4096}),
        ("sparton", lm_head_sparton, {"vocab_tile": 4096}),
    ]

    abstract = (jax.eval_shape(lambda: params),
                jax.ShapeDtypeStruct(toks.shape, toks.dtype),
                jax.ShapeDtypeStruct(mask.shape, mask.dtype))

    rows = []
    bb_fwd = jax.jit(backbone)
    t = time_fn(bb_fwd, params, toks, mask)
    m = compiled_peak_bytes(backbone, *abstract)
    rows.append(("fwd", "backbone", round(t, 1), round(m / 2**20, 1)))
    bb_bwd = jax.jit(jax.grad(
        lambda p, t_, m_: jnp.sum(backbone(p, t_, m_) ** 2) * 1e-3))
    t = time_fn(bb_bwd, params, toks, mask)
    m = compiled_peak_bytes(
        jax.grad(lambda p, t_, m_: jnp.sum(backbone(p, t_, m_) ** 2) * 1e-3),
        *abstract)
    rows.append(("fwd+bwd", "backbone", round(t, 1), round(m / 2**20, 1)))

    for name, fn, kw in heads:
        f = full(fn, kw)
        t = time_fn(jax.jit(f), params, toks, mask)
        m = compiled_peak_bytes(f, *abstract)
        rows.append(("fwd", f"+{name}", round(t, 1), round(m / 2**20, 1)))
    for name, fn, kw in heads:
        g = train(fn, kw)
        t = time_fn(jax.jit(g), params, toks, mask)
        m = compiled_peak_bytes(g, *abstract)
        rows.append(("fwd+bwd", f"+{name}", round(t, 1),
                     round(m / 2**20, 1)))

    if csv:
        csv_print(("pass", "component", "time_ms", "peak_mib"), rows)
    return rows


if __name__ == "__main__":
    run()
