"""Sparse-native retrieval pipeline: SparseRep, inverted impact index,
and the unified retrieve() dispatcher (DESIGN.md §7).

The acceptance anchor is the three-way parity test: inverted-index
impact scoring, the streaming topk_score kernel, and the dense einsum
fallback must return identical top-k doc ids (scores within fp
tolerance) from the same SparseRep/dense inputs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.retrieval import (InvertedIndex, SparseRep,
                             build_inverted_index, impact_scores,
                             retrieve, sparsify_threshold, sparsify_topk,
                             split_rows, stack_rows)

V = 128


def _sparse_mat(rng, n, nnz, vocab=V):
    m = np.zeros((n, vocab), np.float32)
    for r in range(n):
        cols = rng.choice(vocab, size=nnz, replace=False)
        m[r, cols] = rng.uniform(0.1, 2.0, size=nnz)
    return m


@pytest.fixture
def corpus():
    rng = np.random.default_rng(0)
    Q = _sparse_mat(rng, 5, 8)
    D = _sparse_mat(rng, 40, 10)
    return Q, D


# ---------------------------------------------------------------------------
# SparseRep + sparsifiers
# ---------------------------------------------------------------------------

def test_sparsify_roundtrip_exact_when_under_budget(corpus):
    Q, _ = corpus
    rep = sparsify_threshold(jnp.asarray(Q), 0.0, max_nnz=16)
    assert np.all(np.asarray(rep.nnz) == 8)
    np.testing.assert_allclose(np.asarray(rep.to_dense(V)), Q, atol=1e-6)
    # active slots are a prefix, sorted by value descending
    vals = np.asarray(rep.values)
    assert (vals[:, :8] > 0).all() and (vals[:, 8:] == 0).all()
    assert (np.diff(vals[:, :8], axis=1) <= 1e-6).all()


def test_sparsify_topk_keeps_largest():
    x = jnp.asarray([[0.5, 0.0, 2.0, 1.0, 0.0, 3.0]])
    rep = sparsify_topk(x, 2, tile=2)   # multiple tiles
    assert int(rep.nnz[0]) == 2
    np.testing.assert_array_equal(np.asarray(rep.indices)[0, :2], [5, 2])
    np.testing.assert_allclose(np.asarray(rep.values)[0, :2], [3.0, 2.0])


def test_sparsify_threshold_drops_small_entries():
    x = jnp.asarray([[0.5, 0.05, 2.0, 0.0, -1.0]])
    rep = sparsify_threshold(x, 0.1, max_nnz=4)
    assert int(rep.nnz[0]) == 2          # 2.0 and 0.5; never negatives
    dense = np.asarray(rep.to_dense(5))
    np.testing.assert_allclose(dense, [[0.5, 0.0, 2.0, 0.0, 0.0]])


def test_sparsify_tie_break_to_lowest_vocab_id():
    """Equal values across tile boundaries: lowest id wins the budget
    (the merge-stability invariant from kernels/topk_score)."""
    x = np.zeros((1, 64), np.float32)
    x[0, [3, 40, 50]] = 1.0              # three equal entries
    rep = sparsify_topk(jnp.asarray(x), 2, tile=16)
    np.testing.assert_array_equal(np.asarray(rep.indices)[0, :2], [3, 40])


def test_sparse_rep_is_a_pytree(corpus):
    Q, _ = corpus
    rep = sparsify_topk(jnp.asarray(Q), 8)
    doubled = jax.jit(lambda r: SparseRep(r.values * 2, r.indices,
                                          r.nnz))(rep)
    np.testing.assert_allclose(np.asarray(doubled.to_dense(V)), 2 * Q,
                               atol=1e-5)


def test_split_and_stack_rows_roundtrip(corpus):
    _, D = corpus
    rep = sparsify_topk(jnp.asarray(D), 12)
    back = stack_rows(split_rows(rep))
    np.testing.assert_allclose(np.asarray(back.to_dense(V)), D,
                               atol=1e-6)


def test_stack_rows_pads_mixed_widths():
    a = sparsify_topk(jnp.asarray([[1.0, 0.0, 2.0, 0.0]]), 2)
    b = sparsify_topk(jnp.asarray([[0.0, 3.0, 0.0, 0.0]]), 1)
    stacked = stack_rows([a, b])
    assert stacked.width == 2
    np.testing.assert_allclose(
        np.asarray(stacked.to_dense(4)),
        [[1.0, 0.0, 2.0, 0.0], [0.0, 3.0, 0.0, 0.0]], atol=1e-6)


# ---------------------------------------------------------------------------
# inverted index
# ---------------------------------------------------------------------------

def test_index_layout_and_stats(corpus):
    _, D = corpus
    rep = sparsify_topk(jnp.asarray(D), 16)
    idx = build_inverted_index(rep, V)
    st = idx.stats()
    assert st["n_docs"] == 40 and st["n_postings"] == 40 * 10
    lens = np.asarray(idx.term_lens)
    starts = np.asarray(idx.term_starts)
    assert lens.sum() == idx.n_postings
    np.testing.assert_array_equal(starts[1:],
                                  np.cumsum(lens)[:-1])
    assert idx.max_postings == lens.max()
    # postings within a term are ordered by doc id (stable build)
    for t in np.flatnonzero(lens > 1)[:10]:
        docs = np.asarray(idx.postings_doc)[starts[t]:starts[t] + lens[t]]
        assert (np.diff(docs) > 0).all()
    # the memory story: postings beat the dense (N, V) matrix
    assert idx.memory_bytes() < 40 * V * 4


def test_index_empty_corpus_is_valid():
    rep = sparsify_topk(jnp.zeros((3, V)), 8)
    idx = build_inverted_index(rep, V)
    assert idx.n_docs == 3 and idx.max_postings == 1
    q = sparsify_topk(jnp.asarray(_sparse_mat(
        np.random.default_rng(1), 2, 4)), 4)
    scores = np.asarray(impact_scores(q, idx))
    assert scores.shape == (2, 3) and (scores == 0).all()


def test_index_rejects_out_of_range_terms():
    rep = SparseRep(values=np.ones((1, 2), np.float32),
                    indices=np.array([[0, V + 5]], np.int32),
                    nnz=np.array([2], np.int32))
    with pytest.raises(ValueError, match="term ids"):
        build_inverted_index(rep, V)


def test_impact_scores_match_dense_einsum(corpus):
    Q, D = corpus
    q_rep = sparsify_threshold(jnp.asarray(Q), 0.0, max_nnz=16)
    d_rep = sparsify_threshold(jnp.asarray(D), 0.0, max_nnz=16)
    idx = build_inverted_index(d_rep, V)
    np.testing.assert_allclose(np.asarray(impact_scores(q_rep, idx)),
                               Q @ D.T, atol=1e-5)


# ---------------------------------------------------------------------------
# retrieve() dispatcher — the acceptance parity test
# ---------------------------------------------------------------------------

def test_parity_impact_streaming_dense(corpus):
    """Acceptance: the three scoring paths return identical top-k doc
    ids (and scores within fp tolerance) from the same SparseRep/dense
    inputs."""
    Q, D = corpus
    k = 7
    q_rep = sparsify_threshold(jnp.asarray(Q), 0.0, max_nnz=16)
    d_rep = sparsify_threshold(jnp.asarray(D), 0.0, max_nnz=16)
    index = build_inverted_index(d_rep, V)

    v_dense, i_dense = retrieve(jnp.asarray(Q), jnp.asarray(D), k,
                                method="dense")
    v_stream, i_stream = retrieve(q_rep, jnp.asarray(D), k,
                                  method="streaming", block_b=2,
                                  block_n=16, interpret=True)
    v_imp, i_imp = retrieve(q_rep, index, k, method="impact")
    v_fused, i_fused = retrieve(q_rep, index, k, method="fused",
                                block_n=16, block_w=128,
                                interpret=True)

    np.testing.assert_array_equal(np.asarray(i_dense),
                                  np.asarray(i_stream))
    np.testing.assert_array_equal(np.asarray(i_dense), np.asarray(i_imp))
    np.testing.assert_array_equal(np.asarray(i_dense),
                                  np.asarray(i_fused))
    np.testing.assert_allclose(np.asarray(v_dense), np.asarray(v_stream),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_dense), np.asarray(v_imp),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_dense), np.asarray(v_fused),
                               atol=1e-5)


def test_auto_routes_by_corpus_type(corpus):
    Q, D = corpus
    q_rep = sparsify_threshold(jnp.asarray(Q), 0.0, max_nnz=16)
    d_rep = sparsify_threshold(jnp.asarray(D), 0.0, max_nnz=16)
    index = build_inverted_index(d_rep, V)
    v_auto, i_auto = retrieve(q_rep, index, 5)           # -> impact
    v_imp, i_imp = retrieve(q_rep, index, 5, method="impact")
    np.testing.assert_array_equal(np.asarray(i_auto), np.asarray(i_imp))
    # dense corpus below the streaming cutoff -> dense
    v_d, i_d = retrieve(jnp.asarray(Q), jnp.asarray(D), 5)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_imp))


def test_k_clamped_to_corpus_size(corpus):
    Q, D = corpus
    vals, idx = retrieve(jnp.asarray(Q), jnp.asarray(D), 100,
                         method="dense")
    assert vals.shape == (5, 40) and idx.shape == (5, 40)


def test_dispatcher_input_errors(corpus):
    Q, D = corpus
    d_rep = sparsify_threshold(jnp.asarray(D), 0.0, max_nnz=16)
    index = build_inverted_index(d_rep, V)
    with pytest.raises(ValueError, match="unknown retrieval method"):
        retrieve(jnp.asarray(Q), jnp.asarray(D), 5, method="bm25")
    with pytest.raises(ValueError, match="SparseRep queries"):
        retrieve(jnp.asarray(Q), index, 5, method="impact")
    with pytest.raises(ValueError, match="InvertedIndex corpus"):
        retrieve(sparsify_topk(jnp.asarray(Q), 8), jnp.asarray(D), 5,
                 method="impact")
    with pytest.raises(ValueError, match="dense .* corpus matrix"):
        retrieve(jnp.asarray(Q), index, 5, method="dense")


def test_dispatcher_rejects_stray_kwargs(corpus):
    """Kwargs the *resolved* method cannot honor raise instead of being
    silently ignored — a typo'd tuning knob must not become a no-op."""
    Q, D = corpus
    q_rep = sparsify_threshold(jnp.asarray(Q), 0.0, max_nnz=16)
    d_rep = sparsify_threshold(jnp.asarray(D), 0.0, max_nnz=16)
    index = build_inverted_index(d_rep, V)
    with pytest.raises(ValueError, match="does not accept mesh"):
        retrieve(q_rep, index, 5, method="impact", mesh=object())
    with pytest.raises(ValueError, match="does not accept prune_margin"):
        retrieve(q_rep, jnp.asarray(D), 5, method="streaming",
                 prune_margin=0.5, interpret=True)
    with pytest.raises(ValueError, match="does not accept block_w"):
        retrieve(q_rep, index, 5, method="impact", block_w=128)
    with pytest.raises(ValueError, match="does not accept candidates"):
        retrieve(q_rep, index, 5, method="fused", candidates=32,
                 interpret=True)
    # the check runs against the *resolved* method, so 'auto' on a
    # small bare index (-> impact) rejects fused-kernel knobs too
    with pytest.raises(ValueError, match="method='impact'"):
        retrieve(q_rep, index, 5, block_n=64)
    # None sentinels are "not passed", never an error
    vals, idx = retrieve(q_rep, index, 5, method="impact", mesh=None,
                         block_w=None)
    assert idx.shape == (5, 5)


# ---------------------------------------------------------------------------
# serving integration: SparseRep as the post-head currency
# ---------------------------------------------------------------------------

def _fake_sparse_encoder(k=4):
    """Token-count encoder emitting SparseReps over a 32-dim vocab."""
    def encode(tokens, mask):
        B, S = tokens.shape
        out = np.zeros((B, 32), np.float32)
        for i in range(B):
            for t, m in zip(np.asarray(tokens[i]), np.asarray(mask[i])):
                if m:
                    out[i, int(t) % 32] += 1
        return sparsify_topk(jnp.asarray(out), k)
    return encode


def test_serving_loop_round_trips_sparse_reps():
    from repro.runtime.serving import (BatchedEncoder, BatchPolicy,
                                       Request, ServingLoop)

    enc = BatchedEncoder(_fake_sparse_encoder(),
                         policy=BatchPolicy(max_batch=4, max_wait_s=0.0))
    loop = ServingLoop(enc, clock=lambda: 0.0)
    for uid in range(6):
        loop.submit(Request(
            uid=uid, tokens=np.array([uid, uid, 5], np.int32)))
        loop.tick(force=True)
    loop.drain()
    reps = [loop.take(u) for u in range(6)]
    assert not loop.completed
    assert all(isinstance(r, SparseRep) for r in reps)
    q = stack_rows(reps)
    dense = np.asarray(q.to_dense(32))
    for uid in range(6):
        expected = 3.0 if uid == 5 else 2.0
        assert dense[uid, uid % 32] == expected


def test_make_config_encoder_emits_sparse_reps():
    """The config's rep knobs flow through head_spec -> make_encoder ->
    serving: the encode fn returns SparseReps, and their densification
    matches the dense encoder's output top-k."""
    from repro.configs import get_config
    from repro.models import transformer as tfm
    from repro.runtime.serving import make_config_encoder

    cfg = get_config("splade_bert").SMOKE
    cfg = dataclasses.replace(cfg, n_layers=1, rep_topk=8)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    enc_sparse = make_config_encoder(params, cfg)
    enc_dense = make_config_encoder(
        params, dataclasses.replace(cfg, rep_topk=None))

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1,
                              cfg.vocab_size)
    mask = jnp.ones((2, 12), jnp.int32)
    rep = enc_sparse(toks, mask)
    assert isinstance(rep, SparseRep) and rep.width == 8
    dense = np.asarray(enc_dense(toks, mask))
    top8 = np.sort(np.argsort(dense, axis=1)[:, -8:], axis=1)
    got = np.sort(np.asarray(rep.indices), axis=1)
    np.testing.assert_array_equal(got, top8)
