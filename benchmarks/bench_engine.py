"""Index-engine benchmark: pruned vs impact vs streaming latency,
quantized vs raw index bytes, and sharded scaling, on one graded LSR
corpus (``repro.data.synthetic.lsr_impact_corpus``).

Four comparisons behind ``BENCH_engine.json``:

* ``methods`` — median ms for ``impact`` (exact segment-sums),
  ``pruned`` (two-tier MaxScore), ``quantized`` (on-the-fly dequant),
  ``fused`` / ``fused_quantized`` (the kernels/impact_score fused
  Pallas paths — no (B, N) matrix, in-kernel u4 dequant for the
  latter) and ``streaming`` (the dense Pallas kernel over the
  densified corpus, the PR-3 reference point), each with its analytic
  peak scoring bytes;
* ``quantization`` — raw vs compressed index bytes; the acceptance
  bar is ratio >= 4x at identical top-k ids;
* ``pruned`` — id parity vs impact at the safe margin plus the
  fraction of queries whose pruning was provably exact, and the same
  at an aggressive ``prune_margin`` for the recall/speed trade;
* ``sharded`` / ``term_sharded`` — median ms at 1/2/4 shards for BOTH
  sharding axes (doc ranges + top-k merge vs vocab ranges +
  partial-sum merge; single-device vmap paths on CI — a work
  partition, not a memory win; the shard_map paths need a real mesh)
  with id parity vs the unsharded scorer;
* ``shard2d`` — the 2D (doc × term) grid at 1x1/2x2/1x4/4x1 with the
  same id-parity bar (DESIGN.md §14), plus ``planner`` — the
  ``plan_placement`` decision record on two synthetic corpora: a
  250k-vocab one (the directory dominates — the plan must carry term
  shards) and a 30k-vocab one (doc-only); ``check.py`` gates both.

``--smoke`` (or ``BENCH_SMOKE=1``) shrinks the workload for CI; the
interpret-mode/CPU caveat from DESIGN.md §5 applies to all timings.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._common import scoring_peak_bytes, time_fn
from repro.data.synthetic import lsr_impact_corpus
from repro.retrieval import (CorpusStats, build_inverted_index,
                             plan_placement, pruned_retrieve,
                             quantize_index, retrieve, shard2d_index,
                             shard_index, sparsify_topk,
                             term_shard_index)

FULL = dict(n_docs=8192, vocab=4096, doc_nnz=64, n_queries=16,
            q_nnz=32, k=10, block_n=2048)
SMOKE = dict(n_docs=2048, vocab=2048, doc_nnz=48, n_queries=8,
             q_nnz=28, k=10, block_n=512)
PRUNE_MARGIN_AGGR = 0.5


def run(smoke: bool = False, json_path: str = None):
    smoke = smoke or os.environ.get("BENCH_SMOKE") == "1"
    p = SMOKE if smoke else FULL
    iters = 3 if smoke else 10
    k = p["k"]

    data = lsr_impact_corpus(
        n_docs=p["n_docs"], vocab=p["vocab"], doc_nnz=p["doc_nnz"],
        n_queries=p["n_queries"], q_nnz=p["q_nnz"])
    q_rep = sparsify_topk(jnp.asarray(data["queries"]),
                          p["q_nnz"]).block_until_ready()
    d_rep = sparsify_topk(jnp.asarray(data["docs"]),
                          p["doc_nnz"]).block_until_ready()
    d_dense = jnp.asarray(data["docs"])

    raw = build_inverted_index(d_rep, p["vocab"])          # impact path
    engine = build_inverted_index(d_rep, p["vocab"],
                                  keep_forward=True)       # pruned path
    quant = quantize_index(raw)
    interpret = jax.default_backend() != "tpu"

    record = {
        "shape": {"N": p["n_docs"], "V": p["vocab"], "B": p["n_queries"],
                  "k": k, "doc_nnz": p["doc_nnz"], "q_nnz": p["q_nnz"]},
        "backend": jax.default_backend(),
        "interpret": interpret,
        "methods": {},
    }

    mem = dict(B=p["n_queries"], N=p["n_docs"], k=k, Q=p["q_nnz"])
    methods = {
        "impact": (lambda: retrieve(q_rep, raw, k, method="impact"),
                   raw.memory_bytes(),
                   scoring_peak_bytes("impact", L=raw.max_postings,
                                      **mem)),
        "fused": (lambda: retrieve(q_rep, raw, k, method="fused",
                                   interpret=interpret),
                  raw.memory_bytes(),
                  scoring_peak_bytes("fused", L=raw.max_postings,
                                     **mem)),
        "pruned": (lambda: retrieve(q_rep, engine, k, method="pruned"),
                   engine.memory_bytes(),
                   scoring_peak_bytes("pruned", L=engine.max_postings,
                                      **mem)),
        "quantized": (lambda: retrieve(q_rep, quant, k,
                                       method="quantized"),
                      quant.memory_bytes(),
                      scoring_peak_bytes("quantized",
                                         L=quant.max_postings, **mem)),
        "fused_quantized": (lambda: retrieve(
            q_rep, quant, k, method="fused", interpret=interpret),
            quant.memory_bytes(),
            scoring_peak_bytes("fused_quantized",
                               L=quant.max_postings, **mem)),
        "streaming": (lambda: retrieve(
            q_rep, d_dense, k, method="streaming",
            block_b=min(8, p["n_queries"]), block_n=p["block_n"],
            interpret=interpret), int(d_dense.nbytes),
            scoring_peak_bytes("streaming", L=0, **mem)),
    }
    ids = {}
    for name, (fn, corpus_bytes, peak_bytes) in methods.items():
        t = time_fn(fn, iters=iters)
        _, idx = fn()
        ids[name] = np.asarray(idx)
        record["methods"][name] = {"median_ms": round(t, 3),
                                   "corpus_bytes": int(corpus_bytes),
                                   "peak_scoring_bytes": int(peak_bytes)}

    # quantization: the >= 4x acceptance bar at identical top-k ids
    ratio = raw.memory_bytes() / quant.memory_bytes()
    record["quantization"] = {
        "raw_bytes": raw.memory_bytes(),
        "quantized_bytes": quant.memory_bytes(),
        "ratio": round(ratio, 3),
        "phantom_frac": round(quant.stats()["phantom_frac"], 4),
        "topk_ids_equal": bool(np.array_equal(ids["impact"],
                                              ids["quantized"])),
    }

    # pruned: safe-margin parity + exactness frontier, then the
    # aggressive-margin operating point
    _, _, frontier = pruned_retrieve(q_rep, engine, k,
                                     with_diagnostics=True)
    _, idx_aggr = pruned_retrieve(q_rep, engine, k,
                                  prune_margin=PRUNE_MARGIN_AGGR)
    overlap = np.mean([
        np.intersect1d(a, b).size / k
        for a, b in zip(ids["impact"], np.asarray(idx_aggr))])
    record["pruned"] = {
        "topk_ids_equal": bool(np.array_equal(ids["impact"],
                                              ids["pruned"])),
        "exact_frontier_frac": float(np.asarray(frontier).mean()),
        "aggr_margin": PRUNE_MARGIN_AGGR,
        "aggr_topk_overlap": round(float(overlap), 4),
    }

    # sharded scaling, both axes (vmap fallback — shard counts
    # partition the work; real scaling needs a device mesh, DESIGN.md
    # §8.3/§9): doc ranges with the top-k merge vs vocab ranges with
    # the partial-sum merge, at identical ids either way
    record["sharded"] = {}
    record["term_sharded"] = {}
    for s in (1, 2, 4):
        sidx = shard_index(d_rep, p["vocab"], s)
        fn = lambda: retrieve(q_rep, sidx, k, method="sharded")
        t = time_fn(fn, iters=iters)
        _, sid = fn()
        record["sharded"][str(s)] = {
            "median_ms": round(t, 3),
            "topk_ids_equal": bool(np.array_equal(ids["impact"],
                                                  np.asarray(sid))),
        }
        tidx = term_shard_index(d_rep, p["vocab"], s)
        fn = lambda: retrieve(q_rep, tidx, k, method="term_sharded")
        t = time_fn(fn, iters=iters)
        _, tid = fn()
        record["term_sharded"][str(s)] = {
            "median_ms": round(t, 3),
            "topk_ids_equal": bool(np.array_equal(ids["impact"],
                                                  np.asarray(tid))),
        }

    # the 2D (doc x term) grid: both degenerate orientations plus the
    # square composition, id-identical at every shape (DESIGN.md §14)
    record["shard2d"] = {}
    for dd, tt in ((1, 1), (2, 2), (1, 4), (4, 1)):
        gidx = shard2d_index(d_rep, p["vocab"], dd, tt)
        fn = lambda: retrieve(q_rep, gidx, k, method="shard2d")
        t = time_fn(fn, iters=iters)
        _, gid = fn()
        record["shard2d"][f"{dd}x{tt}"] = {
            "median_ms": round(t, 3),
            "topk_ids_equal": bool(np.array_equal(ids["impact"],
                                                  np.asarray(gid))),
        }

    # planner decision record: the placement the ShardPlan API picks
    # for a huge-vocab corpus (the O(V) directory dominates any
    # per-device posting slice — must carry term shards) vs a
    # small-vocab one (directory is a rounding error — doc-only)
    planner_stats = {
        "huge_vocab": CorpusStats(posting_bytes=8 * 50_000 * 16,
                                  vocab_size=250_000, n_docs=50_000),
        "small_vocab": CorpusStats(posting_bytes=8 * 50_000 * 16,
                                   vocab_size=30_000, n_docs=50_000),
    }
    record["planner"] = {"n_devices": 4}
    for name, stats in planner_stats.items():
        plan = plan_placement(stats, 4)
        record["planner"][name] = {
            "vocab_size": stats.vocab_size,
            "grid": f"{plan.doc_shards}x{plan.term_shards}",
            "axis": plan.axis,
            "doc_shards": plan.doc_shards,
            "term_shards": plan.term_shards,
            "reason": plan.reason,
        }

    # fused parity: raw-index fused vs exact impact, and the in-kernel
    # dequant vs the unfused dequantizing scorer (same compressed
    # index, so the ids must match bit-exactly, not just within
    # quantization tolerance)
    fused_agree = bool(
        np.array_equal(ids["impact"], ids["fused"])
        and np.array_equal(ids["quantized"], ids["fused_quantized"]))
    record["parity"] = {
        "topk_ids_equal": bool(
            record["quantization"]["topk_ids_equal"]
            and record["pruned"]["topk_ids_equal"]
            and all(v["topk_ids_equal"]
                    for v in record["sharded"].values())
            and all(v["topk_ids_equal"]
                    for v in record["term_sharded"].values())
            and all(v["topk_ids_equal"]
                    for v in record["shard2d"].values())),
        "fused_ids_equal": fused_agree,
    }

    print("method,median_ms,corpus_bytes,peak_scoring_bytes")
    for name, rec in record["methods"].items():
        print(f"{name},{rec['median_ms']},{rec['corpus_bytes']},"
              f"{rec['peak_scoring_bytes']}")
    print(f"quantized/raw bytes: 1/{ratio:.2f} "
          f"(ids equal: {record['quantization']['topk_ids_equal']})")
    print(f"pruned ids equal: {record['pruned']['topk_ids_equal']} "
          f"(exact frontier: "
          f"{record['pruned']['exact_frontier_frac']:.2f}, "
          f"margin={PRUNE_MARGIN_AGGR} overlap: "
          f"{record['pruned']['aggr_topk_overlap']:.2f})")
    for s, rec in record["sharded"].items():
        trec = record["term_sharded"][s]
        print(f"sharded x{s}: doc {rec['median_ms']} ms / "
              f"term {trec['median_ms']} ms (ids equal: "
              f"{rec['topk_ids_equal']}/{trec['topk_ids_equal']})")
    for g, rec in record["shard2d"].items():
        print(f"shard2d {g}: {rec['median_ms']} ms (ids equal: "
              f"{rec['topk_ids_equal']})")
    for name in ("huge_vocab", "small_vocab"):
        prec = record["planner"][name]
        print(f"planner {name} (V={prec['vocab_size']}): "
              f"{prec['grid']} -> {prec['axis']}")
    print(f"top-k ids identical across engine paths: "
          f"{record['parity']['topk_ids_equal']}")
    print(f"fused ids identical (raw vs impact, u4 vs quantized): "
          f"{fused_agree}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="emit BENCH_engine.json-style record here")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)
