"""Row-sharded embedding tables (DLRM model parallelism) via shard_map.

A 40M-row x 128-dim table cannot be replicated per chip; the classic
DLRM answer is to shard table *rows* across devices and resolve
lookups with a mask-and-reduce: every device gathers the indices that
fall inside its row range (clipped gather on its local shard) and the
partial results are ``psum``-combined. No table is ever all-gathered,
and the collective payload is only ``(batch, dim)`` per table.

This mirrors the Sparton head's vocabulary sharding (DESIGN.md §3):
the heavy dimension lives sharded, and only the reduced output crosses
the interconnect.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import shard_map

Array = jax.Array


def sharded_lookup_local(
    local_table: Array,    # (rows_local, dim) — this device's row shard
    idx: Array,            # (batch,) global row ids (replicated)
    *,
    axis_name: str,
) -> Array:
    """Inside-shard_map body: masked local gather + psum."""
    rows_local = local_table.shape[0]
    shard = jax.lax.axis_index(axis_name)
    lo = shard * rows_local
    local_idx = idx - lo
    in_range = (local_idx >= 0) & (local_idx < rows_local)
    safe = jnp.clip(local_idx, 0, rows_local - 1)
    out = jnp.take(local_table, safe, axis=0)
    out = jnp.where(in_range[:, None], out, 0.0)
    return jax.lax.psum(out, axis_name)


def make_sharded_lookup(mesh: Mesh, axis_name: str = "model"):
    """Returns lookup(table, idx) with the table row-sharded on `axis_name`.

    The table must be padded so rows % axis_size == 0 (see
    ``pad_table_rows``).
    """

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis_name, None), P()),
        out_specs=P(),
    )
    def lookup(table: Array, idx: Array) -> Array:
        return sharded_lookup_local(table, idx, axis_name=axis_name)

    return lookup


def pad_table_rows(rows: int, n_shards: int) -> int:
    return rows + ((-rows) % n_shards)


def table_sharding(mesh: Mesh, axis_name: str = "model") -> NamedSharding:
    return NamedSharding(mesh, P(axis_name, None))


def init_tables(
    key: jax.Array, table_sizes: Sequence[int], dim: int,
    n_shards: int = 1, dtype=jnp.float32,
):
    """One (padded_rows, dim) array per table; rows padded for sharding."""
    keys = jax.random.split(key, len(table_sizes))
    return [
        jax.random.normal(k, (pad_table_rows(r, n_shards), dim), dtype)
        * (dim ** -0.5)
        for k, r in zip(keys, table_sizes)
    ]
