"""Pytree checkpointing: atomic, async, auto-resume, multi-host aware.

Format: one ``.npz`` per step directory holding flattened leaves +
a tiny JSON manifest with the treedef and metadata. Writes go to a
temp dir then ``os.replace`` (atomic on POSIX) so a killed writer can
never leave a half checkpoint that resume would trust — the invariant
fault tolerance rests on.

Multi-host discipline: only process 0 writes (single-writer); all
processes read. Leaves are fetched with ``jax.device_get`` which
gathers addressable shards — on a real multi-host pod you would use
distributed array serialization (tensorstore); the API boundary here
is identical, so swapping the backend is a leaf change.

``AsyncCheckpointer`` runs saves on a worker thread: training never
blocks on disk (the device->host copy is the only sync part), and a
bounded queue applies back-pressure instead of unbounded memory growth.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _leaf_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_checkpoint(
    ckpt_dir: str,
    step: int,
    state: PyTree,
    *,
    process_index: Optional[int] = None,
    keep: int = 3,
    extra_meta: Optional[Dict[str, Any]] = None,
) -> Optional[str]:
    """Atomic checkpoint write. Returns the final path (or None if this
    process is not the writer)."""
    pi = jax.process_index() if process_index is None else process_index
    if pi != 0:
        return None

    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays = _leaf_paths(state)
    np.savez(os.path.join(tmp, _ARRAYS), **arrays)
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(arrays.keys()),
        "meta": extra_meta or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _gc_old(ckpt_dir, keep)
    return final


def _gc_old(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            path = os.path.join(ckpt_dir, name)
            if os.path.exists(os.path.join(path, _MANIFEST)):
                try:
                    out.append(int(name[len("step_"):]))
                except ValueError:
                    pass
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(
    ckpt_dir: str,
    template: PyTree,
    *,
    step: Optional[int] = None,
) -> Tuple[PyTree, int]:
    """Restore into the shape of ``template`` (validates leaf shapes —
    the elastic re-mesh path reshards by placing these host arrays with
    the *new* sharding). Returns (state, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, _ARRAYS))

    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves, treedef = flat
    restored = []
    for p, leaf in leaves:
        key = "/".join(str(x) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        restored.append(arr)
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), restored)
    return state, manifest["step"]


class AsyncCheckpointer:
    """Non-blocking checkpoint writer with a bounded queue."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, max_pending: int = 1):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_state, meta = item
            try:
                save_checkpoint(self.ckpt_dir, step, host_state,
                                keep=self.keep, extra_meta=meta)
            except BaseException as e:  # surfaced on next save()/close()
                self._err = e

    def save(self, step: int, state: PyTree,
             meta: Optional[Dict[str, Any]] = None) -> None:
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err
        # device->host copy happens here (sync); disk write is async
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self._q.put((step, host_state, meta))

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err
