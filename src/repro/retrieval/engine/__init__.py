"""Index engine — pruned, quantized, device-sharded inverted retrieval
with an incremental builder (DESIGN.md §8).

Four pieces over the PR-3 ``InvertedIndex``:

* ``pruning``       — MaxScore/WAND-style two-tier scoring: a cheap
                      per-term-upper-bound pass selects candidate docs,
                      exact rescoring runs only on the survivors.
* ``quantize``      — posting-list compression: nibble-packed u4
                      impacts with per-term affine scales + u8
                      delta-encoded doc ids; the scorer dequantizes on
                      the fly.
* ``sharded_index`` — doc-sharded index over a mesh via ``shard_map``
                      (or a single-device vmap fallback), merged with
                      the same running top-k the kernels use.
* ``builder``       — incremental ``IndexBuilder``: add/remove/flush
                      of document batches with tombstones, a base +
                      delta segment pair, and periodic compaction.

Everything threads through ``repro.retrieval.retrieve`` (methods
``pruned`` / ``quantized`` / ``sharded``).
"""

from repro.retrieval.engine.builder import IndexBuilder
from repro.retrieval.engine.pruning import (default_candidates,
                                            pruned_retrieve,
                                            upper_bound_scores)
from repro.retrieval.engine.quantize import (QuantizedIndex,
                                             quantize_index,
                                             quantized_retrieve,
                                             quantized_scores)
from repro.retrieval.engine.sharded_index import (ShardedIndex,
                                                  shard_index,
                                                  sharded_retrieve)

__all__ = [
    "IndexBuilder",
    "QuantizedIndex",
    "ShardedIndex",
    "default_candidates",
    "pruned_retrieve",
    "quantize_index",
    "quantized_retrieve",
    "quantized_scores",
    "shard_index",
    "sharded_retrieve",
    "upper_bound_scores",
]
