from repro.data.synthetic import (
    lsr_pair_batches,
    lm_token_batches,
    recsys_batches,
    molecule_batches,
    make_synthetic_graph,
)
from repro.data.loader import HostShardedLoader, length_bucket
