"""Deterministic synthetic data shards for every architecture family.

No datasets ship with the container, so the data pipeline generates
deterministic, seeded, *statistically plausible* batches:

* LSR pairs — (query tokens, positive doc tokens) with Zipfian token
  ids and variable lengths (padding + mask), mimicking MS-MARCO-style
  passages.
* LM tokens — Zipfian next-token streams for causal-LM training.
* RecSys clicks — power-law categorical ids per field (the hard case
  for embedding sharding), Gaussian dense features, Bernoulli labels.
* Molecules — random 3-D point clouds with distance-cutoff edges for
  DimeNet.
* Citation-style graphs — configurable power-law degree graphs for the
  full-graph / sampled GNN shapes.

Everything is host-side numpy (like a real input pipeline: CPU workers
feed the accelerator), seeded per (shard, step) so multi-host loaders
produce disjoint, reproducible streams.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np


def _rng(seed: int, shard: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, shard, step]))


def _zipf_ids(rng, size, vocab: int, a: float = 1.3) -> np.ndarray:
    """Zipf-distributed ids in [0, vocab) — heavy head like real text."""
    raw = rng.zipf(a, size=size)
    return np.clip(raw - 1, 0, vocab - 1).astype(np.int32)


def lsr_pair_batches(
    *,
    batch: int,
    q_len: int,
    d_len: int,
    vocab: int,
    seed: int = 0,
    shard: int = 0,
    min_frac: float = 0.3,
) -> Iterator[Dict[str, np.ndarray]]:
    """(query, positive-doc) token batches with masks, SPLADE-style."""
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        q_tok = _zipf_ids(rng, (batch, q_len), vocab)
        d_tok = _zipf_ids(rng, (batch, d_len), vocab)
        q_n = rng.integers(int(q_len * min_frac), q_len + 1, size=batch)
        d_n = rng.integers(int(d_len * min_frac), d_len + 1, size=batch)
        q_mask = (np.arange(q_len)[None] < q_n[:, None]).astype(np.int32)
        d_mask = (np.arange(d_len)[None] < d_n[:, None]).astype(np.int32)
        # overlap positives: splice some query tokens into the doc so
        # the contrastive task is learnable
        n_copy = max(1, q_len // 2)
        d_tok[:, :n_copy] = q_tok[:, :n_copy]
        yield {
            "q_tokens": q_tok, "q_mask": q_mask,
            "d_tokens": d_tok * d_mask, "d_mask": d_mask,
        }
        step += 1


def lm_token_batches(
    *, batch: int, seq_len: int, vocab: int, seed: int = 0, shard: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        tok = _zipf_ids(rng, (batch, seq_len + 1), vocab)
        yield {
            "tokens": tok[:, :-1],
            "labels": tok[:, 1:],
            "mask": np.ones((batch, seq_len), np.int32),
        }
        step += 1


def recsys_batches(
    *,
    batch: int,
    n_dense: int,
    n_sparse: int,
    table_sizes: Sequence[int],
    seq_len: int = 0,
    seed: int = 0,
    shard: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        out: Dict[str, np.ndarray] = {
            "label": rng.binomial(1, 0.25, size=batch).astype(np.float32),
        }
        if n_dense:
            out["dense"] = rng.normal(size=(batch, n_dense)).astype(
                np.float32)
        if seq_len:  # DIEN
            rows = table_sizes[0]
            out["hist_idx"] = _zipf_ids(rng, (batch, seq_len), rows)
            out["target_idx"] = _zipf_ids(rng, (batch,), rows)
        else:
            cols = [
                _zipf_ids(rng, (batch,), rows) for rows in table_sizes
            ]
            out["sparse_idx"] = np.stack(cols, axis=1)
        yield out
        step += 1


def make_synthetic_graph(
    n_nodes: int, n_edges: int, *, seed: int = 0,
    power_law: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Random (src, dst) edge lists; power-law dst to mimic citation
    hubs (the regime that makes triplet counting explode)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int64)
    if power_law:
        ranks = rng.zipf(1.5, size=n_edges)
        dst = np.clip(ranks - 1, 0, n_nodes - 1).astype(np.int64)
        dst = (dst * 2654435761 % n_nodes).astype(np.int64)  # de-cluster
    else:
        dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int64)
    keep = src != dst
    return src[keep], dst[keep]


def molecule_batches(
    *,
    n_graphs: int,
    nodes_per_graph: int,
    edges_per_graph: int,
    n_atom_types: int = 95,
    cutoff: float = 5.0,
    seed: int = 0,
    shard: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Batched random molecules: 3-D positions, cutoff-radius edges
    (capped at edges_per_graph), graph-level scalar targets."""
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        N = n_graphs * nodes_per_graph
        pos = rng.uniform(0, cutoff * 1.2,
                          size=(n_graphs, nodes_per_graph, 3))
        feats = rng.integers(0, n_atom_types, size=N).astype(np.int32)

        srcs, dsts = [], []
        for g in range(n_graphs):
            d = np.linalg.norm(
                pos[g][:, None] - pos[g][None], axis=-1)
            np.fill_diagonal(d, np.inf)
            cand = np.argwhere(d < cutoff)
            if len(cand) > edges_per_graph:
                sel = rng.choice(len(cand), edges_per_graph, replace=False)
                cand = cand[sel]
            base = g * nodes_per_graph
            srcs.append(cand[:, 0] + base)
            dsts.append(cand[:, 1] + base)
        src = np.concatenate(srcs).astype(np.int32)
        dst = np.concatenate(dsts).astype(np.int32)

        E_cap = n_graphs * edges_per_graph
        e_mask = np.zeros(E_cap, np.int32)
        e_mask[:len(src)] = 1
        src_p = np.zeros(E_cap, np.int32)
        dst_p = np.zeros(E_cap, np.int32)
        src_p[:len(src)] = src
        dst_p[:len(dst)] = dst

        yield {
            "positions": pos.reshape(N, 3).astype(np.float32),
            "node_feat": feats,
            "node_mask": np.ones(N, np.int32),
            "node_graph_id": np.repeat(
                np.arange(n_graphs, dtype=np.int32), nodes_per_graph),
            "edge_src": src_p, "edge_dst": dst_p, "edge_mask": e_mask,
            "target": rng.normal(size=(n_graphs, 1)).astype(np.float32),
        }
        step += 1
