"""Triplet construction for directional message passing (DimeNet).

DimeNet updates the message on each directed edge (j -> i) by
aggregating over *triplets* (k -> j -> i), k in N(j) \\ {i}. The exact
triplet count is sum_j deg(j)^2 — quadratic in hub degree, which
explodes on power-law graphs (ogb-products would exceed 10^9). We
therefore support a per-edge cap K (``max_triplets_per_edge``),
matching the neighbor-capping used by large-scale molecular/GNN systems
(GemNet-OC / OCP practice); exact mode (cap=0) is used for molecules
and small graphs.

This is a *data-pipeline* step (host-side numpy, like the neighbor
sampler): the model consumes fixed-shape index arrays
``(t_src_edge, t_dst_edge)`` meaning message[t_dst_edge] aggregates
basis-weighted message[t_src_edge].
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def build_triplets(
    edge_src: np.ndarray,
    edge_dst: np.ndarray,
    n_nodes: int,
    *,
    max_per_edge: int = 0,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (t_in, t_out): triplet k->j contributes edge t_in[m] =
    index of edge (k->j), to target edge t_out[m] = index of edge
    (j->i). Self-loops k == i are excluded.
    """
    n_edges = len(edge_src)
    rng = np.random.default_rng(seed)
    # incoming-edge lists per node j: edges whose dst == j
    order = np.argsort(edge_dst, kind="stable")
    sorted_dst = edge_dst[order]
    starts = np.searchsorted(sorted_dst, np.arange(n_nodes), side="left")
    ends = np.searchsorted(sorted_dst, np.arange(n_nodes), side="right")

    t_in, t_out = [], []
    for e in range(n_edges):
        j = edge_src[e]          # target edge is (j -> i); aggregate k -> j
        i = edge_dst[e]
        lo, hi = starts[j], ends[j]
        incoming = order[lo:hi]
        ks = edge_src[incoming]
        valid = incoming[ks != i]
        if max_per_edge and len(valid) > max_per_edge:
            valid = rng.choice(valid, size=max_per_edge, replace=False)
        t_in.extend(valid.tolist())
        t_out.extend([e] * len(valid))
    return (np.asarray(t_in, np.int32), np.asarray(t_out, np.int32))


def densify_triplets(
    t_in: np.ndarray,
    t_out: np.ndarray,
    n_edges: int,
    k: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Flat (t_in, t_out) lists -> dense (E, K) layout + mask.

    The dense layout is what the distributed-gather forward path
    consumes (models/dimenet.py::forward_dense_triplets): row e holds
    the (<= K) in-edge indices of target edge e, zero-padded.
    """
    dense = np.zeros((n_edges, k), np.int32)
    mask = np.zeros((n_edges, k), np.int32)
    fill = np.zeros(n_edges, np.int32)
    for src_e, dst_e in zip(t_in, t_out):
        slot = fill[dst_e]
        if slot < k:
            dense[dst_e, slot] = src_e
            mask[dst_e, slot] = 1
            fill[dst_e] = slot + 1
    return dense, mask


def count_triplets(
    edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int,
    max_per_edge: int = 0,
) -> int:
    """Triplet-count *upper bound* without materializing them (for
    static budgets; ignores the k == i exclusion)."""
    in_deg = np.bincount(edge_dst, minlength=n_nodes)
    per_edge = in_deg[edge_src]  # edges into j, minus possibly one (k==i)
    if max_per_edge:
        per_edge = np.minimum(per_edge, max_per_edge)
    return int(per_edge.sum())


def triplet_budget(
    n_nodes: int, n_edges: int, max_per_edge: int
) -> int:
    """Static triplet budget for dry-run ShapeDtypeStructs (no graph
    materialization): cap * n_edges for capped mode; for exact mode we
    assume a regular graph (deg = E/N) giving E * deg triplets.
    """
    if max_per_edge:
        return n_edges * max_per_edge
    avg_deg = max(1, n_edges // max(1, n_nodes))
    return n_edges * avg_deg
