"""llama3.2-3b — dense GQA decoder [hf:meta-llama/Llama-3.2-3B].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256. Pure
full-attention => long_500k skipped (DESIGN.md §4). The 128k vocabulary
is squarely the paper's "larger vocabularies" motivation for the
Sparton head.
"""

from repro.configs.base import TransformerConfig, shapes_lm

CONFIG = TransformerConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    attn_chunk=2048,   # §Perf: -4% memory term vs 512
    head_block_b=None,   # autotuned (128k vocab)
    head_block_s=None,
    head_block_v=None,
)

SMOKE = TransformerConfig(
    name="llama3.2-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=512,
    rope_theta=500000.0,
    tie_embeddings=True,
    remat=False,
)

SHAPES = shapes_lm(
    long_ok=False,
    long_skip_reason="pure full attention; 524k-token decode needs "
                     "sub-quadratic attention (assignment rule)",
)
