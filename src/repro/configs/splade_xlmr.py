"""SPLADE on xlm-roberta-base — the paper's multilingual backbone.

|V| = 250002: the regime where Sparton's gains are largest (26x batch,
2.5x faster training on H100 — paper §4.1).
"""

from repro.configs.base import ShapeSpec, TransformerConfig

CONFIG = TransformerConfig(
    name="splade-xlmr",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=250002,
    bidirectional_encoder=True,
    tie_embeddings=True,
    # 250k vocab: block_v choice dominates HBM traffic — leave on auto
    # so the tuner can pick the largest vocab tile that fits VMEM.
    head_block_b=None,
    head_block_s=None,
    head_block_v=None,
)

SMOKE = TransformerConfig(
    name="splade-xlmr-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=1024,
    bidirectional_encoder=True,
    tie_embeddings=True,
    remat=False,
)

SHAPES = {
    "train_16": ShapeSpec("train_16", "train", seq_len=256, global_batch=16),
    "train_420": ShapeSpec("train_420", "train", seq_len=256,
                           global_batch=420),
}
