"""Wide & Deep [arXiv:1606.07792].

n_sparse=40 embed_dim=32 mlp=1024-512-256, concat interaction.
Google-Play-style field cardinalities (apps/categories/user features).
"""

from repro.configs.base import RecSysConfig, SHAPES_RECSYS

TABLE_SIZES = tuple(
    [1000000, 1000000, 500000] + [10000] * 7 + [1000] * 15 + [100] * 15
)

CONFIG = RecSysConfig(
    name="wide-deep",
    interaction="concat",
    n_sparse=40,
    embed_dim=32,
    table_sizes=TABLE_SIZES,
    mlp=(1024, 512, 256),
)

SMOKE = RecSysConfig(
    name="wide-deep-smoke",
    interaction="concat",
    n_sparse=4,
    embed_dim=8,
    table_sizes=(200, 100, 50, 30),
    mlp=(32, 16),
)

SHAPES = SHAPES_RECSYS
