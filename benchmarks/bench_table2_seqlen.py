"""Paper Table 2: backward-pass time + memory vs sequence length
(B=128, |V|=30522 in the paper; CPU-scaled B, same |V|), tiled vs
sparton. The paper's point: tiled OOMs at S=4096-8192 while Sparton's
memory stays flat-ish (O(B*V) residuals, not O(B*S*V)).

We report the XLA-planned peak bytes for both so the OOM wall is
visible as a crossing of the (real) HBM budget rather than an actual
crash on this CPU host.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._common import compiled_peak_bytes, csv_print, time_fn
from repro.core.head_api import HeadSpec, make_head

B, D, V = 4, 64, 30522
HBM_BUDGET_GB = 40.0  # the paper's A100-40GB


def run(csv: bool = True):
    rows = []
    for S in (128, 256, 512, 1024):
        ks = jax.random.split(jax.random.PRNGKey(S), 2)
        H = jax.random.normal(ks[0], (B, S, D))
        E = jax.random.normal(ks[1], (V, D)) * 0.2
        b = jnp.zeros((V,))
        mask = jnp.ones((B, S), jnp.int32)
        habs = (jax.ShapeDtypeStruct(H.shape, H.dtype),
                jax.ShapeDtypeStruct(E.shape, E.dtype),
                jax.ShapeDtypeStruct(b.shape, b.dtype))

        for name in ("tiled", "sparton"):
            fn = make_head(HeadSpec(impl=name, vocab_tile=4096))

            def loss(H, E, b):
                return jnp.sum(fn(H, E, b, mask) ** 2)
            g = jax.grad(loss, argnums=(0, 1))
            t = time_fn(jax.jit(g), H, E, b, warmup=1, iters=2)
            m = compiled_peak_bytes(g, *habs)
            # scale the paper's B=128 peak from our CPU-sized B measurement:
            # residuals scale linearly in B for both impls
            paper_scale = 128 / B
            projected_gb = m * paper_scale / 2**30
            rows.append((S, name, round(t, 1), round(m / 2**20, 1),
                         round(projected_gb, 2),
                         "OOM" if projected_gb > HBM_BUDGET_GB else "fits"))
    if csv:
        csv_print(("seq_len", "impl", "bwd_time_ms", "peak_mib_b8",
                   "projected_gb_b128", "a100_40gb"), rows)
    return rows


if __name__ == "__main__":
    run()
