"""Sparse-native retrieval: SparseRep reps, inverted impact index,
the unified ``retrieve()`` dispatcher, and the index engine —
pruned / quantized / sharded scoring plus the incremental builder
(DESIGN.md §7–§8)."""

from repro.retrieval.engine import (CorpusStats, IndexBuilder,
                                    QuantizedIndex, Shard2DIndex,
                                    ShardPlan, ShardedIndex,
                                    TermShardedIndex,
                                    choose_shard_axis,
                                    fused_quantized_retrieve,
                                    plan_placement,
                                    pruned_retrieve,
                                    quantize_index, shard2d_index,
                                    shard2d_retrieve, shard_index,
                                    sharded_retrieve, term_shard_index,
                                    term_sharded_retrieve)
from repro.retrieval.index import InvertedIndex, build_inverted_index
from repro.retrieval.score import (METHODS, fused_retrieve,
                                   impact_scores, retrieve)
from repro.retrieval.sparse_rep import (SparseRep, sparsify_threshold,
                                        sparsify_topk, split_rows,
                                        stack_rows, truncate_width)

__all__ = [
    "CorpusStats",
    "IndexBuilder",
    "InvertedIndex",
    "METHODS",
    "QuantizedIndex",
    "Shard2DIndex",
    "ShardPlan",
    "ShardedIndex",
    "SparseRep",
    "TermShardedIndex",
    "build_inverted_index",
    "choose_shard_axis",
    "fused_quantized_retrieve",
    "fused_retrieve",
    "impact_scores",
    "plan_placement",
    "pruned_retrieve",
    "quantize_index",
    "retrieve",
    "shard2d_index",
    "shard2d_retrieve",
    "shard_index",
    "sharded_retrieve",
    "sparsify_threshold",
    "sparsify_topk",
    "split_rows",
    "stack_rows",
    "term_shard_index",
    "term_sharded_retrieve",
    "truncate_width",
]
