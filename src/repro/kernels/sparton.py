"""Sparton fused LM-head forward v2 — Pallas TPU kernel.

One kernel fuses: tiled GEMM (``H @ E^T``), bias add, optional
gemma-2-style logit soft-capping, attention masking, streaming max
reduction over the sequence dimension (with argmax tracking), and the
final ``log1p(relu(.))`` epilogue. The full ``(B, S, V)`` logit tensor
is never materialized — per grid step only a ``(block_b*block_s,
block_v)`` logit tile lives in VMEM.

v2 over v1 (DESIGN.md §"Kernel v2"):

* The running ``(block_b, block_v)`` max/argmax live in **VMEM
  scratch** (``scratch_shapes``) across sequence steps; the ``(B, V)``
  output tiles are written to HBM exactly once, at the finalize step.
  v1 accumulated through the output refs, leaving the write-back/
  re-fetch decision to the pipeline; v2 makes the single-store
  guarantee structural.
* ``dimension_semantics=("parallel", "parallel", "arbitrary")`` tells
  Mosaic the batch/vocab grid dims carry no cross-step state, so they
  can split across the two TensorCores of a megacore chip; only the
  sequence dim is ordered (it owns the scratch accumulator).
* bf16 ``H``/``E`` tiles feed the MXU directly (no upcast in VMEM);
  accumulation is always f32 via ``preferred_element_type``.

Grid layout: ``(B/bb, V/bv, S/bs)`` with the sequence dimension
innermost, so each ``(b, v)`` tile's accumulator is live for exactly
one scratch lifetime (deterministic, no atomics).

VMEM working set per step:
    H tile   bb*bs*D        (input dtype)
    E tile   bv*D           (input dtype)
    logits   bb*bs*bv       f32 (register/VMEM temporary)
    scratch  2 * bb*bv      f32/i32 (running max / argmax)
    y, i     2 * bb*bv      f32/i32 (output tiles)
Block selection is shape-dependent — see ``kernels/autotune.py``; the
(8, 128, 128) fallback keeps this under ~2 MB at D=4096.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import NEG_INF, pad_to


def _fwd_kernel(
    h_ref,      # (bb, bs, D)  input dtype (f32 or bf16)
    e_ref,      # (bv, D)      input dtype
    bias_ref,   # (1, bv)  f32
    mask_ref,   # (bb, bs) int32
    y_ref,      # (bb, bv) f32 out — written once, at finalize
    i_ref,      # (bb, bv) i32 out — written once, at finalize
    acc_ref,    # (bb, bv) f32 VMEM scratch — running max
    arg_ref,    # (bb, bv) i32 VMEM scratch — running argmax
    *,
    n_s_blocks: int,
    block_s: int,
    softcap: Optional[float],
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full(acc_ref.shape, NEG_INF, jnp.float32)
        arg_ref[...] = jnp.zeros(arg_ref.shape, jnp.int32)

    bb, bs, d = h_ref.shape
    bv = e_ref.shape[0]

    h = h_ref[...].reshape(bb * bs, d)
    e = e_ref[...]
    # (bb*bs, bv) logit tile on the MXU; f32 accumulation regardless of
    # the input dtype (bf16 operands feed the MXU natively).
    logits = jax.lax.dot_general(
        h, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    logits = logits + bias_ref[...]  # (1, bv) broadcasts over rows
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = logits.reshape(bb, bs, bv)

    keep = mask_ref[...] > 0  # (bb, bs)
    logits = jnp.where(keep[:, :, None], logits, NEG_INF)

    tile_max = jnp.max(logits, axis=1)  # (bb, bv)
    # First-occurrence argmax without lax.argmax (portable in Pallas):
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bb, bs, bv), 1)
    hit = logits >= tile_max[:, None, :]
    tile_arg = jnp.min(jnp.where(hit, s_iota, bs), axis=1) + k * block_s

    cur = acc_ref[...]
    better = tile_max > cur  # strict: earlier blocks win ties (first occ.)
    acc_ref[...] = jnp.where(better, tile_max, cur)
    arg_ref[...] = jnp.where(better, tile_arg, arg_ref[...])

    @pl.when(k == n_s_blocks - 1)
    def _finalize():
        # single HBM store per (b, v) output tile
        y_ref[...] = jnp.log1p(jnp.maximum(acc_ref[...], 0.0))
        i_ref[...] = arg_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_b", "block_s", "block_v", "softcap", "interpret"
    ),
)
def _forward_call(
    H, E, b, mask, *, block_b, block_s, block_v, softcap, interpret
):
    B, S, D = H.shape
    V = E.shape[0]

    Hp = pad_to(pad_to(H, 0, block_b), 1, block_s)
    maskp = pad_to(pad_to(mask.astype(jnp.int32), 0, block_b), 1, block_s)
    Ep = pad_to(E, 0, block_v)
    bp = pad_to(b.astype(jnp.float32), 0, block_v).reshape(1, -1)

    Bp, Sp, _ = Hp.shape
    Vp = Ep.shape[0]
    grid = (Bp // block_b, Vp // block_v, Sp // block_s)

    kernel = functools.partial(
        _fwd_kernel,
        n_s_blocks=grid[2],
        block_s=block_s,
        softcap=softcap,
    )
    y, i_max = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_s, D), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((block_v, D), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, block_v), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_b, block_s), lambda i, j, k: (i, k)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_v), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Vp), jnp.float32),
            jax.ShapeDtypeStruct((Bp, Vp), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, block_v), jnp.float32),
            pltpu.VMEM((block_b, block_v), jnp.int32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(Hp, Ep, bp, maskp)
    return y[:B, :V], i_max[:B, :V]


def sparton_forward(
    H: jax.Array,        # (B, S, D) f32 or bf16
    E: jax.Array,        # (V, D) f32 or bf16
    b: jax.Array,        # (V,)
    mask: jax.Array,     # (B, S) int32/bool, 1 = keep
    *,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused forward. Returns (y (B, V) f32, i_max (B, V) i32).

    Block sizes default to the autotuner's cached/heuristic choice for
    the call shape (``kernels/autotune.py``); pass explicit ints to pin.
    """
    if block_b is None or block_s is None or block_v is None:
        from repro.kernels.autotune import resolve_blocks  # avoids cycle

        B, S, D = H.shape
        block_b, block_s, block_v = resolve_blocks(
            B, S, D, E.shape[0], H.dtype, block_b, block_s, block_v,
            kernel="fwd")
    return _forward_call(
        H, E, b, mask, block_b=block_b, block_s=block_s, block_v=block_v,
        softcap=softcap, interpret=interpret,
    )
