"""Fused streaming top-k kernel vs oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import topk_score_ref
from repro.kernels.topk_score import topk_score
from repro.launch.steps import streaming_topk


def _qc(B, N, D, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (B, D))
    C = jax.random.normal(k2, (N, D))
    return q, C


@pytest.mark.parametrize("B,N,D,k,bn", [
    (1, 100, 16, 5, 32),
    (3, 500, 32, 10, 128),
    (8, 1024, 64, 100, 256),
    (2, 999, 8, 7, 128),       # non-divisible N
])
def test_topk_kernel_matches_oracle(B, N, D, k, bn):
    q, C = _qc(B, N, D)
    v, i = topk_score(q, C, k=k, block_b=2, block_n=bn, interpret=True)
    vr, ir = topk_score_ref(q, C, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_streaming_topk_pure_jax_matches_oracle():
    q, C = _qc(4, 2000, 16, seed=2)
    v, i = streaming_topk(q, C, k=13, tile=256)
    vr, ir = topk_score_ref(q, C, 13)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@settings(max_examples=15, deadline=None)
@given(N=st.integers(10, 400), k=st.integers(1, 9),
       seed=st.integers(0, 2**16))
def test_property_topk_invariants(N, k, seed):
    q, C = _qc(2, N, 8, seed=seed)
    v, i = topk_score(q, C, k=k, block_b=2, block_n=64, interpret=True)
    v, i = np.asarray(v), np.asarray(i)
    # scores sorted descending, indices valid and unique
    assert (np.diff(v, axis=1) <= 1e-6).all()
    assert (i >= 0).all() and (i < N).all()
    for row in i:
        assert len(set(row.tolist())) == k
    # values actually equal q . C[idx]
    scores = np.einsum("bd,bkd->bk", np.asarray(q), np.asarray(C)[i])
    np.testing.assert_allclose(v, scores, atol=1e-4)
