"""Attention primitives: RoPE, chunked (online-softmax) attention, GQA.

The training path uses a *chunked* attention (lax.scan over KV blocks
with a running max/sum — the flash-attention recurrence expressed in
XLA) so that the ``(B, H, S, S)`` score tensor is never materialized.
This keeps the multi-pod dry-run compilable on the CPU backend (Pallas
TPU attention kernels cannot lower there) while preserving the O(S)
activation footprint that a fused TPU kernel would give.

Masks are never materialized as ``(S, S)`` tensors: causal and
sliding-window constraints are evaluated per KV chunk from iota
comparisons, which XLA fuses into the score computation.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, N, d_head); positions: (B, S) int32."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                 # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked multi-head attention with GQA
# ---------------------------------------------------------------------------

def _chunk_mask(
    q_pos: Array,       # (Sq,) absolute query positions
    k_pos: Array,       # (Ck,) absolute key positions of this chunk
    kv_valid: Array,    # (B, Ck) bool — padding mask of this chunk
    causal: bool,
    window: Optional[int],
) -> Array:
    """(B, Sq, Ck) bool keep-mask, built from iota comparisons."""
    m = kv_valid[:, None, :]
    rel = q_pos[None, :, None] - k_pos[None, None, :]  # (1, Sq, Ck)
    if causal:
        m = m & (rel >= 0)
    if window is not None:
        m = m & (rel < window)
    return m


def chunked_attention(
    q: Array,            # (B, Sq, H, dh)
    k: Array,            # (B, Sk, KV, dh)
    v: Array,            # (B, Sk, KV, dh)
    *,
    q_positions: Array,  # (Sq,)
    k_positions: Array,  # (Sk,)
    kv_mask: Array,      # (B, Sk) 1 = valid
    causal: bool,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    chunk_size: int = 512,
    unroll: int = 1,
) -> Array:
    """Online-softmax attention over KV chunks; returns (B, Sq, H, dh).

    ``unroll`` is for cost-probe lowering only (roofline.py): the KV
    chunk scan body must be replicated so cost_analysis counts it."""
    B, Sq, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV  # query groups per kv head
    scale = dh ** -0.5

    chunk_size = min(chunk_size, max(Sk, 1))  # no padding blow-up at small S
    pad = (-Sk) % chunk_size
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pad)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=-1)
    n_chunks = k.shape[1] // chunk_size

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, G, dh)
    k_c = k.reshape(B, n_chunks, chunk_size, KV, dh)
    v_c = v.reshape(B, n_chunks, chunk_size, KV, dh)
    m_c = kv_mask.reshape(B, n_chunks, chunk_size)
    p_c = k_positions.reshape(n_chunks, chunk_size)

    def body(carry, xs):
        acc, row_max, row_sum = carry
        kc, vc, mc, pc = xs  # (B,C,KV,dh), (B,C,KV,dh), (B,C), (C,)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qf.astype(kc.dtype), kc,
                       preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        keep = _chunk_mask(q_positions, pc, mc > 0, causal, window)
        s = jnp.where(keep[:, :, None, None, :], s, NEG_INF)
        new_max = jnp.maximum(row_max, jnp.max(s, axis=-1))
        alpha = jnp.exp(row_max - new_max)
        p = jnp.exp(s - new_max[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        row_sum = row_sum * alpha + jnp.sum(p, axis=-1)
        return (acc, new_max, row_sum), None

    init = (
        jnp.zeros((B, Sq, KV, G, dh), jnp.float32),
        jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32),
        jnp.zeros((B, Sq, KV, G), jnp.float32),
    )
    (acc, _, row_sum), _ = jax.lax.scan(
        body, init,
        (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0),
         jnp.moveaxis(m_c, 1, 0), p_c),
        unroll=unroll,
    )
    out = acc / jnp.maximum(row_sum[..., None], 1e-30)
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def decode_attention(
    q: Array,            # (B, 1, H, dh)
    k_cache: Array,      # (B, S_max, KV, dh)
    v_cache: Array,      # (B, S_max, KV, dh)
    *,
    positions: Array,    # (B,) current write position (# valid tokens - 1)
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
) -> Array:
    """Single-token decode attention against a (possibly huge) cache.

    Scores are (B, H, S_max) — linear in cache length, never quadratic.
    """
    B, _, H, dh = q.shape
    S_max, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = dh ** -0.5

    # keep the (huge) cache in its storage dtype: upcasting would
    # materialize an fp32 copy of the full cache (2x HBM). The einsum
    # accumulates in fp32 via preferred_element_type (MXU-native).
    qf = (q.astype(jnp.float32) * scale).astype(k_cache.dtype)
    qf = qf.reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache,
                   preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    k_pos = jnp.arange(S_max, dtype=jnp.int32)
    keep = k_pos[None, :] <= positions[:, None]           # causal / validity
    if window is not None:
        keep = keep & (positions[:, None] - k_pos[None, :] < window)
    s = jnp.where(keep[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)
