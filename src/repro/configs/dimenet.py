"""DimeNet — directional message-passing GNN [arXiv:2003.03123].

n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7 n_radial=6.
Triplet regime (kernel taxonomy §GNN). The Sparton technique has no
vocab projection / sequence max-pool here => built WITHOUT it
(DESIGN.md §4); the shared primitive is segment_max-with-argmax
gradient routing (repro/sparse/segment.py).

Large-graph shapes cap triplets per edge (max_triplets_per_edge=8,
GemNet-OC practice); molecules use exact triplets.
"""

import dataclasses

from repro.configs.base import DimeNetConfig, SHAPES_GNN

CONFIG = DimeNetConfig(
    name="dimenet",
    n_blocks=6,
    d_hidden=128,
    n_bilinear=8,
    n_spherical=7,
    n_radial=6,
    max_triplets_per_edge=8,   # large-graph shapes; molecule uses exact
)

SMOKE = DimeNetConfig(
    name="dimenet-smoke",
    n_blocks=2,
    d_hidden=32,
    n_bilinear=4,
    n_spherical=3,
    n_radial=4,
    max_triplets_per_edge=4,
)

SHAPES = SHAPES_GNN
