"""phi3.5-moe-42b-a6.6b — MoE 16e top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400(per expert) vocab=32064,
16 experts top-2.
"""

from repro.configs.base import TransformerConfig, shapes_lm

CONFIG = TransformerConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    top_k=2,
    rope_theta=10000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    attn_chunk=2048,   # §Perf: -4% memory term vs 512

)

SMOKE = TransformerConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=64,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    tie_embeddings=False,
    remat=False,
)

SHAPES = shapes_lm(
    long_ok=False,
    long_skip_reason="pure full attention; 524k-token decode needs "
                     "sub-quadratic attention (assignment rule)",
)
