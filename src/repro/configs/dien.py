"""DIEN — interest evolution with AUGRU [arXiv:1809.03672].

embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80, AUGRU interaction.
Item vocabulary sized to Amazon-Books scale (~370k items).
"""

from repro.configs.base import RecSysConfig, SHAPES_RECSYS

CONFIG = RecSysConfig(
    name="dien",
    interaction="augru",
    n_sparse=1,
    embed_dim=18,
    table_sizes=(367983,),
    seq_len=100,
    gru_dim=108,
    mlp=(200, 80),
)

SMOKE = RecSysConfig(
    name="dien-smoke",
    interaction="augru",
    n_sparse=1,
    embed_dim=8,
    table_sizes=(500,),
    seq_len=12,
    gru_dim=16,
    mlp=(24, 12),
)

SHAPES = SHAPES_RECSYS
