"""Render the dry-run / roofline JSON artifacts into the generated
table sections of EXPERIMENTS.md (between AUTOGEN markers).

Usage: PYTHONPATH=src python -m benchmarks.report
"""

import glob
import json
import os
import re
import sys

GB = 1e9


def _fmt(x, nd=2):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) >= 1e5 or abs(x) < 1e-3:
        return f"{x:.2e}"
    return f"{x:.{nd}f}"


def dryrun_table(path: str) -> str:
    recs = json.load(open(path))
    lines = [
        "| cell | status | compile s | flops/dev | HLO bytes/dev | "
        "coll wire B/dev | peak GB/dev | bottleneck |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        cell = f"{r['arch']}/{r['shape']}"
        if r.get("status") == "skipped":
            lines.append(f"| {cell} | skipped | - | - | - | - | - | "
                         f"{r['reason'][:48]} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {cell} | **FAILED** | - | - | - | - | - | - |")
            continue
        peak = (r.get("memory_analysis") or {}).get(
            "peak_estimate_bytes", None)
        lines.append(
            f"| {cell} | ok | {r['compile_s']} | "
            f"{_fmt(r['flops_per_device'])} | "
            f"{_fmt(r['hbm_bytes_per_device'])} | "
            f"{_fmt(r['collective_wire_bytes'])} | "
            f"{_fmt(peak / GB if peak else None)} | {r['bottleneck']} |")
    ok = sum(1 for r in recs if r.get("status") == "ok")
    sk = sum(1 for r in recs if r.get("status") == "skipped")
    lines.append("")
    lines.append(f"**{ok} ok / {sk} skipped / "
                 f"{len(recs) - ok - sk} failed.**")
    return "\n".join(lines)


def roofline_table(path: str) -> str:
    recs = json.load(open(path))
    lines = [
        "| cell | compute s | memory s (unfused UB) | memory s "
        "(fused est) | collective s | bottleneck | useful ratio |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or "roof_compute_s" not in r:
            continue
        cell = f"{r['arch']}/{r['shape']}"
        lines.append(
            f"| {cell} | {_fmt(r['roof_compute_s'], 4)} | "
            f"{_fmt(r['roof_memory_s'], 4)} | "
            f"{_fmt(r.get('roof_memory_s_fused_est'), 4)} | "
            f"{_fmt(r['roof_collective_s'], 4)} | "
            f"{r['roof_bottleneck']} | "
            f"{_fmt(r.get('roof_useful_ratio'), 3)} |")
    return "\n".join(lines)


def _bench_metrics(path: str) -> dict:
    """Flatten one BENCH_*.json record to ``{metric: value}``.

    Understands the five shapes: ``BENCH_kernels.json`` (``heads`` ->
    fwd/fwd_bwd passes), ``BENCH_retrieval.json`` (``methods``),
    ``BENCH_engine.json`` (``methods`` + quantization ratio + sharded
    / 2D-grid scaling + planner decisions), ``BENCH_serving.json``
    (per-phase traffic stats +
    ladder quality + fault-run outcome), ``BENCH_quality.json``
    (method/ladder/rep-width nDCG@10 + trained-vs-init deltas), and
    ``BENCH_frontier.json`` (cache hit rate, cache-on/off p99 and
    QPS, churn coherence, tenant fairness, continuous-batching gain).
    """
    d = json.load(open(path))
    out = {}
    for head, passes in d.get("heads", {}).items():
        for pss, rec in passes.items():
            out[f"{head}/{pss}"] = rec.get("median_ms")
    for m, rec in d.get("methods", {}).items():
        out[f"retrieval/{m}"] = rec.get("median_ms")
        # the fused-kernel rows carry the analytic scoring-memory
        # model; trend it in MB next to the latency so a peak
        # regression (someone reintroducing a (B, N) materialization)
        # is as visible as a slowdown
        if rec.get("peak_scoring_bytes") is not None:
            out[f"retrieval/{m}/peak_mb"] = round(
                rec["peak_scoring_bytes"] / 1e6, 3)
    if "quantization" in d:
        out["quant/ratio"] = d["quantization"].get("ratio")
    for s, rec in d.get("sharded", {}).items():
        out[f"sharded/x{s}"] = rec.get("median_ms")
    for s, rec in d.get("term_sharded", {}).items():
        out[f"term_sharded/x{s}"] = rec.get("median_ms")
    for g, rec in d.get("shard2d", {}).items():
        out[f"shard2d/{g}"] = rec.get("median_ms")
    for probe in ("huge_vocab", "small_vocab"):
        rec = d.get("planner", {}).get(probe)
        if rec is not None:
            # trend the decision itself: a planner regression shows as
            # the term-shard count jumping, not as a latency delta
            out[f"planner/{probe}/term_shards"] = rec.get("term_shards")
    for p in d.get("phases", []):
        name = p.get("name", "?")
        for k in ("sustained_qps", "p99_ms", "shed_rate"):
            out[f"serving/{name}/{k}"] = p.get(k)
    for rung, overlap in d.get("degrade_quality", {}).items():
        out[f"serving/quality/{rung}"] = overlap
    if "faults" in d:
        out["serving/faults/lost"] = d["faults"].get("lost")
    for m, rec in d.get("method_quality", {}).items():
        out[f"quality/method/{m}"] = rec.get("ndcg@10")
    for rung, v in d.get("ladder_quality", {}).items():
        out[f"quality/ladder/{rung}"] = v
    for w, rec in d.get("rep_topk_sweep", {}).items():
        out[f"quality/rep_topk/w{w}"] = rec.get("ndcg@10")
    tv = d.get("trained_vs_init", {})
    for k, v in tv.get("delta", {}).items():
        out[f"quality/train_delta/{k}"] = v
    replay = d.get("zipf_replay", {})
    for mode, rec in replay.items():
        for k in ("sustained_qps", "p99_ms"):
            out[f"frontier/{mode}/{k}"] = rec.get(k)
        if "hit_rate" in rec:
            out[f"frontier/{mode}/hit_rate"] = rec.get("hit_rate")
    if "churn" in d:
        out["frontier/churn/mismatches"] = d["churn"].get("mismatches")
    if "tenancy" in d:
        out["frontier/tenancy/fairness_ab"] = d["tenancy"].get(
            "fairness_ratio_ab")
    for mode, rec in d.get("continuous", {}).items():
        out[f"frontier/{mode}/qps"] = rec.get("sustained_qps")
        out[f"frontier/{mode}/shed_rate"] = rec.get("shed_rate")
    return out


_SNAP_RE = re.compile(
    r"^(?P<name>BENCH_[A-Za-z]+)(?:-(?P<date>\d{8})-(?P<sha>[0-9a-f]+)"
    r"(?:-(?P<run>\d+))?)?\.json$")


def _snapshot_key(path: str):
    """Chronological sort key for ``bench_history/`` snapshot names.

    Tolerates every key generation CI has emitted: ``<name>.json``
    (the current record — sorts last), ``<name>-<date>-<sha>.json``
    (PR-4 era) and ``<name>-<date>-<sha>-<run_id>.json`` (run-id
    suffix so same-commit-same-day runs stop overwriting each other;
    the run id is monotonic, giving an order within the day).
    """
    m = _SNAP_RE.match(os.path.basename(path))
    if not m or m.group("date") is None:
        return ("99999999", 1 << 62, os.path.basename(path))
    run = int(m.group("run")) if m.group("run") else 0
    return (m.group("date"), run, os.path.basename(path))


def _snapshot_label(path: str) -> str:
    """Column header: drop the shared ``BENCH_<family>-`` prefix and
    ``.json`` suffix; the bare current record renders as "current"."""
    m = _SNAP_RE.match(os.path.basename(path))
    if not m:
        return os.path.basename(path)
    if m.group("date") is None:
        return "current"
    label = f"{m.group('date')}-{m.group('sha')}"
    if m.group("run"):
        label += f"-{m.group('run')}"
    return label


def trend_table(paths: list) -> str:
    """Per-metric median-ms trend across bench snapshots, oldest first.

    The last column is the relative change of the newest snapshot vs
    its predecessor — the row CI watches once a few PRs of history
    exist (ROADMAP "start trending" item). Metrics missing from a
    snapshot render as "-" (bench coverage grows over PRs).
    """
    snaps = [(_snapshot_label(p), _bench_metrics(p)) for p in paths]
    metrics = []
    for _, m in snaps:
        for key in m:
            if key not in metrics:
                metrics.append(key)
    header = ("| metric | " + " | ".join(n for n, _ in snaps)
              + " | Δ% (last vs prev) |")
    lines = [header,
             "|---|" + "---|" * (len(snaps) + 1)]
    for key in metrics:
        vals = [m.get(key) for _, m in snaps]
        cells = [_fmt(v) if v is not None else "-" for v in vals]
        prev, last = vals[-2], vals[-1]
        if prev and last is not None:
            delta = f"{(last - prev) / prev * 100:+.1f}%"
        else:
            delta = "-"
        lines.append(f"| {key} | " + " | ".join(cells) + f" | {delta} |")
    return "\n".join(lines)


def bench_trends(history_dir: str = "bench_history") -> int:
    """Print (and inject) trend tables for every bench family that has
    history: prior snapshots live in ``bench_history/<NAME>*.json``,
    the current record next to them as ``<NAME>.json``. Returns the
    number of tables printed."""
    printed = 0
    for name in ("BENCH_kernels", "BENCH_retrieval", "BENCH_engine",
                 "BENCH_serving", "BENCH_frontier", "BENCH_quality"):
        hist = sorted(glob.glob(os.path.join(history_dir,
                                             f"{name}*.json")),
                      key=_snapshot_key)
        cur = f"{name}.json"
        paths = hist + ([cur] if os.path.exists(cur) else [])
        if len(paths) < 2:
            if os.path.exists(cur):
                print(f"no bench history for {name} (put prior "
                      f"snapshots in {history_dir}/) — skipping trend")
            continue
        table = trend_table(paths)
        print(f"\n== {name} trend ==")
        print(table)
        if os.path.exists("EXPERIMENTS.md"):
            inject("EXPERIMENTS.md", f"TREND_{name}", table)
        printed += 1
    return printed


def inject(md_path: str, marker: str, content: str) -> None:
    text = open(md_path).read()
    begin = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- /AUTOGEN:{marker} -->"
    pattern = re.compile(re.escape(begin) + ".*?" + re.escape(end),
                         re.DOTALL)
    block = f"{begin}\n{content}\n{end}"
    if pattern.search(text):
        text = pattern.sub(block, text)
    else:
        text += "\n" + block + "\n"
    open(md_path, "w").write(text)


def main() -> int:
    md = "EXPERIMENTS.md"
    jobs = [
        ("DRYRUN_SINGLE", "dryrun_single_pod.json", dryrun_table),
        ("DRYRUN_MULTI", "dryrun_multi_pod.json", dryrun_table),
        ("ROOFLINE_SINGLE", "roofline_single_pod.json", roofline_table),
        ("ROOFLINE_MULTI", "roofline_multi_pod.json", roofline_table),
        ("BASELINE_SINGLE", "baseline_dryrun_single_pod.json",
         dryrun_table),
    ]
    for marker, path, fn in jobs:
        if os.path.exists(path):
            inject(md, marker, fn(path))
            print(f"injected {marker} from {path}")
        else:
            print(f"skip {marker}: {path} missing")
    bench_trends()
    return 0


if __name__ == "__main__":
    sys.exit(main())
