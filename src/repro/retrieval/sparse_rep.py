"""SparseRep — the canonical post-head currency of the retrieval stack.

The Sparton head never materializes the ``(B, S, V)`` logit tensor,
but the serving stack used to throw that win away by shipping the
dense ``(B, V)`` rep per request (~1 MB/query at V≈250k) and scoring
against a dense ``(N, V)`` corpus matrix. An LSR rep out of the head
(``log1p(relu(max))``) is non-negative with a few hundred active
terms, so the natural wire/index format is a fixed-width sparse row:

    values  (..., K) f32  — impact weights, strictly positive when
                            active; padded slots hold 0.0
    indices (..., K) i32  — vocab ids of the active terms; padded
                            slots hold 0 (harmless: value 0 there)
    nnz     (...,)   i32  — active slots per row (always a prefix —
                            the sparsifiers sort by value descending)

The fixed width keeps every consumer jit-able (no ragged shapes), and
the ``value == 0`` padding convention makes padded slots a no-op for
every linear operation (scoring, densify-by-scatter-add). The price is
that non-positive entries are not representable — fine for LSR, whose
impact weights are non-negative by construction.

Sparsification follows the Unified-LSR view of top-k / thresholding as
first-class model knobs: ``sparsify_topk`` / ``sparsify_threshold``
reduce the dense ``(B, V)`` head output on-device with the same
running-top-k merge the ``kernels/topk_score.py`` streaming kernel
uses (vocab tiles + ``merge_topk``), so the full-vocab sort is never
materialized and only ``(B, K)`` ever reaches the host.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels._common import NEG_INF
from repro.kernels.topk_score import merge_topk

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseRep:
    """Fixed-width sparse rows (see module docstring for the layout)."""

    values: Array       # (..., K) float
    indices: Array      # (..., K) int32
    nnz: Array          # (...,)   int32

    def tree_flatten(self):
        return (self.values, self.indices, self.nnz), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- shape helpers ---------------------------------------------------

    @property
    def width(self) -> int:
        """K — the fixed per-row slot budget."""
        return self.values.shape[-1]

    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return self.values.shape[:-1]

    @property
    def n_rows(self) -> int:
        return int(np.prod(self.batch_shape, dtype=np.int64)) \
            if self.batch_shape else 1

    # -- conversions -----------------------------------------------------

    def to_dense(self, vocab_size: int) -> Array:
        """Scatter back to a dense ``(..., V)`` array.

        Padded slots add 0.0 at column 0 — a no-op by construction.
        Exact inverse of the sparsifiers whenever no active term was
        dropped (``nnz`` never hit the width/threshold caps).
        """
        k = self.width
        flat_v = self.values.reshape(-1, k)
        flat_i = self.indices.reshape(-1, k)
        rows = flat_v.shape[0]
        out = jnp.zeros((rows, vocab_size), flat_v.dtype)
        out = out.at[jnp.arange(rows)[:, None], flat_i].add(flat_v)
        return out.reshape(*self.batch_shape, vocab_size)

    @classmethod
    def from_dense(cls, dense: Array, *, max_nnz: int,
                   threshold: float = 0.0, tile: int = 4096
                   ) -> "SparseRep":
        return sparsify_threshold(dense, threshold, max_nnz=max_nnz,
                                  tile=tile)

    def block_until_ready(self) -> "SparseRep":
        jax.block_until_ready((self.values, self.indices, self.nnz))
        return self


# ---------------------------------------------------------------------------
# sparsifiers (device-side, jit-able)
# ---------------------------------------------------------------------------

def _streaming_topk_rows(x: Array, k: int, tile: int
                         ) -> Tuple[Array, Array]:
    """Running top-k over vocab tiles of a dense ``(B, V)`` array.

    The same merge machinery as the streaming retrieval kernel
    (``kernels.topk_score.merge_topk``): scan the vocab in ``tile``
    chunks keeping only the ``(B, k)`` running winners, so the
    reduction is on-device and no full-V sort is materialized. Tiles
    are visited in ascending-id order, so equal values tie-break to
    the lowest vocab id.
    """
    B, V = x.shape
    x = x.astype(jnp.float32)
    tile = min(tile, V)
    pad = (-V) % tile
    xp = jnp.pad(x, ((0, 0), (0, pad)), constant_values=0.0)
    n_tiles = xp.shape[1] // tile
    xt = jnp.moveaxis(xp.reshape(B, n_tiles, tile), 1, 0)  # (T, B, tile)
    ids0 = jnp.arange(tile, dtype=jnp.int32)

    def body(carry, xs):
        vals, idx = carry
        x_tile, t = xs
        ids = t * tile + jnp.broadcast_to(ids0[None], x_tile.shape)
        # padded cols (id >= V) hold 0.0 and would beat real entries
        masked = jnp.where(ids < V, x_tile, NEG_INF)
        return merge_topk(vals, idx, masked, ids, k), None

    init = (jnp.full((B, k), NEG_INF, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    (vals, idx), _ = jax.lax.scan(
        body, init, (xt, jnp.arange(n_tiles, dtype=jnp.int32)))
    return vals, idx


def _finalize(vals: Array, idx: Array, threshold: float) -> SparseRep:
    # Non-positive entries are "absent" (the rep convention); the
    # winners are value-descending, so kept slots form a prefix.
    thr = max(float(threshold), 0.0)
    keep = vals > thr
    return SparseRep(
        values=jnp.where(keep, vals, 0.0),
        indices=jnp.where(keep, idx, 0),
        nnz=jnp.sum(keep, axis=-1).astype(jnp.int32),
    )


def sparsify_topk(dense: Array, k: int, *, threshold: float = 0.0,
                  tile: int = 4096) -> SparseRep:
    """Keep the ``k`` largest strictly-positive entries per row.

    ``threshold`` additionally drops kept entries at or below it (the
    combined Unified-LSR knob). Width of the result is ``min(k, V)``.
    """
    B, V = dense.shape
    vals, idx = _streaming_topk_rows(dense, min(k, V), tile)
    return _finalize(vals, idx, threshold)


def sparsify_threshold(dense: Array, threshold: float = 0.0, *,
                       max_nnz: int = 256, tile: int = 4096) -> SparseRep:
    """Keep entries strictly above ``threshold``, capped at ``max_nnz``.

    The cap keeps the output shape static for jit; when a row has more
    than ``max_nnz`` qualifying entries the *largest* ones win (the
    selection is a running top-k, not a truncation by vocab order).
    """
    B, V = dense.shape
    vals, idx = _streaming_topk_rows(dense, min(max_nnz, V), tile)
    return _finalize(vals, idx, threshold)


# ---------------------------------------------------------------------------
# host-side plumbing (serving loop / index build)
# ---------------------------------------------------------------------------

def device_get(rep: SparseRep) -> SparseRep:
    """Rep with numpy leaves (one transfer for all three arrays)."""
    v, i, n = jax.device_get((rep.values, rep.indices, rep.nnz))
    return SparseRep(np.asarray(v), np.asarray(i), np.asarray(n))


def split_rows(rep: SparseRep) -> List[SparseRep]:
    """A batched ``(B, K)`` rep as B single-row ``(K,)`` reps (numpy)."""
    host = device_get(rep)
    v = host.values.reshape(-1, host.width)
    i = host.indices.reshape(-1, host.width)
    n = host.nnz.reshape(-1)
    return [SparseRep(v[r], i[r], n[r]) for r in range(v.shape[0])]


def truncate_width(rep: SparseRep, k: int) -> SparseRep:
    """Shrink the fixed width to the ``k`` largest-value slots per row.

    The degrade-ladder move on the query side (DESIGN.md §10): a
    narrower query touches fewer posting lists, trading recall for
    latency without re-encoding. Host-side (numpy) — serving queries
    are already on host when search runs. Rows keep the
    value-descending-prefix convention; no-op when ``k >= width``.
    """
    if k >= rep.width:
        return rep
    if k < 1:
        raise ValueError(f"truncate_width needs k >= 1, got {k}")
    v = np.asarray(rep.values, np.float32).reshape(-1, rep.width)
    i = np.asarray(rep.indices, np.int32).reshape(-1, rep.width)
    sel = np.argsort(-v, axis=1, kind="stable")[:, :k]
    rows = np.arange(v.shape[0])[:, None]
    nv, ni = v[rows, sel], i[rows, sel]
    shape = rep.batch_shape
    return SparseRep(
        nv.reshape(*shape, k),
        ni.reshape(*shape, k),
        (nv > 0).sum(axis=1).astype(np.int32).reshape(shape))


def stack_rows(reps: Sequence[SparseRep]) -> SparseRep:
    """Stack single-row (or batched) reps into one ``(N, K)`` rep.

    Widths may differ between sources (e.g. corpora indexed with
    different budgets) — narrower rows are zero-padded to the widest,
    which is a no-op under the padding convention.
    """
    if not reps:
        raise ValueError("stack_rows: empty sequence")
    parts = [device_get(r) if isinstance(r.values, jax.Array) else r
             for r in reps]
    width = max(p.width for p in parts)
    vs, is_, ns = [], [], []
    for p in parts:
        v = p.values.reshape(-1, p.width)
        i = p.indices.reshape(-1, p.width)
        pad = width - p.width
        if pad:
            v = np.pad(v, ((0, 0), (0, pad)))
            i = np.pad(i, ((0, 0), (0, pad)))
        vs.append(np.asarray(v, np.float32))
        is_.append(np.asarray(i, np.int32))
        ns.append(np.asarray(p.nnz).reshape(-1))
    return SparseRep(np.concatenate(vs), np.concatenate(is_),
                     np.concatenate(ns).astype(np.int32))
