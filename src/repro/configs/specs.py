"""Per-cell input specs: ShapeDtypeStruct stand-ins for every model
input of every (architecture × shape) cell — weak-type-correct,
shardable, no device allocation.

``cell_spec(arch_id, shape_name)`` returns a ``CellSpec`` carrying:
  * ``step_kind`` — which step function the cell lowers
    (lsr_train / lsr_prefill / decode / gnn_train / recsys_train /
     recsys_serve / retrieval),
  * ``batch`` — dict of ShapeDtypeStructs for the step's batch arg,
  * ``n_micro`` — gradient-accumulation microbatches for train cells
    (sized so per-chip activations fit v5e HBM; see DESIGN.md §5),
  * static extras (decode cache length etc.).

Static-shape padding conventions (divisibility by the 512-device
multi-pod mesh): edge/triplet/candidate counts are padded up to
multiples of 512; token batches are sharded over the largest batch-axis
prefix that divides them (launch/sharding.py).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

# §Perf: dense (E, K) triplet layout + distributed gather/scatter for
# capped-triplet GNN cells (see models/dimenet.py::forward_dense_triplets
# and EXPERIMENTS.md §Perf). "1" (default) = optimized layout,
# "0" = the flat baseline layout the baseline table was measured with.
DENSE_TRIPLETS = os.environ.get("REPRO_DENSE_TRIPLETS", "1") == "1"

from repro.configs import get_config
from repro.configs.base import (DimeNetConfig, RecSysConfig, ShapeSpec,
                                TransformerConfig)

S = jax.ShapeDtypeStruct


def _pad512(n: int) -> int:
    return n + ((-n) % 512)


@dataclasses.dataclass(frozen=True)
class CellSpec:
    arch: str
    shape: str
    step_kind: str
    batch: Dict[str, Any]
    n_micro: int = 1
    # decode extras
    cache_len: int = 0
    # gnn extras
    n_nodes: int = 0
    n_edges: int = 0
    n_triplets: int = 0
    d_feat: int = 0
    n_graphs: int = 0
    # retrieval extras
    n_candidates: int = 0


# per-(arch, trainshape) microbatch counts — sized so remat-saved layer
# inputs fit per chip (DESIGN.md §5). Larger model => more microbatches.
_N_MICRO = {
    ("llama3_2_3b", "train_4k"): 4,
    ("gemma2_27b", "train_4k"): 8,
    ("phi3_mini", "train_4k"): 4,
    ("moonshot_v1_16b", "train_4k"): 8,
    ("phi3_5_moe", "train_4k"): 8,
}


def _lm_cell(arch: str, cfg: TransformerConfig, spec: ShapeSpec) -> CellSpec:
    B, L = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    if spec.kind == "train":
        pairs = max(1, B // 2)
        batch = {
            "q_tokens": S((pairs, L), i32),
            "q_mask": S((pairs, L), i32),
            "d_tokens": S((pairs, L), i32),
            "d_mask": S((pairs, L), i32),
        }
        return CellSpec(arch, spec.name, "lsr_train", batch,
                        n_micro=_N_MICRO.get((arch, spec.name), 1))
    if spec.kind == "prefill":
        batch = {
            "tokens": S((B, L), i32),
            "mask": S((B, L), i32),
        }
        return CellSpec(arch, spec.name, "lsr_prefill", batch)
    if spec.kind == "decode":
        cdtype = jnp.dtype(cfg.compute_dtype)
        batch = {
            "tokens": S((B, 1), i32),
            "positions": S((B,), i32),
            "cache_k": S((cfg.n_layers, B, L, cfg.n_kv_heads, cfg.d_head),
                         cdtype),
            "cache_v": S((cfg.n_layers, B, L, cfg.n_kv_heads, cfg.d_head),
                         cdtype),
        }
        return CellSpec(arch, spec.name, "decode", batch, cache_len=L)
    raise ValueError(f"unknown LM shape kind {spec.kind}")


def _gnn_cell(arch: str, cfg: DimeNetConfig, spec: ShapeSpec) -> CellSpec:
    i32, f32 = jnp.int32, jnp.float32
    cap = cfg.max_triplets_per_edge

    if spec.kind == "batched_graphs":          # molecule
        n_graphs = spec.n_graphs
        N = _pad512(spec.n_nodes * n_graphs)   # 30 * 128 -> padded
        E = _pad512(spec.n_edges * n_graphs)   # 64 * 128
        T = _pad512(E * 2)                     # exact triplets, avg deg ~2
        batch = {
            "positions": S((N, 3), f32),
            "node_feat": S((N,), i32),
            "node_mask": S((N,), i32),
            "node_graph_id": S((N,), i32),
            "edge_src": S((E,), i32), "edge_dst": S((E,), i32),
            "edge_mask": S((E,), i32),
            "t_in": S((T,), i32), "t_out": S((T,), i32),
            "t_mask": S((T,), i32),
            "target": S((n_graphs, cfg.n_targets), f32),
        }
        return CellSpec(arch, spec.name, "gnn_train", batch,
                        n_nodes=N, n_edges=E, n_triplets=T,
                        n_graphs=n_graphs)

    def triplet_specs(E: int) -> Dict[str, Any]:
        if DENSE_TRIPLETS and cap:
            return {
                "t_in_dense": S((E, cap), i32),
                "t_mask_dense": S((E, cap), i32),
            }
        T = _pad512(E * max(1, cap))
        return {
            "t_in": S((T,), i32), "t_out": S((T,), i32),
            "t_mask": S((T,), i32),
        }

    if spec.kind == "minibatch":               # sampled training
        n_seed = spec.batch_nodes
        # per-hop edge budgets: seeds*f1, seeds*f1*f2 (fanout sampler)
        E_total = _pad512(n_seed * spec.fanout[0]
                          + n_seed * spec.fanout[0] * spec.fanout[1])
        N = _pad512(n_seed + E_total)
        T = _pad512(E_total * max(1, cap))
        d_feat = 602                           # Reddit feature width
        batch = {
            "positions": S((N, 3), f32),       # synthetic coords (DESIGN)
            "node_feat": S((N, d_feat), f32),
            "node_mask": S((N,), i32),
            "edge_src": S((E_total,), i32), "edge_dst": S((E_total,), i32),
            "edge_mask": S((E_total,), i32),
            "seed_ids": S((n_seed,), i32),
            "target": S((n_seed, cfg.n_targets), f32),
            **triplet_specs(E_total),
        }
        return CellSpec(arch, spec.name, "gnn_train", batch,
                        n_nodes=N, n_edges=E_total, n_triplets=T,
                        d_feat=d_feat)

    # full-graph (cora-size and ogb-products-size)
    N = _pad512(spec.n_nodes)
    E = _pad512(spec.n_edges)
    T = _pad512(E * max(1, cap))
    batch = {
        "positions": S((N, 3), f32),
        "node_feat": S((N, spec.d_feat), f32),
        "node_mask": S((N,), i32),
        "edge_src": S((E,), i32), "edge_dst": S((E,), i32),
        "edge_mask": S((E,), i32),
        "target": S((N, cfg.n_targets), f32),
        **triplet_specs(E),
    }
    return CellSpec(arch, spec.name, "gnn_train", batch,
                    n_nodes=N, n_edges=E, n_triplets=T, d_feat=spec.d_feat)


def _recsys_cell(arch: str, cfg: RecSysConfig, spec: ShapeSpec) -> CellSpec:
    i32, f32 = jnp.int32, jnp.float32

    def family_inputs(B: int) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        if cfg.interaction == "dot":
            out["dense"] = S((B, cfg.n_dense), f32)
            out["sparse_idx"] = S((B, cfg.n_sparse), i32)
        elif cfg.interaction == "augru":
            out["hist_idx"] = S((B, cfg.seq_len), i32)
            out["target_idx"] = S((B,), i32)
        else:
            out["sparse_idx"] = S((B, cfg.n_sparse), i32)
        return out

    if spec.kind == "train":
        batch = family_inputs(spec.batch)
        batch["label"] = S((spec.batch,), f32)
        return CellSpec(arch, spec.name, "recsys_train", batch)
    if spec.kind == "serve":
        return CellSpec(arch, spec.name, "recsys_serve",
                        family_inputs(spec.batch))
    if spec.kind == "retrieval":
        NC = _pad512(spec.n_candidates)
        batch = family_inputs(spec.batch)
        batch["candidates"] = S((NC, cfg.embed_dim), f32)
        return CellSpec(arch, spec.name, "retrieval", batch,
                        n_candidates=NC)
    raise ValueError(f"unknown recsys shape kind {spec.kind}")


def cell_spec(arch_id: str, shape_name: str) -> CellSpec:
    mod = get_config(arch_id)
    cfg = mod.CONFIG
    spec = mod.SHAPES[shape_name]
    if spec.skip:
        raise ValueError(
            f"cell ({arch_id}, {shape_name}) is skipped: {spec.skip_reason}")
    if isinstance(cfg, TransformerConfig):
        return _lm_cell(arch_id, cfg, spec)
    if isinstance(cfg, DimeNetConfig):
        return _gnn_cell(arch_id, cfg, spec)
    if isinstance(cfg, RecSysConfig):
        return _recsys_cell(arch_id, cfg, spec)
    raise TypeError(f"unknown config type {type(cfg)}")
