"""Qrels — graded relevance judgments keyed by **external** doc ids.

A ``Qrels`` is the classic TREC structure: for each query id, a
mapping from document id to a relevance grade (> 0 = relevant; higher
= more relevant). Document keys are the *external* ids the retrieval
engine hands out (``IndexBuilder.add`` / ``CorpusEngine.add_docs``
return them, ``search`` returns them back) — external ids survive
delta flushes, tombstoning and compaction by contract (DESIGN.md
§8.4), so one Qrels stays valid across the index's whole mutation
history. Internal slot numbering is never exposed here.

``to_arrays`` emits the padded ``(B, R)`` id/grade arrays the batched
JAX metric path consumes; ``remap_docs`` translates doc keys when a
corpus is re-ingested under fresh external ids.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np


class Qrels:
    """Graded (query, doc, grade) judgments (see module docstring)."""

    def __init__(self,
                 judgments: Mapping[int, Mapping[int, float]] = None):
        self._by_q: Dict[int, Dict[int, float]] = {}
        for q, docs in (judgments or {}).items():
            self._by_q[int(q)] = {int(d): float(g)
                                  for d, g in docs.items()}

    # -- constructors ----------------------------------------------------

    @classmethod
    def from_triples(cls, triples: Iterable[Sequence[float]]) -> "Qrels":
        """From ``(query, doc, grade)`` rows — a list of tuples or an
        ``(M, 3)`` array (``data.synthetic.lsr_impact_corpus`` emits
        one). A repeated (query, doc) pair keeps the highest grade."""
        out = cls()
        for row in np.asarray(list(triples), dtype=np.float64).reshape(-1, 3):
            q, d, g = int(row[0]), int(row[1]), float(row[2])
            docs = out._by_q.setdefault(q, {})
            docs[d] = max(g, docs.get(d, g))
        return out

    @classmethod
    def paired(cls, n: int, *, grade: float = 1.0,
               doc_ids: Optional[Sequence[int]] = None) -> "Qrels":
        """Query i's sole relevant doc is ``doc_ids[i]`` (default: i) —
        the (query, positive-passage) pair shape of MS-MARCO-style
        training data and ``data.synthetic.lsr_pair_batches``."""
        ids = (np.arange(n) if doc_ids is None
               else np.asarray(list(doc_ids)))
        if ids.shape[0] != n:
            raise ValueError(f"{ids.shape[0]} doc ids for {n} queries")
        return cls({q: {int(ids[q]): grade} for q in range(n)})

    # -- lookups ---------------------------------------------------------

    @property
    def query_ids(self) -> List[int]:
        return sorted(self._by_q)

    @property
    def n_queries(self) -> int:
        return len(self._by_q)

    @property
    def n_judged(self) -> int:
        return sum(len(d) for d in self._by_q.values())

    @property
    def max_relevant(self) -> int:
        """Widest per-query judgment set (the R of ``to_arrays``)."""
        return max((len(d) for d in self._by_q.values()), default=0)

    def relevant(self, qid: int) -> Dict[int, float]:
        """``{doc: grade}`` for one query (a copy; empty if unjudged)."""
        return dict(self._by_q.get(int(qid), {}))

    def grade(self, qid: int, doc: int) -> float:
        return self._by_q.get(int(qid), {}).get(int(doc), 0.0)

    def __len__(self) -> int:
        return len(self._by_q)

    def __repr__(self) -> str:
        return (f"Qrels(n_queries={self.n_queries}, "
                f"n_judged={self.n_judged})")

    # -- transforms ------------------------------------------------------

    def remap_docs(self, mapping: Mapping[int, int],
                   *, strict: bool = True) -> "Qrels":
        """Qrels with doc keys translated through ``mapping`` (old
        external id -> new external id) — for a corpus re-ingested
        under fresh ids. ``strict=False`` drops unmapped docs instead
        of raising."""
        out: Dict[int, Dict[int, float]] = {}
        for q, docs in self._by_q.items():
            new: Dict[int, float] = {}
            for d, g in docs.items():
                if d in mapping:
                    new[int(mapping[d])] = g
                elif strict:
                    raise KeyError(
                        f"doc {d} (query {q}) has no entry in the "
                        f"remap — pass strict=False to drop it")
            if new:
                out[q] = new
        return Qrels(out)

    def to_arrays(self, query_ids: Optional[Sequence[int]] = None,
                  *, width: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded judgment arrays for the batched JAX metric path.

        Returns ``(rel_ids (B, R) int64, rel_grades (B, R) float32)``
        over ``query_ids`` (default: all judged queries, sorted);
        unused slots hold id -1 / grade 0 — exactly the "no match"
        conventions ``metrics.ranked_grades`` treats as absent.
        ``width`` pins R (>= the widest requested judgment set).
        """
        qids = (self.query_ids if query_ids is None
                else [int(q) for q in query_ids])
        need = max((len(self._by_q.get(q, {})) for q in qids), default=0)
        r = width if width is not None else max(need, 1)
        if r < need:
            raise ValueError(f"width {r} < widest judgment set {need}")
        ids = np.full((len(qids), r), -1, np.int64)
        grades = np.zeros((len(qids), r), np.float32)
        for b, q in enumerate(qids):
            docs = self._by_q.get(q, {})
            for j, (d, g) in enumerate(sorted(docs.items())):
                ids[b, j] = d
                grades[b, j] = g
        return ids, grades
