"""2D hybrid (doc × term) sharding + ShardPlan placement tests
(DESIGN.md §14).

The acceptance anchors:

* ``method="shard2d"`` returns top-k ids identical to
  ``method="impact"`` at every tested grid shape — (1×1), (2×2),
  (1×4), (4×1) and a non-square (3×2) — including uneven vocab cuts
  and uneven doc chunks: the psum-over-terms / top-k-merge-over-docs
  composition must be invisible in the results;
* the two-tier MaxScore composition across BOTH axes (per-cell
  ceilings psum'd over terms, scatter-maxed over chunks, exact
  rescore from forward rows) is id-identical at ``prune_margin=0``;
* ``plan_placement`` accounts posting mass, the replicated O(V)
  directory and forward rows: huge-vocab corpora get term-bearing
  grids, small-vocab ones stay doc-only, spare devices under an HBM
  budget become whole-grid replicas, and infeasible budgets say so
  loudly instead of silently overcommitting;
* the ``shard_map`` path on a forced multi-host-device 2D mesh
  matches the single-device scorer in BOTH mesh orientations
  (``plan.axis_order``) — subprocess, device count from
  ``REPRO_SHARD_TEST_DEVICES`` (CI's multidevice job runs it 4-wide).
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import lsr_impact_corpus
from repro.retrieval import (CorpusStats, IndexBuilder, ShardPlan,
                             build_inverted_index, choose_shard_axis,
                             plan_placement, retrieve, shard2d_index,
                             shard2d_retrieve, sparsify_threshold,
                             sparsify_topk)
from repro.retrieval.engine.shard2d import (DIR_BYTES_PER_TERM,
                                            mass_balanced_boundaries)

K = 10
BENCH = dict(n_docs=1024, vocab=1024, doc_nnz=32, n_queries=8,
             q_nnz=28)


@pytest.fixture(scope="module")
def graded():
    data = lsr_impact_corpus(**BENCH)
    q = sparsify_topk(jnp.asarray(data["queries"]), BENCH["q_nnz"])
    d = sparsify_topk(jnp.asarray(data["docs"]), BENCH["doc_nnz"])
    vals, idx = retrieve(q, build_inverted_index(d, BENCH["vocab"]), K,
                         method="impact")
    return {"q": q, "d": d, "vals": np.asarray(vals),
            "idx": np.asarray(idx)}


def _small(rng, n, nnz, vocab, lo=0, hi=None):
    """Random sparse rows whose active terms lie in [lo, hi)."""
    hi = vocab if hi is None else hi
    m = np.zeros((n, vocab), np.float32)
    for r in range(n):
        cols = lo + rng.choice(hi - lo, size=nnz, replace=False)
        m[r, cols] = rng.uniform(0.1, 2.0, size=nnz)
    return m


def _rep(m, nnz=8):
    return sparsify_threshold(jnp.asarray(m), 0.0, max_nnz=nnz)


# ---------------------------------------------------------------------------
# planner: budget boundaries, replica emission, the deprecation shim
# ---------------------------------------------------------------------------

def test_plan_no_budget_small_vocab_stays_doc_only():
    # the 30k-vocab regime: the replicated directory is a rounding
    # error next to any per-device posting slice
    stats = CorpusStats(posting_bytes=8 * 50_000 * 16,
                        vocab_size=30_000, n_docs=50_000)
    plan = plan_placement(stats, 4)
    assert (plan.doc_shards, plan.term_shards) == (4, 1)
    assert plan.axis == "doc"
    assert "doc-only" in plan.reason


def test_plan_no_budget_huge_vocab_gets_term_shards():
    # the 250k-vocab multilingual regime: the O(V) directory dominates
    # the posting slice until the vocab is cut
    stats = CorpusStats(posting_bytes=8 * 50_000 * 16,
                        vocab_size=250_000, n_docs=50_000)
    plan = plan_placement(stats, 4)
    assert plan.term_shards >= 2
    assert plan.grid == 4
    directory = DIR_BYTES_PER_TERM * stats.vocab_size
    assert (directory / plan.term_shards
            <= stats.posting_bytes / 4)


def test_plan_no_budget_term_only_extreme():
    # directory dwarfs postings at every narrower cut
    stats = CorpusStats(posting_bytes=1_000, vocab_size=250_000,
                        n_docs=10)
    plan = plan_placement(stats, 4)
    assert (plan.doc_shards, plan.term_shards) == (1, 4)
    assert plan.axis == "term"


def test_plan_budget_emits_replicas():
    # corpus fits on one device with room: every spare device becomes
    # a whole-grid throughput replica
    stats = CorpusStats(posting_bytes=1_000, vocab_size=100, n_docs=50)
    plan = plan_placement(stats, 8, per_device_hbm=10**9)
    assert (plan.doc_shards, plan.term_shards) == (1, 1)
    assert plan.replicas == 8
    assert plan.n_devices == 8
    assert "replicas" in plan.reason


def test_plan_budget_boundaries():
    # per-device footprints: 1x1 = 1000 + 120 = 1120,
    # 2x1 = 500 + 120 = 620, 1x2 = 500 + 60 = 560
    stats = CorpusStats(posting_bytes=1_000, vocab_size=10, n_docs=50)
    assert ShardPlan(1, 1).per_device_bytes(stats) == 1120
    assert ShardPlan(2, 1).per_device_bytes(stats) == 620
    assert ShardPlan(1, 2).per_device_bytes(stats) == 560
    # 700 B: 1x1 is over, 2x1 fits and wins (doc merge is cheaper
    # than the term psum, so equal-size grids prefer fewer term cuts)
    plan = plan_placement(stats, 4, per_device_hbm=700)
    assert (plan.doc_shards, plan.term_shards) == (2, 1)
    assert plan.replicas == 2
    # 600 B: only the term cut trims the directory enough
    plan = plan_placement(stats, 4, per_device_hbm=600)
    assert (plan.doc_shards, plan.term_shards) == (1, 2)
    # exact boundary is feasible
    plan = plan_placement(stats, 4, per_device_hbm=620)
    assert (plan.doc_shards, plan.term_shards) == (2, 1)


def test_plan_over_budget_says_so():
    stats = CorpusStats(posting_bytes=10**9, vocab_size=10**6,
                        n_docs=10**6)
    plan = plan_placement(stats, 4, per_device_hbm=10)
    assert plan.grid == 4        # full-device grid, smallest footprint
    assert plan.replicas == 1
    assert "OVER BUDGET" in plan.reason


def test_plan_forward_bytes_are_replicated_per_device():
    # forward rows are stored once per device, never divided by the
    # grid — the planner must charge them at full price
    base = CorpusStats(posting_bytes=8_000, vocab_size=10, n_docs=100)
    fwd = CorpusStats(posting_bytes=8_000, vocab_size=10, n_docs=100,
                      forward_bytes=5_000)
    assert (ShardPlan(2, 2).per_device_bytes(fwd)
            - ShardPlan(2, 2).per_device_bytes(base)) == 5_000


def test_plan_validation():
    with pytest.raises(ValueError, match="n_devices"):
        plan_placement(CorpusStats(1, 1, 1), 0)
    with pytest.raises(ValueError, match="doc_shards"):
        ShardPlan(doc_shards=0, term_shards=1)
    with pytest.raises(ValueError, match="replicas"):
        ShardPlan(1, 1, replicas=0)
    with pytest.raises(ValueError, match="axis_order"):
        ShardPlan(1, 1, axis_order=("doc", "doc"))


def test_plan_axis_and_describe():
    assert ShardPlan(4, 1).axis == "doc"
    assert ShardPlan(1, 4).axis == "term"
    assert ShardPlan(2, 2).axis == "2d"
    assert "2x2" in ShardPlan(2, 2).describe()
    assert "x3 replicas" in ShardPlan(1, 1, replicas=3).describe()


def test_choose_shard_axis_shim_reports_2d():
    # the legacy string API can only name the 2D grid, not shape it
    with pytest.warns(DeprecationWarning, match="plan_placement"):
        axis = choose_shard_axis(8 * 50_000 * 16, 250_000, 4)
    assert axis == "2d"


def test_corpus_stats_from_index():
    rng = np.random.default_rng(7)
    rep = _rep(_small(rng, 20, 6, 64))
    idx = build_inverted_index(rep, 64, keep_forward=True)
    stats = CorpusStats.from_index(idx)
    assert stats.posting_bytes == 8 * idx.n_postings
    assert stats.vocab_size == 64 and stats.n_docs == 20
    assert stats.forward_bytes > 0
    bare = CorpusStats.from_rep(rep, 64)
    assert bare.n_docs == 20 and bare.forward_bytes == 0


# ---------------------------------------------------------------------------
# mass-balanced vocab cuts (shared with term_sharded — satellite 3)
# ---------------------------------------------------------------------------

def test_mass_balanced_boundaries_isolate_stopword():
    # one term owns ~87% of all postings: the quantile cuts give it a
    # (nearly) private range instead of width-slicing around it
    counts = np.ones(16, np.int64)
    counts[0] = 100
    assert mass_balanced_boundaries(counts, 4) == (0, 1, 2, 3, 16)


def test_mass_balanced_boundaries_degenerate():
    # zero mass falls back to width cuts; too many shards is loud
    assert mass_balanced_boundaries(np.zeros(8, np.int64), 4) == \
        (0, 2, 4, 6, 8)
    with pytest.raises(ValueError, match="exceeds vocab"):
        mass_balanced_boundaries(np.ones(4, np.int64), 5)


def test_mass_cuts_shrink_skewed_padding_and_keep_parity():
    """Skew regression: a stopword-heavy term makes one width-cut
    range dwarf the rest, and the stacked layout pads every cell to
    it. Mass cuts bound the padding — and both layouts stay
    id-identical to impact."""
    rng = np.random.default_rng(11)
    m = _small(rng, 96, 6, 128, lo=1)
    m[:, 0] = rng.uniform(0.5, 1.0, size=96)    # term 0 in every doc
    d = _rep(m, nnz=8)
    q = _rep(_small(rng, 4, 5, 128), nnz=6)
    ref = build_inverted_index(d, 128, stopword_warn_frac=1.1)
    v_ref, i_ref = retrieve(q, ref, 7, method="impact")
    by_mass = shard2d_index(d, 128, 2, 4)               # default
    by_width = shard2d_index(d, 128, 2, 4, balance="width")
    # padded posting width: the width cut pays the stopword everywhere
    assert (by_mass.postings_val.shape[-1]
            < by_width.postings_val.shape[-1])
    for idx in (by_mass, by_width):
        vals, ext = shard2d_retrieve(q, idx, 7)
        np.testing.assert_array_equal(np.asarray(ext),
                                      np.asarray(i_ref))
        np.testing.assert_allclose(np.asarray(vals),
                                   np.asarray(v_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# exact retrieval parity (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("grid", [(1, 1), (2, 2), (1, 4), (4, 1),
                                  (3, 2)])
def test_shard2d_matches_impact(graded, grid):
    idx = shard2d_index(graded["d"], BENCH["vocab"], *grid)
    vals, ext = retrieve(graded["q"], idx, K, method="shard2d")
    np.testing.assert_array_equal(np.asarray(ext), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)


def test_shard2d_auto_dispatch(graded):
    # method="auto" routes a Shard2DIndex to the 2D scorer
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2)
    _, ext = retrieve(graded["q"], idx, K)
    np.testing.assert_array_equal(np.asarray(ext), graded["idx"])


def test_shard2d_uneven_boundaries(graded):
    # uneven doc chunks AND uneven vocab cuts: the chunk-start scatter
    # and range routing must still reassemble global ids exactly
    idx = shard2d_index(
        graded["d"], BENCH["vocab"], 3, 2,
        doc_boundaries=[0, 100, 700, BENCH["n_docs"]],
        term_boundaries=[0, 100, BENCH["vocab"]])
    vals, ext = retrieve(graded["q"], idx, K, method="shard2d")
    np.testing.assert_array_equal(np.asarray(ext), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)


def test_shard2d_empty_cells_width_cuts():
    # all posting mass lives in vocab [0, 32): with width cuts three
    # of four term ranges hold empty cells that must contribute
    # exactly zero to the psum
    rng = np.random.default_rng(3)
    d = _rep(_small(rng, 40, 6, 128, hi=32))
    q = _rep(_small(rng, 3, 5, 128, hi=32), nnz=6)
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 128), 5,
                            method="impact")
    idx = shard2d_index(d, 128, 2, 4, balance="width")
    vals, ext = shard2d_retrieve(q, idx, 5)
    np.testing.assert_array_equal(np.asarray(ext), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               atol=1e-4)


def test_shard2d_single_query():
    rng = np.random.default_rng(5)
    d = _rep(_small(rng, 64, 8, 96))
    q = _rep(_small(rng, 1, 5, 96), nnz=6)
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 96), 9,
                            method="impact")
    vals, ext = shard2d_retrieve(q, shard2d_index(d, 96, 4, 2), 9)
    assert np.asarray(ext).shape == (1, 9)
    np.testing.assert_array_equal(np.asarray(ext), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), gi=st.integers(0, 3))
def test_shard2d_parity_property(seed, gi):
    """Property: any small corpus, any grid shape — ids and scores
    match the unsharded impact scorer exactly."""
    rng = np.random.default_rng(seed)
    d = _rep(_small(rng, 48, 6, 64))
    q = _rep(_small(rng, 3, 4, 64), nnz=5)
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 64), 5,
                            method="impact")
    grid = [(1, 1), (2, 2), (3, 1), (1, 3)][gi]
    vals, ext = shard2d_retrieve(q, shard2d_index(d, 64, *grid), 5)
    np.testing.assert_array_equal(np.asarray(ext), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# the pruned two-tier composition across both axes
# ---------------------------------------------------------------------------

def test_shard2d_pruned_margin0_is_id_identical(graded):
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2,
                        keep_forward=True)
    vals, ext = retrieve(graded["q"], idx, K, method="shard2d",
                         prune_margin=0.0)
    np.testing.assert_array_equal(np.asarray(ext), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)
    # aggressive margin keeps the clear graded winner
    _, aggr = retrieve(graded["q"], idx, K, method="shard2d",
                       prune_margin=0.5)
    np.testing.assert_array_equal(np.asarray(aggr)[:, 0],
                                  graded["idx"][:, 0])


def test_shard2d_pruned_needs_forward_rows(graded):
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2)
    with pytest.raises(ValueError, match="keep_forward"):
        shard2d_retrieve(graded["q"], idx, K, prune_margin=0.0)


def test_shard2d_prune_margin_validation(graded):
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2,
                        keep_forward=True)
    with pytest.raises(ValueError, match="prune_margin"):
        shard2d_retrieve(graded["q"], idx, K, prune_margin=1.5)


# ---------------------------------------------------------------------------
# build validation, tombstoning, plan threading through retrieve()
# ---------------------------------------------------------------------------

def test_shard2d_build_validation(graded):
    with pytest.raises(ValueError, match="shard counts"):
        shard2d_index(graded["d"], BENCH["vocab"], 0, 2)
    with pytest.raises(ValueError, match="exceeds vocab"):
        shard2d_index(graded["d"], 4, 1, 5)
    with pytest.raises(ValueError, match="exceeds corpus"):
        rng = np.random.default_rng(0)
        shard2d_index(_rep(_small(rng, 4, 4, 32)), 32, 8, 1)
    with pytest.raises(ValueError, match="balance"):
        shard2d_index(graded["d"], BENCH["vocab"], 2, 2,
                      balance="luck")
    with pytest.raises(ValueError, match="strictly increasing"):
        shard2d_index(graded["d"], BENCH["vocab"], 2, 2,
                      term_boundaries=[0, 512, 512, BENCH["vocab"]])


def test_shard2d_zero_docs_tombstones(graded):
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2,
                        keep_forward=True)
    victim = int(graded["idx"][0, 0])
    idx2 = idx.zero_docs([victim])
    _, ext = shard2d_retrieve(graded["q"], idx2, K)
    assert victim not in np.asarray(ext)[0]
    # the original index is untouched (functional update)
    _, ext0 = shard2d_retrieve(graded["q"], idx, K)
    assert victim in np.asarray(ext0)[0]


def test_retrieve_validates_plan_against_index(graded):
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2)
    with pytest.raises(ValueError, match="does not match"):
        retrieve(graded["q"], idx, K, method="shard2d",
                 plan=ShardPlan(4, 1))
    # a matching plan threads through cleanly
    _, ext = retrieve(graded["q"], idx, K, method="shard2d",
                      plan=ShardPlan(2, 2))
    np.testing.assert_array_equal(np.asarray(ext), graded["idx"])


def test_retrieve_rejects_plan_on_unsharded_methods(graded):
    inv = build_inverted_index(graded["d"], BENCH["vocab"])
    with pytest.raises(ValueError, match="does not accept"):
        retrieve(graded["q"], inv, K, method="impact",
                 plan=ShardPlan(1, 1))


def test_retrieve_axis_name_kwarg_is_gone(graded):
    # the per-method axis_name= kwarg was collapsed into plan=; it is
    # no longer in the signature at all
    idx = shard2d_index(graded["d"], BENCH["vocab"], 2, 2)
    with pytest.raises(TypeError):
        retrieve(graded["q"], idx, K, method="shard2d",
                 axis_name="model")


# ---------------------------------------------------------------------------
# incremental builder + serving integration
# ---------------------------------------------------------------------------

def test_builder_2d_base(graded):
    b = IndexBuilder(BENCH["vocab"], plan=ShardPlan(2, 2))
    b.add(graded["d"])
    vals, ext = b.search(graded["q"], K)
    np.testing.assert_array_equal(ext, graded["idx"])
    np.testing.assert_allclose(vals, graded["vals"], atol=1e-4)
    s = b.stats()
    assert s["doc_shards"] == 2 and s["grid_term_shards"] == 2
    # tombstoning zeroes chunk-local postings across all cells
    victim = int(ext[0, 0])
    b.remove([victim])
    _, ext2 = b.search(graded["q"], K)
    assert victim not in ext2
    with pytest.raises(ValueError, match="not both"):
        IndexBuilder(BENCH["vocab"], plan=ShardPlan(2, 2),
                     term_shards=2)
    with pytest.raises(ValueError, match="exclusive"):
        IndexBuilder(BENCH["vocab"], plan=ShardPlan(2, 2),
                     quantize=True)


def test_builder_2d_base_serves_pruned_search(graded):
    """search(method='pruned') on a 2D base must route to the 2D
    two-tier composition (safe margin: ids == impact)."""
    b = IndexBuilder(BENCH["vocab"], plan=ShardPlan(2, 2),
                     keep_forward=True)
    b.add(graded["d"])
    vals, ext = b.search(graded["q"], K, method="pruned",
                         prune_margin=0.0)
    np.testing.assert_array_equal(ext, graded["idx"])
    np.testing.assert_allclose(vals, graded["vals"], atol=1e-4)


def test_builder_2d_base_with_raw_delta():
    """Base 2D, delta raw: the merged search must equal a frozen
    unsharded build over all rows."""
    rng = np.random.default_rng(4)
    D = _small(rng, 60, 8, 128)
    Q = _small(rng, 4, 6, 128)
    q = _rep(Q)
    v_ref, i_ref = retrieve(q, build_inverted_index(_rep(D), 128), 7,
                            method="impact")
    b = IndexBuilder(128, plan=ShardPlan(2, 2), merge_frac=0.5)
    b.add(_rep(D[:48]))
    b.flush()
    b.add(_rep(D[48:]))
    vals, ext = b.search(q, 7)
    assert b.stats()["delta_docs"] == 12    # delta kept, not merged
    np.testing.assert_array_equal(ext, np.asarray(i_ref))
    np.testing.assert_allclose(vals, np.asarray(v_ref), atol=1e-4)


def test_corpus_engine_plan():
    from repro.retrieval import sparsify_topk as topk
    from repro.runtime.serving import (BatchedEncoder, BatchPolicy,
                                       CorpusEngine)

    def encode(tokens, mask):
        B = tokens.shape[0]
        out = np.zeros((B, 32), np.float32)
        for i in range(B):
            for t, m in zip(np.asarray(tokens[i]), np.asarray(mask[i])):
                if m:
                    out[i, int(t) % 32] += 1
        return topk(jnp.asarray(out), 4)

    eng = CorpusEngine(
        BatchedEncoder(encode, policy=BatchPolicy(max_batch=8)), 32,
        plan=ShardPlan(2, 2))
    eng.add_docs([np.array([d, d, d], np.int32) for d in range(6)])
    q = topk(jnp.asarray(np.eye(32, dtype=np.float32)[[3]] * 5), 4)
    _, ext = eng.search(q, 2)
    assert ext[0, 0] == 3
    s = eng.stats()
    assert s["doc_shards"] == 2 and s["grid_term_shards"] == 2
    with pytest.raises(ValueError, match="not both"):
        CorpusEngine(BatchedEncoder(encode), 32, plan=ShardPlan(2, 2),
                     shard_axis="term", n_shards=2)


# ---------------------------------------------------------------------------
# shard_map on a 2D mesh (subprocess, forced host devices)
# ---------------------------------------------------------------------------

_SHARD2D_SCRIPT = textwrap.dedent("""
    import os
    n = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "2"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}")
    import jax, jax.numpy as jnp, numpy as np
    from repro.data.synthetic import lsr_impact_corpus
    from repro.retrieval import (ShardPlan, build_inverted_index,
                                 retrieve, shard2d_index,
                                 shard2d_retrieve, sparsify_topk)

    assert jax.device_count() >= n, jax.devices()
    data = lsr_impact_corpus(n_docs=192, vocab=256, doc_nnz=16,
                             n_queries=4, q_nnz=14, graded=6)
    q = sparsify_topk(jnp.asarray(data["queries"]), 14)
    d = sparsify_topk(jnp.asarray(data["docs"]), 16)
    k = 4
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 256), k,
                            method="impact")

    grids = {1: [(1, 1)], 2: [(2, 1), (1, 2)],
             4: [(2, 2), (4, 1), (1, 4)]}[n]
    for dd, tt in grids:
        idx = shard2d_index(d, 256, dd, tt, keep_forward=True)
        mesh = jax.make_mesh((dd, tt), ("x", "y"))
        # exact: psum over terms, all_gather + re-top-k over docs
        v_sm, i_sm = shard2d_retrieve(q, idx, k, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(i_sm),
                                      np.asarray(i_ref))
        np.testing.assert_allclose(np.asarray(v_sm),
                                   np.asarray(v_ref), atol=1e-4)
        # transposed mesh orientation via plan.axis_order
        tmesh = jax.make_mesh((tt, dd), ("ty", "tx"))
        plan = ShardPlan(dd, tt, axis_order=("term", "doc"))
        v_t, i_t = shard2d_retrieve(q, idx, k, mesh=tmesh, plan=plan)
        np.testing.assert_array_equal(np.asarray(i_t),
                                      np.asarray(i_ref))
        # pruned composition at the safe margin
        v_pr, i_pr = shard2d_retrieve(q, idx, k, mesh=mesh,
                                      prune_margin=0.0)
        np.testing.assert_array_equal(np.asarray(i_pr),
                                      np.asarray(i_ref))
        # the retrieve() dispatcher threads mesh + plan through
        v_d, i_d = retrieve(q, idx, k, mesh=mesh,
                            plan=ShardPlan(dd, tt))
        np.testing.assert_array_equal(np.asarray(i_d),
                                      np.asarray(i_ref))
    # grid / mesh-shape mismatch is a loud error
    if n > 1:
        dd, tt = grids[0]
        idx = shard2d_index(d, 256, dd, tt)
        bad = jax.make_mesh((1, 1, n), ("a", "b", "c"))
        try:
            shard2d_retrieve(q, idx, k, mesh=bad)
            raise SystemExit("mismatch not rejected")
        except ValueError as e:
            assert "must equal mesh axis" in str(e), e
    print("ALL_SHARD2D_PASSED")
""")


def test_shard2d_multi_device_subprocess():
    """The 2D shard_map path on a forced multi-host-device mesh == the
    unsharded impact scorer — every grid factorization of the device
    count, both mesh orientations, exact and pruned tiers (device
    count from REPRO_SHARD_TEST_DEVICES; CI's multidevice job sets
    4)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD2D_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL_SHARD2D_PASSED" in proc.stdout
