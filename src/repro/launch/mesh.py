"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model).

``pod`` and ``data`` both carry data parallelism (gradient psum spans
both); ``model`` carries tensor parallelism for the trunk AND the
vocabulary/table sharding of the Sparton head + embeddings (the
paper's axis of interest) AND expert parallelism for MoE.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(shape: Tuple[int, ...],
                  axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary mesh (elastic re-mesh path + tests)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
