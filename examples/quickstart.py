"""Quickstart: the Sparton head as a drop-in JAX module.

Shows the paper's core contribution in 40 lines: encode a batch of
token sequences into sparse lexical vectors with the fused,
memory-lean LM head — and differentiate through it with O(B*V)
residuals instead of O(B*S*V).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.lm_head import (lm_head_naive, lm_head_sparton,
                                sparton_forward_with_indices)

B, S, D, V = 4, 64, 128, 30522  # bert-base-uncased vocabulary

key = jax.random.PRNGKey(0)
kh, ke, kb, km = jax.random.split(key, 4)
H = jax.random.normal(kh, (B, S, D))          # backbone hidden states
E = jax.random.normal(ke, (V, D)) * 0.05      # vocab embedding matrix
b = jax.random.normal(kb, (V,)) * 0.05        # head bias
mask = (jax.random.uniform(km, (B, S)) > 0.1).astype(jnp.int32)

# --- forward: sparse lexical reps, identical to the naive head -------
y_sparton = lm_head_sparton(H, E, b, mask)
y_naive = lm_head_naive(H, E, b, mask)
print("output shape:", y_sparton.shape)
print("max |sparton - naive|:",
      float(jnp.max(jnp.abs(y_sparton - y_naive))))
nnz = float(jnp.mean(jnp.sum(y_sparton > 0, axis=-1)))
print(f"active vocab dims per example: {nnz:.0f} / {V} "
      "(untrained weights are dense; the FLOPS regularizer induces "
      "sparsity during training — see examples/train_splade.py)")

# --- the memory story: residuals are (y, i_max), not (B, S, V) --------
def contrastive_ish_loss(H, E, b):
    y = lm_head_sparton(H, E, b, mask)
    return jnp.sum(y * y)

grads = jax.grad(contrastive_ish_loss, argnums=(0, 1, 2))(H, E, b)
print("grad shapes:", [g.shape for g in grads])

# --- interpretability: which token activated each vocab dim -----------
y, i_max = sparton_forward_with_indices(H, E, b, mask)
top_dims = jnp.argsort(-y[0])[:5]
print("example 0 — top vocab dims:", top_dims.tolist(),
      "activated at tokens:", i_max[0, top_dims].tolist())
