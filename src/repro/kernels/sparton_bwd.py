"""Sparton fused LM-head backward v2 — Pallas TPU kernels.

The paper's Alg. 3 computes, per (b, v), the activation-derivative
factor ``g`` and scatters ``g*E[v]`` into ``dH[b, i_max]`` / gathers
``H[b, i_max]`` into ``dE[v]`` using *atomic* accumulation across GPU
thread blocks. TPU Pallas has no atomics; instead we exploit the
sequential grid to accumulate deterministically (DESIGN.md §3):

* ``dH`` kernel — grid ``(B/bb, S/bs, V/bv)``, vocab innermost: each
  ``(b, s)`` tile of ``dH`` accumulates
  ``sum_v g[b,v] * onehot(i_max[b,v], s) * E[v]``.
* ``dE`` kernel — grid ``(V/bv, B/bb, S/bs)``, batch/seq innermost:
  each vocab tile of ``dE`` accumulates
  ``sum_b g[b,v] * onehot(i_max[b,v], s) * H[b,s]``.

v2 over v1 (DESIGN.md §"Kernel v2"):

* **Fused epilogue** — the kernels take the raw upstream cotangent
  ``dy`` and the stored post-activation ``y`` and evaluate ``g = dy *
  f'(y)`` per VMEM tile (``_common.bwd_factor``). v1 materialized ``g``
  with a standalone ``(B, V)`` elementwise pass: one full HBM write +
  two reads of a ``(B, V)`` f32 tensor, gone. The factor is recomputed
  by both kernels — a few VPU ops per tile versus a ``(B, V)`` HBM
  round-trip.
* **Fused bias gradient** — ``db = sum_b g`` accumulates in the dE
  kernel's scratch (one extra ``(1, bv)`` vector), so the wrapper's
  separate ``jnp.sum`` over a re-read ``g`` is gone too.
* **VMEM scratch accumulators** — both kernels accumulate into
  ``scratch_shapes`` and store each output tile to HBM exactly once at
  their finalize step, mirroring the forward's single-store guarantee.
* The weighted one-hot tile construction is shared between the two
  contractions via ``_common.onehot_weights``. (The contractions
  themselves must stay in separate kernels: dH tiles are indexed by
  (b, s) and dE tiles by (v), so no single grid order visits both
  accumulators in consecutive steps — the precondition for
  deterministic revisit-accumulation on Mosaic pipelines.)

Gather/scatter by ``i_max`` is re-expressed as a *one-hot contraction*
(``onehot(i_max) @ E`` / ``(onehot*g)^T @ H``) so the irregular memory
access becomes an MXU matmul — the TPU-native replacement for GPU
scattered atomics.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import bwd_factor, onehot_weights, pad_to


def _dh_kernel(
    dy_ref,    # (bb, bv) f32 — raw upstream cotangent
    y_ref,     # (bb, bv) f32 — stored post-activation
    i_ref,     # (bb, bv) i32 — argmax sequence index
    e_ref,     # (bv, D)
    dh_ref,    # (bb, bs, D) out — written once, at finalize
    acc_ref,   # (bb, bs, D) f32 VMEM scratch
    *,
    n_v_blocks: int,
    block_s: int,
    softcap: Optional[float],
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    bb, bs, d = dh_ref.shape
    k = pl.program_id(1)

    g = bwd_factor(y_ref[...], dy_ref[...], softcap)     # fused epilogue
    local_i = i_ref[...] - k * block_s          # (bb, bv); in-range => hit
    w = onehot_weights(g, local_i, bs)          # (bb, bs, bv)
    # dH[b, s, :] += sum_v w[b, s, v] * E[v, :]  — one MXU contraction.
    contrib = jax.lax.dot_general(
        w.reshape(bb * bs, -1), e_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).reshape(bb, bs, d)
    acc_ref[...] += contrib

    @pl.when(j == n_v_blocks - 1)
    def _finalize():
        dh_ref[...] = acc_ref[...]


def _de_kernel(
    dy_ref,    # (bb, bv) f32
    y_ref,     # (bb, bv) f32
    i_ref,     # (bb, bv) i32
    h_ref,     # (bb, bs, D)
    de_ref,    # (bv, D) out — written once, at finalize
    db_ref,    # (1, bv) f32 out — fused bias gradient
    de_acc,    # (bv, D) f32 VMEM scratch
    db_acc,    # (1, bv) f32 VMEM scratch
    *,
    n_b_blocks: int,
    n_s_blocks: int,
    block_s: int,
    softcap: Optional[float],
):
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        de_acc[...] = jnp.zeros(de_acc.shape, jnp.float32)
        db_acc[...] = jnp.zeros(db_acc.shape, jnp.float32)

    bb, bs, _ = h_ref.shape

    g = bwd_factor(y_ref[...], dy_ref[...], softcap)     # fused epilogue
    local_i = i_ref[...] - k * block_s
    w = onehot_weights(g, local_i, bs).reshape(bb * bs, -1)
    # dE[v, :] += sum_{b,s} w[bs, v] * H[bs, :]
    contrib = jax.lax.dot_general(
        w, h_ref[...].reshape(bb * bs, -1).astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    de_acc[...] += contrib

    # db[v] = sum_b g[b, v] — independent of s, so add once per b block.
    @pl.when(k == 0)
    def _db():
        db_acc[...] += jnp.sum(g, axis=0, keepdims=True)

    @pl.when((i == n_b_blocks - 1) & (k == n_s_blocks - 1))
    def _finalize():
        de_ref[...] = de_acc[...]
        db_ref[...] = db_acc[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_s", "block_v", "softcap",
                     "interpret"),
)
def _backward_call(
    dy, y, i_max, H, E, *, block_b, block_s, block_v, softcap, interpret
):
    B, S, D = H.shape
    V = E.shape[0]

    dyp = pad_to(pad_to(dy.astype(jnp.float32), 0, block_b), 1, block_v)
    # Padded rows/cols must not route anywhere real: y == 0 there, so
    # bwd_factor yields g == 0 and any index is safe.
    yp = pad_to(pad_to(y.astype(jnp.float32), 0, block_b), 1, block_v)
    ip = pad_to(pad_to(i_max, 0, block_b), 1, block_v)
    Hp = pad_to(pad_to(H, 0, block_b), 1, block_s)
    Ep = pad_to(E, 0, block_v)

    Bp, Sp, _ = Hp.shape
    Vp = Ep.shape[0]
    nb, ns, nv = Bp // block_b, Sp // block_s, Vp // block_v

    bv_spec = pl.BlockSpec((block_b, block_v), lambda i, k, j: (i, j))
    dH = pl.pallas_call(
        functools.partial(_dh_kernel, n_v_blocks=nv, block_s=block_s,
                          softcap=softcap),
        grid=(nb, ns, nv),
        in_specs=[
            bv_spec,
            bv_spec,
            bv_spec,
            pl.BlockSpec((block_v, D), lambda i, k, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, block_s, D), lambda i, k, j: (i, k, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((Bp, Sp, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b, block_s, D), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(dyp, yp, ip, Ep)

    vb_spec = pl.BlockSpec((block_b, block_v), lambda j, i, k: (i, j))
    dE, db = pl.pallas_call(
        functools.partial(
            _de_kernel, n_b_blocks=nb, n_s_blocks=ns, block_s=block_s,
            softcap=softcap,
        ),
        grid=(nv, nb, ns),
        in_specs=[
            vb_spec,
            vb_spec,
            vb_spec,
            pl.BlockSpec((block_b, block_s, D), lambda j, i, k: (i, k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_v, D), lambda j, i, k: (j, 0)),
            pl.BlockSpec((1, block_v), lambda j, i, k: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Vp, D), jnp.float32),
            jax.ShapeDtypeStruct((1, Vp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_v, D), jnp.float32),
            pltpu.VMEM((1, block_v), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(dyp, yp, ip, Hp)

    return dH[:B, :S], dE[:V], db[0, :V]


def sparton_backward(
    dy: jax.Array,      # (B, V) — raw upstream cotangent (any float dtype)
    y: jax.Array,       # (B, V) f32 — stored post-activation
    i_max: jax.Array,   # (B, V) i32
    H: jax.Array,       # (B, S, D) f32 or bf16
    E: jax.Array,       # (V, D) f32 or bf16
    *,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused backward. Returns (dH (B,S,D), dE (V,D), db (V,)) in f32.

    The activation-derivative factor and the bias gradient are fused
    into the kernels — no standalone elementwise pass over ``(B, V)``.
    Block sizes default to the autotuner's choice for the call shape.
    """
    if block_b is None or block_s is None or block_v is None:
        from repro.kernels.autotune import resolve_blocks  # avoids cycle

        B, S, D = H.shape
        block_b, block_s, block_v = resolve_blocks(
            B, S, D, E.shape[0], H.dtype, block_b, block_s, block_v)
    return _backward_call(
        dy, y, i_max, H, E, block_b=block_b, block_s=block_s,
        block_v=block_v, softcap=softcap, interpret=interpret,
    )
