"""Serving-frontier caches: query results + hot posting windows.

Skewed query popularity dominates real LSR serving traffic (GPUSparse
organizes its GPU inverted indexes around exactly this access
pattern), so the highest-leverage throughput win in front of
``CorpusEngine`` is remembering work: a repeated query should cost a
hash lookup, and the heaviest terms' gather windows should be resident
instead of re-gathered per query. Two caches, one hard invariant:

**Cache-on must be bit-identical to cache-off.** Not "close", not
"same ids" — identical arrays. Both caches get there structurally
rather than by tolerance:

* ``QueryResultCache`` — bounded, byte-accounted LRU over *final*
  search results ``(vals (k,), ext_ids (k,))``. The key is derived
  from the normalized query rep (the exact f32/i32 bytes of its
  active-prefix slots — f32 **is** the wire quantization; an optional
  ``decimals`` knob coarsens it, off by default because rounding two
  near-equal queries onto one entry would serve one query the other's
  results), the search kwargs, the corpus tag, and the index
  **generation**. ``IndexBuilder`` bumps its generation on every
  visible mutation (add/remove/dirty-flush/compact — compact too,
  because re-packing reorders fp summation), so a stale entry's key
  simply never matches again; ``invalidate()`` reclaims the dead
  entries' bytes eagerly.
* ``HotPostingCache`` — pins the top-m heaviest terms' gather windows
  (their posting lists padded to the index's static ``max_postings``
  width) so the fused scorer's window assembly skips the gather for
  exactly the terms that dominate it. Byte accounting charges the
  *padded* window (the memory the cache actually spends); the host
  mirror of the posting arrays stands in for the backing store the
  windows are pinned out of. ``ensure()`` rebuilds on generation
  change — a stale window is never served.

``hot_fused_retrieve`` reproduces ``score._fused_windows`` exactly
(same valid-lane masking, same f32 multiply, same resolved kernel
blocks) and feeds the same ``fused_impact_topk`` — so with the hot
cache on, off, or partially warm, the kernel sees bit-identical
inputs. ``CachedEngine`` wires both caches over a ``CorpusEngine``:
row-level result lookups (a batch with 3 hits only re-scores the 2
misses — rows are scored independently by every retrieval path),
generation-driven invalidation, and the ``base_scorer`` seam into
``IndexBuilder.search`` for hot-window scoring. DESIGN.md §13.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.retrieval.index import InvertedIndex
from repro.retrieval.sparse_rep import SparseRep, split_rows, stack_rows

__all__ = [
    "QueryResultCache",
    "HotPostingCache",
    "CachedEngine",
    "query_cache_key",
    "hot_fused_retrieve",
]

# fixed per-entry overhead charged on top of the payload arrays (key
# digest + OrderedDict node + entry record, order-of-magnitude)
ENTRY_OVERHEAD_BYTES = 128


def query_cache_key(row: SparseRep, k: int, kwargs: Mapping[str, Any],
                    tag: str, generation: int,
                    decimals: Optional[int] = None) -> bytes:
    """Digest of one normalized query row + everything else that can
    change its result.

    The rep is normalized to its active prefix (``nnz`` leading slots
    — the sparsifiers keep actives as a value-descending prefix), so
    two reps differing only in padding width hash the same. Values
    enter as exact f32 bytes by default; ``decimals`` rounds first —
    a recall-over-parity knob that is deliberately **not** used by the
    serving stack (see module docstring).
    """
    v = np.asarray(row.values, np.float32).reshape(-1)
    i = np.asarray(row.indices, np.int32).reshape(-1)
    n = int(np.asarray(row.nnz).reshape(-1)[0])
    v, i = v[:n], i[:n]
    if decimals is not None:
        v = np.round(v, decimals).astype(np.float32)
    h = hashlib.blake2b(digest_size=16)
    h.update(v.tobytes())
    h.update(i.tobytes())
    meta = (int(k), str(tag), int(generation),
            tuple(sorted((name, repr(val)) for name, val
                         in kwargs.items() if val is not None)))
    h.update(repr(meta).encode())
    return h.digest()


@dataclasses.dataclass
class _Entry:
    tag: str
    generation: int
    vals: np.ndarray
    ids: np.ndarray
    nbytes: int


class QueryResultCache:
    """Bounded byte-accounted LRU over per-row search results.

    ``get``/``put`` move entries to the MRU end; inserts evict from
    the LRU end until the payload fits ``capacity_bytes``. Entries are
    tagged with a corpus name + generation so one tenant's mutation
    invalidates only its own entries (``invalidate(tag, live_gen)``)
    — keys embed the generation, so stale entries can never *hit*;
    invalidation just reclaims their bytes eagerly.
    """

    def __init__(self, capacity_bytes: int):
        if capacity_bytes <= 0:
            raise ValueError(
                f"capacity_bytes must be positive, got {capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self._entries: "collections.OrderedDict[bytes, _Entry]" = \
            collections.OrderedDict()
        self.bytes_used = 0
        self.counters: collections.Counter = collections.Counter()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: bytes) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        e = self._entries.get(key)
        if e is None:
            self.counters["misses"] += 1
            return None
        self._entries.move_to_end(key)
        self.counters["hits"] += 1
        # copies: a caller mutating its result must not poison the
        # cache (parity with cache-off is a hard invariant)
        return e.vals.copy(), e.ids.copy()

    def put(self, key: bytes, tag: str, generation: int,
            vals: np.ndarray, ids: np.ndarray) -> None:
        vals = np.asarray(vals)
        ids = np.asarray(ids)
        nbytes = int(vals.nbytes + ids.nbytes) + ENTRY_OVERHEAD_BYTES
        if nbytes > self.capacity_bytes:
            self.counters["oversize_skipped"] += 1
            return
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes_used -= old.nbytes
        self._entries[key] = _Entry(str(tag), int(generation),
                                    vals.copy(), ids.copy(), nbytes)
        self.bytes_used += nbytes
        while self.bytes_used > self.capacity_bytes and self._entries:
            _, victim = self._entries.popitem(last=False)
            self.bytes_used -= victim.nbytes
            self.counters["evictions"] += 1

    def invalidate(self, tag: str, live_generation: int) -> int:
        """Reclaim every entry of ``tag`` whose generation is not the
        live one. Returns the number invalidated."""
        dead = [k for k, e in self._entries.items()
                if e.tag == tag and e.generation != live_generation]
        for k in dead:
            e = self._entries.pop(k)
            self.bytes_used -= e.nbytes
        self.counters["invalidations"] += len(dead)
        return len(dead)

    def stats(self) -> Dict[str, Any]:
        c = self.counters
        looked = c["hits"] + c["misses"]
        return {
            "entries": len(self._entries),
            "bytes_used": self.bytes_used,
            "capacity_bytes": self.capacity_bytes,
            "hits": c["hits"],
            "misses": c["misses"],
            "hit_rate": round(c["hits"] / looked, 4) if looked else 0.0,
            "evictions": c["evictions"],
            "invalidations": c["invalidations"],
        }


class HotPostingCache:
    """Pinned gather windows for the heaviest terms of one index.

    ``ensure(index, generation)`` (re)builds against the given index
    snapshot: posting arrays are mirrored to host once, terms are
    ranked by posting-list length, and the top terms' windows — docs
    and raw (un-multiplied) impact values padded to ``max_postings`` —
    are pinned until ``capacity_bytes`` is spent. ``window(term)``
    serves a pinned window or ``None`` (counted as hit/miss).

    Byte accounting covers the pinned padded windows — that padding is
    the memory the cache trades for gather-free scoring. A generation
    change drops everything (``invalidations`` counts rebuilds that
    discarded pins); a stale window is never served.
    """

    def __init__(self, capacity_bytes: int, *, top_m: int = 1 << 30):
        if capacity_bytes <= 0:
            raise ValueError(
                f"capacity_bytes must be positive, got {capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self.top_m = int(top_m)
        self.counters: collections.Counter = collections.Counter()
        self.bytes_pinned = 0
        self.generation: Optional[int] = None
        self._index_ref: Optional[int] = None
        self._windows: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._pdoc: Optional[np.ndarray] = None
        self._pval: Optional[np.ndarray] = None
        self._starts: Optional[np.ndarray] = None
        self._lens: Optional[np.ndarray] = None
        self._l_max = 1

    @property
    def pinned_terms(self) -> int:
        return len(self._windows)

    def ensure(self, index: InvertedIndex, generation: int) -> None:
        """Make the cache current for ``(index, generation)``; no-op
        when it already is."""
        if (self.generation == generation
                and self._index_ref == id(index)):
            return
        if self._windows:
            self.counters["invalidations"] += 1
        self.counters["rebuilds"] += 1
        self.generation = generation
        self._index_ref = id(index)
        # host mirror of the backing store the windows are pinned from
        self._pdoc = np.asarray(index.postings_doc, np.int32)
        self._pval = np.asarray(index.postings_val, np.float32)
        self._starts = np.asarray(index.term_starts, np.int32)
        self._lens = np.asarray(index.term_lens, np.int32)
        self._l_max = int(index.max_postings)
        self._windows = {}
        self.bytes_pinned = 0
        per_window = self._l_max * (4 + 4) + ENTRY_OVERHEAD_BYTES
        # heaviest terms first — the gathers worth skipping; stable
        # sort keeps the pin set deterministic across rebuilds
        order = np.argsort(-self._lens, kind="stable")
        for t in order[:self.top_m]:
            n = int(self._lens[t])
            if n == 0 or self.bytes_pinned + per_window > \
                    self.capacity_bytes:
                break
            s = int(self._starts[t])
            docs = np.zeros(self._l_max, np.int32)
            vals = np.zeros(self._l_max, np.float32)
            docs[:n] = self._pdoc[s:s + n]
            vals[:n] = self._pval[s:s + n]
            self._windows[int(t)] = (docs, vals)
            self.bytes_pinned += per_window

    def window(self, term: int) -> Optional[Tuple[np.ndarray,
                                                  np.ndarray]]:
        win = self._windows.get(int(term))
        if win is None:
            self.counters["misses"] += 1
        else:
            self.counters["hits"] += 1
        return win

    def stats(self) -> Dict[str, Any]:
        c = self.counters
        looked = c["hits"] + c["misses"]
        return {
            "pinned_terms": self.pinned_terms,
            "bytes_pinned": self.bytes_pinned,
            "capacity_bytes": self.capacity_bytes,
            "hits": c["hits"],
            "misses": c["misses"],
            "hit_rate": round(c["hits"] / looked, 4) if looked else 0.0,
            "rebuilds": c["rebuilds"],
            "invalidations": c["invalidations"],
        }


def hot_fused_retrieve(
    queries: SparseRep,
    index: InvertedIndex,
    k: int,
    *,
    hot: HotPostingCache,
    block_n: Optional[int] = None,
    block_w: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """``score.fused_retrieve`` with hot-window reuse — bit-identical
    outputs for the same call, cache warm or cold.

    The window assembly mirrors ``score._fused_windows`` lane for
    lane: a (query-slot, lane) position is valid iff the lane is
    inside the term's posting list AND the slot's value is positive;
    valid lanes carry ``postings_val * qv`` (one f32 multiply — same
    op, same order as the jit path) and gathered doc ids, everything
    else exact zeros. Hot terms copy their pinned window instead of
    gathering; kernel blocks resolve through the same
    ``resolve_impact_blocks`` call as ``fused_retrieve``.
    """
    from repro.kernels.autotune import resolve_impact_blocks
    from repro.kernels.impact_score import fused_impact_topk

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qv = np.asarray(queries.values, np.float32).reshape(
        -1, queries.width)
    qi = np.asarray(queries.indices, np.int32).reshape(
        -1, queries.width)
    b, q_width = qv.shape
    block_n, block_w = resolve_impact_blocks(
        b, q_width, index.max_postings, index.n_docs,
        block_n, block_w, variant="f32")

    l_max = hot._l_max
    w = np.zeros((b, q_width, l_max), np.float32)
    docs = np.zeros((b, q_width, l_max), np.int32)
    for r in range(b):
        for s in range(q_width):
            v = qv[r, s]
            if not v > 0:
                continue
            t = int(qi[r, s])
            win = hot.window(t)
            if win is not None:
                docs[r, s] = win[0]
                w[r, s] = win[1] * v
            else:
                n = int(hot._lens[t])
                if n:
                    p0 = int(hot._starts[t])
                    docs[r, s, :n] = hot._pdoc[p0:p0 + n]
                    w[r, s, :n] = hot._pval[p0:p0 + n] * v
    return fused_impact_topk(
        w.reshape(b, -1), docs.reshape(b, -1),
        n_docs=index.n_docs, k=min(k, index.n_docs),
        block_n=block_n, block_w=block_w, interpret=interpret)


class CachedEngine:
    """The caching frontier over one ``CorpusEngine``.

    Mutations delegate straight through (the builder's generation bump
    is the invalidation signal); ``search`` goes row-level through the
    shared ``QueryResultCache`` — hits are served from cache, misses
    are re-batched into **one** underlying search (rows are scored
    independently by every retrieval path, so re-batching cannot
    change a row's result) and stored. When a ``HotPostingCache`` is
    attached, miss searches thread a hot-window ``base_scorer`` into
    ``IndexBuilder.search``; the scorer engages only when the resolved
    method is ``fused`` over a raw ``InvertedIndex`` base and declines
    (returns None → normal dispatch) otherwise.

    ``tag`` namespaces this corpus's entries inside a cache shared
    across tenants — invalidation is per-tag, so one tenant's churn
    never cold-starts another's entries.
    """

    def __init__(self, engine, *, result_cache: QueryResultCache,
                 hot_cache: Optional[HotPostingCache] = None,
                 tag: str = "corpus"):
        self.engine = engine
        self.results = result_cache
        self.hot = hot_cache
        self.tag = str(tag)
        self._seen_generation: Optional[int] = None

    # -- delegated mutations --------------------------------------------

    @property
    def builder(self):
        return self.engine.builder

    def add_docs(self, docs, ids=None):
        return self.engine.add_docs(docs, ids=ids)

    def remove_docs(self, ids):
        return self.engine.remove_docs(ids)

    def flush(self, **kw):
        return self.engine.flush(**kw)

    # -- search ----------------------------------------------------------

    def _hot_scorer(self):
        hot = self.hot

        def scorer(queries, base, k, resolved, kw):
            if resolved != "fused" or type(base) is not InvertedIndex:
                return None
            hot.ensure(base, self.builder.generation)
            return hot_fused_retrieve(queries, base, k, hot=hot, **kw)

        return scorer if hot is not None else None

    def search(self, queries: SparseRep, k: int = 10,
               **kw) -> Tuple[np.ndarray, np.ndarray]:
        """Row-cached top-k — same signature, same results as
        ``CorpusEngine.search`` (the hard parity invariant)."""
        b = self.builder
        if b.dirty:
            b.flush()
        gen = b.generation
        if gen != self._seen_generation:
            self.results.invalidate(self.tag, gen)
            self._seen_generation = gen

        rows = split_rows(queries)
        keys = [query_cache_key(r, k, kw, self.tag, gen) for r in rows]
        out_v: List[Optional[np.ndarray]] = [None] * len(rows)
        out_i: List[Optional[np.ndarray]] = [None] * len(rows)
        miss_rows, miss_pos = [], []
        for j, key in enumerate(keys):
            hit = self.results.get(key)
            if hit is not None:
                out_v[j], out_i[j] = hit
            else:
                miss_rows.append(rows[j])
                miss_pos.append(j)
        if miss_rows:
            mv, mi = b.search(stack_rows(miss_rows), k,
                              base_scorer=self._hot_scorer(), **kw)
            mv = np.asarray(mv)
            mi = np.asarray(mi)
            for r, j in enumerate(miss_pos):
                self.results.put(keys[j], self.tag, gen, mv[r], mi[r])
                out_v[j], out_i[j] = mv[r], mi[r]
        return np.stack(out_v), np.stack(out_i)

    # -- observability ---------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        d = {"tag": self.tag, "results": self.results.stats()}
        if self.hot is not None:
            d["hot"] = self.hot.stats()
        d["engine"] = self.engine.stats()
        return d
