"""Per-LM-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU, asserting output shapes + no NaNs. Also
prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import (build_decode_step, build_lsr_prefill_step,
                                build_lsr_train_step, init_state)
from repro.models import transformer as tfm

LM_ARCHS = ["llama3_2_3b", "gemma2_27b", "phi3_mini", "moonshot_v1_16b",
            "phi3_5_moe", "splade_bert", "splade_xlmr"]


def _batch(cfg, B=4, S=24, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    toks = jax.random.randint(k1, (B, S), 1, cfg.vocab_size)
    n_valid = jax.random.randint(k2, (B,), S // 2, S + 1)
    mask = (jnp.arange(S)[None] < n_valid[:, None]).astype(jnp.int32)
    return toks * mask, mask


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(0), smoke=True)
    toks, mask = _batch(cfg)
    H, aux = tfm.forward_hidden(state["params"], cfg, toks, mask)
    assert H.shape == (4, 24, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(H.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_lsr_train_step(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(0), smoke=True)
    q_toks, q_mask = _batch(cfg, seed=1)
    d_toks, d_mask = _batch(cfg, seed=2)
    batch = {"q_tokens": q_toks, "q_mask": q_mask,
             "d_tokens": d_toks, "d_mask": d_mask}
    step = build_lsr_train_step(cfg, None, n_micro=2, n_pairs=4)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["llama3_2_3b", "gemma2_27b",
                                  "moonshot_v1_16b"])
def test_smoke_prefill_outputs_sparse_reps(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(0), smoke=True)
    toks, mask = _batch(cfg)
    serve = build_lsr_prefill_step(cfg, None, 4)
    y = jax.jit(serve)(state["params"], {"tokens": toks, "mask": mask})
    assert y.shape == (4, cfg.vocab_size)
    y32 = np.asarray(y, np.float32)
    assert np.isfinite(y32).all() and (y32 >= 0).all()


@pytest.mark.parametrize("arch", ["phi3_mini", "gemma2_27b",
                                  "phi3_5_moe"])
def test_decode_step_updates_cache(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(0), smoke=True)
    B, L = 2, 16
    cache = tfm.init_kv_cache(cfg, B, L)
    serve = build_decode_step(cfg, None)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "positions": jnp.array([0, 3], jnp.int32),
             "cache_k": cache["k"], "cache_v": cache["v"]}
    logits, ck, cv = jax.jit(serve)(state["params"], batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache written at the given positions (nonzero now)
    assert float(jnp.abs(ck[:, 0, 0]).max()) > 0
    assert float(jnp.abs(ck[:, 1, 3]).max()) > 0


@pytest.mark.parametrize("dtype,atol", [
    ("float32", 1e-5),     # exact-path check: logic must agree
    ("bfloat16", 8e-2),    # bf16: rounding points differ between paths
])
def test_decode_matches_full_forward(dtype, atol):
    """Causal-LM consistency: token-by-token decode logits == logits of
    the full (teacher-forced) forward at each position."""
    import dataclasses
    cfg = dataclasses.replace(get_config("phi3_mini").SMOKE,
                              compute_dtype=dtype)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 1,
                              cfg.vocab_size)
    full_logits, _ = tfm.causal_lm_logits(params, cfg, toks)

    cache = tfm.init_kv_cache(cfg, B, S)
    for s in range(S):
        step_logits, cache = tfm.decode_step(
            params, cfg, cache, toks[:, s:s + 1],
            jnp.full((B,), s, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(step_logits, np.float32),
            np.asarray(full_logits[:, s], np.float32),
            atol=atol, rtol=atol)


def test_gemma2_local_global_alternation_matters():
    """Sliding-window layers must actually restrict attention."""
    cfg = get_config("gemma2_27b").SMOKE
    state, _ = init_state("gemma2_27b", jax.random.PRNGKey(0), smoke=True)
    toks, mask = _batch(cfg, B=1, S=24)
    H1, _ = tfm.forward_hidden(state["params"], cfg, toks, mask)
    # same tokens, perturb the FIRST token: with window=16 the local
    # layers can't see it from the last position, but global layers can
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) % (cfg.vocab_size - 2)) + 1)
    H2, _ = tfm.forward_hidden(state["params"], cfg, toks2, mask)
    assert float(jnp.max(jnp.abs((H1 - H2).astype(jnp.float32)))) > 0


def test_moe_aux_loss_nonzero_and_finite():
    cfg = get_config("moonshot_v1_16b").SMOKE
    state, _ = init_state("moonshot_v1_16b", jax.random.PRNGKey(0),
                          smoke=True)
    toks, mask = _batch(cfg)
    _, aux = tfm.forward_hidden(state["params"], cfg, toks, mask)
    assert bool(jnp.isfinite(aux)) and float(aux) > 0
