"""Distributed gather for row-sharded tensors — the §Perf fix for the
GNN full-graph cells.

Problem (measured on dimenet/ogb_products, EXPERIMENTS.md §Perf):
``jnp.take(edge_tensor, triplet_idx)`` with a row-sharded operand makes
the SPMD partitioner ALL-GATHER the operand — a 31.6 GB replica per
device per gather, 439 GB peak for the full model.

Fix: the classic partition-parallel gather (DGL/P3-style), expressed
in shard_map:

  1. each device sorts its needed row ids by owner shard,
  2. ids are exchanged with ``all_to_all`` (capacity-capped, like MoE
     dispatch — uniform random ids concentrate at R/n ± 3·sqrt(R/n),
     so a 1.25x cap drops nothing in practice and drop counts are
     returned for monitoring),
  3. every owner gathers its requested rows locally,
  4. rows return via the reverse ``all_to_all`` and are scattered back
     into request order.

Per-device wire: ~2 x cap_factor x R x d bytes (requests are int32,
payload dominates) vs n_shards x R x d for replication — an ~8x wire
and ~250x peak reduction at ogb-products scale.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

Array = jax.Array


def _capacity(R: int, n: int, cap_factor: float) -> int:
    """Request slots per peer: cap_factor x mean + a 3-sigma floor so
    small-R cases don't truncate (uniform ids ~ Binomial(R, 1/n))."""
    import math
    mean = R / n
    return max(4, int(math.ceil(cap_factor * mean + 3 * math.sqrt(mean))))


def distributed_take_local(
    src_local: Array,     # (rows_local, d) this shard's rows
    idx_local: Array,     # (R,) int32 GLOBAL row ids needed locally
    *,
    axis_names: Tuple[str, ...],
    cap_factor: float = 1.25,
) -> Tuple[Array, Array]:
    """Inside-shard_map body. Returns ((R, d) gathered rows, dropped
    count). Over-cap requests yield zero rows (monitored, not silent).
    """
    rows_local, d = src_local.shape
    R = idx_local.shape[0]
    n = 1
    for ax in axis_names:
        n *= axis_size(ax)
    C = _capacity(R, n, cap_factor)

    owner = jnp.clip(idx_local // rows_local, 0, n - 1)       # (R,)
    order = jnp.argsort(owner)                                 # stable
    s_owner = owner[order]
    s_idx = idx_local[order]

    counts = jnp.bincount(owner, length=n)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(R, dtype=jnp.int32) - starts[s_owner]
    keep = rank < C
    slot = jnp.where(keep, rank, C)
    dropped = jnp.sum(~keep)

    # request buffer: local row id on the owner, per (owner, slot)
    req = jnp.zeros((n, C + 1), jnp.int32)
    req = req.at[s_owner, slot].set(s_idx % rows_local)
    req = req[:, :C]                                           # (n, C)

    # exchange requests; serve; exchange payloads back
    req_in = jax.lax.all_to_all(req, axis_names, split_axis=0,
                                concat_axis=0, tiled=True)     # (n, C)
    served = jnp.take(src_local, req_in.reshape(-1), axis=0)
    served = served.reshape(n, C, d)
    vals_back = jax.lax.all_to_all(served, axis_names, split_axis=0,
                                   concat_axis=0, tiled=True)  # (n, C, d)

    # un-sort: sorted entry i got its row from (s_owner[i], slot[i])
    got = vals_back[s_owner, jnp.minimum(slot, C - 1)]         # (R, d)
    got = jnp.where(keep[:, None], got, 0)
    out = jnp.zeros((R, d), src_local.dtype).at[order].set(got)
    return out, jax.lax.psum(dropped, axis_names)


def distributed_segment_sum_local(
    vals_local: Array,    # (R, d) rows to scatter-add
    idx_local: Array,     # (R,) int32 GLOBAL destination row ids
    out_local_rows: int,  # rows of the output owned by this shard
    *,
    axis_names: Tuple[str, ...],
    cap_factor: float = 1.25,
) -> Tuple[Array, Array]:
    """Inside-shard_map scatter-add to a row-sharded output: the
    transpose of ``distributed_take_local``. Each value row is shipped
    to its destination's owner with one ``all_to_all``; owners
    segment-sum locally. Returns ((rows_local, d) partial output,
    dropped count)."""
    R, d = vals_local.shape
    n = 1
    for ax in axis_names:
        n *= axis_size(ax)
    C = _capacity(R, n, cap_factor)

    owner = jnp.clip(idx_local // out_local_rows, 0, n - 1)
    order = jnp.argsort(owner)
    s_owner = owner[order]
    s_idx = idx_local[order]
    s_vals = jnp.take(vals_local, order, axis=0)

    counts = jnp.bincount(owner, length=n)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(R, dtype=jnp.int32) - starts[s_owner]
    keep = rank < C
    slot = jnp.where(keep, rank, C)
    dropped = jnp.sum(~keep)

    send_ids = jnp.full((n, C + 1), out_local_rows, jnp.int32)
    send_ids = send_ids.at[s_owner, slot].set(
        jnp.where(keep, s_idx % out_local_rows, out_local_rows))
    send_vals = jnp.zeros((n, C + 1, d), vals_local.dtype)
    send_vals = send_vals.at[s_owner, slot].set(
        jnp.where(keep[:, None], s_vals, 0))

    ids_in = jax.lax.all_to_all(send_ids[:, :C], axis_names,
                                split_axis=0, concat_axis=0, tiled=True)
    vals_in = jax.lax.all_to_all(send_vals[:, :C], axis_names,
                                 split_axis=0, concat_axis=0, tiled=True)
    out = jax.ops.segment_sum(
        vals_in.reshape(n * C, d), ids_in.reshape(n * C),
        num_segments=out_local_rows + 1)[:out_local_rows]
    return out, jax.lax.psum(dropped, axis_names)


def make_distributed_take(mesh, axis_names: Tuple[str, ...],
                          *, cap_factor: float = 1.25):
    """Factory: take(src, idx) -> (rows, dropped) with src row-sharded
    and idx row-sharded over ``axis_names``."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    body = functools.partial(distributed_take_local,
                             axis_names=axis_names,
                             cap_factor=cap_factor)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis_names, None), P(axis_names)),
        out_specs=(P(axis_names, None), P()),
        check_vma=False,
    )
