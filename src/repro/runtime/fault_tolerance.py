"""Fault-tolerant training runtime: checkpoint/restart, stragglers,
elastic re-mesh.

Designed for 1000+ nodes where *something* is always failing:

* **Checkpoint/restart** — async atomic checkpoints every N steps
  (repro.checkpoint); on start the runner auto-resumes from the latest
  valid step. A SIGTERM-style shutdown hook flushes a final
  checkpoint.
* **Straggler mitigation** — every step runs under a deadline
  (EWMA of recent step times × slack). A step exceeding the deadline
  is retried once; a second miss marks the step skipped (the grad
  accumulation window renormalizes — see optim.accumulation) and the
  host is recorded as suspect. Persistent suspects trigger a re-mesh.
* **Elastic re-mesh** — on device loss (or operator resize request),
  ``ElasticMeshManager`` rebuilds the mesh at the largest supported
  (pod, data, model) factorization of the surviving device count,
  re-places the *host-side* checkpoint against the new sharding (pure
  pytree: no device-order assumptions), and re-jits the step.

The runner is deliberately engine-agnostic: it owns *policy* (when to
checkpoint / retry / re-mesh) and delegates *mechanism* to injected
callables, so unit tests drive it with toy steps and fault injectors.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    load_checkpoint)

PyTree = Any


@dataclasses.dataclass
class StragglerPolicy:
    slack: float = 3.0           # deadline = slack * EWMA step time
    ewma_alpha: float = 0.1
    min_deadline_s: float = 1.0
    max_retries: int = 1
    suspect_threshold: int = 3   # suspect marks before demanding re-mesh


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    keep_ckpts: int = 3
    max_steps: int = 1000
    straggler: StragglerPolicy = dataclasses.field(
        default_factory=StragglerPolicy)
    log_every: int = 10


class ElasticMeshManager:
    """Owns mesh (re)construction under changing device counts.

    ``factorize(n)`` picks the largest (pod, data, model) with
    pod*data*model == usable <= n, preferring to keep the model axis
    (resharding E is cheaper than re-tuning per-device batch) and
    power-of-two axes.
    """

    def __init__(self, make_mesh: Callable[[Tuple[int, ...]], Any],
                 *, model_axis: int = 16):
        self.make_mesh = make_mesh
        self.model_axis = model_axis

    def factorize(self, n_devices: int) -> Tuple[int, int, int]:
        model = self.model_axis
        while model > 1 and n_devices < model:
            model //= 2
        rest = n_devices // model
        # largest power of two <= rest for the data axis
        data = 1 << (max(rest, 1).bit_length() - 1)
        pod = 1  # pods collapse into data when devices are lost
        return (pod, data, model)

    def build(self, n_devices: int):
        shape = self.factorize(n_devices)
        return self.make_mesh(shape), shape


class _StepClock:
    def __init__(self, policy: StragglerPolicy):
        self.policy = policy
        self.ewma: Optional[float] = None

    def deadline(self) -> float:
        if self.ewma is None:
            return float("inf")  # first step: no baseline yet
        return max(self.policy.min_deadline_s,
                   self.policy.slack * self.ewma)

    def record(self, dt: float) -> None:
        a = self.policy.ewma_alpha
        self.ewma = dt if self.ewma is None else (1 - a) * self.ewma + a * dt


class FaultTolerantRunner:
    """Drives (state, batch) -> (state, metrics) steps with FT policy.

    Parameters
    ----------
    step_fn: the jitted train step.
    state: initial train state pytree (params, opt state, step).
    batches: iterator of host batches.
    place_batch: host batch -> device arrays (applies shardings).
    config: RunnerConfig.
    on_remesh: optional callback(state) -> (step_fn, state) invoked when
      the straggler policy demands a re-mesh (tests inject this;
      launch/train.py wires it to ElasticMeshManager + re-jit).
    on_step: optional callback(step, state) invoked after every
      *successful* step (skipped/straggled steps don't fire it) — the
      periodic-work hook (eval, extra logging). A returned non-empty
      dict is appended to ``metrics_log`` as its own
      ``{"step": step, **extras}`` entry; the callback decides its own
      cadence. Exceptions propagate: the hook runs host-side work the
      caller asked for, not step execution the FT policy owns.
    """

    def __init__(
        self,
        step_fn: Callable[[PyTree, PyTree], Tuple[PyTree, Dict[str, Any]]],
        state: PyTree,
        batches,
        *,
        config: RunnerConfig,
        place_batch: Callable[[Dict[str, np.ndarray]], PyTree] = lambda b: b,
        on_remesh: Optional[Callable[[PyTree],
                                     Tuple[Callable, PyTree]]] = None,
        on_step: Optional[Callable[[int, PyTree],
                                   Optional[Dict[str, Any]]]] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.step_fn = step_fn
        self.state = state
        self.batches = batches
        self.config = config
        self.place_batch = place_batch
        self.on_remesh = on_remesh
        self.on_step = on_step
        self.clock = clock
        self._step_clock = _StepClock(config.straggler)
        self._ckpt = AsyncCheckpointer(config.ckpt_dir,
                                       keep=config.keep_ckpts)
        self.start_step = 0
        self.suspect_strikes = 0
        self.skipped_steps: List[int] = []
        self.remesh_events: List[int] = []
        self.metrics_log: List[Dict[str, Any]] = []

    # -- resume ----------------------------------------------------------
    def try_resume(self) -> bool:
        step = latest_step(self.config.ckpt_dir)
        if step is None:
            return False
        self.state, self.start_step = load_checkpoint(
            self.config.ckpt_dir, self.state)
        return True

    # -- main loop --------------------------------------------------------
    def run(self) -> PyTree:
        cfg = self.config
        step = self.start_step
        while step < cfg.max_steps:
            batch = next(self.batches)
            placed = self.place_batch(batch)
            ok, metrics = self._attempt_step(placed, step)
            if not ok:
                self.skipped_steps.append(step)
                self.suspect_strikes += 1
                if (self.suspect_strikes
                        >= cfg.straggler.suspect_threshold
                        and self.on_remesh is not None):
                    self.step_fn, self.state = self.on_remesh(self.state)
                    self.remesh_events.append(step)
                    self.suspect_strikes = 0
                step += 1
                continue
            self.suspect_strikes = 0
            if cfg.log_every and step % cfg.log_every == 0:
                self.metrics_log.append({"step": step, **metrics})
            if self.on_step is not None:
                extras = self.on_step(step, self.state)
                if extras:
                    self.metrics_log.append({"step": step, **extras})
            step += 1
            if cfg.ckpt_every and step % cfg.ckpt_every == 0:
                self._ckpt.save(step, self.state)
        self._ckpt.save(cfg.max_steps, self.state)
        self._ckpt.close()
        return self.state

    def _attempt_step(self, placed_batch, step: int
                      ) -> Tuple[bool, Dict[str, Any]]:
        deadline = self._step_clock.deadline()
        for _ in range(1 + self.config.straggler.max_retries):
            t0 = self.clock()
            try:
                new_state, metrics = self.step_fn(self.state, placed_batch)
                new_state = jax.block_until_ready(new_state)
            except Exception as e:  # device loss surfaces as XlaRuntimeError
                return False, {"error": repr(e)}
            dt = self.clock() - t0
            if dt <= deadline:
                self._step_clock.record(dt)
                self.state = new_state
                m = dict(metrics)
                m["step_time_s"] = dt
                return True, m
            # straggler: discard result, retry once with fresh deadline
        return False, {"straggler": True, "deadline_s": deadline}
