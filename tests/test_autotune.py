"""Autotuner unit tests: candidate enumeration under the VMEM budget,
heuristic determinism, measured-winner JSON cache round trip, and the
config-level threading."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (MIN_BLOCKS, autotune_blocks,
                                    candidate_blocks, get_blocks,
                                    heuristic_blocks, resolve_blocks,
                                    shape_key, vmem_bytes)


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    """Every test gets a fresh cache file + empty in-memory cache."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.clear_cache()
    yield str(path)
    autotune.clear_cache()


def test_candidates_respect_vmem_budget():
    budget = 4 * 1024 * 1024
    cands = candidate_blocks(320, 512, 768, 30522, vmem_budget=budget)
    assert cands, "no candidates under a 4 MiB budget at bert-base size"
    for blocks in cands:
        assert vmem_bytes(blocks, 768) <= budget
        assert blocks[2] % 128 == 0  # lane alignment preserved


def test_candidates_sorted_by_traffic_model():
    cands = candidate_blocks(320, 512, 768, 30522)
    traffic = [autotune.hbm_traffic_elems(c, 320, 512, 768, 30522)
               for c in cands]
    assert traffic == sorted(traffic)


def test_heuristic_covers_paper_operating_points():
    """Acceptance: the tuner selects blocks for splade_bert (V≈30k) and
    splade_xlmr (V≈250k) shapes — and large-V gets a vocab tile at
    least as large (HBM traffic scales with V/block_v)."""
    bert = heuristic_blocks(320, 512, 768, 30522)
    xlmr = heuristic_blocks(16, 256, 768, 250002)
    for blocks in (bert, xlmr):
        assert all(x >= 1 for x in blocks)
        assert vmem_bytes(blocks, 768) <= autotune.VMEM_BUDGET_BYTES
    assert xlmr[2] >= bert[2]


def test_heuristic_fallback_when_budget_unreachable():
    # nothing fits => the overflow-minimizing smallest triple, never a
    # larger "default" that would amplify the VMEM overflow
    assert heuristic_blocks(8, 128, 65536, 1024,
                            vmem_budget=1) == MIN_BLOCKS


def test_get_blocks_without_cache_is_heuristic():
    assert get_blocks(4, 32, 16, 64) == heuristic_blocks(4, 32, 16, 64)


def test_autotune_cache_round_trip(isolated_cache):
    """Measured winner is persisted to JSON and read back — including
    by a cold in-memory cache (a fresh process)."""
    blocks = autotune_blocks(4, 32, 16, 64, max_candidates=2)
    assert os.path.exists(isolated_cache)
    raw = json.load(open(isolated_cache))
    key = shape_key(4, 32, 16, 64, jnp.float32, jax.default_backend())
    assert raw[key]["source"] == "measured"
    assert (raw[key]["block_b"], raw[key]["block_s"],
            raw[key]["block_v"]) == blocks

    # simulate a fresh process: drop the in-memory cache, hit the file
    autotune.clear_cache()
    assert get_blocks(4, 32, 16, 64) == blocks
    # re-tuning the same key is a cache hit (no re-measurement)
    assert autotune_blocks(4, 32, 16, 64) == blocks


def test_cache_keys_are_shape_and_dtype_specific(isolated_cache):
    autotune_blocks(4, 32, 16, 64, max_candidates=1)
    # different dtype => different key => heuristic (not the cached hit)
    raw = json.load(open(isolated_cache))
    backend = jax.default_backend()
    assert shape_key(4, 32, 16, 64, jnp.bfloat16, backend) not in raw
    assert shape_key(4, 32, 16, 64, jnp.float32, backend) in raw


def test_distinct_cache_paths_stay_isolated(tmp_path):
    """Entries written to one cache file must not bleed into saves of
    another (per-path in-memory caches)."""
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    autotune_blocks(4, 32, 16, 64, max_candidates=1, path=a)
    autotune_blocks(2, 16, 8, 32, max_candidates=1, path=b)
    keys_a = set(json.load(open(a)))
    keys_b = set(json.load(open(b)))
    backend = jax.default_backend()
    assert keys_a == {shape_key(4, 32, 16, 64, jnp.float32, backend)}
    assert keys_b == {shape_key(2, 16, 8, 32, jnp.float32, backend)}


def test_partial_pin_respects_vmem_budget():
    """Pinning one component must re-derive the free ones under the
    budget, not graft a pin onto blocks tuned without it."""
    blocks = heuristic_blocks(320, 512, 768, 250002,
                              pinned=(None, None, 1024))
    assert blocks[2] == 1024
    assert vmem_bytes(blocks, 768) <= autotune.VMEM_BUDGET_BYTES
    # a pin no free choice can rescue (bv=2048 at D=768 overflows on
    # the dE scratch alone): minimal free components, not silent drop
    blocks = heuristic_blocks(320, 512, 768, 250002,
                              pinned=(None, None, 2048))
    assert blocks == (1, 64, 2048)
    # the kernel-wrapper path must re-enumerate jointly too, not graft
    # the pin onto the unpinned winner
    blocks = resolve_blocks(64, 512, 64, 250002, jnp.float32,
                            None, 512, None)
    assert blocks[1] == 512
    assert vmem_bytes(blocks, 64) <= autotune.VMEM_BUDGET_BYTES


def test_all_candidates_failing_does_not_poison_cache(
        isolated_cache, monkeypatch):
    """If every timing attempt raises, no 'measured' entry may be
    persisted — a later call must retry."""
    def boom(*a, **k):
        raise RuntimeError("lowering failed")
    monkeypatch.setattr(autotune, "_time_ms", boom)
    blocks = autotune_blocks(4, 32, 16, 64, max_candidates=2)
    assert blocks == heuristic_blocks(4, 32, 16, 64)
    assert not os.path.exists(isolated_cache)


# ---------------------------------------------------------------------------
# per-kernel tuning (fwd vs dH vs dE)
# ---------------------------------------------------------------------------

def test_per_kernel_round_trip(isolated_cache):
    """Per-kernel winners are persisted under kernel-suffixed keys and
    read back by kernel-scoped lookups (including a cold cache)."""
    from repro.kernels.autotune import autotune_kernel_blocks

    winners = autotune_kernel_blocks(4, 32, 16, 64, max_candidates=2)
    assert set(winners) == set(autotune.KERNELS)
    raw = json.load(open(isolated_cache))
    backend = jax.default_backend()
    for kn in autotune.KERNELS:
        key = shape_key(4, 32, 16, 64, jnp.float32, backend, kn)
        assert raw[key]["source"] == "measured"
        assert raw[key]["kernel"] == kn
    autotune.clear_cache()
    for kn in autotune.KERNELS:
        assert get_blocks(4, 32, 16, 64, kernel=kn) == winners[kn]
    # re-tuning is a pure cache hit
    assert autotune_kernel_blocks(4, 32, 16, 64) == winners


def test_per_kernel_falls_back_to_legacy_joint_entry(isolated_cache):
    """Old cache files (joint keys only) must keep working: a
    per-kernel lookup with no suffixed entry reads the joint one."""
    blocks = autotune_blocks(4, 32, 16, 64, max_candidates=1)
    autotune.clear_cache()
    for kn in autotune.KERNELS:
        assert get_blocks(4, 32, 16, 64, kernel=kn) == blocks


def test_per_kernel_vmem_is_component_of_joint():
    """Kernel-scoped VMEM residency never exceeds the joint worst case,
    and the joint is exactly the max over the three kernels."""
    for blocks in [(2, 64, 128), (8, 128, 512), (1, 256, 2048)]:
        per = [vmem_bytes(blocks, 768, kernel=kn)
               for kn in autotune.KERNELS]
        assert vmem_bytes(blocks, 768) == max(per)


def test_per_kernel_candidates_admit_more_than_joint():
    """A tight budget excludes a triple jointly (worst-case kernel
    overflows) while still admitting it for a cheaper kernel — the
    reason per-kernel enumeration exists."""
    B, S, D, V = 16, 256, 2048, 30522
    per_kernel = {kn: candidate_blocks(B, S, D, V, kernel=kn)
                  for kn in autotune.KERNELS}
    joint = candidate_blocks(B, S, D, V)
    for kn, cands in per_kernel.items():
        assert set(joint) <= set(cands), kn
    assert any(len(cands) > len(joint)
               for cands in per_kernel.values())


def test_all_kernel_candidates_failing_does_not_poison_cache(
        isolated_cache, monkeypatch):
    from repro.kernels.autotune import autotune_kernel_blocks

    def boom(*a, **k):
        raise RuntimeError("lowering failed")
    monkeypatch.setattr(autotune, "_time_ms", boom)
    winners = autotune_kernel_blocks(4, 32, 16, 64, max_candidates=2)
    for kn in autotune.KERNELS:
        assert winners[kn] == heuristic_blocks(4, 32, 16, 64, kernel=kn)
    assert not os.path.exists(isolated_cache)


# ---------------------------------------------------------------------------
# fused impact-scoring kernel (``_impact`` key family)
# ---------------------------------------------------------------------------

def test_impact_candidates_respect_vmem_budget():
    budget = 2 * 1024 * 1024
    cands = autotune.impact_candidate_blocks(16, 32, 512, 1 << 20,
                                             vmem_budget=budget)
    assert cands, "no impact candidates under a 2 MiB budget"
    for blocks in cands:
        assert autotune.impact_vmem_bytes(blocks, 32, 512) <= budget
    proxies = [autotune.impact_traffic_proxy(c, 16, 32, 512, 1 << 20)
               for c in cands]
    assert proxies == sorted(proxies)


def test_impact_shape_key_rejects_unknown_variant():
    with pytest.raises(ValueError, match="variant"):
        autotune.impact_shape_key(4, 8, 16, 64, "f16", "cpu")


def test_impact_cache_round_trip(isolated_cache):
    """Measured impact winner persists under the ``_impact`` key and is
    read back by a cold cache; the head-kernel key family is
    untouched."""
    blocks = autotune.autotune_impact_blocks(2, 4, 8, 64,
                                             max_candidates=2)
    raw = json.load(open(isolated_cache))
    backend = jax.default_backend()
    key = autotune.impact_shape_key(2, 4, 8, 64, "f32", backend)
    assert raw[key]["source"] == "measured"
    assert raw[key]["kernel"] == "impact"
    assert (raw[key]["block_n"], raw[key]["block_w"]) == blocks
    assert all(k.endswith("_impact") for k in raw)

    autotune.clear_cache()
    assert autotune.get_impact_blocks(2, 4, 8, 64) == blocks
    # re-tuning the same key is a cache hit (no re-measurement)
    assert autotune.autotune_impact_blocks(2, 4, 8, 64) == blocks


def test_impact_variants_get_distinct_keys(isolated_cache):
    autotune.autotune_impact_blocks(2, 4, 8, 64, max_candidates=1)
    raw = json.load(open(isolated_cache))
    backend = jax.default_backend()
    assert autotune.impact_shape_key(2, 4, 8, 64, "u4",
                                     backend) not in raw
    u4 = autotune.autotune_impact_blocks(2, 4, 8, 64, variant="u4",
                                         max_candidates=1)
    raw = json.load(open(isolated_cache))
    key = autotune.impact_shape_key(2, 4, 8, 64, "u4", backend)
    assert (raw[key]["block_n"], raw[key]["block_w"]) == u4
    assert raw[key]["variant"] == "u4"


def test_impact_cold_cache_is_heuristic():
    assert (autotune.get_impact_blocks(4, 16, 64, 4096)
            == autotune.heuristic_impact_blocks(4, 16, 64, 4096))


def test_impact_resolve_partial_pins():
    """Explicit pair passes through; a single pin filters the
    candidate enumeration instead of grafting onto the cached
    winner."""
    assert autotune.resolve_impact_blocks(4, 16, 64, 4096, 256,
                                          128) == (256, 128)
    bn, bw = autotune.resolve_impact_blocks(4, 16, 64, 4096, 256, None)
    assert bn == 256 and bw in autotune._IMPACT_BW_CHOICES
    bn, bw = autotune.resolve_impact_blocks(4, 16, 64, 4096, None, None)
    assert (bn, bw) == autotune.heuristic_impact_blocks(4, 16, 64, 4096)


def test_impact_all_candidates_failing_does_not_poison_cache(
        isolated_cache, monkeypatch):
    def boom(*a, **k):
        raise RuntimeError("lowering failed")
    monkeypatch.setattr(autotune, "_time_ms", boom)
    blocks = autotune.autotune_impact_blocks(2, 4, 8, 64,
                                             max_candidates=2)
    assert blocks == autotune.heuristic_impact_blocks(2, 4, 8, 64)
    assert not os.path.exists(isolated_cache)


def test_config_head_blocks_threading():
    """TransformerConfig.head_blocks: pinned fields win, None = auto."""
    from repro.configs import get_config

    cfg = get_config("splade_bert").CONFIG
    assert cfg.head_block_b is None  # configs stopped hard-coding
    auto = cfg.head_blocks(8, 128)
    assert auto == get_blocks(8, 128, cfg.d_model, cfg.vocab_size,
                              dtype=jnp.dtype(cfg.compute_dtype))

    import dataclasses
    pinned = dataclasses.replace(cfg, head_block_b=2, head_block_s=64,
                                 head_block_v=256)
    assert pinned.head_blocks(8, 128) == (2, 64, 256)
