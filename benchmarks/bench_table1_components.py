"""Paper Table 1: runtime + peak memory of backbone vs backbone+head,
for eager-equivalent (naive), tiled, Sparton (pure-JAX scan) and the
Pallas Sparton kernel.

The paper measures SPLADE-V3 (bert-base, |V|=30522) at B=320, S=512 on
an H100. On this CPU container we keep the architecture shape faithful
but scale B/S down (CPU-feasible) — the *comparison structure*
(naive vs tiled vs sparton vs sparton-kernel; fwd vs fwd+bwd; time and
peak memory) is the paper's; columns scale with the workload.

``--json PATH`` (or ``run(json_path=...)``) additionally emits
``BENCH_kernels.json`` — the per-head median ms + peak bytes record CI
tracks from PR 1 onward. ``--smoke`` (or env ``BENCH_SMOKE=1``) shrinks
the workload for CI latency; the kernel runs through the Pallas
interpreter off-TPU either way, so smoke timings order implementations
rather than predict hardware.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks._common import compiled_peak_bytes, csv_print, time_fn
from repro.configs import get_config
from repro.core.head_api import HeadSpec, make_head
from repro.kernels import autotune
from repro.models import transformer as tfm

B, S = 16, 128  # CPU-scaled stand-ins for the paper's 320 x 512

# bench-row label -> registry impl. Labels are the BENCH_kernels.json
# keys CI has tracked since PR 1 — keep them stable across refactors.
BENCH_IMPLS = (
    ("naive", "naive"),
    ("tiled", "tiled"),
    ("sparton-jax", "sparton"),
    ("sparton-kernel", "kernel"),
)


def _head_impls(blocks, interpret):
    bb, bs, bv = blocks
    heads = []
    for label, impl in BENCH_IMPLS:
        spec = HeadSpec(impl=impl, vocab_tile=4096, block_b=bb,
                        block_s=bs, block_v=bv, interpret=interpret)
        heads.append((label, make_head(spec)))
    return heads


def run(csv: bool = True, smoke: bool = False, json_path: str = None):
    smoke = smoke or os.environ.get("BENCH_SMOKE") == "1"
    b_sz, s_len = (4, 64) if smoke else (B, S)
    vocab = 4096 if smoke else 30522
    iters = 3 if smoke else 10

    cfg = get_config("splade_bert").SMOKE
    # widen the smoke config toward bert-base proportions but CPU-sized
    import dataclasses
    if smoke:
        cfg = dataclasses.replace(cfg, vocab_size=vocab)
    else:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, n_heads=8,
                                  n_kv_heads=8, d_head=32, d_ff=1024,
                                  vocab_size=vocab)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    toks = jax.random.randint(jax.random.PRNGKey(1), (b_sz, s_len), 1,
                              cfg.vocab_size)
    mask = jnp.ones((b_sz, s_len), jnp.int32)

    interpret = jax.default_backend() != "tpu"
    blocks = autotune.get_blocks(b_sz, s_len, cfg.d_model, cfg.vocab_size)
    heads = _head_impls(blocks, interpret)

    def backbone(params, toks, mask):
        H, _ = tfm.forward_hidden(params, cfg, toks, mask)
        return H

    def full(head_fn):
        def f(params, toks, mask):
            H, _ = tfm.forward_hidden(params, cfg, toks, mask)
            E, b = tfm.head_weights(params, cfg)
            return head_fn(H, E.astype(H.dtype), b, mask)
        return f

    def train(head_fn):
        def loss(params, toks, mask):
            H, _ = tfm.forward_hidden(params, cfg, toks, mask)
            E, b = tfm.head_weights(params, cfg)
            y = head_fn(H, E.astype(H.dtype), b, mask)
            return jnp.sum(y * y) * 1e-3
        return jax.grad(loss)

    abstract = (jax.eval_shape(lambda: params),
                jax.ShapeDtypeStruct(toks.shape, toks.dtype),
                jax.ShapeDtypeStruct(mask.shape, mask.dtype))

    rows = []
    record = {
        "shape": {"B": b_sz, "S": s_len, "D": cfg.d_model,
                  "V": cfg.vocab_size},
        "blocks": list(blocks),
        "backend": jax.default_backend(),
        "interpret": interpret,
        "heads": {},
    }
    bb_fwd = jax.jit(backbone)
    t = time_fn(bb_fwd, params, toks, mask, iters=iters)
    m = compiled_peak_bytes(backbone, *abstract)
    rows.append(("fwd", "backbone", round(t, 1), round(m / 2**20, 1)))
    bb_loss = jax.grad(
        lambda p, t_, m_: jnp.sum(backbone(p, t_, m_) ** 2) * 1e-3)
    t = time_fn(jax.jit(bb_loss), params, toks, mask, iters=iters)
    m = compiled_peak_bytes(bb_loss, *abstract)
    rows.append(("fwd+bwd", "backbone", round(t, 1), round(m / 2**20, 1)))

    for name, fn in heads:
        f = full(fn)
        t = time_fn(jax.jit(f), params, toks, mask, iters=iters)
        m = compiled_peak_bytes(f, *abstract)
        rows.append(("fwd", f"+{name}", round(t, 1), round(m / 2**20, 1)))
        record["heads"].setdefault(name, {})["fwd"] = {
            "median_ms": round(t, 3),
            "peak_bytes": None if m != m else int(m)}
    for name, fn in heads:
        g = train(fn)
        t = time_fn(jax.jit(g), params, toks, mask, iters=iters)
        m = compiled_peak_bytes(g, *abstract)
        rows.append(("fwd+bwd", f"+{name}", round(t, 1),
                     round(m / 2**20, 1)))
        record["heads"].setdefault(name, {})["fwd_bwd"] = {
            "median_ms": round(t, 3),
            "peak_bytes": None if m != m else int(m)}

    if csv:
        csv_print(("pass", "component", "time_ms", "peak_mib"), rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="emit BENCH_kernels.json-style record here")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)
