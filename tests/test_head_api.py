"""Unified head API: HeadSpec + registry + mesh-aware make_head.

Covers the single dispatch seam (DESIGN.md §6): every registered
backend agrees with the naive oracle through the same factory call,
the registry is the live impl enumeration (``lm_head``'s error lists
it dynamically), the deprecated ``softcap=`` spelling warns, and — the
ROADMAP item this API unblocked — the Pallas kernel runs inside the
vocab-sharded shard_map body with blocks resolved per *local* shard.
"""

import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.head_api import (HeadSpec, available_impls, get_head_impl,
                                 make_head, register_head_impl)
from repro.core.lm_head import lm_head, lm_head_naive


def _inputs(B=3, S=20, D=16, V=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    H = jax.random.normal(ks[0], (B, S, D))
    E = jax.random.normal(ks[1], (V, D)) * 0.3
    b = jax.random.normal(ks[2], (V,)) * 0.1
    mask = (jax.random.uniform(ks[3], (B, S)) > 0.25).astype(jnp.int32)
    mask = mask.at[:, 0].set(1)
    return H, E, b, mask


def _spec(impl, **kw):
    # small pinned blocks so the kernel's interpret-mode grid stays tiny
    return HeadSpec(impl=impl, vocab_tile=16, interpret=True,
                    block_b=1, block_s=16, block_v=32, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_impls_registered():
    assert {"naive", "tiled", "sparton", "kernel"} <= set(available_impls())


def test_register_custom_impl_and_dynamic_error():
    name = "doubled-naive"
    try:
        register_head_impl(
            name, lambda H, E, b, mask, *, spec:
            2.0 * lm_head_naive(H, E, b, mask,
                                logit_softcap=spec.logit_softcap))
        assert name in available_impls()
        H, E, b, mask = _inputs()
        y = make_head(HeadSpec(impl=name))(H, E, b, mask)
        np.testing.assert_allclose(
            np.asarray(y), 2.0 * np.asarray(lm_head_naive(H, E, b, mask)),
            atol=1e-6)
        # lm_head dispatches through the registry too — and its error
        # message enumerates the live registry, not a stale list
        y2 = lm_head(H, E, b, mask, impl=name)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=0)
        with pytest.raises(ValueError, match=name):
            lm_head(H, E, b, mask, impl="no-such-impl")
    finally:
        from repro.core import head_api
        head_api._REGISTRY.pop(name, None)


def test_kernel_in_user_facing_enumeration():
    assert "kernel" in available_impls()
    H, E, b, mask = _inputs()
    y = lm_head(H, E, b, mask, impl="kernel", interpret=True,
                block_b=1, block_s=32, block_v=32)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(lm_head_naive(H, E, b, mask)),
                               atol=1e-5, rtol=1e-5)


def test_get_head_impl_unknown_lists_registry():
    with pytest.raises(ValueError) as ei:
        get_head_impl("bogus")
    for name in available_impls():
        assert name in str(ei.value)


# ---------------------------------------------------------------------------
# one factory, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["naive", "tiled", "sparton", "kernel"])
@pytest.mark.parametrize("softcap", [None, 4.0])
def test_all_impls_match_naive_through_factory(impl, softcap):
    H, E, b, mask = _inputs(seed=3)
    y_ref = lm_head_naive(H, E, b, mask, logit_softcap=softcap)
    head = make_head(_spec(impl, logit_softcap=softcap))
    y = head(H, E, b, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("impl", ["sparton", "kernel"])
def test_factory_grads_match_naive(impl):
    H, E, b, mask = _inputs(seed=7)
    head = make_head(_spec(impl, logit_softcap=3.0))

    def loss(fn):
        return lambda H, E, b: jnp.sum(fn(H, E, b, mask) ** 2)

    g = jax.grad(loss(head), argnums=(0, 1, 2))(H, E, b)
    g_ref = jax.grad(
        loss(lambda H, E, b, m: lm_head_naive(H, E, b, m,
                                              logit_softcap=3.0)),
        argnums=(0, 1, 2))(H, E, b)
    for a, c in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-4, rtol=1e-4)


def test_default_b_mask_and_out_dtype():
    H, E, _, _ = _inputs()
    head = make_head(_spec("sparton", out_dtype="bfloat16"))
    y = head(H, E)
    assert y.dtype == jnp.bfloat16
    y_ref = lm_head_naive(H, E)
    np.testing.assert_allclose(np.asarray(y, dtype=np.float32),
                               np.asarray(y_ref), atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# kwarg normalization / deprecation shims
# ---------------------------------------------------------------------------

def test_sparton_head_softcap_kwarg_deprecated():
    from repro.kernels.ops import sparton_head

    H, E, b, mask = _inputs()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y_dep = sparton_head(H, E, b, mask, block_b=1, block_s=32,
                             block_v=32, softcap=4.0, interpret=True)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    y = sparton_head(H, E, b, mask, block_b=1, block_s=32, block_v=32,
                     logit_softcap=4.0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_dep), np.asarray(y), atol=0)
    with pytest.raises(ValueError, match="conflicting"):
        sparton_head(H, E, b, mask, logit_softcap=2.0, softcap=4.0,
                     interpret=True)


def test_lm_head_softcap_kwarg_deprecated():
    H, E, b, mask = _inputs()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y_dep = lm_head(H, E, b, mask, impl="naive", softcap=4.0)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    np.testing.assert_allclose(
        np.asarray(y_dep),
        np.asarray(lm_head_naive(H, E, b, mask, logit_softcap=4.0)),
        atol=0)


# ---------------------------------------------------------------------------
# config -> spec
# ---------------------------------------------------------------------------

def test_config_head_spec_translation():
    from repro.configs.base import TransformerConfig

    cfg = TransformerConfig(
        name="t", family="dense", n_layers=1, d_model=8, n_heads=2,
        n_kv_heads=2, d_head=4, d_ff=16, vocab_size=64,
        final_logit_softcap=30.0, head_block_b=2, head_vocab_tile=128)
    spec = cfg.head_spec()
    assert spec.impl == "sparton"          # "jax" is the legacy alias
    assert spec.logit_softcap == 30.0
    assert spec.block_b == 2 and spec.block_s is None
    assert spec.vocab_tile == 128
    assert cfg.head_spec(impl="kernel").impl == "kernel"
    import dataclasses
    assert dataclasses.replace(cfg, head_impl="kernel").head_spec().impl \
        == "kernel"


# ---------------------------------------------------------------------------
# sharded: the Pallas kernel inside the shard_map body
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    os.environ["SPARTON_AUTOTUNE_CACHE"] = os.path.join(
        os.environ.get("TMPDIR", "/tmp"), "sparton_headapi_test.json")
    import jax, jax.numpy as jnp, numpy as np
    from repro.compat import set_mesh
    from repro.core.head_api import HeadSpec, make_head
    from repro.core.lm_head import lm_head_naive
    import repro.kernels.autotune as autotune

    assert jax.device_count() >= 2, jax.devices()

    B, S, D, V = 4, 24, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    H = jax.random.normal(ks[0], (B, S, D))
    E = jax.random.normal(ks[1], (V, D)) * 0.3
    b = jax.random.normal(ks[2], (V,)) * 0.1
    mask = (jax.random.uniform(ks[3], (B, S)) > 0.2).astype(jnp.int32)
    mask = mask.at[:, 0].set(1)

    # spy on block resolution: the kernel must be keyed on the LOCAL
    # vocab shard, not the global V
    seen_V = []
    _orig = autotune.resolve_blocks
    def _spy(B_, S_, D_, V_, dtype, bb, bs, bv, **kw):
        seen_V.append(V_)
        return _orig(B_, S_, D_, V_, dtype, bb, bs, bv, **kw)
    autotune.resolve_blocks = _spy

    for n_model, softcap in [(1, None), (2, None), (2, 4.0)]:
        mesh = jax.make_mesh(
            (n_model,), ("model",),
            devices=jax.devices()[:n_model])
        y_ref = lm_head_naive(H, E, b, mask, logit_softcap=softcap)
        spec_k = HeadSpec(impl="kernel", interpret=True,
                          logit_softcap=softcap)
        spec_s = HeadSpec(impl="sparton", vocab_tile=16,
                          logit_softcap=softcap)
        head_k = make_head(spec_k, mesh=mesh, batch_axes=())
        head_s = make_head(spec_s, mesh=mesh, batch_axes=())

        seen_V.clear()
        with set_mesh(mesh):
            y_k = jax.jit(head_k)(H, E, b, mask)
            y_s = jax.jit(head_s)(H, E, b, mask)
        assert seen_V and all(v == V // n_model for v in seen_V), \\
            (n_model, seen_V)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_s),
                                   atol=1e-5, rtol=1e-5)

        def loss(fn):
            return lambda H, E, b: jnp.sum(jnp.sin(fn(H, E, b, mask)))
        with set_mesh(mesh):
            g_k = jax.jit(jax.grad(loss(head_k), (0, 1, 2)))(H, E, b)
            g_s = jax.jit(jax.grad(loss(head_s), (0, 1, 2)))(H, E, b)
        g_ref = jax.grad(
            loss(lambda H, E, b, m=mask: lm_head_naive(
                H, E, b, m, logit_softcap=softcap)), (0, 1, 2))(H, E, b)
        for a, c in zip(g_k, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       atol=1e-4, rtol=1e-4)
        for a, c in zip(g_k, g_s):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       atol=1e-4, rtol=1e-4)
        print(f"OK sharded kernel n_model={n_model} softcap={softcap}")

    print("ALL_HEAD_API_SHARDED_PASSED")
""")


def test_sharded_kernel_head_subprocess():
    """make_head(spec, mesh) with impl='kernel': Pallas inside shard_map
    on 1- and 2-device meshes matches impl='sparton' and the unsharded
    naive oracle (values + grads, incl. softcap), with the autotuner
    keyed on the local vocab shard. Runs in a subprocess so the forced
    host-device count never leaks into the main pytest process."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL_HEAD_API_SHARDED_PASSED" in proc.stdout
