"""CI gate checks over the ``BENCH_*.json`` records.

One place for the acceptance bars that used to live as four
copy-pasted ``python -c "import json; assert ..."`` blobs inside
``ci.yml`` — inline blobs are neither testable nor reviewable as
diffs. Each bench family has a named check:

* ``kernels``   — the head-implementation set is complete (a missing
                  row means a backend silently fell out of the bench);
* ``retrieval`` — the four scoring paths ran and their top-k ids
                  agree (the PR-3 parity acceptance), and the fused
                  kernel clears its bars (id parity with impact,
                  strictly lower analytic peak scoring bytes, and —
                  on real backends only — latency at-or-below impact);
* ``engine``    — the six engine methods ran, pruned/quantized ids
                  match impact, the quantized index clears the >= 4x
                  compression bar, BOTH sharding axes (doc top-k
                  merge and term partial-sum merge) are id-identical
                  to the unsharded scorer at 1/2/4 shards, the 2D
                  (doc × term) grid is id-identical at every tested
                  shape, the ``plan_placement`` decision record picks
                  a term-bearing grid for the 250k-vocab synthetic
                  corpus and doc-only for the 30k one, and both
                  fused rows (raw + in-kernel-dequant) clear the
                  fused bars against their unfused references;
* ``serving``   — the traffic simulation survived: non-zero sustained
                  QPS every phase, healthy warm/recovery (no shedding,
                  p99 under the SLO, back to ``exact``), the overload
                  phase actually degraded with a bounded shed rate,
                  nDCG@10 falls monotonically down the ladder from an
                  exact rung at 1.0, and the fault run lost zero
                  requests with only poisoned uids failing (plus an
                  OOM cap halve + regrow);
* ``frontier``  — the caching/tenancy frontier holds its invariants:
                  cache-on results id- and value-identical to
                  cache-off (Zipf replay probe AND zero mismatches
                  under index churn), hit rate >= 0.5 on the skewed
                  replay with a sustained-QPS win over cache-off,
                  weighted-fair tenant scheduling near its configured
                  ratio with poison failures confined to the poisoned
                  tenant, and continuous batching strictly out-serving
                  one-batch-per-tick at no worse shed rate;
* ``quality``   — the effectiveness loop closed: exact retrieval
                  scores nDCG@10 = 1.0 on the planted graded corpus,
                  pruned (default margin) and quantized match exact
                  within tolerance (the paper's "no effectiveness
                  loss" claim), the degrade ladder is monotone
                  non-increasing, the rep_topk sweep recovers exact
                  quality at full width, and the short training run
                  beats its untrained init on MRR@10 and nDCG@10.

Checks return a list of human-readable failures (empty = pass) so
they are unit-testable (``tests/test_bench_check.py``); the CLI exits
non-zero and prints every failure, plus the record itself so the CI
log keeps the numbers in view:

    python -m benchmarks.check BENCH_engine.json
    python -m benchmarks.check --bench kernels some/path.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Callable, Dict, List

EXPECTED_HEADS = {"naive", "tiled", "sparton-jax", "sparton-kernel"}
EXPECTED_RETRIEVAL = {"dense", "streaming", "impact", "fused"}
EXPECTED_ENGINE = {"impact", "fused", "pruned", "quantized",
                   "fused_quantized", "streaming"}
EXPECTED_SHARD_COUNTS = {"1", "2", "4"}
EXPECTED_SHARD2D_GRIDS = {"1x1", "2x2", "1x4", "4x1"}
MIN_COMPRESSION_RATIO = 4.0
EXPECTED_PHASES = ("warm", "overload", "recovery")
# steady phases must sit comfortably inside the SLO; the overload p99
# may transiently blow through it while the ladder engages, but must
# stay bounded (shedding + degradation keep the tail finite)
STEADY_P99_X = 1.0
OVERLOAD_P99_X = 3.0
MAX_STEADY_SHED = 0.05
MAX_OVERLOAD_SHED = 0.9
LADDER_RUNGS = ("exact", "pruned", "aggressive", "minimal")
# quality gate bars: the paper's "no effectiveness loss" methods must
# sit within QUALITY_TOL of exact; training must clear a real margin
# over the untrained init, not round-off
EXPECTED_QUALITY_METHODS = {"exact", "pruned", "quantized",
                            "term_sharded", "doc_sharded", "aggressive"}
LOSSLESS_METHODS = ("pruned", "quantized", "term_sharded",
                    "doc_sharded")
QUALITY_TOL = 1e-3
MIN_TRAIN_DELTA = 0.01
# frontier bars: the ISSUE-9 acceptance criteria
MIN_HIT_RATE = 0.5
# measured fair-share ratio must land near the configured weight
# ratio — wide enough for batch-quantization noise, tight enough that
# unweighted round-robin (ratio 1.0) fails
FAIRNESS_REL_TOL = 0.35


def check_kernels(d: dict) -> List[str]:
    heads = set(d.get("heads", {}))
    if heads != EXPECTED_HEADS:
        return [f"kernel bench heads {sorted(heads)} != expected "
                f"{sorted(EXPECTED_HEADS)}"]
    return []


def _check_fused(d: dict, pairs) -> List[str]:
    """Fused-kernel gates shared by the retrieval and engine benches.

    ``pairs`` lists (fused_row, unfused_reference) method names. Three
    bars per pair: the fused parity flag must hold, the fused path's
    analytic peak *scoring* bytes must be strictly below the unfused
    reference's (no (B, N) materialization — the kernel's reason to
    exist), and on real backends its latency must not exceed the
    reference's. The latency bar is skipped under the Pallas
    interpreter (``interpret: true``): interpret-mode timings order
    implementations, they do not predict hardware (DESIGN.md §5), and
    a serially-interpreted grid losing to jitted XLA says nothing
    about the TPU.
    """
    errs = []
    if not d.get("parity", {}).get("fused_ids_equal"):
        errs.append(f"fused top-k id parity failed: {d.get('parity')}")
    methods = d.get("methods", {})
    for fused, ref in pairs:
        frec, rrec = methods.get(fused, {}), methods.get(ref, {})
        if not frec or not rrec:
            continue    # the method-set check reports the missing row
        fp = frec.get("peak_scoring_bytes")
        rp = rrec.get("peak_scoring_bytes")
        if fp is None or not fp < rp:
            errs.append(f"{fused} peak scoring bytes {fp} not strictly "
                        f"below {ref}'s {rp} — the (B, N) matrix is "
                        f"supposed to be gone")
        if not d.get("interpret", True):
            ft, rt = frec.get("median_ms"), rrec.get("median_ms")
            if ft is None or not ft <= rt:
                errs.append(f"{fused} median {ft}ms above {ref}'s "
                            f"{rt}ms on a real backend")
    return errs


def check_retrieval(d: dict) -> List[str]:
    errs = []
    methods = set(d.get("methods", {}))
    if methods != EXPECTED_RETRIEVAL:
        errs.append(f"retrieval methods {sorted(methods)} != expected "
                    f"{sorted(EXPECTED_RETRIEVAL)}")
    if not d.get("parity", {}).get("topk_ids_equal"):
        errs.append(f"retrieval top-k id parity failed: "
                    f"{d.get('parity')}")
    errs += _check_fused(d, [("fused", "impact")])
    return errs


def _check_shard_rows(d: dict, key: str) -> List[str]:
    rows = d.get(key, {})
    missing = EXPECTED_SHARD_COUNTS - set(rows)
    errs = []
    if missing:
        errs.append(f"{key} scaling rows missing shard counts "
                    f"{sorted(missing)} (have {sorted(rows)})")
    for s, rec in sorted(rows.items()):
        if not rec.get("topk_ids_equal"):
            errs.append(f"{key} x{s} top-k ids differ from the "
                        f"unsharded scorer: {rec}")
    return errs


def _check_shard2d(d: dict) -> List[str]:
    """The 2D grid rows: every tested (doc × term) shape present and
    id-identical to the unsharded scorer."""
    rows = d.get("shard2d", {})
    missing = EXPECTED_SHARD2D_GRIDS - set(rows)
    errs = []
    if missing:
        errs.append(f"shard2d scaling rows missing grids "
                    f"{sorted(missing)} (have {sorted(rows)})")
    for g, rec in sorted(rows.items()):
        if not rec.get("topk_ids_equal"):
            errs.append(f"shard2d {g} top-k ids differ from the "
                        f"unsharded scorer: {rec}")
    return errs


def _check_planner(d: dict) -> List[str]:
    """The ``plan_placement`` decision record: the 250k-vocab probe
    must get a term-bearing grid (its O(V) directory dominates any
    per-device posting slice), the 30k-vocab probe must stay doc-only
    (the directory is a rounding error there — term sharding would
    buy an all-reduce for nothing)."""
    planner = d.get("planner", {})
    huge = planner.get("huge_vocab", {})
    small = planner.get("small_vocab", {})
    errs = []
    if not huge or not small:
        return [f"planner decision record missing "
                f"huge_vocab/small_vocab probes (have "
                f"{sorted(planner)})"]
    if not huge.get("term_shards", 0) >= 2:
        errs.append(f"planner picked no term shards for the "
                    f"{huge.get('vocab_size')}-term vocab: {huge}")
    if small.get("axis") != "doc":
        errs.append(f"planner did not pick doc-only for the "
                    f"{small.get('vocab_size')}-term vocab: {small}")
    return errs


def check_engine(d: dict) -> List[str]:
    errs = []
    methods = set(d.get("methods", {}))
    if methods != EXPECTED_ENGINE:
        errs.append(f"engine methods {sorted(methods)} != expected "
                    f"{sorted(EXPECTED_ENGINE)}")
    quant = d.get("quantization", {})
    if not quant.get("topk_ids_equal"):
        errs.append(f"quantized top-k ids differ from impact: {quant}")
    ratio = quant.get("ratio", 0.0)
    if not ratio >= MIN_COMPRESSION_RATIO:
        errs.append(f"compression ratio {ratio} below the "
                    f"{MIN_COMPRESSION_RATIO}x bar")
    if not d.get("pruned", {}).get("topk_ids_equal"):
        errs.append(f"pruned top-k ids differ from impact: "
                    f"{d.get('pruned')}")
    errs += _check_shard_rows(d, "sharded")
    errs += _check_shard_rows(d, "term_sharded")
    errs += _check_shard2d(d)
    errs += _check_planner(d)
    if not d.get("parity", {}).get("topk_ids_equal"):
        errs.append(f"engine cross-path parity flag is false: "
                    f"{d.get('parity')}")
    errs += _check_fused(d, [("fused", "impact"),
                             ("fused_quantized", "quantized")])
    return errs


def check_serving(d: dict) -> List[str]:
    errs = []
    phases = {p.get("name"): p for p in d.get("phases", [])}
    missing = set(EXPECTED_PHASES) - set(phases)
    if missing:
        return [f"serving phases missing {sorted(missing)} "
                f"(have {sorted(phases)})"]
    slo = d.get("slo_ms", 0.0)
    for name, p in phases.items():
        if not p.get("sustained_qps", 0.0) > 0.0:
            errs.append(f"{name}: sustained_qps "
                        f"{p.get('sustained_qps')} not > 0")
        if p.get("failed", 0) != 0:
            errs.append(f"{name}: {p.get('failed')} failed requests "
                        f"in a fault-free run")
    for name in ("warm", "recovery"):
        p = phases[name]
        if p["shed_rate"] > MAX_STEADY_SHED:
            errs.append(f"{name}: shed_rate {p['shed_rate']} > "
                        f"{MAX_STEADY_SHED} at steady offered load")
        if p["p99_ms"] > STEADY_P99_X * slo:
            errs.append(f"{name}: p99 {p['p99_ms']}ms blows the "
                        f"{slo}ms SLO at steady offered load")
    over = phases["overload"]
    if over["degrade_transitions"] < 1:
        errs.append("overload: degrade ladder never engaged "
                    "(0 transitions)")
    if not 0.0 < over["shed_rate"] <= MAX_OVERLOAD_SHED:
        errs.append(f"overload: shed_rate {over['shed_rate']} outside "
                    f"(0, {MAX_OVERLOAD_SHED}] — no shedding means the "
                    f"ramp isn't an overload; above means collapse")
    if over["p99_ms"] > OVERLOAD_P99_X * slo:
        errs.append(f"overload: p99 {over['p99_ms']}ms > "
                    f"{OVERLOAD_P99_X}x the {slo}ms SLO")
    if over["sustained_qps"] <= phases["warm"]["sustained_qps"]:
        errs.append(f"overload sustained {over['sustained_qps']} qps "
                    f"did not exceed warm "
                    f"{phases['warm']['sustained_qps']} — degradation "
                    f"bought no capacity")
    if phases["recovery"]["degrade_name_end"] != "exact":
        errs.append(f"recovery ended degraded: "
                    f"{phases['recovery']['degrade_name_end']}")
    if d.get("quality_metric") != "ndcg@10":
        errs.append(f"quality_metric {d.get('quality_metric')!r} != "
                    f"'ndcg@10' — degrade_quality must be scored with "
                    f"the shared eval metrics against qrels")
    quality = d.get("degrade_quality", {})
    ladder = [quality.get(r) for r in LADDER_RUNGS]
    if None in ladder:
        errs.append(f"degrade_quality missing rungs: {quality}")
    else:
        if ladder[0] != 1.0:
            errs.append(f"exact-rung nDCG@10 {ladder[0]} != 1.0 on the "
                        f"planted graded corpus")
        if any(a < b for a, b in zip(ladder, ladder[1:])):
            errs.append(f"nDCG@10 not monotone down the ladder: "
                        f"{ladder}")
        if not ladder[-1] > 0.0:
            errs.append(f"minimal rung nDCG@10 {ladder[-1]} not > 0 — "
                        f"degraded search returns garbage")
    f = d.get("faults", {})
    if f.get("lost", -1) != 0:
        errs.append(f"faults: {f.get('lost')} requests lost (submitted "
                    f"uid with no served/shed/failed completion)")
    if f.get("failed_outside_poison", -1) != 0:
        errs.append(f"faults: {f.get('failed_outside_poison')} "
                    f"non-poisoned requests failed — isolation leaked")
    if not f.get("poisoned_failed", 0) >= 1:
        errs.append("faults: no poisoned request reached a "
                    "FailedResult (injection never exercised)")
    if not f.get("oom_faults", 0) >= 1:
        errs.append("faults: the OOM rule never fired")
    if not f.get("min_batch_cap", 1 << 30) < f.get("end_batch_cap", 0):
        errs.append(f"faults: batch cap never halved+regrew "
                    f"(min {f.get('min_batch_cap')}, "
                    f"end {f.get('end_batch_cap')})")
    return errs


def check_frontier(d: dict) -> List[str]:
    errs = []
    replay = d.get("zipf_replay", {})
    on, off = replay.get("cache_on", {}), replay.get("cache_off", {})
    if not on or not off:
        return [f"zipf_replay missing cache_on/cache_off rows: "
                f"{sorted(replay)}"]
    if on.get("parity") is not True:
        errs.append(f"cache-on results are not id/value-identical to "
                    f"the raw engine on the replay probe: "
                    f"parity={on.get('parity')}")
    hr = on.get("hit_rate", 0.0)
    if not hr >= MIN_HIT_RATE:
        errs.append(f"zipf replay hit_rate {hr} below the "
                    f"{MIN_HIT_RATE} bar")
    if not on.get("sustained_qps", 0.0) > off.get("sustained_qps",
                                                  float("inf")):
        errs.append(f"cache-on sustained {on.get('sustained_qps')} qps "
                    f"not above cache-off "
                    f"{off.get('sustained_qps')} — the cache bought "
                    f"no throughput")
    if not on.get("p99_ms", float("inf")) < off.get("p99_ms", 0.0):
        errs.append(f"cache-on p99 {on.get('p99_ms')}ms not below "
                    f"cache-off {off.get('p99_ms')}ms")
    churn = d.get("churn", {})
    if not churn.get("rounds", 0) > 0:
        errs.append("churn experiment ran 0 rounds")
    if churn.get("mismatches", -1) != 0:
        errs.append(f"churn: {churn.get('mismatches')} cached searches "
                    f"differed from cache-off — a stale entry was "
                    f"served")
    if not churn.get("invalidations", 0) >= 1:
        errs.append("churn: generation invalidation never fired — the "
                    "mutations were not exercised against the cache")
    ten = d.get("tenancy", {})
    per = ten.get("tenants", {})
    poisoned = [n for n, t in per.items() if t.get("failed", 0) > 0]
    if poisoned != ["c"]:
        errs.append(f"tenancy isolation: expected only tenant 'c' to "
                    f"record failures, got {poisoned or 'none'}")
    for n in ("a", "b"):
        t = per.get(n, {})
        if t.get("shed", -1) != 0 or t.get("failed", -1) != 0:
            errs.append(f"tenancy isolation: victim tenant {n!r} has "
                        f"shed={t.get('shed')} failed={t.get('failed')}"
                        f" — the poisoned tenant leaked")
    ratio = ten.get("fairness_ratio_ab", 0.0)
    want = ten.get("weight_ratio_ab", 0.0)
    if not want > 0 or abs(ratio - want) > FAIRNESS_REL_TOL * want:
        errs.append(f"tenancy fairness: contended served ratio a/b "
                    f"{ratio} not within {FAIRNESS_REL_TOL:.0%} of the "
                    f"weight ratio {want}")
    cont = d.get("continuous", {})
    cb, ob = cont.get("continuous", {}), cont.get("one_batch", {})
    if not cb or not ob:
        return errs + [f"continuous experiment missing rows: "
                       f"{sorted(cont)}"]
    for name, row in (("one_batch", ob), ("continuous", cb)):
        if row.get("lost", -1) != 0:
            errs.append(f"continuous/{name}: {row.get('lost')} "
                        f"requests lost")
        if row.get("failed", -1) != 0:
            errs.append(f"continuous/{name}: {row.get('failed')} "
                        f"failed in a fault-free run")
    if not cb.get("sustained_qps", 0.0) > ob.get("sustained_qps",
                                                 float("inf")):
        errs.append(f"continuous sustained {cb.get('sustained_qps')} "
                    f"qps not strictly above one-batch-per-tick "
                    f"{ob.get('sustained_qps')}")
    if not cb.get("shed_rate", float("inf")) <= ob.get("shed_rate",
                                                       -1.0):
        errs.append(f"continuous shed_rate {cb.get('shed_rate')} above "
                    f"one-batch-per-tick {ob.get('shed_rate')} — the "
                    f"QPS win was bought with extra shedding")
    return errs


def check_quality(d: dict) -> List[str]:
    errs = []
    if d.get("quality_metric") != "ndcg@10":
        errs.append(f"quality_metric {d.get('quality_metric')!r} != "
                    f"'ndcg@10'")
    methods = d.get("method_quality", {})
    missing = EXPECTED_QUALITY_METHODS - set(methods)
    if missing:
        errs.append(f"method_quality missing {sorted(missing)} "
                    f"(have {sorted(methods)})")
        return errs
    exact = methods["exact"]
    for m in ("mrr@10", "ndcg@10"):
        if exact.get(m) != 1.0:
            errs.append(f"exact {m} {exact.get(m)} != 1.0 — the "
                        f"planted graded corpus must be perfectly "
                        f"recoverable by exact retrieval")
    for name in LOSSLESS_METHODS:
        for m in ("mrr@10", "ndcg@10"):
            got, ref = methods[name].get(m, 0.0), exact.get(m, 1.0)
            if abs(got - ref) > QUALITY_TOL:
                errs.append(f"{name} {m} {got} differs from exact "
                            f"{ref} by > {QUALITY_TOL} — effectiveness "
                            f"loss on a nominally lossless method")
    ladder = [d.get("ladder_quality", {}).get(r) for r in LADDER_RUNGS]
    if None in ladder:
        errs.append(f"ladder_quality missing rungs: "
                    f"{d.get('ladder_quality')}")
    else:
        if ladder[0] != 1.0:
            errs.append(f"ladder exact rung {ladder[0]} != 1.0")
        if any(a < b for a, b in zip(ladder, ladder[1:])):
            errs.append(f"ladder nDCG@10 not monotone non-increasing: "
                        f"{ladder}")
        if not ladder[-1] > 0.0:
            errs.append(f"minimal rung {ladder[-1]} not > 0")
    sweep = d.get("rep_topk_sweep", {})
    if not sweep:
        errs.append("rep_topk_sweep missing/empty")
    else:
        by_w = sorted(((int(w), v.get("ndcg@10", 0.0))
                       for w, v in sweep.items()))
        vals = [v for _, v in by_w]
        if any(a > b + QUALITY_TOL for a, b in zip(vals, vals[1:])):
            errs.append(f"rep_topk sweep not non-decreasing in width: "
                        f"{by_w}")
        if abs(vals[-1] - exact.get("ndcg@10", 1.0)) > QUALITY_TOL:
            errs.append(f"widest rep_topk (w={by_w[-1][0]}) nDCG@10 "
                        f"{vals[-1]} does not recover exact quality")
    tv = d.get("trained_vs_init", {})
    delta = tv.get("delta", {})
    for m in ("mrr@10", "ndcg@10"):
        if not delta.get(m, -1.0) >= MIN_TRAIN_DELTA:
            errs.append(f"trained_vs_init {m} delta {delta.get(m)} < "
                        f"{MIN_TRAIN_DELTA} — training did not beat "
                        f"the untrained init "
                        f"(init {tv.get('init', {}).get(m)}, trained "
                        f"{tv.get('trained', {}).get(m)})")
    if not tv.get("loss_last", float("inf")) < tv.get("loss_first", 0.0):
        errs.append(f"training loss did not fall: "
                    f"{tv.get('loss_first')} -> {tv.get('loss_last')}")
    return errs


CHECKS: Dict[str, Callable[[dict], List[str]]] = {
    "kernels": check_kernels,
    "retrieval": check_retrieval,
    "engine": check_engine,
    "serving": check_serving,
    "frontier": check_frontier,
    "quality": check_quality,
}


def infer_bench(path: str) -> str:
    """``BENCH_engine*.json`` -> ``engine`` etc.; raises on unknown."""
    base = os.path.basename(path)
    for name in CHECKS:
        if base.startswith(f"BENCH_{name}"):
            return name
    raise ValueError(
        f"cannot infer bench family from {base!r}; pass --bench "
        f"{{{','.join(CHECKS)}}}")


def check_file(path: str, bench: str = None) -> List[str]:
    """Run the (inferred or given) check; returns failure strings."""
    if bench is None:
        bench = infer_bench(path)
    if bench not in CHECKS:
        raise ValueError(f"unknown bench {bench!r}; one of "
                         f"{sorted(CHECKS)}")
    with open(path) as f:
        record = json.load(f)
    return [f"{path}: {e}" for e in CHECKS[bench](record)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="assert the BENCH_*.json acceptance bars (the CI "
                    "gate; see module docstring)")
    ap.add_argument("paths", nargs="+", metavar="BENCH.json")
    ap.add_argument("--bench", default=None, choices=sorted(CHECKS),
                    help="bench family (default: inferred from each "
                         "file name)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress echoing the records")
    args = ap.parse_args(argv)

    failures = []
    for path in args.paths:
        if not args.quiet:
            with open(path) as f:
                print(f"== {path} ==")
                print(json.dumps(json.load(f), indent=2,
                                 sort_keys=True))
        failures += check_file(path, bench=args.bench)
    for f in failures:
        print(f"GATE FAILED: {f}", file=sys.stderr)
    if not failures:
        print(f"all gates passed for {len(args.paths)} record(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
