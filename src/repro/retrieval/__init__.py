"""Sparse-native retrieval: SparseRep reps, inverted impact index,
and the unified ``retrieve()`` dispatcher (DESIGN.md §7)."""

from repro.retrieval.index import InvertedIndex, build_inverted_index
from repro.retrieval.score import METHODS, impact_scores, retrieve
from repro.retrieval.sparse_rep import (SparseRep, sparsify_threshold,
                                        sparsify_topk, split_rows,
                                        stack_rows)

__all__ = [
    "InvertedIndex",
    "METHODS",
    "SparseRep",
    "build_inverted_index",
    "impact_scores",
    "retrieve",
    "sparsify_threshold",
    "sparsify_topk",
    "split_rows",
    "stack_rows",
]
