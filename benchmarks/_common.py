"""Shared benchmark utilities: timing, CSV output, memory proxies.

Wall-clock here is CPU-container time — meaningful for RELATIVE
comparisons between implementations of the same op at the same shape
(the paper's tables compare implementations, which is preserved), not
as absolute TPU numbers. Peak-memory comparisons use the analytic
activation/residual byte counts (exact for XLA's plan via
``memory_analysis`` where available).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 3, iters: int = 10,
            **kw) -> float:
    """Median wall time (ms) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def compiled_peak_bytes(fn: Callable, *abstract_args) -> float:
    """Peak-memory estimate from XLA's buffer assignment."""
    compiled = jax.jit(fn).lower(*abstract_args).compile()
    ma = compiled.memory_analysis()
    if ma is None:
        return float("nan")
    return float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


def scoring_peak_bytes(method: str, *, B: int, N: int, k: int,
                       Q: int = 0, L: int = 0) -> int:
    """Analytic peak bytes of one scoring call's *intermediates*.

    The quantity the fused-kernel gate compares (corpus bytes are
    reported separately): every lane of the gathered posting windows
    costs 8 bytes (f32 weight + i32 doc id, or i32 packed byte + i32
    gap for the u4 variant), the unfused index paths then materialize
    the dense ``(B, N)`` f32 score matrix, and every path emits the
    ``(B, k)`` winners (f32 + i32). The fused and streaming paths'
    peaks are the ones with no N term — the whole point of the kernel
    (DESIGN.md §12). ``Q``/``L`` are the query width and the index's
    ``max_postings`` (0 for the dense-corpus paths, which gather no
    windows).
    """
    window = B * Q * L * 8
    topk = B * k * 8
    if method == "dense":
        return B * N * 4 + topk
    if method in ("impact", "pruned", "quantized"):
        return window + B * N * 4 + topk
    if method in ("fused", "fused_quantized"):
        return window + topk
    if method == "streaming":
        return topk
    raise ValueError(f"no scoring-memory model for method {method!r}")


def csv_print(header: Iterable[str], rows: List[Iterable]) -> None:
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(str(x) for x in r))
