"""Host-sharded loader + length bucketing.

Production multi-host JAX training feeds each host its own slice of
the global batch (``jax.process_index()`` selecting the shard); arrays
are then placed with ``jax.device_put`` against the global sharding.
On this single-process container the loader still exercises the same
shard arithmetic (n_shards > 1 with a fixed shard id).
"""

from __future__ import annotations

import collections
import queue
import threading
from typing import Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np


class HostShardedLoader:
    """Wraps a batch iterator factory with host sharding + prefetch.

    ``make_iter(shard, n_shards)`` must return an iterator of dict
    batches whose leading dim is the *per-host* batch.
    """

    def __init__(
        self,
        make_iter: Callable[[int, int], Iterator[Dict[str, np.ndarray]]],
        *,
        shard: int = 0,
        n_shards: int = 1,
        prefetch: int = 2,
    ):
        self.shard = shard
        self.n_shards = n_shards
        self._it = make_iter(shard, n_shards)
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        try:
            for batch in self._it:
                if self._stop.is_set():
                    return
                self._q.put(batch)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def length_bucket(
    lengths: Sequence[int],
    boundaries: Sequence[int],
) -> List[List[int]]:
    """Group example indices into length buckets (minimizes padding).

    Returns one list of indices per bucket; bucket i holds lengths in
    (boundaries[i-1], boundaries[i]].
    """
    buckets: List[List[int]] = [[] for _ in range(len(boundaries) + 1)]
    for idx, ln in enumerate(lengths):
        placed = False
        for bi, bound in enumerate(boundaries):
            if ln <= bound:
                buckets[bi].append(idx)
                placed = True
                break
        if not placed:
            buckets[-1].append(idx)
    return buckets
