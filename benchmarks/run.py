"""Benchmark orchestrator: one benchmark per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``            — all tables
``PYTHONPATH=src python -m benchmarks.run --only table1``

The dry-run / roofline matrices are separate processes (they need 512
placeholder devices BEFORE jax init):
  PYTHONPATH=src python -m repro.launch.dryrun --json dryrun.json
  PYTHONPATH=src python -m benchmarks.roofline --dryrun dryrun.json ...
"""

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["table1", "fig2", "table2", "table3"])
    args = ap.parse_args(argv)

    benches = [
        ("table1", "LM-head component breakdown (paper Table 1)",
         "benchmarks.bench_table1_components"),
        ("fig2", "B/S/V scaling (paper Figure 2)",
         "benchmarks.bench_fig2_scaling"),
        ("table2", "backward seq-len scaling + OOM wall (paper Table 2)",
         "benchmarks.bench_table2_seqlen"),
        ("table3", "end-to-end LSR training (paper Table 3)",
         "benchmarks.bench_table3_e2e"),
    ]

    rc = 0
    for key, title, module in benches:
        if args.only and key != args.only:
            continue
        print(f"\n=== {key}: {title} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"[{key} done in {time.time() - t0:.1f}s]", flush=True)
        except Exception as e:
            rc = 1
            print(f"[{key} FAILED: {e!r}]", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
