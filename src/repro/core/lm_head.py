"""Sparton LM head — the paper's core contribution, in pure JAX.

Implements Eq. 1 of the paper::

    Y = max_s [ log(1 + ReLU(H E^T + b)) . M' ]

in four flavours that mirror the paper's experimental conditions:

* ``lm_head_naive``    — Alg. 1: materializes the full ``(B, S, V)``
  logit tensor. The "Eager/Compiled LM Head" baseline.
* ``lm_head_tiled``    — Alg. 2 forward only: scans vocabulary tiles
  with a running max, but lets autograd differentiate through the scan
  (residual tiles are saved => O(B*S*V) backward state). The paper's
  "Tiled Head" baseline, which fixes forward memory but not backward.
* ``lm_head_sparton``  — Alg. 2 + Alg. 3: ``jax.custom_vjp`` whose
  residuals are only ``(H, E, y, i_max)``; the backward routes the
  gradient through the single argmax position per ``(b, v)``.
* ``lm_head_sparton_kernel`` (in ``repro.kernels.ops``) — the Pallas
  TPU kernel version, numerically identical.

Masking note: the paper multiplies the *post-activation* matrix by the
broadcast mask (Eq. 1) / the raw logits by the mask (Alg. 2, line 6).
Both are equivalent to excluding masked positions from the max and
clamping the result at zero, because ``f(x) = log1p(relu(x))`` is
monotone with ``f(x) >= 0`` and ``f(0) = 0``. We exclude masked
positions with ``-inf`` *before* the max so that ``i_max`` always
points at a valid (unmasked) token, which makes the gradient routing of
Alg. 3 unambiguous.

``logit_softcap`` extends Eq. 1 with gemma-2 style tanh soft-capping
``c * tanh(x / c)`` applied to the raw logits. The cap is monotone, so
the reordering argument of the paper still holds; the stored
post-activation value still suffices for the backward factor.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels._common import NEG_INF as _NEG_INF, bwd_factor

Array = jax.Array


def _f(x: Array) -> Array:
    """The paper's pointwise map f(x) = log(1 + ReLU(x))."""
    return jnp.log1p(jax.nn.relu(x))


def _apply_softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _mask_to_neg_inf(logits: Array, mask: Optional[Array]) -> Array:
    """Set masked sequence positions to -inf (mask: (B, S) with 1=keep)."""
    if mask is None:
        return logits
    keep = mask.astype(bool)[..., None]  # (B, S, 1) broadcast over V
    return jnp.where(keep, logits, jnp.asarray(_NEG_INF, logits.dtype))


# ---------------------------------------------------------------------------
# Alg. 1 — naive / eager baseline
# ---------------------------------------------------------------------------

def lm_head_naive(
    H: Array,
    E: Array,
    b: Optional[Array] = None,
    mask: Optional[Array] = None,
    *,
    logit_softcap: Optional[float] = None,
) -> Array:
    """Materializes the (B, S, V) logit tensor, then f, then max_s.

    This is the paper's Alg. 1 written as Eq. 1 verbatim (mask applied
    multiplicatively on the post-activation tensor).
    """
    logits = jnp.einsum("bsd,vd->bsv", H, E, preferred_element_type=jnp.float32)
    if b is not None:
        logits = logits + b
    logits = _apply_softcap(logits, logit_softcap)
    acts = _f(logits)
    if mask is not None:
        acts = acts * mask.astype(acts.dtype)[..., None]
    return jnp.max(acts, axis=1).astype(H.dtype)


# ---------------------------------------------------------------------------
# Alg. 2 forward (tiled) — autograd backward (the paper's "Tiled Head")
# ---------------------------------------------------------------------------

def lm_head_tiled(
    H: Array,
    E: Array,
    b: Optional[Array] = None,
    mask: Optional[Array] = None,
    *,
    vocab_tile: int = 4096,
    logit_softcap: Optional[float] = None,
) -> Array:
    """Vocabulary-tiled forward; backward left to autograd.

    Forward peak activation is O(B*S*tile), but ``lax.scan`` saves the
    per-tile residuals for the backward pass, so total autograd state
    remains O(B*S*V) — reproducing the paper's RQ2 finding that tiling
    alone does not relieve backward memory.
    """
    B, S, D = H.shape
    V = E.shape[0]
    pad = (-V) % vocab_tile
    E_p = jnp.pad(E, ((0, pad), (0, 0)))
    b_p = None if b is None else jnp.pad(b, (0, pad))
    n_tiles = (V + pad) // vocab_tile

    E_t = E_p.reshape(n_tiles, vocab_tile, D)
    b_t = None if b_p is None else b_p.reshape(n_tiles, vocab_tile)
    keep = None if mask is None else mask.astype(bool)[..., None]

    def tile_fn(carry, xs):
        if b_t is None:
            (e_tile,) = xs
            logits = jnp.einsum(
                "bsd,vd->bsv", H, e_tile, preferred_element_type=jnp.float32
            )
        else:
            e_tile, bias_tile = xs
            logits = (
                jnp.einsum("bsd,vd->bsv", H, e_tile,
                           preferred_element_type=jnp.float32)
                + bias_tile
            )
        logits = _apply_softcap(logits, logit_softcap)
        if keep is not None:
            logits = jnp.where(keep, logits, _NEG_INF)
        return carry, jnp.max(logits, axis=1)  # (B, vocab_tile)

    xs = (E_t,) if b_t is None else (E_t, b_t)
    _, maxima = jax.lax.scan(tile_fn, (), xs)
    maxima = jnp.moveaxis(maxima, 0, 1).reshape(B, V + pad)[:, :V]
    return _f(maxima).astype(H.dtype)


# ---------------------------------------------------------------------------
# Alg. 2 + Alg. 3 — Sparton (custom_vjp, pure JAX)
# ---------------------------------------------------------------------------

def _sparton_forward_scan(
    H: Array,
    E: Array,
    b: Optional[Array],
    mask: Optional[Array],
    vocab_tile: int,
    logit_softcap: Optional[float],
    unroll: int = 1,
) -> Tuple[Array, Array]:
    """Streaming max over vocabulary tiles. Returns (y, i_max).

    y      — (B, V) post-activation f(max_s logits)   [float32]
    i_max  — (B, V) argmax sequence index             [int32]
    """
    B, S, D = H.shape
    V = E.shape[0]
    pad = (-V) % vocab_tile
    E_p = jnp.pad(E, ((0, pad), (0, 0)))
    b_p = None if b is None else jnp.pad(b, (0, pad))
    n_tiles = (V + pad) // vocab_tile
    E_t = E_p.reshape(n_tiles, vocab_tile, D)
    b_t = None if b_p is None else b_p.reshape(n_tiles, vocab_tile)
    keep = None if mask is None else mask.astype(bool)[..., None]

    def tile_fn(carry, xs):
        if b_t is None:
            (e_tile,) = xs
            bias = 0.0
        else:
            e_tile, bias_tile = xs
            bias = bias_tile
        logits = (
            jnp.einsum("bsd,vd->bsv", H, e_tile,
                       preferred_element_type=jnp.float32)
            + bias
        )
        logits = _apply_softcap(logits, logit_softcap)
        if keep is not None:
            logits = jnp.where(keep, logits, _NEG_INF)
        m = jnp.max(logits, axis=1)                       # (B, tile)
        i = jnp.argmax(logits, axis=1).astype(jnp.int32)  # (B, tile)
        return carry, (m, i)

    xs = (E_t,) if b_t is None else (E_t, b_t)
    _, (maxima, indices) = jax.lax.scan(tile_fn, (), xs, unroll=unroll)
    maxima = jnp.moveaxis(maxima, 0, 1).reshape(B, V + pad)[:, :V]
    indices = jnp.moveaxis(indices, 0, 1).reshape(B, V + pad)[:, :V]
    return _f(maxima), indices


# g = dY/d(raw max logit) from the stored post-activation y — shared
# with the Pallas kernels (which fuse it into their backward epilogue).
_sparton_bwd_factor = bwd_factor


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _sparton_core(
    H: Array,
    E: Array,
    b: Array,
    mask: Array,
    vocab_tile: int,
    logit_softcap: Optional[float],
    bwd_batch_chunk: int,
    unroll: int = 1,
) -> Array:
    y, _ = _sparton_forward_scan(H, E, b, mask, vocab_tile, logit_softcap,
                                 unroll)
    return y.astype(H.dtype)


def _sparton_fwd(H, E, b, mask, vocab_tile, logit_softcap, bwd_batch_chunk,
                 unroll=1):
    y, i_max = _sparton_forward_scan(H, E, b, mask, vocab_tile,
                                     logit_softcap, unroll)
    # Residuals: O(B*V) head state + the inputs (which exist regardless).
    return y.astype(H.dtype), (H, E, y, i_max)


def _sparton_bwd(vocab_tile, logit_softcap, bwd_batch_chunk, unroll,
                 res, dy):
    H, E, y, i_max = res
    B, S, D = H.shape
    V = E.shape[0]
    g = _sparton_bwd_factor(y, dy.astype(jnp.float32), logit_softcap)  # (B,V)

    chunk = max(1, min(bwd_batch_chunk, B))
    n_chunks = -(-B // chunk)
    pad_b = n_chunks * chunk - B
    if pad_b:
        g_p = jnp.pad(g, ((0, pad_b), (0, 0)))
        H_p = jnp.pad(H, ((0, pad_b), (0, 0), (0, 0)))
        i_p = jnp.pad(i_max, ((0, pad_b), (0, 0)))
    else:
        g_p, H_p, i_p = g, H, i_max
    g_c = g_p.reshape(n_chunks, chunk, V)
    H_c = H_p.reshape(n_chunks, chunk, S, D).astype(jnp.float32)
    i_c = i_p.reshape(n_chunks, chunk, V)
    E32 = E.astype(jnp.float32)

    def chunk_fn(dE_acc, xs):
        g_b, h_b, i_b = xs  # (chunk, V), (chunk, S, D), (chunk, V)
        # gathered[c, v, :] = H[c, i_max[c, v], :]  — per-row gather.
        gathered = jax.vmap(lambda h, i: jnp.take(h, i, axis=0))(h_b, i_b)
        dE_acc = dE_acc + jnp.einsum("cv,cvd->vd", g_b, gathered)
        # dH[c, s, :] = sum_v g[c, v] 1[i_max=s] E[v]  — scatter-add.
        contrib = g_b[..., None] * E32[None]  # (chunk, V, D)
        dH_b = jax.vmap(
            lambda con, i: jax.ops.segment_sum(con, i, num_segments=S)
        )(contrib, i_b)
        return dE_acc, dH_b

    dE, dH_c = jax.lax.scan(chunk_fn, jnp.zeros((V, D), jnp.float32),
                            (g_c, H_c, i_c), unroll=unroll)
    dH = dH_c.reshape(n_chunks * chunk, S, D)[:B]
    db = jnp.sum(g, axis=0)  # bias grad: d(logit)/db = 1 at the max position
    return (dH.astype(H.dtype), dE.astype(E.dtype), db.astype(jnp.float32),
            None)


_sparton_core.defvjp(_sparton_fwd, _sparton_bwd)


def lm_head_sparton(
    H: Array,
    E: Array,
    b: Optional[Array] = None,
    mask: Optional[Array] = None,
    *,
    vocab_tile: int = 4096,
    logit_softcap: Optional[float] = None,
    bwd_batch_chunk: int = 8,
    unroll: int = 1,
) -> Array:
    """Sparton LM head (paper Alg. 2 + 3), pure-JAX, differentiable.

    Saves only ``(y, i_max)`` beyond the inputs — O(B*V) backward state
    instead of O(B*S*V). ``unroll`` replicates the scan bodies for
    cost-probe lowering (roofline.py); runtime uses 1.
    """
    B, S, _ = H.shape
    V = E.shape[0]
    if b is None:
        b = jnp.zeros((V,), jnp.float32)
    if mask is None:
        mask = jnp.ones((B, S), jnp.int32)
    return _sparton_core(H, E, b, mask, vocab_tile, logit_softcap,
                         bwd_batch_chunk, unroll)


def sparton_forward_with_indices(
    H: Array,
    E: Array,
    b: Optional[Array] = None,
    mask: Optional[Array] = None,
    *,
    vocab_tile: int = 4096,
    logit_softcap: Optional[float] = None,
) -> Tuple[Array, Array]:
    """Inference-path forward that also returns the argmax indices.

    Useful for interpretability (which token activated each vocab
    dimension) and for the serving path's term-weight extraction.
    """
    y, i_max = _sparton_forward_scan(H, E, b, mask, vocab_tile,
                                     logit_softcap)
    return y.astype(H.dtype), i_max


# Legacy table of the pure-JAX impls. Kept for external callers; the
# canonical enumeration (which also includes the Pallas ``kernel``
# backend and anything registered at runtime) is
# ``repro.core.head_api.available_impls()``.
IMPLEMENTATIONS = {
    "naive": lm_head_naive,
    "tiled": lm_head_tiled,
    "sparton": lm_head_sparton,
}


def lm_head(H, E, b=None, mask=None, *, impl="sparton", softcap=None, **kw):
    """Deprecation shim over the unified head API (``core.head_api``).

    Dispatches through the registry — so ``impl="kernel"`` (and any
    runtime-registered backend) works here too, and an unknown name
    lists the live registry contents. Keyword arguments are the
    ``HeadSpec`` fields; irrelevant ones are ignored by the backend
    (e.g. ``vocab_tile`` for ``naive``). Prefer
    ``make_head(HeadSpec(...))`` in new code: it also handles meshes.
    """
    from repro.core.head_api import (HeadSpec, _with_defaults,
                                     get_head_impl,
                                     normalize_softcap_kwarg)

    kw["logit_softcap"] = normalize_softcap_kwarg(
        kw.get("logit_softcap"), softcap, "lm_head")
    spec = HeadSpec(impl=impl, **kw)
    fn = get_head_impl(impl)
    b, mask = _with_defaults(H, E, b, mask)
    return fn(H, E, b, mask, spec=spec)
