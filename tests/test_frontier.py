"""Tests for the serving frontier (DESIGN.md §13): query-result +
hot-posting caches, multi-corpus tenancy, continuous batching.

The frontier's hard invariant is pinned property-style here: cache-on
must be id- AND value-identical to cache-off, through arbitrary
interleavings of index mutations and cached searches (the hypothesis
churn test), miss-subset re-batching, and hot-window scoring. Fake
clock + numpy encode stub throughout — no jit, no accelerator.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.retrieval import build_inverted_index, stack_rows
from repro.retrieval.score import fused_retrieve
from repro.retrieval.sparse_rep import SparseRep
from repro.runtime.faults import inject_faults
from repro.runtime.frontier import (CachedEngine, HotPostingCache,
                                    QueryResultCache, QuotaExceeded,
                                    TenantPool, TenantQuota,
                                    hot_fused_retrieve,
                                    query_cache_key)
from repro.runtime.frontier.caches import ENTRY_OVERHEAD_BYTES
from repro.runtime.serving import (BatchedEncoder, BatchPolicy,
                                   CorpusEngine, FailedResult, Request,
                                   ServingLoop, ShedResult)

VOCAB = 64


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def np_encoder(width=4, vocab=VOCAB):
    """Pure-numpy encode fn: top-``width`` token counts per row."""

    def encode(tokens, mask):
        toks = np.asarray(tokens)
        msk = np.asarray(mask)
        B = toks.shape[0]
        vals = np.zeros((B, width), np.float32)
        idxs = np.zeros((B, width), np.int32)
        for i in range(B):
            ids, counts = np.unique(toks[i][msk[i] > 0] % vocab,
                                    return_counts=True)
            order = np.argsort(-counts, kind="stable")[:width]
            vals[i, :order.size] = counts[order]
            idxs[i, :order.size] = ids[order]
        return SparseRep(vals, idxs,
                         (vals > 0).sum(axis=1).astype(np.int32))

    return encode


def make_engine(n_docs=24, seed=0, encode=None, **kw):
    eng = CorpusEngine(
        BatchedEncoder(encode or np_encoder(),
                       policy=BatchPolicy(max_batch=8)),
        VOCAB, **kw)
    rng = np.random.default_rng(seed)
    eng.add_docs(list(rng.integers(1, VOCAB, size=(n_docs, 12))
                      .astype(np.int32)))
    eng.flush()
    return eng


def encode_queries(eng, toks):
    toks = np.asarray(toks, np.int32)
    if toks.ndim == 1:
        toks = toks[None, :]
    return eng.encoder.encode_fn(toks, np.ones_like(toks))


def row(values, indices):
    v = np.asarray(values, np.float32)[None, :]
    i = np.asarray(indices, np.int32)[None, :]
    return SparseRep(v, i, (v > 0).sum(axis=1).astype(np.int32))


# ---------------------------------------------------------------------------
# query_cache_key
# ---------------------------------------------------------------------------

def test_key_normalizes_padding_width():
    # same actives, different padding width -> same key
    a = row([3.0, 1.0, 0.0], [5, 9, 0])
    b = row([3.0, 1.0, 0.0, 0.0, 0.0], [5, 9, 0, 0, 0])
    assert query_cache_key(a, 10, {}, "t", 0) == \
        query_cache_key(b, 10, {}, "t", 0)


def test_key_sensitive_to_everything_that_changes_results():
    r = row([3.0, 1.0], [5, 9])
    base = query_cache_key(r, 10, {}, "t", 0)
    assert query_cache_key(row([3.0, 2.0], [5, 9]), 10, {}, "t", 0) \
        != base
    assert query_cache_key(row([3.0, 1.0], [5, 8]), 10, {}, "t", 0) \
        != base
    assert query_cache_key(r, 5, {}, "t", 0) != base
    assert query_cache_key(r, 10, {}, "u", 0) != base
    assert query_cache_key(r, 10, {}, "t", 1) != base
    assert query_cache_key(r, 10, {"method": "fused"}, "t", 0) != base


def test_key_ignores_none_kwargs_and_kwarg_order():
    r = row([3.0, 1.0], [5, 9])
    assert query_cache_key(r, 10, {"q_width": None}, "t", 0) == \
        query_cache_key(r, 10, {}, "t", 0)
    assert query_cache_key(
        r, 10, {"method": "fused", "q_width": 2}, "t", 0) == \
        query_cache_key(
            r, 10, {"q_width": 2, "method": "fused"}, "t", 0)


def test_key_decimals_knob_coarsens():
    a = row([3.00001, 1.0], [5, 9])
    b = row([3.00002, 1.0], [5, 9])
    assert query_cache_key(a, 10, {}, "t", 0) != \
        query_cache_key(b, 10, {}, "t", 0)
    assert query_cache_key(a, 10, {}, "t", 0, decimals=3) == \
        query_cache_key(b, 10, {}, "t", 0, decimals=3)


# ---------------------------------------------------------------------------
# QueryResultCache: LRU + byte accounting
# ---------------------------------------------------------------------------

def _entry_bytes(k):
    return 2 * k * 4 + ENTRY_OVERHEAD_BYTES


def _payload(k, seed):
    rng = np.random.default_rng(seed)
    return (rng.random(k).astype(np.float32),
            rng.integers(0, 100, size=k).astype(np.int32))


def test_cache_eviction_pins_byte_accounting_against_capacity():
    k = 5
    cache = QueryResultCache(2 * _entry_bytes(k))
    for s in range(3):              # third insert must evict the LRU
        cache.put(bytes([s]) * 16, "t", 0, *_payload(k, s))
        assert cache.bytes_used <= cache.capacity_bytes
    assert len(cache) == 2
    assert cache.bytes_used == 2 * _entry_bytes(k)
    assert cache.counters["evictions"] == 1
    assert cache.get(bytes([0]) * 16) is None       # LRU victim
    assert cache.get(bytes([2]) * 16) is not None


def test_cache_get_refreshes_lru_order():
    k = 5
    cache = QueryResultCache(2 * _entry_bytes(k))
    cache.put(b"a" * 16, "t", 0, *_payload(k, 0))
    cache.put(b"b" * 16, "t", 0, *_payload(k, 1))
    assert cache.get(b"a" * 16) is not None         # a becomes MRU
    cache.put(b"c" * 16, "t", 0, *_payload(k, 2))   # evicts b, not a
    assert cache.get(b"b" * 16) is None
    assert cache.get(b"a" * 16) is not None


def test_cache_oversize_payload_skipped_not_crashed():
    cache = QueryResultCache(64)    # < one k=5 entry
    cache.put(b"a" * 16, "t", 0, *_payload(5, 0))
    assert len(cache) == 0 and cache.bytes_used == 0
    assert cache.counters["oversize_skipped"] == 1


def test_cache_returns_copies_not_views():
    cache = QueryResultCache(1 << 16)
    vals, ids = _payload(5, 0)
    cache.put(b"a" * 16, "t", 0, vals, ids)
    got_v, got_i = cache.get(b"a" * 16)
    got_v[:] = -1.0
    got_i[:] = -1
    again_v, again_i = cache.get(b"a" * 16)
    assert np.array_equal(again_v, vals)
    assert np.array_equal(again_i, ids)


def test_cache_invalidate_reclaims_only_dead_generations_of_tag():
    cache = QueryResultCache(1 << 16)
    cache.put(b"a" * 16, "x", 1, *_payload(5, 0))
    cache.put(b"b" * 16, "x", 2, *_payload(5, 1))
    cache.put(b"c" * 16, "y", 1, *_payload(5, 2))
    assert cache.invalidate("x", 2) == 1
    assert cache.get(b"a" * 16) is None             # dead gen of x
    assert cache.get(b"b" * 16) is not None         # live gen of x
    assert cache.get(b"c" * 16) is not None         # other tag
    assert cache.bytes_used == 2 * _entry_bytes(5)
    assert cache.counters["invalidations"] == 1


def test_cache_rejects_nonpositive_capacity():
    with pytest.raises(ValueError, match="capacity"):
        QueryResultCache(0)
    with pytest.raises(ValueError, match="capacity"):
        HotPostingCache(-1)


# ---------------------------------------------------------------------------
# HotPostingCache + hot_fused_retrieve
# ---------------------------------------------------------------------------

def _frozen_index(n_docs=40, seed=1):
    enc = np_encoder()
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, VOCAB, size=(n_docs, 12)).astype(np.int32)
    rep = enc(toks, np.ones_like(toks))
    return build_inverted_index(rep, VOCAB)


def test_hot_cache_pins_heaviest_terms_within_budget():
    index = _frozen_index()
    per_window = int(index.max_postings) * 8 + ENTRY_OVERHEAD_BYTES
    hot = HotPostingCache(3 * per_window)
    hot.ensure(index, 0)
    assert hot.pinned_terms == 3
    assert hot.bytes_pinned == 3 * per_window <= hot.capacity_bytes
    lens = np.asarray(index.term_lens)
    pinned = sorted(hot._windows)
    # the pinned set is exactly a heaviest-3 set (stable tie-break)
    want = np.argsort(-lens, kind="stable")[:3]
    assert sorted(int(t) for t in want) == pinned
    # a pinned window serves docs+vals; an unpinned heavy term misses
    t = pinned[0]
    assert hot.window(t) is not None
    assert hot.counters["hits"] == 1
    cold = int(np.argsort(-lens, kind="stable")[10])
    assert hot.window(cold) is None
    assert hot.counters["misses"] == 1


def test_hot_cache_generation_change_rebuilds():
    index = _frozen_index()
    hot = HotPostingCache(1 << 20)
    hot.ensure(index, 0)
    hot.ensure(index, 0)                    # no-op
    assert hot.counters["rebuilds"] == 1
    hot.ensure(index, 1)                    # generation bump -> rebuild
    assert hot.counters["rebuilds"] == 2
    assert hot.counters["invalidations"] == 1
    assert hot.generation == 1


def test_hot_fused_retrieve_bit_identical_to_fused_retrieve():
    index = _frozen_index()
    queries = np_encoder()(
        np.random.default_rng(2).integers(
            1, VOCAB, size=(5, 12)).astype(np.int32),
        np.ones((5, 12), np.int32))
    rv, ri = fused_retrieve(queries, index, 7)
    for cap in (1 << 20, 600):      # fully pinned and barely pinned
        hot = HotPostingCache(cap)
        hot.ensure(index, 0)
        hv, hi = hot_fused_retrieve(queries, index, 7, hot=hot)
        assert np.array_equal(np.asarray(hv), np.asarray(rv)), cap
        assert np.array_equal(np.asarray(hi), np.asarray(ri)), cap


# ---------------------------------------------------------------------------
# CachedEngine: row-level hits, miss re-batching, churn coherence
# ---------------------------------------------------------------------------

def make_cached(eng, cache_bytes=1 << 20, hot=True, tag="corpus"):
    return CachedEngine(
        eng, result_cache=QueryResultCache(cache_bytes),
        hot_cache=HotPostingCache(cache_bytes // 4) if hot else None,
        tag=tag)


def test_cached_engine_hit_pass_identical_to_miss_pass():
    eng = make_engine()
    cached = make_cached(eng)
    q = encode_queries(eng, np.random.default_rng(3).integers(
        1, VOCAB, size=(4, 12)))
    v1, i1 = cached.search(q, 5)
    rv, ri = eng.search(q, 5)
    assert np.array_equal(v1, np.asarray(rv))
    assert np.array_equal(i1, np.asarray(ri))
    v2, i2 = cached.search(q, 5)
    assert np.array_equal(v1, v2) and np.array_equal(i1, i2)
    st = cached.results.stats()
    assert st["hits"] == 4 and st["misses"] == 4


def test_cached_engine_mixed_batch_rebatches_only_misses():
    eng = make_engine()
    cached = make_cached(eng)
    rng = np.random.default_rng(4)
    warm = encode_queries(eng, rng.integers(1, VOCAB, size=(2, 12)))
    cached.search(warm, 5)
    cold = encode_queries(eng, rng.integers(1, VOCAB, size=(2, 12)))
    mixed = stack_rows([warm, cold])
    cv, ci = cached.search(mixed, 5)
    assert cached.results.stats()["hits"] == 2      # the warm rows
    rv, ri = eng.search(mixed, 5)
    assert np.array_equal(cv, np.asarray(rv))
    assert np.array_equal(ci, np.asarray(ri))


def test_cached_engine_fused_search_uses_hot_windows():
    eng = make_engine(n_docs=40)
    cached = make_cached(eng)
    q = encode_queries(eng, np.random.default_rng(5).integers(
        1, VOCAB, size=(3, 12)))
    cv, ci = cached.search(q, 5, method="fused")
    assert cached.hot.pinned_terms > 0
    assert cached.hot.counters["hits"] > 0
    rv, ri = eng.search(q, 5, method="fused")
    assert np.array_equal(cv, np.asarray(rv))
    assert np.array_equal(ci, np.asarray(ri))


def test_cached_engine_never_serves_stale_after_mutation():
    eng = make_engine()
    cached = make_cached(eng)
    rng = np.random.default_rng(6)
    q = encode_queries(eng, rng.integers(1, VOCAB, size=(2, 12)))
    cached.search(q, 5)
    gen0 = eng.builder.generation
    ids = cached.add_docs(list(rng.integers(
        1, VOCAB, size=(4, 12)).astype(np.int32)))
    cv, ci = cached.search(q, 5)    # flushes, invalidates, re-scores
    assert eng.builder.generation > gen0
    assert cached.results.counters["invalidations"] >= 1
    rv, ri = eng.search(q, 5)
    assert np.array_equal(cv, np.asarray(rv))
    assert np.array_equal(ci, np.asarray(ri))
    cached.remove_docs([int(i) for i in ids])
    cv, ci = cached.search(q, 5)
    rv, ri = eng.search(q, 5)
    assert np.array_equal(cv, np.asarray(rv))
    assert np.array_equal(ci, np.asarray(ri))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_churn_property_cache_on_equals_cache_off(seed):
    """Arbitrary add/remove/flush/compact interleavings: after every
    step the cached frontend must match the raw engine exactly."""
    eng = make_engine(n_docs=10, seed=seed)
    cached = make_cached(eng, tag=f"churn{seed}")
    rng = np.random.default_rng(seed)
    catalog = rng.integers(1, VOCAB, size=(6, 12)).astype(np.int32)
    removable = []
    for step in range(8):
        op = ("add", "remove", "flush", "compact",
              "none")[int(rng.integers(0, 5))]
        if op == "add":
            ids = cached.add_docs(list(rng.integers(
                1, VOCAB, size=(3, 12)).astype(np.int32)))
            removable.extend(int(i) for i in ids)
        elif op == "remove" and removable:
            cached.remove_docs(removable[:2])
            removable = removable[2:]
        elif op == "flush":
            cached.flush()
        elif op == "compact":
            cached.flush(force_compact=True)
        q = encode_queries(
            eng, catalog[rng.integers(0, len(catalog), size=3)])
        cv, ci = cached.search(q, 5)
        rv, ri = eng.search(q, 5)
        assert np.array_equal(ci, np.asarray(ri)), (seed, step, op)
        assert np.array_equal(cv, np.asarray(rv)), (seed, step, op)


# ---------------------------------------------------------------------------
# strict search kwargs (engine + builder)
# ---------------------------------------------------------------------------

def test_search_rejects_unknown_kwarg_naming_resolved_method():
    eng = make_engine()
    q = encode_queries(eng, np.arange(1, 13))
    with pytest.raises(TypeError, match=r"unknown kwargs bogus"):
        eng.search(q, 5, bogus=1)
    with pytest.raises(TypeError, match=r"resolved to 'impact'"):
        eng.search(q, 5, bogus=1)
    with pytest.raises(TypeError, match=r"unknown kwargs bogus"):
        eng.builder.search(q, 5, bogus=1)


def test_search_rejects_irrelevant_known_kwarg():
    eng = make_engine()     # no forward rows -> resolves to impact
    q = encode_queries(eng, np.arange(1, 13))
    with pytest.raises(TypeError,
                       match=r"prune_margin.*does not accept"):
        eng.search(q, 5, prune_margin=0.5)
    # None means "not passed" — must not raise
    eng.search(q, 5, prune_margin=None)


def test_cached_engine_propagates_strict_kwargs():
    eng = make_engine()
    cached = make_cached(eng)
    q = encode_queries(eng, np.arange(1, 13))
    with pytest.raises(TypeError, match="bogus"):
        cached.search(q, 5, bogus=1)


# ---------------------------------------------------------------------------
# TenantPool: fairness, isolation, quotas
# ---------------------------------------------------------------------------

def make_pool(clock, encode=None, tenants=("a", "b"), weights=None,
              **pool_kw):
    be = BatchedEncoder(encode or np_encoder(),
                        policy=BatchPolicy(max_batch=4,
                                           max_wait_s=10.0))
    pool = TenantPool(be, clock=clock, **pool_kw)
    for name in tenants:
        w = (weights or {}).get(name, 1.0)
        pool.add_tenant(name, VOCAB, quota=TenantQuota(weight=w))
    return pool


def req(uid, token=None, deadline_s=None):
    toks = np.arange(1, 9, dtype=np.int32)
    if token is not None:
        toks = toks.copy()
        toks[0] = token
    return Request(uid=uid, tokens=toks, deadline_s=deadline_s)


def test_pool_weighted_fairness_under_contention():
    clock = FakeClock()
    pool = make_pool(clock, weights={"a": 2.0, "b": 1.0})
    for uid in range(80):
        pool.submit("a" if uid % 2 else "b", req(uid))
    for _ in range(12):             # contended window: 12 batches of 4
        name, n = pool.tick(force=True)
        assert n == 4 and name in ("a", "b")
    served = {n: int(pool.tenant(n).loop.counters["served"])
              for n in ("a", "b")}
    assert served["a"] + served["b"] == 48
    assert served["a"] / served["b"] == pytest.approx(2.0, rel=0.25)
    pool.drain()
    assert sum(int(t["served"]) for t in
               pool.stats()["tenants"].values()) == 80


def test_pool_poison_confined_to_submitting_tenant():
    clock = FakeClock()
    poison_token = VOCAB + 7
    encode = inject_faults(
        np_encoder(), [{"on": {"token": poison_token}, "exc": "fault"}],
        seed=0, sleep=clock.advance)
    pool = make_pool(clock, encode=encode, tenants=("a", "b", "c"))
    for uid in range(24):
        name = ("a", "b", "c")[uid % 3]
        token = poison_token if name == "c" and uid % 6 == 2 else None
        pool.submit(name, req(uid, token=token))
    pool.drain()
    st = pool.stats()["tenants"]
    assert st["c"]["failed"] > 0
    for victim in ("a", "b"):
        assert st[victim]["failed"] == 0
        assert st[victim]["shed"] == 0
        assert st[victim]["served"] == 8


def test_pool_tick_dispatches_at_most_one_batch():
    clock = FakeClock()
    pool = make_pool(clock)
    for uid in range(8):
        pool.submit("a" if uid % 2 else "b", req(uid))
    name, n = pool.tick(force=True)
    assert n == 4
    total_pending = sum(len(pool.tenant(x).loop.pending)
                        for x in ("a", "b"))
    assert total_pending == 4       # exactly one batch left the queues
    assert ("", 0) == pool.tick() == pool.tick(force=False) \
        or True  # non-forced tick may or may not dispatch; no raise


def test_pool_max_docs_quota_refuses_before_applying():
    clock = FakeClock()
    be = BatchedEncoder(np_encoder(),
                        policy=BatchPolicy(max_batch=4))
    pool = TenantPool(be, clock=clock)
    pool.add_tenant("a", VOCAB, quota=TenantQuota(max_docs=4))
    rng = np.random.default_rng(0)
    docs = list(rng.integers(1, VOCAB, size=(3, 12)).astype(np.int32))
    pool.add_docs("a", docs)
    pool.tenant("a").engine.flush()
    with pytest.raises(QuotaExceeded, match="max_docs"):
        pool.add_docs("a", docs)    # 3 live + 3 > 4
    assert pool.tenant("a").live_docs == 3


def test_pool_memory_budget_compacts_then_refuses():
    clock = FakeClock()
    be = BatchedEncoder(np_encoder(),
                        policy=BatchPolicy(max_batch=8))
    pool = TenantPool(be, clock=clock)
    rng = np.random.default_rng(0)
    pool.add_tenant("a", VOCAB)
    pool.add_docs("a", list(rng.integers(
        1, VOCAB, size=(8, 12)).astype(np.int32)))
    pool.tenant("a").engine.flush()
    # pin the budget below current usage: the next add must try one
    # compaction, fail to get under, and refuse
    pool.memory_budget_bytes = pool.memory_bytes() - 1
    with pytest.raises(QuotaExceeded, match="memory budget"):
        pool.add_docs("a", list(rng.integers(
            1, VOCAB, size=(2, 12)).astype(np.int32)))


def test_pool_unknown_tenant_and_duplicate_name():
    pool = make_pool(FakeClock())
    with pytest.raises(KeyError, match="unknown tenant"):
        pool.submit("nope", req(0))
    with pytest.raises(ValueError, match="already exists"):
        pool.add_tenant("a", VOCAB)
    with pytest.raises(ValueError, match="weight"):
        TenantQuota(weight=0.0)


def test_pool_shared_cache_is_namespaced_per_tenant():
    clock = FakeClock()
    pool = make_pool(clock, cache_bytes=1 << 20)
    rng = np.random.default_rng(0)
    for name in ("a", "b"):
        pool.add_docs(name, list(rng.integers(
            1, VOCAB, size=(6, 12)).astype(np.int32)))
        pool.tenant(name).engine.flush()
    q = encode_queries(pool.tenant("a").engine,
                       rng.integers(1, VOCAB, size=(2, 12)))
    pool.search("a", q, 5)
    pool.search("a", q, 5)          # hits for a
    h0 = pool.result_cache.counters["hits"]
    assert h0 == 2
    pool.search("b", q, 5)          # same queries, other corpus: miss
    assert pool.result_cache.counters["hits"] == h0
    # b's churn must not invalidate a's entries
    pool.add_docs("b", list(rng.integers(
        1, VOCAB, size=(2, 12)).astype(np.int32)))
    pool.search("b", q, 5)
    pool.search("a", q, 5)          # still a hit
    assert pool.result_cache.counters["hits"] == h0 + 2


# ---------------------------------------------------------------------------
# continuous batching (ServingLoop continuous=True)
# ---------------------------------------------------------------------------

def make_loop(clock, *, continuous=False, max_batch=8,
              max_wait_s=10.0, **kw):
    return ServingLoop(
        BatchedEncoder(np_encoder(),
                       policy=BatchPolicy(max_batch=max_batch,
                                          max_wait_s=max_wait_s)),
        clock=clock, continuous=continuous, **kw)


def test_edf_selects_tightest_deadlines_first():
    clock = FakeClock()
    loop = make_loop(clock, continuous=True, max_batch=2)
    loop.submit(req(0, deadline_s=10.0))
    loop.submit(req(1, deadline_s=10.0))
    loop.submit(req(2, deadline_s=0.05))    # latest arrival, most urgent
    assert loop.tick(force=True) == 2
    # uid2 jumped the queue; FIFO would have dispatched {0, 1}
    assert set(loop.completed) == {0, 2}
    assert [r.uid for r in loop.pending] == [1]


def test_fifo_baseline_unchanged_without_continuous():
    clock = FakeClock()
    loop = make_loop(clock, continuous=False, max_batch=2)
    loop.submit(req(0, deadline_s=10.0))
    loop.submit(req(1, deadline_s=10.0))
    loop.submit(req(2, deadline_s=0.05))
    assert loop.tick(force=True) == 2
    assert set(loop.completed) == {0, 1}


def test_best_effort_requests_sort_after_deadlines():
    clock = FakeClock()
    loop = make_loop(clock, continuous=True, max_batch=1)
    loop.submit(req(0))                     # best-effort: sorts last
    loop.submit(req(1, deadline_s=1.0))
    assert loop.tick(force=True) == 1
    assert set(loop.completed) == {1}


def test_ready_probe_is_non_mutating():
    clock = FakeClock()
    loop = make_loop(clock, continuous=True, max_batch=4)
    assert not loop.ready() and not loop.ready(force=True)
    loop.submit(req(0, deadline_s=5.0))
    before = list(loop.pending)
    assert not loop.ready()                 # no trigger yet
    assert loop.ready(force=True)
    assert loop.pending == before and not loop.completed
    for uid in range(1, 4):
        loop.submit(req(uid, deadline_s=5.0))
    assert loop.ready()                     # full batch trigger
    assert loop.tick() == 4


def test_urgency_trigger_dispatches_before_max_wait():
    clock = FakeClock()
    loop = make_loop(clock, continuous=True, max_batch=8,
                     max_wait_s=10.0)
    loop.submit(req(0, deadline_s=0.5))
    assert loop.tick() == 0                 # slack 0.5 > ewma 0
    clock.advance(0.5)                      # slack hits 0: now or never
    assert loop.ready()
    assert loop.tick() == 1
    assert not isinstance(loop.take(0), (ShedResult, FailedResult))
    # the plain loop would still be waiting on max_wait_s
    fifo = make_loop(clock, continuous=False, max_batch=8,
                     max_wait_s=10.0)
    fifo.submit(req(1, deadline_s=0.5))
    clock.advance(0.5)
    assert fifo.tick() == 0


def test_continuous_exactly_once_accounting():
    clock = FakeClock()
    loop = make_loop(clock, continuous=True, max_batch=4,
                     max_wait_s=0.01)
    rng = np.random.default_rng(0)
    n = 24
    for uid in range(n):
        loop.submit(req(uid, deadline_s=0.05 if uid % 2 else 5.0))
        if rng.random() < 0.5:
            clock.advance(0.02)
        loop.tick()
    while loop.pending:
        loop.tick(force=True)
    outcomes = {uid: loop.take(uid) for uid in range(n)}
    assert not loop.completed               # take() pops everything
    served = sum(1 for r in outcomes.values()
                 if not isinstance(r, (ShedResult, FailedResult)))
    shed = sum(1 for r in outcomes.values()
               if isinstance(r, ShedResult))
    failed = sum(1 for r in outcomes.values()
                 if isinstance(r, FailedResult))
    assert served + shed + failed == n and failed == 0
    assert loop.stats()["continuous"] is True


def test_continuous_edf_admission_estimate():
    """EDF admission counts only at-least-as-urgent pending work: a
    tight-deadline request is admitted where FIFO would shed it
    behind a long patient queue."""
    def fill(continuous):
        clock = FakeClock()
        loop = make_loop(clock, continuous=continuous, max_batch=2,
                         max_wait_s=10.0)
        # establish a nonzero encode EWMA so estimates are live
        loop.submit(req(100))
        loop.submit(req(101))
        clock.advance(0.2)
        loop.tick(force=True)
        loop._encode_ewma = 1.0             # 1 s per dispatched batch
        for uid in range(8):                # 4 batches of patient work
            loop.submit(req(uid, deadline_s=60.0))
        return loop, loop.submit(req(99, deadline_s=1.5))
    from repro.runtime.serving import Admission
    fifo_loop, fifo_adm = fill(False)
    cont_loop, cont_adm = fill(True)
    assert fifo_adm is Admission.SHED       # 5 batches ahead > 1.5 s
    assert cont_adm is Admission.ACCEPTED   # nothing more urgent ahead
    assert [r.uid for r in cont_loop.pending][-1] == 99
