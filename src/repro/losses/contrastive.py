"""LSR training objectives — InfoNCE + SPLADE sparsity regularizers.

The paper trains SPLADE with the InfoNCE loss [19] over in-batch
negatives on Mistral-Splade data; SPLADE sparsity is induced by the
FLOPS regularizer (Paria et al. / Formal et al.) and optionally L1.
MarginMSE distillation is included because SPLADE-v3's recipe uses it
(the paper's Table 3 compares against it).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def infonce_loss(
    q_reps: Array,     # (B, V) query sparse vectors
    d_reps: Array,     # (B*(1+n_neg), V) docs; first B are positives
    *,
    temperature: float = 1.0,
) -> Array:
    """In-batch-negatives InfoNCE: positive of query i is document i."""
    scores = jnp.einsum("qv,dv->qd", q_reps, d_reps,
                        preferred_element_type=jnp.float32) / temperature
    labels = jnp.arange(q_reps.shape[0])
    logp = jax.nn.log_softmax(scores, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def gathered_infonce(
    q_reps: Array,     # (B_local, V) this shard's query rows
    d_reps: Array,     # (B_local, V) this shard's doc rows
    *,
    axis_names: Tuple[str, ...] = (),
    temperature: float = 1.0,
) -> Array:
    """Mesh-aware in-batch InfoNCE: negatives gathered across the data
    axes.

    Inside ``shard_map``/``pmap`` over ``axis_names``, each device
    holds a ``B_local`` slice of the global batch; in-batch negatives
    must still span the *global* batch or the effective negative pool
    shrinks by the data-parallel degree. Documents are all_gather'd
    over ``axis_names`` (row-major gather order), the diagonal label
    is offset by this shard's global row position, and the per-shard
    mean is pmean'd so the result equals single-device
    :func:`infonce_loss` on the concatenated batch. With no axes it
    *is* ``infonce_loss``. (The vocab-sharded head path instead uses
    ``core.sharded.sharded_infonce``, which fuses the same gather with
    the partial-score psum.)
    """
    if not axis_names:
        return infonce_loss(q_reps, d_reps, temperature=temperature)
    from repro.compat import axis_size

    d_full = jax.lax.all_gather(d_reps, axis_names, axis=0, tiled=True)
    scores = jnp.einsum("qv,dv->qd", q_reps, d_full,
                        preferred_element_type=jnp.float32) / temperature
    offset = jnp.zeros((), jnp.int32)
    for ax in axis_names:
        offset = offset * axis_size(ax) + jax.lax.axis_index(ax)
    labels = offset * q_reps.shape[0] + jnp.arange(q_reps.shape[0])
    logp = jax.nn.log_softmax(scores, axis=-1)
    local = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    return jax.lax.pmean(local, axis_names)


def infonce_from_scores(scores: Array, *, temperature: float = 1.0) -> Array:
    """InfoNCE when the (Bq, Bd) score matrix is precomputed (the
    vocab-sharded path computes scores without gathering reps)."""
    labels = jnp.arange(scores.shape[0])
    logp = jax.nn.log_softmax(scores / temperature, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def flops_regularizer(reps: Array) -> Array:
    """SPLADE FLOPS: sum_v (mean_b |Y[b, v]|)^2 — pushes mean activation
    per vocab dim to zero => sparsity aligned with inverted-index cost."""
    mean_act = jnp.mean(jnp.abs(reps.astype(jnp.float32)), axis=0)
    return jnp.sum(mean_act * mean_act)


def l1_regularizer(reps: Array) -> Array:
    return jnp.mean(jnp.sum(jnp.abs(reps.astype(jnp.float32)), axis=-1))


def margin_mse_loss(
    q_reps: Array, d_pos: Array, d_neg: Array, teacher_margin: Array,
) -> Array:
    """MarginMSE distillation: match teacher score margins."""
    s_pos = jnp.einsum("bv,bv->b", q_reps, d_pos)
    s_neg = jnp.einsum("bv,bv->b", q_reps, d_neg)
    return jnp.mean((s_pos - s_neg - teacher_margin) ** 2)


def splade_loss(
    q_reps: Array,
    d_reps: Array,
    *,
    temperature: float = 1.0,
    lambda_q: float = 5e-4,
    lambda_d: float = 3e-4,
    l1_weight: float = 0.0,
    aux_loss: Optional[Array] = None,
    aux_weight: float = 1e-2,
) -> Array:
    """Full SPLADE objective = InfoNCE + FLOPS(q) + FLOPS(d) (+ MoE aux)."""
    loss = infonce_loss(q_reps, d_reps, temperature=temperature)
    loss = loss + lambda_q * flops_regularizer(q_reps)
    loss = loss + lambda_d * flops_regularizer(d_reps)
    if l1_weight:
        loss = loss + l1_weight * (
            l1_regularizer(q_reps) + l1_regularizer(d_reps))
    if aux_loss is not None:
        loss = loss + aux_weight * aux_loss
    return loss
