"""Tests for the CI gate script (``benchmarks/check.py``) and the
bench-history handling in ``benchmarks/report.py``.

The gates used to be four inline ``python -c "assert ..."`` blobs in
ci.yml — untestable by definition. Now they are functions returning
failure lists, pinned here; and the history snapshot keys grew a
``run_id`` component (two runs on the same commit+day used to
silently overwrite each other), which ``report.py`` must order and
label correctly alongside the older key shapes.
"""

import copy
import json

import pytest

from benchmarks import report
from benchmarks.check import (check_engine, check_file,
                              check_frontier, check_kernels,
                              check_quality, check_retrieval,
                              check_serving, infer_bench, main)

GOOD_KERNELS = {"heads": {"naive": {}, "tiled": {}, "sparton-jax": {},
                          "sparton-kernel": {}}}
GOOD_RETRIEVAL = {"methods": {"dense": {}, "streaming": {},
                              "impact": {"median_ms": 1.0,
                                         "peak_scoring_bytes": 100},
                              "fused": {"median_ms": 1.0,
                                        "peak_scoring_bytes": 40}},
                  "interpret": True,
                  "parity": {"topk_ids_equal": True,
                             "fused_ids_equal": True}}
GOOD_ENGINE = {
    "methods": {"impact": {"median_ms": 1.0,
                           "peak_scoring_bytes": 100},
                "fused": {"median_ms": 1.0,
                          "peak_scoring_bytes": 40},
                "pruned": {},
                "quantized": {"median_ms": 1.0,
                              "peak_scoring_bytes": 100},
                "fused_quantized": {"median_ms": 1.0,
                                    "peak_scoring_bytes": 40},
                "streaming": {}},
    "interpret": True,
    "quantization": {"ratio": 4.82, "topk_ids_equal": True},
    "pruned": {"topk_ids_equal": True},
    "sharded": {s: {"topk_ids_equal": True, "median_ms": 1.0}
                for s in ("1", "2", "4")},
    "term_sharded": {s: {"topk_ids_equal": True, "median_ms": 1.0}
                     for s in ("1", "2", "4")},
    "shard2d": {g: {"topk_ids_equal": True, "median_ms": 1.0}
                for g in ("1x1", "2x2", "1x4", "4x1")},
    "planner": {
        "n_devices": 4,
        "huge_vocab": {"vocab_size": 250_000, "grid": "2x2",
                       "axis": "2d", "doc_shards": 2, "term_shards": 2,
                       "reason": "2d"},
        "small_vocab": {"vocab_size": 30_000, "grid": "4x1",
                        "axis": "doc", "doc_shards": 4,
                        "term_shards": 1, "reason": "doc-only"},
    },
    "parity": {"topk_ids_equal": True, "fused_ids_equal": True},
}


def _phase(name, **kw):
    p = {"name": name, "sustained_qps": 80.0, "p50_ms": 15.0,
         "p99_ms": 27.0, "shed_rate": 0.0, "failed": 0,
         "degrade_transitions": 0, "degrade_name_end": "exact"}
    p.update(kw)
    return p


GOOD_SERVING = {
    "slo_ms": 50.0,
    "phases": [
        _phase("warm"),
        _phase("overload", sustained_qps=390.0, p99_ms=100.0,
               shed_rate=0.22, degrade_transitions=3,
               degrade_name_end="aggressive"),
        _phase("recovery"),
    ],
    "quality_metric": "ndcg@10",
    "degrade_quality": {"exact": 1.0, "pruned": 1.0,
                        "aggressive": 0.98, "minimal": 0.91},
    "faults": {"submitted": 234, "served": 205, "shed": 23,
               "failed": 6, "lost": 0, "poisoned": 6,
               "poisoned_failed": 6, "failed_outside_poison": 0,
               "oom_faults": 1, "min_batch_cap": 8,
               "end_batch_cap": 16},
}


def _tenant(weight, contended, failed=0, shed=0):
    return {"weight": weight, "served_contended": contended,
            "served": 80, "shed": shed, "failed": failed}


GOOD_FRONTIER = {
    "zipf_replay": {
        "cache_off": {"sustained_qps": 151.0, "p99_ms": 41.0},
        "cache_on": {"sustained_qps": 262.0, "p99_ms": 8.7,
                     "hit_rate": 0.72, "parity": True},
    },
    "churn": {"rounds": 40, "mismatches": 0, "invalidations": 12},
    "tenancy": {
        "tenants": {"a": _tenant(2.0, 40),
                    "b": _tenant(1.0, 21),
                    "c": _tenant(1.0, 20, failed=6)},
        "fairness_ratio_ab": 1.9,
        "weight_ratio_ab": 2.0,
    },
    "continuous": {
        "one_batch": {"sustained_qps": 145.0, "shed_rate": 0.18,
                      "lost": 0, "failed": 0},
        "continuous": {"sustained_qps": 176.0, "shed_rate": 0.0,
                       "lost": 0, "failed": 0},
    },
}


def _q_method(ndcg=1.0, mrr=1.0):
    return {"mrr@10": mrr, "ndcg@10": ndcg, "recall@10": 0.83,
            "success@10": 1.0}


GOOD_QUALITY = {
    "quality_metric": "ndcg@10",
    "method_quality": {
        "exact": _q_method(), "pruned": _q_method(),
        "quantized": _q_method(), "term_sharded": _q_method(),
        "doc_sharded": _q_method(),
        "aggressive": _q_method(ndcg=0.97, mrr=0.95),
    },
    "ladder_quality": {"exact": 1.0, "pruned": 1.0,
                       "aggressive": 0.977, "minimal": 0.923},
    "rep_topk_sweep": {"8": {"ndcg@10": 0.9}, "16": {"ndcg@10": 0.95},
                       "32": {"ndcg@10": 1.0}, "64": {"ndcg@10": 1.0}},
    "trained_vs_init": {
        "steps": 250, "loss_first": 20.6, "loss_last": 8.1,
        "init": {"mrr@10": 0.27, "ndcg@10": 0.38},
        "trained": {"mrr@10": 0.36, "ndcg@10": 0.46},
        "delta": {"mrr@10": 0.09, "ndcg@10": 0.08},
    },
}


def test_good_records_pass():
    assert check_kernels(GOOD_KERNELS) == []
    assert check_retrieval(GOOD_RETRIEVAL) == []
    assert check_engine(GOOD_ENGINE) == []
    assert check_serving(GOOD_SERVING) == []
    assert check_frontier(GOOD_FRONTIER) == []
    assert check_quality(GOOD_QUALITY) == []


def test_kernels_missing_head_fails():
    bad = {"heads": {"naive": {}, "tiled": {}}}
    assert any("sparton-kernel" in e for e in check_kernels(bad))


def test_retrieval_parity_and_method_gates():
    bad = copy.deepcopy(GOOD_RETRIEVAL)
    bad["parity"]["topk_ids_equal"] = False
    assert any("parity" in e for e in check_retrieval(bad))
    del bad["methods"]["impact"]
    assert len(check_retrieval(bad)) == 2


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d["quantization"].update(ratio=3.2), "4.0x bar"),
    (lambda d: d["quantization"].update(topk_ids_equal=False),
     "quantized top-k"),
    (lambda d: d["pruned"].update(topk_ids_equal=False),
     "pruned top-k"),
    (lambda d: d["sharded"].pop("4"), "sharded scaling rows missing"),
    (lambda d: d["term_sharded"]["2"].update(topk_ids_equal=False),
     "term_sharded x2"),
    (lambda d: d.pop("term_sharded"), "term_sharded scaling rows"),
    (lambda d: d["shard2d"].pop("2x2"), "shard2d scaling rows missing"),
    (lambda d: d["shard2d"]["1x4"].update(topk_ids_equal=False),
     "shard2d 1x4"),
    (lambda d: d.pop("planner"), "planner decision record missing"),
    (lambda d: d["planner"]["huge_vocab"].update(term_shards=1),
     "no term shards"),
    (lambda d: d["planner"]["small_vocab"].update(axis="term"),
     "did not pick doc-only"),
    (lambda d: d["parity"].update(topk_ids_equal=False),
     "parity flag"),
    (lambda d: d["parity"].update(fused_ids_equal=False),
     "fused top-k id parity"),
    (lambda d: d["methods"]["fused"].update(peak_scoring_bytes=100),
     "not strictly"),
    (lambda d: d["methods"]["fused_quantized"].pop(
        "peak_scoring_bytes"), "fused_quantized peak"),
    (lambda d: (d.update(interpret=False),
                d["methods"]["fused"].update(median_ms=9.0)),
     "real backend"),
])
def test_engine_gate_failures(mutate, needle):
    bad = copy.deepcopy(GOOD_ENGINE)
    mutate(bad)
    errs = check_engine(bad)
    assert any(needle in e for e in errs), (needle, errs)


def test_fused_latency_gate_only_on_real_backends():
    """Interpret-mode timings never gate (DESIGN.md §5) — the latency
    bar arms only once the record says it ran on a real backend."""
    rec = copy.deepcopy(GOOD_RETRIEVAL)
    rec["methods"]["fused"]["median_ms"] = 99.0
    assert check_retrieval(rec) == []
    rec["interpret"] = False
    assert any("real backend" in e for e in check_retrieval(rec))


def _phases(d):
    return {p["name"]: p for p in d["phases"]}


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d["phases"].pop(2), "phases missing"),
    (lambda d: _phases(d)["recovery"].update(sustained_qps=0.0),
     "not > 0"),
    (lambda d: _phases(d)["warm"].update(failed=2), "fault-free"),
    (lambda d: _phases(d)["warm"].update(shed_rate=0.2),
     "steady offered load"),
    (lambda d: _phases(d)["recovery"].update(p99_ms=60.0),
     "blows the 50.0ms SLO"),
    (lambda d: _phases(d)["overload"].update(degrade_transitions=0),
     "never engaged"),
    (lambda d: _phases(d)["overload"].update(shed_rate=0.0),
     "isn't an overload"),
    (lambda d: _phases(d)["overload"].update(shed_rate=0.95),
     "isn't an overload"),
    (lambda d: _phases(d)["overload"].update(p99_ms=200.0), "3.0x"),
    (lambda d: _phases(d)["overload"].update(sustained_qps=50.0),
     "bought no capacity"),
    (lambda d: _phases(d)["recovery"].update(
        degrade_name_end="pruned"), "ended degraded"),
    (lambda d: d.pop("quality_metric"), "quality_metric"),
    (lambda d: d.update(quality_metric="topk_overlap"),
     "quality_metric"),
    (lambda d: d["degrade_quality"].pop("minimal"), "missing rungs"),
    (lambda d: d["degrade_quality"].update(exact=0.9), "!= 1.0"),
    (lambda d: d["degrade_quality"].update(aggressive=1.1),
     "not monotone"),
    (lambda d: d["degrade_quality"].update(minimal=0.0), "garbage"),
    (lambda d: d["faults"].update(lost=1), "lost"),
    (lambda d: d["faults"].update(failed_outside_poison=1),
     "isolation leaked"),
    (lambda d: d["faults"].update(poisoned_failed=0),
     "never exercised"),
    (lambda d: d["faults"].update(oom_faults=0), "OOM rule"),
    (lambda d: d["faults"].update(min_batch_cap=16),
     "halved+regrew"),
])
def test_serving_gate_failures(mutate, needle):
    bad = copy.deepcopy(GOOD_SERVING)
    mutate(bad)
    errs = check_serving(bad)
    assert any(needle in e for e in errs), (needle, errs)


def _replay(d):
    return d["zipf_replay"]


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d["zipf_replay"].pop("cache_on"),
     "missing cache_on/cache_off"),
    (lambda d: _replay(d)["cache_on"].update(parity=False),
     "not id/value-identical"),
    (lambda d: _replay(d)["cache_on"].update(hit_rate=0.3),
     "below the 0.5 bar"),
    (lambda d: _replay(d)["cache_on"].update(sustained_qps=140.0),
     "bought no throughput"),
    (lambda d: _replay(d)["cache_on"].update(p99_ms=50.0),
     "not below cache-off"),
    (lambda d: d["churn"].update(rounds=0), "0 rounds"),
    (lambda d: d["churn"].update(mismatches=2), "stale entry"),
    (lambda d: d["churn"].update(invalidations=0), "never fired"),
    (lambda d: d["tenancy"]["tenants"]["c"].update(failed=0),
     "expected only tenant 'c'"),
    (lambda d: d["tenancy"]["tenants"]["a"].update(failed=1),
     "expected only tenant 'c'"),
    (lambda d: d["tenancy"]["tenants"]["b"].update(shed=3),
     "poisoned tenant leaked"),
    (lambda d: d["tenancy"].update(fairness_ratio_ab=1.0),
     "fairness"),
    (lambda d: d["continuous"].pop("one_batch"), "missing rows"),
    (lambda d: d["continuous"]["continuous"].update(lost=1), "lost"),
    (lambda d: d["continuous"]["one_batch"].update(failed=2),
     "fault-free"),
    (lambda d: d["continuous"]["continuous"].update(
        sustained_qps=145.0), "not strictly above"),
    (lambda d: d["continuous"]["continuous"].update(shed_rate=0.2),
     "bought with extra shedding"),
])
def test_frontier_gate_failures(mutate, needle):
    bad = copy.deepcopy(GOOD_FRONTIER)
    mutate(bad)
    errs = check_frontier(bad)
    assert any(needle in e for e in errs), (needle, errs)


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.update(quality_metric="topk_overlap"),
     "quality_metric"),
    (lambda d: d["method_quality"].pop("quantized"),
     "method_quality missing"),
    (lambda d: d["method_quality"]["exact"].update({"ndcg@10": 0.99}),
     "perfectly recoverable"),
    (lambda d: d["method_quality"]["exact"].update({"mrr@10": 0.9}),
     "perfectly recoverable"),
    (lambda d: d["method_quality"]["quantized"].update(
        {"ndcg@10": 0.98}), "effectiveness loss"),
    (lambda d: d["method_quality"]["pruned"].update({"mrr@10": 0.99}),
     "effectiveness loss"),
    (lambda d: d["ladder_quality"].pop("aggressive"),
     "ladder_quality missing"),
    (lambda d: d["ladder_quality"].update(exact=0.99), "!= 1.0"),
    (lambda d: d["ladder_quality"].update(minimal=0.99),
     "not monotone"),
    (lambda d: d["ladder_quality"].update(minimal=0.0), "not > 0"),
    (lambda d: d.update(rep_topk_sweep={}), "rep_topk_sweep"),
    (lambda d: d["rep_topk_sweep"]["16"].update({"ndcg@10": 0.85}),
     "not non-decreasing"),
    (lambda d: d["rep_topk_sweep"]["64"].update({"ndcg@10": 0.97}),
     "recover exact"),
    (lambda d: d["trained_vs_init"]["delta"].update({"mrr@10": 0.004}),
     "did not beat"),
    (lambda d: d["trained_vs_init"]["delta"].update(
        {"ndcg@10": -0.02}), "did not beat"),
    (lambda d: d["trained_vs_init"].update(loss_last=25.0),
     "loss did not fall"),
])
def test_quality_gate_failures(mutate, needle):
    bad = copy.deepcopy(GOOD_QUALITY)
    mutate(bad)
    errs = check_quality(bad)
    assert any(needle in e for e in errs), (needle, errs)


def test_quality_gate_aggressive_margin_may_trade():
    """The aggressive prune margin is allowed to lose quality — only
    the nominally lossless methods are held to exact."""
    rec = copy.deepcopy(GOOD_QUALITY)
    rec["method_quality"]["aggressive"].update(
        {"ndcg@10": 0.7, "mrr@10": 0.6})
    assert check_quality(rec) == []


def test_infer_bench_and_check_file(tmp_path):
    assert infer_bench("BENCH_kernels.json") == "kernels"
    assert infer_bench("BENCH_frontier.json") == "frontier"
    assert infer_bench("BENCH_serving-20260809-abc.json") == "serving"
    assert infer_bench("BENCH_quality-20260809-abc.json") == "quality"
    assert infer_bench("a/b/BENCH_engine-20260801-abc-77.json") == \
        "engine"
    with pytest.raises(ValueError, match="cannot infer"):
        infer_bench("results.json")
    p = tmp_path / "BENCH_retrieval.json"
    p.write_text(json.dumps(GOOD_RETRIEVAL))
    assert check_file(str(p)) == []
    bad = copy.deepcopy(GOOD_RETRIEVAL)
    bad["parity"]["topk_ids_equal"] = False
    p.write_text(json.dumps(bad))
    fails = check_file(str(p))
    assert len(fails) == 1 and str(p) in fails[0]


def test_cli_exit_codes(tmp_path, capsys):
    good = tmp_path / "BENCH_kernels.json"
    good.write_text(json.dumps(GOOD_KERNELS))
    assert main([str(good), "--quiet"]) == 0
    bad = tmp_path / "BENCH_engine.json"
    bad.write_text(json.dumps({}))
    assert main([str(bad), "--quiet"]) == 1
    assert "GATE FAILED" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# report.py: snapshot-key tolerance + term-sharded trend metrics
# ---------------------------------------------------------------------------

def test_snapshot_key_orders_all_generations():
    names = [
        "bench_history/BENCH_engine-20260801-aaa111-900.json",
        "bench_history/BENCH_engine-20260731-bbb222.json",     # PR-4 era
        "bench_history/BENCH_engine-20260801-ccc333-100.json",
        "BENCH_engine.json",                                   # current
    ]
    ordered = sorted(names, key=report._snapshot_key)
    assert ordered == [
        "bench_history/BENCH_engine-20260731-bbb222.json",
        "bench_history/BENCH_engine-20260801-ccc333-100.json",
        "bench_history/BENCH_engine-20260801-aaa111-900.json",
        "BENCH_engine.json",
    ]


def test_snapshot_labels():
    assert report._snapshot_label("BENCH_engine.json") == "current"
    assert report._snapshot_label(
        "h/BENCH_engine-20260801-abc123-77.json") == \
        "20260801-abc123-77"
    assert report._snapshot_label(
        "h/BENCH_kernels-20260801-abc123.json") == "20260801-abc123"


def test_bench_metrics_flattens_serving(tmp_path):
    p = tmp_path / "BENCH_serving.json"
    p.write_text(json.dumps(GOOD_SERVING))
    m = report._bench_metrics(str(p))
    assert m["serving/overload/sustained_qps"] == 390.0
    assert m["serving/overload/shed_rate"] == 0.22
    assert m["serving/warm/p99_ms"] == 27.0
    assert m["serving/quality/minimal"] == 0.91
    assert m["serving/faults/lost"] == 0


def test_bench_metrics_flattens_frontier(tmp_path):
    p = tmp_path / "BENCH_frontier.json"
    p.write_text(json.dumps(GOOD_FRONTIER))
    m = report._bench_metrics(str(p))
    assert m["frontier/cache_on/sustained_qps"] == 262.0
    assert m["frontier/cache_on/hit_rate"] == 0.72
    assert m["frontier/cache_off/p99_ms"] == 41.0
    assert "frontier/cache_off/hit_rate" not in m
    assert m["frontier/churn/mismatches"] == 0
    assert m["frontier/tenancy/fairness_ab"] == 1.9
    assert m["frontier/continuous/qps"] == 176.0
    assert m["frontier/one_batch/shed_rate"] == 0.18


def test_bench_metrics_flattens_quality(tmp_path):
    p = tmp_path / "BENCH_quality.json"
    p.write_text(json.dumps(GOOD_QUALITY))
    m = report._bench_metrics(str(p))
    assert m["quality/method/aggressive"] == 0.97
    assert m["quality/ladder/minimal"] == 0.923
    assert m["quality/rep_topk/w16"] == 0.95
    assert m["quality/train_delta/mrr@10"] == 0.09
    assert m["quality/train_delta/ndcg@10"] == 0.08


def test_bench_metrics_flattens_shard2d_and_planner(tmp_path):
    p = tmp_path / "BENCH_engine.json"
    p.write_text(json.dumps(GOOD_ENGINE))
    m = report._bench_metrics(str(p))
    assert m["shard2d/2x2"] == 1.0
    assert m["shard2d/4x1"] == 1.0
    assert m["planner/huge_vocab/term_shards"] == 2
    assert m["planner/small_vocab/term_shards"] == 1


def test_trend_table_with_run_id_keys(tmp_path):
    old = {"methods": {"impact": {"median_ms": 2.0}},
           "term_sharded": {"2": {"median_ms": 4.0}}}
    new = {"methods": {"impact": {"median_ms": 1.0}},
           "term_sharded": {"2": {"median_ms": 2.0}}}
    p1 = tmp_path / "BENCH_engine-20260801-abc123-100.json"
    p2 = tmp_path / "BENCH_engine-20260801-abc123-200.json"
    p1.write_text(json.dumps(old))
    p2.write_text(json.dumps(new))
    paths = sorted([str(p2), str(p1)], key=report._snapshot_key)
    table = report.trend_table(paths)
    assert "term_sharded/x2" in table
    assert "-50.0%" in table            # 4.0 -> 2.0 against the
    assert "20260801-abc123-200" in table   # run-id-ordered previous
