"""Fused impact scoring + streaming top-k — Pallas TPU kernel.

The Sparton fusion applied to the *query* side of LSR retrieval
(DESIGN.md §12). The plain-JAX impact scorer
(``retrieval/score.py:impact_scores``) gathers the query terms' posting
windows, segment-sums them into a dense ``(B, N)`` score matrix, and
runs a *separate* ``lax.top_k`` — at serving batch sizes and
million-doc corpora that matrix is the retrieval analogue of the
``(B, S, V)`` logit tensor Sparton refuses to materialize on the encode
side. This kernel streams it away: per query, the gathered posting
window stays resident in VMEM while doc-range tiles of width
``block_n`` are scored via a scatter-free one-hot contraction and
folded into a running ``(1, k)`` top-k with the same ``merge_topk``
reduction every other streaming top-k in the repo uses. Peak scoring
memory is the window plus one ``(block_n,)`` tile — independent of N.

Grid: ``(B, N_pad / block_n)``, doc tiles innermost and visited in
ascending-id order so ties break to the lowest doc id exactly like the
reference ``lax.top_k`` path (the id-parity contract).

Scoring one tile: the flattened posting axis (``W = Q * L_max`` lanes
of ``(weight, doc_id)``) is walked in ``block_w`` chunks; each chunk
builds the ``(block_w, block_n)`` membership one-hot ``1[doc_c ==
d0 + n]`` and multiply-accumulates ``w_chunk @ onehot`` on the MXU —
the same irregular-scatter-to-dense-contraction trade as the backward
kernels' ``onehot_weights`` (Mosaic has no scatter).

Two entry points share that machinery:

* ``fused_impact_topk`` — raw f32 windows (from an ``InvertedIndex``).
* ``fused_quantized_topk`` — u4+delta windows (from a
  ``QuantizedIndex``): the *packed* byte and gap windows are shipped to
  the kernel, which unpacks nibbles, affine-decodes against the
  per-term bounds, and cumsums gaps to absolute doc ids per tile — the
  standalone dequant materialization ``quantized_scores`` pays is gone.

Both run under the Pallas interpreter off-TPU (CI's forced host
devices); hardware validation stays the open ROADMAP item.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import NEG_INF, pad_to
from repro.kernels.topk_score import merge_topk

# matches engine.quantize._LEVELS (duplicated to keep this module
# importable without the engine package — kernels sit below it)
_U4_LEVELS = 14


def _score_tile(w, docs, d0, *, block_n: int, block_w: int):
    """Score one ``(1, block_n)`` doc tile from flat posting lanes.

    ``w``/``docs`` are ``(1, W)`` with W a multiple of ``block_w``;
    invalid lanes carry weight 0 (their doc id then contributes
    nothing). Each chunk's one-hot is built with the repo's
    3D-broadcasted-iota idiom (``_common.onehot_weights``) and
    contracted on the MXU with f32 accumulation.
    """
    n_chunks = w.shape[1] // block_w

    def body(c, acc):
        wc = jax.lax.dynamic_slice(w, (0, c * block_w), (1, block_w))
        dc = jax.lax.dynamic_slice(docs, (0, c * block_w), (1, block_w))
        col = jax.lax.broadcasted_iota(
            jnp.int32, (1, block_w, block_n), 2)
        onehot = (dc[:, :, None] - d0 == col).astype(jnp.float32)
        return acc + jax.lax.dot_general(
            wc, onehot.reshape(block_w, block_n),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    return jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((1, block_n), jnp.float32))


def _merge_tile(scores, val_ref, idx_ref, j, *, k: int, block_n: int,
                n_real: int):
    """Mask padded docs and fold one scored tile into the running top-k."""
    cand = j * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n), 1)
    scores = jnp.where(cand < n_real, scores, NEG_INF)
    top_vals, top_idx = merge_topk(val_ref[...], idx_ref[...], scores,
                                   cand, k)
    val_ref[...] = top_vals
    idx_ref[...] = top_idx


def _impact_kernel(
    w_ref,      # (1, W) f32 — q[t] * impact, invalid lanes 0
    d_ref,      # (1, W) i32 — absolute doc ids
    val_ref,    # (1, k) out — running top-k values
    idx_ref,    # (1, k) out — running top-k doc ids
    *,
    k: int,
    block_n: int,
    block_w: int,
    n_real: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, NEG_INF, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    scores = _score_tile(w_ref[...], d_ref[...], j * block_n,
                         block_n=block_n, block_w=block_w)
    _merge_tile(scores, val_ref, idx_ref, j, k=k, block_n=block_n,
                n_real=n_real)


def _impact_q_kernel(
    byte_ref,    # (1, Q, L) i32 — gathered *packed* bytes per lane
    gap_ref,     # (1, Q, L) i32 — gathered doc-id gaps per lane
    starts_ref,  # (1, Q, 1) i32 — posting offsets (nibble parity)
    lens_ref,    # (1, Q, 1) i32 — expanded list lengths
    qv_ref,      # (1, Q, 1) f32 — query term weights
    lo_ref,      # (1, Q, 1) f32 — per-term affine low
    step_ref,    # (1, Q, 1) f32 — per-term affine step
    val_ref,     # (1, k) out
    idx_ref,     # (1, k) out
    *,
    k: int,
    block_n: int,
    block_w: int,
    n_real: int,
    q_width: int,
    l_width: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, NEG_INF, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    q, l = q_width, l_width
    lane = jax.lax.broadcasted_iota(jnp.int32, (q, l), 1)
    starts = starts_ref[...].reshape(q, 1)
    lens = lens_ref[...].reshape(q, 1)
    qv = qv_ref[...].reshape(q, 1)
    lo = lo_ref[...].reshape(q, 1)
    step = step_ref[...].reshape(q, 1)

    # in-kernel u4+delta decode — bit-identical to quantized_scores:
    # nibble parity from the absolute posting position, code 0 =
    # phantom (weight exactly 0, cumsum still advances)
    valid = (lane < lens) & (qv > 0)
    byte = byte_ref[...].reshape(q, l)
    code = jnp.where((starts + lane) & 1 == 1, byte >> 4, byte & 0xF)
    code = jnp.where(valid, code, 0)
    gaps = jnp.where(valid, gap_ref[...].reshape(q, l), 0)
    docs = jnp.cumsum(gaps, axis=1)
    w = jnp.where(code > 0,
                  lo + (code - 1).astype(jnp.float32) * step,
                  0.0) * qv

    scores = _score_tile(w.reshape(1, q * l), docs.reshape(1, q * l),
                         j * block_n, block_n=block_n, block_w=block_w)
    _merge_tile(scores, val_ref, idx_ref, j, k=k, block_n=block_n,
                n_real=n_real)


def _out_shapes(B: int, k: int):
    specs = [pl.BlockSpec((1, k), lambda i, j: (i, 0)),
             pl.BlockSpec((1, k), lambda i, j: (i, 0))]
    shapes = [jax.ShapeDtypeStruct((B, k), jnp.float32),
              jax.ShapeDtypeStruct((B, k), jnp.int32)]
    return specs, shapes


@functools.partial(
    jax.jit,
    static_argnames=("n_docs", "k", "block_n", "block_w", "interpret"))
def fused_impact_topk(
    w: jax.Array,       # (B, W) f32 — per-lane q[t]*impact, invalid 0
    docs: jax.Array,    # (B, W) i32 — per-lane absolute doc ids
    *,
    n_docs: int,
    k: int,
    block_n: int = 1024,
    block_w: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused scoring + top-k over flat posting windows.

    Returns ``(vals (B, k), idx (B, k))`` with the ``topk_score``
    contract: ties to the lowest doc id, ``k > n_docs`` columns carry
    NEG_INF. Callers clamp k; the posting axis is zero-padded here so
    the chunk walk divides evenly (weight-0 lanes score nothing).
    """
    B, W = w.shape
    if W == 0:      # no active terms anywhere — keep the grid non-empty
        w = jnp.zeros((B, block_w), jnp.float32)
        docs = jnp.zeros((B, block_w), jnp.int32)
    wp = pad_to(w.astype(jnp.float32), 1, block_w)
    dp = pad_to(docs.astype(jnp.int32), 1, block_w)
    w_pad = wp.shape[1]
    n_tiles = -(-n_docs // block_n)
    grid = (B, n_tiles)

    out_specs, out_shape = _out_shapes(B, k)
    vals, idx = pl.pallas_call(
        functools.partial(_impact_kernel, k=k, block_n=block_n,
                          block_w=block_w, n_real=n_docs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((1, w_pad), lambda i, j: (i, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(wp, dp)
    return vals, idx


@functools.partial(
    jax.jit,
    static_argnames=("n_docs", "k", "block_n", "block_w", "interpret"))
def fused_quantized_topk(
    byte_win: jax.Array,   # (B, Q, L) i32 — packed bytes per lane
    gap_win: jax.Array,    # (B, Q, L) i32 — doc-id gaps per lane
    starts: jax.Array,     # (B, Q) i32 — posting offsets per term
    lens: jax.Array,       # (B, Q) i32 — expanded lengths per term
    qv: jax.Array,         # (B, Q) f32 — query term weights
    lo: jax.Array,         # (B, Q) f32 — per-term affine low
    step: jax.Array,       # (B, Q) f32 — per-term affine step
    *,
    n_docs: int,
    k: int,
    block_n: int = 1024,
    block_w: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Fused u4+delta dequant + scoring + top-k.

    The packed windows are decoded *inside* the kernel (nibble unpack,
    affine decode, gap cumsum) — no dequantized ``(B, Q, L)`` weight or
    doc-id array is ever materialized in HBM. Lane padding added here
    lands outside every term's length, so the in-kernel valid mask
    zeroes it.
    """
    B, Q, L = byte_win.shape
    bw = pad_to(byte_win.astype(jnp.int32), 2, block_w)
    gw = pad_to(gap_win.astype(jnp.int32), 2, block_w)
    l_pad = bw.shape[2]
    meta3 = [a.reshape(B, Q, 1) for a in (
        starts.astype(jnp.int32), lens.astype(jnp.int32),
        qv.astype(jnp.float32), lo.astype(jnp.float32),
        step.astype(jnp.float32))]
    n_tiles = -(-n_docs // block_n)
    grid = (B, n_tiles)

    win_spec = pl.BlockSpec((1, Q, l_pad), lambda i, j: (i, 0, 0))
    meta_spec = pl.BlockSpec((1, Q, 1), lambda i, j: (i, 0, 0))
    out_specs, out_shape = _out_shapes(B, k)
    vals, idx = pl.pallas_call(
        functools.partial(_impact_q_kernel, k=k, block_n=block_n,
                          block_w=block_w, n_real=n_docs,
                          q_width=Q, l_width=l_pad),
        grid=grid,
        in_specs=[win_spec, win_spec] + [meta_spec] * 5,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(bw, gw, *meta3)
    return vals, idx


def fused_window_bytes(B: int, Q: int, L: int,
                       variant: str = "f32") -> int:
    """HBM bytes of the gathered posting windows one fused call ships.

    The analytic peak-scoring-memory model benches gate on: the fused
    path's scoring footprint is these windows plus the ``(B, k)``
    outputs — the ``(B, N)`` score matrix of the unfused paths never
    exists. ``variant`` "f32" = raw windows (f32 weights + i32 docs),
    "u4" = quantized windows (i32 packed bytes + i32 gaps + 5 small
    per-term columns).
    """
    if variant == "f32":
        return B * Q * L * (4 + 4)
    if variant == "u4":
        return B * Q * L * (4 + 4) + B * Q * 5 * 4
    raise ValueError(f"unknown fused variant {variant!r}")
