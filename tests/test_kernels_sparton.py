"""Sparton Pallas kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret mode on CPU).

v2 coverage: scratch-accumulated forward on non-divisible shapes, bf16
inputs against the f32 oracle, the fused backward epilogue (g and db
computed in-kernel) against both the fused oracle and autograd.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import sparton_head, sparton_lm_head_kernel
from repro.kernels.ref import (sparton_backward_fused_ref,
                               sparton_backward_ref, sparton_forward_ref)
from repro.kernels.sparton import sparton_forward
from repro.kernels.sparton_bwd import sparton_backward

KEY = jax.random.PRNGKey(0)


def _inputs(B, S, D, V, dtype=jnp.float32, seed=0, mask_p=0.2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    H = jax.random.normal(ks[0], (B, S, D), dtype)
    E = jax.random.normal(ks[1], (V, D), dtype) * 0.2
    b = jax.random.normal(ks[2], (V,), jnp.float32) * 0.2
    mask = (jax.random.uniform(ks[3], (B, S)) > mask_p).astype(jnp.int32)
    # guarantee >= 1 valid position per row
    mask = mask.at[:, 0].set(1)
    return H, E, b, mask


SHAPES = [
    # (B, S, D, V, blocks)
    (1, 16, 8, 16, (1, 8, 8)),
    (4, 96, 64, 200, (2, 32, 64)),
    (3, 33, 24, 100, (2, 32, 64)),     # non-divisible everything
    (8, 128, 128, 256, (8, 128, 128)),  # exact MXU-aligned tiles
    (2, 256, 32, 512, (2, 64, 256)),
]


@pytest.mark.parametrize("B,S,D,V,blocks", SHAPES)
def test_forward_matches_oracle(B, S, D, V, blocks):
    H, E, b, mask = _inputs(B, S, D, V)
    bb, bs, bv = blocks
    y, i_max = sparton_forward(H, E, b, mask, block_b=bb, block_s=bs,
                               block_v=bv, interpret=True)
    y_ref, i_ref = sparton_forward_ref(H, E, b, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_max), np.asarray(i_ref))


@pytest.mark.parametrize("B,S,D,V,blocks", SHAPES)
def test_forward_bf16_matches_f32_oracle(B, S, D, V, blocks):
    """bf16 H/E with f32 in-kernel accumulation vs the f32 oracle."""
    H, E, b, mask = _inputs(B, S, D, V, dtype=jnp.bfloat16, seed=1)
    bb, bs, bv = blocks
    y, i_max = sparton_forward(H, E, b, mask, block_b=bb, block_s=bs,
                               block_v=bv, interpret=True)
    assert y.dtype == jnp.float32  # accumulator dtype, not input dtype
    # oracle at f32 on the *same bf16 values* (exact upcast)
    y_ref, i_ref = sparton_forward_ref(
        H.astype(jnp.float32), E.astype(jnp.float32), b, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_max), np.asarray(i_ref))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forward_dtypes(dtype):
    H, E, b, mask = _inputs(2, 64, 32, 128, dtype=dtype)
    y, i_max = sparton_forward(H, E, b, mask, block_b=2, block_s=32,
                               block_v=64, interpret=True)
    y_ref, i_ref = sparton_forward_ref(H, E, b, mask)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


def test_forward_softcap():
    H, E, b, mask = _inputs(2, 32, 16, 64)
    y, _ = sparton_forward(H, E, b, mask, block_b=2, block_s=16,
                           block_v=32, softcap=5.0, interpret=True)
    y_ref, _ = sparton_forward_ref(H, E, b, mask, softcap=5.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    # capped: f(max) <= log1p(cap)
    assert float(jnp.max(y)) <= np.log1p(5.0) + 1e-6


def test_fully_masked_row_yields_zero():
    H, E, b, _ = _inputs(2, 16, 8, 32)
    mask = jnp.zeros((2, 16), jnp.int32).at[0, :].set(1)
    y, _ = sparton_forward(H, E, b, mask, block_b=2, block_s=16,
                           block_v=32, interpret=True)
    # masked row: max over -inf -> relu clamps to 0 -> log1p(0) = 0
    assert float(jnp.max(jnp.abs(y[1]))) == 0.0


def test_forward_auto_blocks():
    """block_*=None resolves through the autotuner and stays correct."""
    H, E, b, mask = _inputs(3, 40, 24, 120, seed=5)
    y, i_max = sparton_forward(H, E, b, mask, interpret=True)
    y_ref, i_ref = sparton_forward_ref(H, E, b, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i_max), np.asarray(i_ref))


@pytest.mark.parametrize("B,S,D,V,blocks", SHAPES[:4])
def test_backward_matches_fused_oracle(B, S, D, V, blocks):
    """v2 backward: raw dy + stored y in, (dH, dE, db) out — the
    activation-derivative factor is applied inside the kernels."""
    H, E, b, mask = _inputs(B, S, D, V, seed=3)
    bb, bs, bv = blocks
    y_ref, i_ref = sparton_forward_ref(H, E, b, mask)
    dy = jax.random.normal(jax.random.PRNGKey(9), (B, V))
    dH, dE, db = sparton_backward(dy, y_ref, i_ref, H, E, block_b=bb,
                                  block_s=bs, block_v=bv, interpret=True)
    dH_ref, dE_ref, db_ref = sparton_backward_fused_ref(
        dy, y_ref, i_ref, H, E)
    np.testing.assert_allclose(np.asarray(dH), np.asarray(dH_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dE), np.asarray(dE_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_ref),
                               atol=1e-4, rtol=1e-4)


def test_backward_fused_factor_equals_manual_g():
    """The in-kernel g matches applying bwd_factor outside + v1-style
    contraction oracle (the refactor changed plumbing, not math)."""
    B, S, D, V = 3, 33, 24, 100
    H, E, b, mask = _inputs(B, S, D, V, seed=13)
    y_ref, i_ref = sparton_forward_ref(H, E, b, mask)
    dy = jax.random.normal(jax.random.PRNGKey(17), (B, V))
    g = jnp.where(y_ref > 0, dy * jnp.exp(-y_ref), 0.0)
    dH, dE, db = sparton_backward(dy, y_ref, i_ref, H, E, block_b=2,
                                  block_s=32, block_v=64, interpret=True)
    dH_ref, dE_ref = sparton_backward_ref(g, i_ref, H, E)
    np.testing.assert_allclose(np.asarray(dH), np.asarray(dH_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dE), np.asarray(dE_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(jnp.sum(g, 0)),
                               atol=1e-4, rtol=1e-4)


def test_fused_db_matches_autodiff():
    """The kernel-accumulated bias grad vs autograd through the pure-JAX
    reference head (ISSUE satellite: fused-db backward vs autograd)."""
    B, S, D, V = 3, 48, 16, 96
    H, E, b, mask = _inputs(B, S, D, V, seed=7)

    def loss_kernel(b):
        y = sparton_head(H, E, b, mask, block_b=1, block_s=16,
                         block_v=32, interpret=True)
        return jnp.sum(jnp.tanh(y) * jnp.arange(V))

    def loss_ref(b):
        y, _ = sparton_forward_ref(H, E, b, mask)
        return jnp.sum(jnp.tanh(y) * jnp.arange(V))

    np.testing.assert_allclose(
        np.asarray(jax.grad(loss_kernel)(b)),
        np.asarray(jax.grad(loss_ref)(b)), atol=2e-4, rtol=2e-4)


def test_custom_vjp_grads_match_autodiff_oracle():
    B, S, D, V = 3, 48, 16, 96
    H, E, b, mask = _inputs(B, S, D, V, seed=7)

    def loss_kernel(H, E, b):
        y = sparton_head(H, E, b, mask, block_b=1, block_s=16,
                         block_v=32, interpret=True)
        return jnp.sum(jnp.sin(y))

    def loss_ref(H, E, b):
        y, _ = sparton_forward_ref(H, E, b, mask)
        return jnp.sum(jnp.sin(y))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(H, E, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(H, E, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=2e-4, rtol=2e-4)


def test_custom_vjp_grads_bf16_inputs():
    """bf16 parity through the whole custom_vjp: grads come back in the
    input dtype and match the f32 oracle at bf16 resolution."""
    B, S, D, V = 2, 32, 16, 64
    H, E, b, mask = _inputs(B, S, D, V, dtype=jnp.bfloat16, seed=21)

    def loss_kernel(H, E, b):
        y = sparton_head(H, E, b, mask, block_b=2, block_s=16,
                         block_v=32, interpret=True)
        return jnp.sum(jnp.sin(y.astype(jnp.float32)))

    def loss_ref(H, E, b):
        y, _ = sparton_forward_ref(H.astype(jnp.float32),
                                   E.astype(jnp.float32), b, mask)
        return jnp.sum(jnp.sin(y))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(H, E, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(H, E, b)
    assert gk[0].dtype == jnp.bfloat16 and gk[1].dtype == jnp.bfloat16
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   atol=5e-2, rtol=5e-2)


def test_custom_vjp_grads_with_softcap():
    B, S, D, V = 2, 32, 8, 64
    H, E, b, mask = _inputs(B, S, D, V, seed=11)

    def loss_kernel(H):
        y = sparton_head(H, E, b, mask, block_b=2, block_s=16,
                         block_v=32, logit_softcap=4.0, interpret=True)
        return jnp.sum(y * y)

    def loss_ref(H):
        y, _ = sparton_forward_ref(H, E, b, mask, softcap=4.0)
        return jnp.sum(y * y)

    np.testing.assert_allclose(
        np.asarray(jax.grad(loss_kernel)(H)),
        np.asarray(jax.grad(loss_ref)(H)), atol=2e-4, rtol=2e-4)


def test_kernel_grads_match_lm_head_sparton_autograd():
    """Acceptance: sparton_lm_head_kernel grads == lm_head_sparton
    autograd to 1e-4."""
    from repro.core.lm_head import lm_head_sparton

    B, S, D, V = 4, 40, 16, 80
    H, E, b, mask = _inputs(B, S, D, V, seed=29)

    def loss_kernel(H, E, b):
        y = sparton_lm_head_kernel(H, E, b, mask, 2, 16, 32, None, True,
                                   None)
        return jnp.sum(jnp.tanh(y))

    def loss_jax(H, E, b):
        y = lm_head_sparton(H, E, b, mask, vocab_tile=32)
        return jnp.sum(jnp.tanh(y))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(H, E, b)
    gj = jax.grad(loss_jax, argnums=(0, 1, 2))(H, E, b)
    for a, c in zip(gk, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# property-based tests (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 5), S=st.integers(1, 40), D=st.integers(1, 24),
    V=st.integers(1, 70), seed=st.integers(0, 2**16),
)
def test_property_forward_equals_oracle(B, S, D, V, seed):
    H, E, b, mask = _inputs(B, S, D, V, seed=seed)
    y, _ = sparton_forward(H, E, b, mask, block_b=2, block_s=16,
                           block_v=32, interpret=True)
    y_ref, _ = sparton_forward_ref(H, E, b, mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_monotonicity_reordering(seed):
    """The paper's core identity: max_s f(l) == f(max_s l)."""
    H, E, b, mask = _inputs(2, 24, 8, 40, seed=seed)
    logits = jnp.einsum("bsd,vd->bsv", H, E) + b
    keep = mask.astype(bool)[:, :, None]
    f = lambda x: jnp.log1p(jax.nn.relu(x))
    lhs = jnp.max(jnp.where(keep, f(logits), 0.0), axis=1)
    rhs = f(jnp.max(jnp.where(keep, logits, -1e30), axis=1))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_output_nonnegative_and_sparse_friendly(seed):
    H, E, b, mask = _inputs(2, 16, 8, 32, seed=seed)
    y, _ = sparton_forward(H, E, b, mask, block_b=2, block_s=16,
                           block_v=32, interpret=True)
    assert float(jnp.min(y)) >= 0.0  # log1p(relu(.)) >= 0 always
