"""End-to-end system behaviour: short SPLADE training runs converge,
resume reproduces, the config registry is complete, and the dry-run
machinery builds every cell spec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, ARCH_IDS, all_cells, get_config
from repro.configs.specs import cell_spec
from repro.data.synthetic import lsr_pair_batches
from repro.launch.steps import build_lsr_train_step, init_state


def _run_training(steps=25, seed=0, lr=2e-3, state=None):
    cfg = get_config("splade_bert").SMOKE
    if state is None:
        state, _ = init_state("splade_bert", jax.random.PRNGKey(seed),
                              smoke=True)
    step = jax.jit(build_lsr_train_step(cfg, None, n_micro=1, n_pairs=8,
                                        lr=lr, total_steps=steps))
    gen = lsr_pair_batches(batch=8, q_len=12, d_len=16,
                           vocab=cfg.vocab_size, seed=7)
    losses = []
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def test_short_training_reduces_loss():
    _, losses = _run_training(steps=25)
    assert np.isfinite(losses).all()
    assert min(losses[-5:]) < losses[0], losses


def test_training_is_deterministic():
    _, l1 = _run_training(steps=5)
    _, l2 = _run_training(steps=5)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_registry_covers_all_assigned_archs():
    assert len(ARCH_IDS) == 12
    for external_id in [
        "llama3.2-3b", "gemma2-27b", "phi3-mini-3.8b",
        "moonshot-v1-16b-a3b", "phi3.5-moe-42b-a6.6b", "dimenet",
        "dlrm-mlperf", "xdeepfm", "dien", "wide-deep",
    ]:
        mod = get_config(external_id)
        assert hasattr(mod, "CONFIG") and hasattr(mod, "SMOKE")
        assert hasattr(mod, "SHAPES")


def test_dry_run_matrix_is_40_cells():
    cells = list(all_cells())
    assert len(cells) == 40
    skips = [(a, s) for a, s, sp in cells if sp.skip]
    # exactly the 4 justified full-attention long-context skips
    assert sorted(skips) == sorted([
        ("llama3_2_3b", "long_500k"), ("phi3_mini", "long_500k"),
        ("moonshot_v1_16b", "long_500k"), ("phi3_5_moe", "long_500k")])


def test_all_unskipped_cell_specs_build():
    built = 0
    for arch, shape, sp in all_cells():
        if sp.skip:
            continue
        cell = cell_spec(arch, shape)
        assert cell.batch, (arch, shape)
        for name, sds in cell.batch.items():
            assert all(d > 0 for d in sds.shape), (arch, shape, name)
        built += 1
    assert built == 36


def test_exact_assigned_configs():
    """The configs must match the assignment text exactly."""
    c = get_config("llama3.2-3b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 3072, 24, 8, 8192, 128256)
    c = get_config("gemma2-27b").CONFIG
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (46, 4608, 32, 16, 36864, 256000)
    c = get_config("moonshot-v1-16b-a3b").CONFIG
    assert (c.n_experts, c.top_k, c.vocab_size) == (64, 6, 163840)
    c = get_config("phi3.5-moe-42b-a6.6b").CONFIG
    assert (c.n_experts, c.top_k, c.d_model) == (16, 2, 4096)
    c = get_config("dimenet").CONFIG
    assert (c.n_blocks, c.d_hidden, c.n_bilinear, c.n_spherical,
            c.n_radial) == (6, 128, 8, 7, 6)
    c = get_config("dlrm-mlperf").CONFIG
    assert c.n_dense == 13 and c.n_sparse == 26 and c.embed_dim == 128
    assert c.bot_mlp == (13, 512, 256, 128)
    c = get_config("xdeepfm").CONFIG
    assert c.cin_layers == (200, 200, 200) and c.embed_dim == 10
    c = get_config("dien").CONFIG
    assert (c.embed_dim, c.seq_len, c.gru_dim) == (18, 100, 108)
    c = get_config("wide-deep").CONFIG
    assert c.n_sparse == 40 and c.embed_dim == 32
    assert c.mlp == (1024, 512, 256)


def test_paper_model_configs():
    c = get_config("splade_bert").CONFIG
    assert c.vocab_size == 30522 and c.bidirectional_encoder
    c = get_config("splade_xlmr").CONFIG
    assert c.vocab_size == 250002
