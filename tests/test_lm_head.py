"""The three LM-head implementations (naive / tiled / sparton) are
numerically identical — values AND gradients (paper §4: "no
effectiveness loss"). Plus memory-residual structure checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lm_head import (lm_head_naive, lm_head_sparton,
                                lm_head_tiled, sparton_forward_with_indices)


def _inputs(B=3, S=40, D=16, V=100, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    H = jax.random.normal(ks[0], (B, S, D))
    E = jax.random.normal(ks[1], (V, D)) * 0.3
    b = jax.random.normal(ks[2], (V,)) * 0.1
    mask = (jax.random.uniform(ks[3], (B, S)) > 0.25).astype(jnp.int32)
    mask = mask.at[:, 0].set(1)
    return H, E, b, mask


@pytest.mark.parametrize("vocab_tile", [16, 64, 128])
def test_three_impls_agree(vocab_tile):
    H, E, b, mask = _inputs()
    y_naive = lm_head_naive(H, E, b, mask)
    y_tiled = lm_head_tiled(H, E, b, mask, vocab_tile=vocab_tile)
    y_spart = lm_head_sparton(H, E, b, mask, vocab_tile=vocab_tile)
    np.testing.assert_allclose(np.asarray(y_naive), np.asarray(y_tiled),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y_naive), np.asarray(y_spart),
                               atol=1e-5, rtol=1e-5)


def test_grads_agree_across_impls():
    H, E, b, mask = _inputs(seed=5)

    def make_loss(impl, **kw):
        def loss(H, E, b):
            y = impl(H, E, b, mask, **kw)
            return jnp.sum(jnp.tanh(y) * jnp.arange(y.shape[-1]))
        return loss

    g_naive = jax.grad(make_loss(lm_head_naive), (0, 1, 2))(H, E, b)
    g_tiled = jax.grad(make_loss(lm_head_tiled, vocab_tile=32),
                       (0, 1, 2))(H, E, b)
    g_spart = jax.grad(make_loss(lm_head_sparton, vocab_tile=32),
                       (0, 1, 2))(H, E, b)
    for gn, gt, gs in zip(g_naive, g_tiled, g_spart):
        np.testing.assert_allclose(np.asarray(gn), np.asarray(gt),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(gn), np.asarray(gs),
                                   atol=2e-5, rtol=2e-5)


def test_grads_agree_with_softcap():
    H, E, b, mask = _inputs(seed=8)

    def loss(impl):
        def f(H):
            y = impl(H, E, b, mask, logit_softcap=4.0)
            return jnp.sum(y ** 2)
        return f

    gn = jax.grad(loss(lm_head_naive))(H)
    gs = jax.grad(loss(lm_head_sparton))(H)
    np.testing.assert_allclose(np.asarray(gn), np.asarray(gs),
                               atol=2e-5, rtol=2e-5)


def test_sparton_residuals_are_reduced():
    """The paper's memory claim, structurally: sparton's saved residuals
    carry no (B, S, V) tensor — only (B, V) + inputs."""
    H, E, b, mask = _inputs(B=2, S=16, D=8, V=64)

    def f(H, E, b):
        return jnp.sum(lm_head_sparton(H, E, b, mask, vocab_tile=16))

    # the vjp closure holds the residuals: largest must be H (B*S*D),
    # never the (B, S, V) = 2048-element logit tensor
    _, vjp_fn = jax.vjp(f, H, E, b)
    for l in jax.tree_util.tree_leaves(vjp_fn):
        if hasattr(l, "shape"):
            assert l.size < 2 * 16 * 64, \
                f"unexpected large residual {l.shape}"


def test_indices_point_at_unmasked_positions():
    H, E, b, mask = _inputs(seed=3)
    _, i_max = sparton_forward_with_indices(H, E, b, mask, vocab_tile=32)
    m = np.asarray(mask)
    i = np.asarray(i_max)
    B, V = i.shape
    for bi in range(B):
        assert m[bi, i[bi]].all(), "argmax routed to a masked position"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), tile=st.sampled_from([8, 32, 256]))
def test_property_tiling_invariance(seed, tile):
    """Output must not depend on the vocab tile size."""
    H, E, b, mask = _inputs(B=2, S=12, D=8, V=50, seed=seed)
    y1 = lm_head_sparton(H, E, b, mask, vocab_tile=tile)
    y2 = lm_head_sparton(H, E, b, mask, vocab_tile=17)  # awkward tile
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_unroll_does_not_change_values():
    H, E, b, mask = _inputs(B=2, S=12, D=8, V=64, seed=4)
    y1 = lm_head_sparton(H, E, b, mask, vocab_tile=16, unroll=1)
    y2 = lm_head_sparton(H, E, b, mask, vocab_tile=16, unroll=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=0)
