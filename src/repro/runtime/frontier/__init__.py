"""Serving frontier: query/posting caches and multi-corpus tenancy.

The layer in front of ``CorpusEngine`` that makes repeated work cheap
(``caches``) and one process serve many corpora fairly (``tenancy``).
Continuous batching lives in ``repro.runtime.serving`` itself — it
changes how the existing loop dispatches, not what sits in front of
it. DESIGN.md §13.
"""

from repro.runtime.frontier.caches import (
    CachedEngine,
    HotPostingCache,
    QueryResultCache,
    hot_fused_retrieve,
    query_cache_key,
)
from repro.runtime.frontier.tenancy import (
    QuotaExceeded,
    TenantPool,
    TenantQuota,
    TenantState,
)

__all__ = [
    "CachedEngine",
    "HotPostingCache",
    "QueryResultCache",
    "QuotaExceeded",
    "hot_fused_retrieve",
    "query_cache_key",
    "TenantPool",
    "TenantQuota",
    "TenantState",
]
