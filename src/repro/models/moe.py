"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Token->expert dispatch on TPU cannot use the (T, E, C) one-hot tensor
of the original GShard formulation at 1M-token batches (it is
astronomically large). We use the sort-based dropping dispatch that
production JAX MoE stacks (MaxText, MegaBlocks-style) use:

1. route: top-k softmax gating over expert logits,
2. sort the T*k (token, expert) assignments by expert id,
3. compute each assignment's rank within its expert (cumulative
   position), drop ranks >= capacity C,
4. scatter surviving tokens into an (E, C, D) buffer,
5. batched expert FFN via one einsum over the stacked expert weights,
6. gather back and combine with gate weights.

All steps are dense, static-shape ops (argsort / cumsum / scatter),
which XLA SPMD can partition: the expert dimension shards over the
``model`` mesh axis (expert parallelism), tokens over ``data``.

Load-balancing auxiliary loss follows Switch Transformer (eq. 4-6).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def moe_ffn(
    x: Array,                 # (T, D) flattened tokens
    router_w: Array,          # (D, E)
    w_gate: Array,            # (E, D, F)
    w_up: Array,              # (E, D, F)
    w_down: Array,            # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
) -> Tuple[Array, Array]:
    """Returns (output (T, D), aux_loss scalar)."""
    T, D = x.shape
    E = router_w.shape[1]
    C = max(1, int(capacity_factor * top_k * T / E))

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1),
        axis=0,
    ) / top_k
    aux_loss = E * jnp.sum(me * ce)

    # --- sort-based dispatch -------------------------------------------
    flat_expert = expert_idx.reshape(-1)                       # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)

    order = jnp.argsort(flat_expert)                           # stable
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_gate = flat_gate[order]

    # rank of each assignment within its expert
    counts = jnp.bincount(flat_expert, length=E)               # (E,)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * top_k, dtype=jnp.int32) - starts[s_expert]
    keep = rank < C

    # scatter into the (E, C, D) expert buffer; dropped tokens go to a
    # sacrificial slot (row C) that is sliced off.
    slot = jnp.where(keep, rank, C)
    buf = jnp.zeros((E, C + 1, D), x.dtype)
    buf = buf.at[s_expert, slot].set(jnp.take(x, s_token, axis=0))
    buf = buf[:, :C]                                           # (E, C, D)

    # --- expert FFN (SwiGLU), one batched einsum over experts ----------
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))

    # --- gather back + combine -----------------------------------------
    y_pad = jnp.concatenate([y, jnp.zeros((E, 1, D), y.dtype)], axis=1)
    per_assign = y_pad[s_expert, slot]                         # (T*k, D)
    per_assign = per_assign * s_gate[:, None].astype(y.dtype)
    out = jax.ops.segment_sum(per_assign, s_token, num_segments=T)
    return out.astype(x.dtype), aux_loss


def moe_ffn_local_experts(
    x: Array,                 # (T_local, D) this token shard
    router_w: Array,          # (D, E_global) replicated
    w_gate: Array,            # (E_local, D, F) this expert shard
    w_up: Array,
    w_down: Array,
    *,
    top_k: int,
    capacity_factor: float,
    expert_axis: str,
    token_axes: Tuple[str, ...],
) -> Tuple[Array, Array]:
    """Expert-parallel MoE body (inside shard_map, DESIGN.md §5).

    Tokens are sharded over ``token_axes`` (data parallel), experts
    over ``expert_axis`` (the model axis). Routing is computed against
    the *global* expert set (router replicated); each device dispatches
    its local tokens to its local experts only (assignments to remote
    experts contribute zero locally) and the partial outputs are
    psum-combined over the expert axis — the EP collective. Capacity is
    per-(token-shard, expert), the standard per-device-capacity
    semantics of production MoE systems.
    """
    T, D = x.shape
    E_local = w_gate.shape[0]
    E = router_w.shape[1]
    shard = jax.lax.axis_index(expert_axis)
    lo = shard * E_local

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # (T, k) global
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1),
        axis=0) / top_k
    aux_loss = E * jnp.sum(me * ce)
    if token_axes:
        aux_loss = jax.lax.pmean(aux_loss, token_axes)
    aux_loss = aux_loss / jax.lax.psum(
        jnp.ones((), jnp.float32), expert_axis)  # replicated psum later

    C = max(1, int(capacity_factor * top_k * T / E))

    flat_expert = expert_idx.reshape(-1)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)

    local_e = flat_expert - lo
    is_local = (local_e >= 0) & (local_e < E_local)
    local_e = jnp.where(is_local, local_e, E_local)  # sink row

    order = jnp.argsort(jnp.where(is_local, flat_expert, E))  # locals first
    s_e = local_e[order]
    s_token = flat_token[order]
    s_gate = jnp.where(is_local, flat_gate, 0.0)[order]

    counts = jnp.bincount(s_e, length=E_local + 1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * top_k, dtype=jnp.int32) - starts[s_e]
    keep = (rank < C) & (s_e < E_local)
    slot = jnp.where(keep, rank, C)
    e_safe = jnp.minimum(s_e, E_local - 1)
    e_scatter = jnp.where(keep, e_safe, 0)
    slot = jnp.where(keep, slot, C)

    buf = jnp.zeros((E_local, C + 1, D), x.dtype)
    buf = buf.at[e_scatter, slot].set(jnp.take(x, s_token, axis=0))
    buf = buf[:, :C]

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))

    y_pad = jnp.concatenate([y, jnp.zeros((E_local, 1, D), y.dtype)], axis=1)
    per_assign = y_pad[e_scatter, slot] * s_gate[:, None].astype(y.dtype)
    per_assign = jnp.where(keep[:, None], per_assign, 0.0)
    out = jax.ops.segment_sum(per_assign, s_token, num_segments=T)
    # combine partial expert outputs across the expert shards
    out = jax.lax.psum(out, expert_axis)
    aux_loss = jax.lax.psum(aux_loss, expert_axis)
    return out.astype(x.dtype), aux_loss


def init_moe_params(
    key: jax.Array, n_layers: int, d_model: int, d_ff: int, n_experts: int,
    dtype=jnp.float32,
) -> Dict[str, Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sc_in = d_model ** -0.5
    sc_ff = d_ff ** -0.5
    return {
        "router": (jax.random.normal(k1, (n_layers, d_model, n_experts),
                                     dtype) * sc_in),
        "w_gate": (jax.random.normal(
            k2, (n_layers, n_experts, d_model, d_ff), dtype) * sc_in),
        "w_up": (jax.random.normal(
            k3, (n_layers, n_experts, d_model, d_ff), dtype) * sc_in),
        "w_down": (jax.random.normal(
            k4, (n_layers, n_experts, d_ff, d_model), dtype) * sc_ff),
    }
