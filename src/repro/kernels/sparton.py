"""Sparton fused LM-head forward — Pallas TPU kernel.

One kernel fuses: tiled GEMM (``H @ E^T``), bias add, optional
gemma-2-style logit soft-capping, attention masking, streaming max
reduction over the sequence dimension (with argmax tracking), and the
final ``log1p(relu(.))`` epilogue. The full ``(B, S, V)`` logit tensor
is never materialized — per grid step only a ``(block_b*block_s,
block_v)`` logit tile lives in VMEM, and only the running ``(B, V)``
maxima/indices are written to HBM.

TPU adaptation of the paper (DESIGN.md §3): the paper ships a *hybrid*
(cuBLAS GEMM + Triton reduction) because a hand-written Triton GEMM
loses to cuBLAS. On TPU the in-kernel ``dot_general`` lowers onto the
MXU — the same unit XLA's GEMMs use — so we implement the paper's
"ideal" fully-fused design instead.

Grid layout: ``(B/bb, V/bv, S/bs)`` with the sequence dimension
innermost, so each ``(b, v)`` output tile is revisited across sequence
steps and accumulates its running max in-place (the standard Pallas TPU
reduction idiom; deterministic, no atomics).

VMEM working set per step (fp32):
    H tile   bb*bs*D
    E tile   bv*D
    logits   bb*bs*bv        (register/VMEM temporary)
    y, i     2 * bb*bv
Block defaults (8, 128, 128) keep this under ~2 MB at D=4096; the MXU
contraction dims (bb*bs and bv) are multiples of 128.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # finite stand-in; see core/lm_head.py


def _fwd_kernel(
    h_ref,      # (bb, bs, D)
    e_ref,      # (bv, D)
    bias_ref,   # (1, bv)
    mask_ref,   # (bb, bs) int32
    y_ref,      # (bb, bv) f32 out — running max, then f(max)
    i_ref,      # (bb, bv) i32 out — running argmax
    *,
    n_s_blocks: int,
    block_s: int,
    softcap: Optional[float],
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.full(y_ref.shape, NEG_INF, jnp.float32)
        i_ref[...] = jnp.zeros(i_ref.shape, jnp.int32)

    bb, bs, d = h_ref.shape
    bv = e_ref.shape[0]

    h = h_ref[...].reshape(bb * bs, d)
    e = e_ref[...]
    # (bb*bs, bv) logit tile on the MXU; accumulate in f32.
    logits = jax.lax.dot_general(
        h, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    logits = logits + bias_ref[...]  # (1, bv) broadcasts over rows
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = logits.reshape(bb, bs, bv)

    keep = mask_ref[...] > 0  # (bb, bs)
    logits = jnp.where(keep[:, :, None], logits, NEG_INF)

    tile_max = jnp.max(logits, axis=1)  # (bb, bv)
    # First-occurrence argmax without lax.argmax (portable in Pallas):
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bb, bs, bv), 1)
    hit = logits >= tile_max[:, None, :]
    tile_arg = jnp.min(jnp.where(hit, s_iota, bs), axis=1) + k * block_s

    cur = y_ref[...]
    better = tile_max > cur  # strict: earlier blocks win ties (first occ.)
    y_ref[...] = jnp.where(better, tile_max, cur)
    i_ref[...] = jnp.where(better, tile_arg, i_ref[...])

    @pl.when(k == n_s_blocks - 1)
    def _finalize():
        raw = y_ref[...]
        y_ref[...] = jnp.log1p(jnp.maximum(raw, 0.0))


def _pad_to(x, axis, multiple, value=0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_b", "block_s", "block_v", "softcap", "interpret"
    ),
)
def sparton_forward(
    H: jax.Array,        # (B, S, D)
    E: jax.Array,        # (V, D)
    b: jax.Array,        # (V,)
    mask: jax.Array,     # (B, S) int32/bool, 1 = keep
    *,
    block_b: int = 8,
    block_s: int = 128,
    block_v: int = 128,
    softcap: Optional[float] = None,
    interpret: bool = False,
):
    """Fused forward. Returns (y (B, V) f32, i_max (B, V) i32)."""
    B, S, D = H.shape
    V = E.shape[0]

    Hp = _pad_to(_pad_to(H, 0, block_b), 1, block_s)
    maskp = _pad_to(_pad_to(mask.astype(jnp.int32), 0, block_b), 1, block_s)
    Ep = _pad_to(E, 0, block_v)
    bp = _pad_to(b.astype(jnp.float32), 0, block_v).reshape(1, -1)

    Bp, Sp, _ = Hp.shape
    Vp = Ep.shape[0]
    grid = (Bp // block_b, Vp // block_v, Sp // block_s)

    kernel = functools.partial(
        _fwd_kernel,
        n_s_blocks=grid[2],
        block_s=block_s,
        softcap=softcap,
    )
    y, i_max = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_s, D), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((block_v, D), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, block_v), lambda i, j, k: (0, j)),
            pl.BlockSpec((block_b, block_s), lambda i, j, k: (i, k)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_b, block_v), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Vp), jnp.float32),
            jax.ShapeDtypeStruct((Bp, Vp), jnp.int32),
        ],
        interpret=interpret,
    )(Hp, Ep, bp, maskp)
    return y[:B, :V], i_max[:B, :V]
