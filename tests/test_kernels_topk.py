"""Fused streaming top-k kernel vs oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import topk_score_ref
from repro.kernels.topk_score import topk_score
from repro.launch.steps import streaming_topk


def _qc(B, N, D, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (B, D))
    C = jax.random.normal(k2, (N, D))
    return q, C


@pytest.mark.parametrize("B,N,D,k,bn", [
    (1, 100, 16, 5, 32),
    (3, 500, 32, 10, 128),
    (8, 1024, 64, 100, 256),
    (2, 999, 8, 7, 128),       # non-divisible N
])
def test_topk_kernel_matches_oracle(B, N, D, k, bn):
    q, C = _qc(B, N, D)
    v, i = topk_score(q, C, k=k, block_b=2, block_n=bn, interpret=True)
    vr, ir = topk_score_ref(q, C, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


def test_streaming_topk_pure_jax_matches_oracle():
    q, C = _qc(4, 2000, 16, seed=2)
    v, i = streaming_topk(q, C, k=13, tile=256)
    vr, ir = topk_score_ref(q, C, 13)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


# ---------------------------------------------------------------------------
# edge cases: k >= N, padded tails, ties, non-dividing block_n
# ---------------------------------------------------------------------------

NEG_INF = -1e30


@pytest.mark.parametrize("N,k,bn", [
    (10, 10, 32),     # k == N
    (10, 16, 32),     # k > N: tail must be NEG_INF, head the full rank
    (7, 12, 4),       # k > N with block_n < k and bn not dividing N
])
def test_topk_k_geq_n(N, k, bn):
    q, C = _qc(3, N, 8, seed=5)
    v, i = topk_score(q, C, k=k, block_b=2, block_n=bn, interpret=True)
    vr, ir = topk_score_ref(q, C, N)
    v, i = np.asarray(v), np.asarray(i)
    np.testing.assert_allclose(v[:, :N], np.asarray(vr), atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_array_equal(i[:, :N], np.asarray(ir))
    # the documented contract for the degenerate tail
    assert (v[:, N:] == NEG_INF).all()


def test_padded_tail_never_beats_real_negatives():
    """All real scores negative + padded tail rows scoring q.0 = 0:
    the padding mask must keep ids < N and values negative."""
    B, N, D, bn = 2, 700, 16, 256          # Np = 768 > N
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    q = jax.random.uniform(k1, (B, D)) + 0.5      # strictly positive
    C = -(jax.random.uniform(k2, (N, D)) + 0.5)   # strictly negative
    v, i = topk_score(q, C, k=9, block_b=2, block_n=bn, interpret=True)
    v, i = np.asarray(v), np.asarray(i)
    assert (v < 0).all()                   # a padded 0 never won
    assert (i >= 0).all() and (i < N).all()
    vr, ir = topk_score_ref(q, C, 9)
    np.testing.assert_allclose(v, np.asarray(vr), atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(i, np.asarray(ir))


@pytest.mark.parametrize("bn", [32, 48])   # dividing and non-dividing
def test_duplicate_scores_tie_break_to_lowest_id(bn):
    """Duplicated candidate rows score identically; the streaming merge
    must resolve ties to the lowest candidate id (stable, matching the
    oracle's lax.top_k), even across block boundaries."""
    B, N, D, k = 2, 96, 8, 12
    q, C = _qc(B, N, D, seed=11)
    C = np.array(C)                        # writable host copy
    dup_src = np.arange(0, 24)
    dup_dst = np.arange(60, 84)            # a different block than src
    C[dup_dst] = C[dup_src]
    C = jnp.asarray(C)
    v, i = topk_score(q, C, k=k, block_b=2, block_n=bn, interpret=True)
    vr, ir = topk_score_ref(q, C, k)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    # at least one tie pair must actually be in the top-k for the test
    # to bite; the winner must be the low id of its duplicate pair
    hit = np.isin(np.asarray(i), dup_src)
    assert hit.any()


def test_merge_topk_stability_unit():
    """merge_topk alone: running entries win ties against new entries
    (first-occurrence semantics of the streaming scan)."""
    from repro.kernels.topk_score import merge_topk

    run_v = jnp.asarray([[5.0, 3.0]])
    run_i = jnp.asarray([[2, 7]], dtype=jnp.int32)
    new_v = jnp.asarray([[5.0, 3.0, 1.0]])
    new_i = jnp.asarray([[9, 11, 13]], dtype=jnp.int32)
    v, i = merge_topk(run_v, run_i, new_v, new_i, 3)
    np.testing.assert_allclose(np.asarray(v), [[5.0, 5.0, 3.0]])
    np.testing.assert_array_equal(np.asarray(i), [[2, 9, 7]])


@settings(max_examples=15, deadline=None)
@given(N=st.integers(10, 400), k=st.integers(1, 9),
       seed=st.integers(0, 2**16))
def test_property_topk_invariants(N, k, seed):
    q, C = _qc(2, N, 8, seed=seed)
    v, i = topk_score(q, C, k=k, block_b=2, block_n=64, interpret=True)
    v, i = np.asarray(v), np.asarray(i)
    # scores sorted descending, indices valid and unique
    assert (np.diff(v, axis=1) <= 1e-6).all()
    assert (i >= 0).all() and (i < N).all()
    for row in i:
        assert len(set(row.tolist())) == k
    # values actually equal q . C[idx]
    scores = np.einsum("bd,bkd->bk", np.asarray(q), np.asarray(C)[i])
    np.testing.assert_allclose(v, scores, atol=1e-4)
