"""Index-engine tests (DESIGN.md §8): two-tier pruning, quantized
compression, doc sharding, and the incremental builder.

The acceptance anchors:

* ``method="pruned"`` returns ids identical to ``method="impact"`` at
  the default (safe) margin on the graded benchmark corpus;
* ``QuantizedIndex`` is >= 4x smaller than the raw ``InvertedIndex``
  on that corpus with identical top-k ids;
* sharded retrieval (vmap fallback and the shard_map multi-device
  path, run in a subprocess like ``test_head_api``) matches the
  single-device scorer;
* ``IndexBuilder`` add/remove/flush/compact keep external ids stable
  and search-consistent with a frozen one-shot build.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lsr_impact_corpus
from repro.retrieval import (IndexBuilder, SparseRep,
                             build_inverted_index, pruned_retrieve,
                             quantize_index, retrieve, shard_index,
                             sparsify_topk, sparsify_threshold)
from repro.retrieval.engine.pruning import (default_candidates,
                                            upper_bound_scores)
from repro.retrieval.engine.quantize import quantized_scores
from repro.retrieval.score import impact_scores

K = 10
BENCH = dict(n_docs=1536, vocab=1536, doc_nnz=32, n_queries=8,
             q_nnz=28)


@pytest.fixture(scope="module")
def graded():
    """Bench-shaped graded corpus: reps, raw/engine/quantized indexes,
    and the exact impact baseline."""
    data = lsr_impact_corpus(**BENCH)
    q = sparsify_topk(jnp.asarray(data["queries"]), BENCH["q_nnz"])
    d = sparsify_topk(jnp.asarray(data["docs"]), BENCH["doc_nnz"])
    raw = build_inverted_index(d, BENCH["vocab"])
    eng = build_inverted_index(d, BENCH["vocab"], keep_forward=True)
    vals, idx = retrieve(q, raw, K, method="impact")
    return {"q": q, "d": d, "raw": raw, "eng": eng,
            "vals": np.asarray(vals), "idx": np.asarray(idx)}


def _small(rng, n, nnz, vocab):
    m = np.zeros((n, vocab), np.float32)
    for r in range(n):
        cols = rng.choice(vocab, size=nnz, replace=False)
        m[r, cols] = rng.uniform(0.1, 2.0, size=nnz)
    return m


# ---------------------------------------------------------------------------
# index extensions: upper bounds, forward rows, percentiles, warning
# ---------------------------------------------------------------------------

def test_index_carries_upper_bounds_and_percentiles(graded):
    raw = graded["raw"]
    assert raw.has_upper_bounds and not raw.has_forward
    ubs = np.asarray(raw.term_ubs)
    lens = np.asarray(raw.term_lens)
    starts = np.asarray(raw.term_starts)
    pv = np.asarray(raw.postings_val)
    for t in np.flatnonzero(lens > 0)[:50]:
        assert ubs[t] == pv[starts[t]:starts[t] + lens[t]].max()
    assert (ubs[lens == 0] == 0).all()
    p50, p90, p99, mx = raw.posting_percentiles
    assert 0 < p50 <= p90 <= p99 <= mx == raw.max_postings
    st = raw.stats()
    assert st["postings_p50"] == p50 and st["postings_max"] == mx


def test_engine_index_has_forward_rows(graded):
    eng, d = graded["eng"], graded["d"]
    assert eng.has_forward
    np.testing.assert_array_equal(np.asarray(eng.doc_values),
                                  np.asarray(d.values))
    # forward rows are counted in the footprint
    assert eng.memory_bytes() > graded["raw"].memory_bytes()


def test_stopword_term_warns_with_percentiles():
    """A term active in most docs pads every query gather to ~N — the
    build must say so, with posting-length stats."""
    rng = np.random.default_rng(0)
    m = _small(rng, 50, 4, 64)
    m[:45, 7] = 1.0                      # stopword-ish term
    rep = sparsify_threshold(jnp.asarray(m), 0.0, max_nnz=8)
    with pytest.warns(UserWarning, match=r"p50=.*p99=.*max=45"):
        idx = build_inverted_index(rep, 64)
    assert idx.max_postings == 45
    # quiet under a permissive threshold
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        build_inverted_index(rep, 64, stopword_warn_frac=0.95)


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------

def test_upper_bound_scores_dominate_exact(graded):
    ub = np.asarray(upper_bound_scores(graded["q"], graded["raw"]))
    exact = np.asarray(impact_scores(graded["q"], graded["raw"]))
    assert (ub >= exact - 1e-4).all()


def test_pruned_ids_identical_to_impact_at_safe_margin(graded):
    """Acceptance: safe-margin pruning is id-identical to the exact
    scorer, and the run is provably exact (frontier diagnostic)."""
    vals, idx, frontier = pruned_retrieve(
        graded["q"], graded["eng"], K, with_diagnostics=True)
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)
    assert np.asarray(frontier).all()


def test_pruned_full_candidates_is_exhaustive(graded):
    """candidates == N rescores everything: exact by construction."""
    vals, idx = pruned_retrieve(graded["q"], graded["eng"], K,
                                candidates=BENCH["n_docs"])
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])


def test_pruned_aggressive_margin_prunes_but_keeps_top1(graded):
    """margin=1 keeps only docs whose ceiling reaches the k-th best
    ceiling — lossy by design, but the clear winner survives."""
    vals, idx = pruned_retrieve(graded["q"], graded["eng"], K,
                                prune_margin=1.0)
    assert np.array_equal(np.asarray(idx)[:, 0], graded["idx"][:, 0])


def test_pruned_input_validation(graded):
    with pytest.raises(ValueError, match="forward"):
        pruned_retrieve(graded["q"], graded["raw"], K)
    with pytest.raises(ValueError, match="prune_margin"):
        pruned_retrieve(graded["q"], graded["eng"], K, prune_margin=2.0)
    import dataclasses
    no_ubs = dataclasses.replace(graded["eng"], term_ubs=None)
    with pytest.raises(ValueError, match="upper bounds"):
        pruned_retrieve(graded["q"], no_ubs, K)


def test_default_candidates_planner_reads_percentiles(graded):
    base = default_candidates(graded["raw"], K)
    assert K <= base <= BENCH["n_docs"]
    # stopword-skewed percentiles double the budget
    import dataclasses
    skewed = dataclasses.replace(
        graded["raw"], posting_percentiles=(4.0, 30.0, 40.0, 900.0))
    assert default_candidates(skewed, K) == min(2 * base,
                                                BENCH["n_docs"])


def test_auto_prefers_pruned_on_engine_index(graded):
    """The dispatch heuristic: an index carrying upper bounds AND
    forward rows routes 'auto' to the pruned path (id-identical), a
    bare index to exact impact."""
    from repro.retrieval.score import _resolve_method

    assert _resolve_method("auto", graded["eng"]) == "pruned"
    assert _resolve_method("auto", graded["raw"]) == "impact"
    v_auto, i_auto = retrieve(graded["q"], graded["eng"], K)
    np.testing.assert_array_equal(np.asarray(i_auto), graded["idx"])


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

def test_quantized_roundtrip_parity_and_4x(graded):
    """Acceptance: >= 4x smaller than the raw index, identical top-k
    ids, scores within the per-term quantization tolerance."""
    raw = graded["raw"]
    quant = quantize_index(raw)
    ratio = raw.memory_bytes() / quant.memory_bytes()
    assert ratio >= 4.0, f"compression ratio {ratio:.2f} < 4x"

    vals, idx = retrieve(graded["q"], quant, K, method="quantized")
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    # worst-case dequant error: sum_t q_t * step_t / 2 per doc
    step = (np.asarray(quant.term_hi, np.float32)
            - np.asarray(quant.term_lo, np.float32)) / 14
    qv = np.asarray(graded["q"].values)
    qi = np.asarray(graded["q"].indices)
    tol = (qv * step[qi] / 2).sum(axis=1, keepdims=True) + 1e-4
    assert (np.abs(np.asarray(vals) - graded["vals"]) <= tol).all()


def test_quantized_scores_match_dense_within_tolerance():
    """Full (B, N) score matrix vs the exact one on a small corpus."""
    data = lsr_impact_corpus(n_docs=96, vocab=256, doc_nnz=16,
                             n_queries=4, q_nnz=14, graded=6)
    q = sparsify_topk(jnp.asarray(data["queries"]), 14)
    d = sparsify_topk(jnp.asarray(data["docs"]), 16)
    raw = build_inverted_index(d, 256)
    quant = quantize_index(raw)
    exact = np.asarray(impact_scores(q, raw))
    approx = np.asarray(quantized_scores(q, quant))
    step = (np.asarray(quant.term_hi, np.float32)
            - np.asarray(quant.term_lo, np.float32)) / 14
    tol = (np.asarray(q.values) * step[np.asarray(q.indices)]
           / 2).sum(axis=1, keepdims=True) + 1e-4
    assert (np.abs(approx - exact) <= tol).all()


def test_quantized_delta_escape_handles_large_gaps():
    """A mostly-dense list with a few gaps > 255 stays u8 and
    round-trips the large gaps through escape phantoms."""
    n = 2000
    v = np.zeros((n, 2), np.float32)
    i = np.zeros((n, 2), np.int32)
    # term 3: a dense run (gap 1) plus two long jumps (gap > 2*255)
    docs = np.concatenate([np.arange(100), [800, 1900]])
    v[docs, 0] = 1.5
    i[docs, 0] = 3
    rep = SparseRep(v, i, (v > 0).sum(1).astype(np.int32))
    raw = build_inverted_index(rep, 8)
    quant = quantize_index(raw)
    assert np.asarray(quant.deltas).dtype == np.uint8
    assert quant.stats()["phantom_frac"] > 0
    q = SparseRep(np.ones((1, 1), np.float32),
                  np.full((1, 1), 3, np.int32),
                  np.ones(1, np.int32))
    scores = np.asarray(quantized_scores(q, quant))[0]
    expected = np.zeros(n, np.float32)
    expected[docs] = 1.5
    np.testing.assert_allclose(scores, expected, atol=1e-3)


def test_quantized_sparse_gaps_pick_wide_deltas():
    """Uniformly sparse posting lists (avg gap >> 255) must switch to
    u16 deltas instead of drowning the index in u8 escape phantoms
    (which used to make the 'compressed' index *larger* than raw and
    blow up the per-query gather window)."""
    rng = np.random.default_rng(7)
    n, vocab, nnz = 20000, 4096, 4      # avg gap ~ n/postings >> 255
    v = rng.uniform(0.5, 1.5, size=(n, nnz)).astype(np.float32)
    i = np.stack([rng.choice(vocab, size=nnz, replace=False)
                  for _ in range(n)]).astype(np.int32)
    rep = SparseRep(v, i, np.full(n, nnz, np.int32))
    raw = build_inverted_index(rep, vocab)
    quant = quantize_index(raw)
    assert np.asarray(quant.deltas).dtype == np.uint16
    assert quant.stats()["phantom_frac"] < 0.01
    assert quant.max_postings <= raw.max_postings + 1
    assert quant.memory_bytes() < raw.memory_bytes()
    q = sparsify_topk(jnp.asarray(_small(rng, 2, 8, vocab)), 8)
    exact = np.asarray(impact_scores(q, raw))
    approx = np.asarray(quantized_scores(q, quant))
    assert np.abs(exact - approx).max() < 0.1


def test_quantized_empty_corpus_is_valid():
    rep = sparsify_topk(jnp.zeros((3, 32)), 4)
    quant = quantize_index(build_inverted_index(rep, 32))
    q = sparsify_topk(jnp.asarray(_small(
        np.random.default_rng(0), 2, 4, 32)), 4)
    scores = np.asarray(quantized_scores(q, quant))
    assert scores.shape == (2, 3) and (scores == 0).all()


# ---------------------------------------------------------------------------
# sharding (vmap path here; shard_map path in the subprocess test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_sharded_vmap_matches_single_device(graded, n_shards):
    sidx = shard_index(graded["d"], BENCH["vocab"], n_shards)
    vals, idx = retrieve(graded["q"], sidx, K, method="sharded")
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)


def test_sharded_uneven_split_and_small_k():
    rng = np.random.default_rng(3)
    D = _small(rng, 41, 6, 64)           # 41 docs over 3 shards: 14/14/13
    Q = _small(rng, 3, 5, 64)
    d = sparsify_threshold(jnp.asarray(D), 0.0, max_nnz=8)
    q = sparsify_threshold(jnp.asarray(Q), 0.0, max_nnz=8)
    sidx = shard_index(d, 64, 3)
    assert sidx.docs_per_shard == 14 and sidx.n_docs == 41
    v1, i1 = retrieve(q, build_inverted_index(d, 64), 5,
                      method="impact")
    v2, i2 = retrieve(q, sidx, 5)        # auto -> sharded
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_shard_index_input_validation(graded):
    with pytest.raises(ValueError, match="n_shards"):
        shard_index(graded["d"], BENCH["vocab"], 0)
    with pytest.raises(ValueError, match="exceeds corpus"):
        shard_index(SparseRep(np.ones((2, 1), np.float32),
                              np.zeros((2, 1), np.int32),
                              np.ones(2, np.int32)), 4, 3)


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    n = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "2"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}")
    import jax, jax.numpy as jnp, numpy as np
    from repro.data.synthetic import lsr_impact_corpus
    from repro.retrieval import (build_inverted_index, retrieve,
                                 shard_index, sparsify_topk)
    from repro.retrieval.engine.sharded_index import sharded_retrieve

    assert jax.device_count() >= n, jax.devices()
    data = lsr_impact_corpus(n_docs=192, vocab=256, doc_nnz=16,
                             n_queries=4, q_nnz=14, graded=6)
    q = sparsify_topk(jnp.asarray(data["queries"]), 14)
    d = sparsify_topk(jnp.asarray(data["docs"]), 16)
    k = 4
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 256), k,
                            method="impact")

    sidx = shard_index(d, 256, n)
    mesh = jax.make_mesh((n,), ("data",))
    v_sm, i_sm = sharded_retrieve(q, sidx, k, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i_sm), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v_sm), np.asarray(v_ref),
                               atol=1e-4)
    # the retrieve() dispatcher threads the mesh through
    v_d, i_d = retrieve(q, sidx, k, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_ref))
    # shard-count / mesh-size mismatch is a loud error
    try:
        sharded_retrieve(q, shard_index(d, 256, n + 1), k, mesh=mesh)
        raise SystemExit("mismatch not rejected")
    except ValueError as e:
        assert "must equal mesh axis" in str(e), e
    print("ALL_SHARDED_ENGINE_PASSED")
""")


def test_sharded_retrieve_multi_device_subprocess():
    """shard_map path on a forced multi-host-device mesh matches the
    single-device scorer (mirrors test_head_api's subprocess
    pattern so the device-count flag never leaks into this
    process). Device count: REPRO_SHARD_TEST_DEVICES (default 2;
    CI's multidevice job sets 4)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL_SHARDED_ENGINE_PASSED" in proc.stdout


# ---------------------------------------------------------------------------
# incremental builder
# ---------------------------------------------------------------------------

def _rep_rows(m):
    return sparsify_threshold(jnp.asarray(m), 0.0, max_nnz=12)


def test_builder_add_flush_matches_frozen_build():
    rng = np.random.default_rng(0)
    D = _small(rng, 60, 8, 128)
    Q = _small(rng, 4, 6, 128)
    q = _rep_rows(Q)
    frozen = build_inverted_index(_rep_rows(D), 128)
    v_ref, i_ref = retrieve(q, frozen, 7, method="impact")

    b = IndexBuilder(128)
    ids = b.add(_rep_rows(D[:40]))
    b.flush()
    assert b.stats()["base_docs"] == 40
    ids2 = b.add(_rep_rows(D[40:]))
    np.testing.assert_array_equal(
        np.concatenate([ids, ids2]), np.arange(60))
    vals, ext = b.search(q, 7)           # auto-flush -> base + delta
    assert b.stats()["delta_docs"] in (0, 20)   # merged or delta'd
    np.testing.assert_array_equal(ext, np.asarray(i_ref))
    np.testing.assert_allclose(vals, np.asarray(v_ref), atol=1e-4)


def test_builder_delta_segment_is_incremental():
    """A small add onto a large base must pack only the delta — the
    base arrays are reused by reference, not rebuilt."""
    rng = np.random.default_rng(1)
    D = _small(rng, 80, 8, 128)
    b = IndexBuilder(128, merge_frac=0.5)
    b.add(_rep_rows(D[:64]))
    b.flush()
    base_before = b._base
    b.add(_rep_rows(D[64:]))
    b.flush()
    assert b._base is base_before, "base was rebuilt for a delta add"
    assert b._delta is not None and b._delta.n_docs == 16
    st = b.stats()
    assert st["base_docs"] == 64 and st["delta_docs"] == 16


def test_builder_remove_tombstones_then_compacts():
    rng = np.random.default_rng(2)
    D = _small(rng, 50, 8, 128)
    Q = _small(rng, 3, 6, 128)
    q = _rep_rows(Q)
    b = IndexBuilder(128, compact_dead_frac=0.5)
    b.add(_rep_rows(D))
    b.flush()
    _, ext0 = b.search(q, 5)
    victim = int(ext0[0, 0])
    assert b.remove([victim, victim, 9999]) == 1   # idempotent+unknown
    _, ext1 = b.search(q, 5)
    assert victim not in ext1, "tombstoned doc still retrieved"
    assert b.stats()["n_dead"] == 1                # tombstoned, kept
    # others' results are unaffected by the tombstone
    assert set(ext1[ext1 >= 0]) <= set(ext0.ravel()) | set(ext1.ravel())

    b.flush(force_compact=True)
    assert b.stats()["n_dead"] == 0 and b.stats()["n_slots"] == 49
    _, ext2 = b.search(q, 5)
    np.testing.assert_array_equal(ext1, ext2)      # ext ids stable


def test_builder_auto_compaction_thresholds():
    rng = np.random.default_rng(4)
    D = _small(rng, 40, 6, 64)
    b = IndexBuilder(64, compact_dead_frac=0.25)
    b.add(_rep_rows(D))
    b.flush()
    b.remove(range(15))                  # 15/40 > 25% dead
    b.flush()
    st = b.stats()
    assert st["n_dead"] == 0 and st["n_slots"] == 25, \
        "dead fraction over threshold must trigger compaction"


def test_builder_quantized_base_serves_search():
    data = lsr_impact_corpus(n_docs=96, vocab=256, doc_nnz=16,
                             n_queries=3, q_nnz=14, graded=6)
    q = sparsify_topk(jnp.asarray(data["queries"]), 14)
    d = sparsify_topk(jnp.asarray(data["docs"]), 16)
    frozen = build_inverted_index(d, 256)
    _, i_ref = retrieve(q, frozen, 4, method="impact")
    b = IndexBuilder(256, quantize=True)
    b.add(d)
    vals, ext = b.search(q, 4)
    assert b.stats()["quantized_base"]
    np.testing.assert_array_equal(ext, np.asarray(i_ref))


def test_builder_external_ids_and_empty():
    b = IndexBuilder(64)
    vals, ext = b.search(_rep_rows(np.zeros((2, 64), np.float32)), 3)
    assert (ext == -1).all()
    rng = np.random.default_rng(5)
    ids = b.add(_rep_rows(_small(rng, 4, 6, 64)), ids=[10, 20, 30, 40])
    np.testing.assert_array_equal(ids, [10, 20, 30, 40])
    with pytest.raises(ValueError, match="duplicate"):
        b.add(_rep_rows(_small(rng, 1, 6, 64)), ids=[20])
    assert b.add(_rep_rows(_small(rng, 1, 6, 64)))[0] == 41


def test_builder_removed_id_is_reusable_before_compaction():
    """delete + reinsert of an external id must work deterministically
    — the tombstoned slot may still exist physically, but the id is
    released at remove() time, not at compaction time."""
    rng = np.random.default_rng(6)
    b = IndexBuilder(64, compact_dead_frac=0.9)   # never auto-compact
    b.add(_rep_rows(_small(rng, 8, 6, 64)))
    b.flush()
    assert b.remove([3]) == 1
    assert b.stats()["n_dead"] == 1               # slot not compacted
    m = _small(rng, 1, 6, 64)
    np.testing.assert_array_equal(b.add(_rep_rows(m), ids=[3]), [3])
    q = _rep_rows(m)
    _, ext = b.search(q, 1)
    assert ext[0, 0] == 3                          # the NEW doc 3
    assert b.remove([3]) == 1                      # and it's removable


# ---------------------------------------------------------------------------
# serving integration: CorpusEngine
# ---------------------------------------------------------------------------

def test_corpus_engine_grows_and_searches():
    from repro.retrieval import sparsify_topk as topk
    from repro.runtime.serving import BatchedEncoder, BatchPolicy, \
        CorpusEngine

    def encode(tokens, mask):
        B = tokens.shape[0]
        out = np.zeros((B, 32), np.float32)
        for i in range(B):
            for t, m in zip(np.asarray(tokens[i]), np.asarray(mask[i])):
                if m:
                    out[i, int(t) % 32] += 1
        return topk(jnp.asarray(out), 4)

    eng = CorpusEngine(
        BatchedEncoder(encode, policy=BatchPolicy(max_batch=8)), 32)
    ids = eng.add_docs([np.array([d, d, d], np.int32)
                        for d in range(6)])
    np.testing.assert_array_equal(ids, np.arange(6))
    ids2 = eng.add_docs([np.array([7, 7, 7], np.int32)])
    # query for token 3 -> doc 3 wins
    q = topk(jnp.asarray(np.eye(32, dtype=np.float32)[[3]] * 5), 4)
    vals, ext = eng.search(q, 2)
    assert ext[0, 0] == 3
    eng.remove_docs([3])
    vals, ext = eng.search(q, 2)
    assert 3 not in ext
    assert eng.stats()["n_alive"] == 6
