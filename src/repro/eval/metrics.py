"""Ranking-quality metrics over retrieved-id arrays.

Everything upstream of this module speaks *ids*: ``retrieve()`` /
``IndexBuilder.search`` return ``(vals (B, K), ids (B, K))`` with
``-1`` marking below-top-k padding. This module turns those arrays
plus graded relevance judgments into MRR@k / nDCG@k / recall@k /
success@k — the effectiveness axis that makes ``prune_margin``,
quantization and ``rep_topk`` measurable quality-vs-speed trades
instead of parity-only knobs (ROADMAP "close the loop").

Two implementations of every metric:

* a **host/NumPy reference** (``*_ref``): one query at a time, the
  relevance judgments as a plain ``{doc_id: grade}`` mapping, written
  as the textbook formula with Python loops — the hand-checkable
  ground truth the tests pin the batched path against;
* a **batched JAX path** (``mrr_at_k`` / ``ndcg_at_k`` / ...):
  jit-able over ``(B, K)`` retrieved-id arrays and padded ``(B, R)``
  relevance arrays (``qrels.Qrels.to_arrays``), returning per-query
  ``(B,)`` metric vectors. ``k`` is static; the matching step is one
  ``(B, K, R)`` broadcast compare, so a full method×k sweep stays a
  handful of fused device ops.

Conventions shared by both paths:

* retrieved ids ``< 0`` are padding/tombstones — never a match;
* a judged grade ``<= 0`` means "not relevant" (and pads the arrays);
* nDCG uses **graded exponential gains** ``(2^g - 1) / log2(rank+1)``
  (the TREC/trec_eval form), so grade order matters, not just set
  membership; MRR / recall / success binarize at ``grade > 0``;
* queries with no relevant documents score 0 on every metric.
"""

from __future__ import annotations

import functools
from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

METRIC_NAMES = ("mrr", "ndcg", "recall", "success")


# ---------------------------------------------------------------------------
# host/NumPy reference (one query, judgments as a mapping)
# ---------------------------------------------------------------------------

def mrr_ref(ranked: Sequence[int], rels: Mapping[int, float],
            k: int) -> float:
    """1 / rank of the first relevant doc within the top ``k``."""
    for pos, doc in enumerate(list(ranked)[:k]):
        if doc >= 0 and rels.get(int(doc), 0.0) > 0.0:
            return 1.0 / (pos + 1)
    return 0.0


def ndcg_ref(ranked: Sequence[int], rels: Mapping[int, float],
             k: int) -> float:
    """nDCG@k with graded exponential gains (see module docstring)."""
    def dcg(grades):
        return sum((2.0 ** g - 1.0) / np.log2(pos + 2.0)
                   for pos, g in enumerate(grades))

    got = [max(rels.get(int(d), 0.0), 0.0) if d >= 0 else 0.0
           for d in list(ranked)[:k]]
    ideal = sorted((g for g in rels.values() if g > 0), reverse=True)[:k]
    idcg = dcg(ideal)
    return dcg(got) / idcg if idcg > 0 else 0.0


def recall_ref(ranked: Sequence[int], rels: Mapping[int, float],
               k: int) -> float:
    """|top-k ∩ relevant| / |relevant| (0 when nothing is judged)."""
    relevant = {d for d, g in rels.items() if g > 0}
    if not relevant:
        return 0.0
    hits = {int(d) for d in list(ranked)[:k] if d >= 0} & relevant
    return len(hits) / len(relevant)


def success_ref(ranked: Sequence[int], rels: Mapping[int, float],
                k: int) -> float:
    """1.0 iff any relevant doc appears in the top ``k``."""
    return 1.0 if recall_ref(ranked, rels, k) > 0 else 0.0


REFERENCE = {"mrr": mrr_ref, "ndcg": ndcg_ref, "recall": recall_ref,
             "success": success_ref}


# ---------------------------------------------------------------------------
# batched JAX path (retrieved-id arrays + padded relevance arrays)
# ---------------------------------------------------------------------------

def ranked_grades(ranked_ids: Array, rel_ids: Array,
                  rel_grades: Array) -> Array:
    """Grade of every retrieved doc: ``(B, K)`` from ``(B, K)`` ids
    matched against padded ``(B, R)`` judgments.

    One broadcast compare — retrieved padding (id < 0) and judgment
    padding (grade <= 0) both fall out as grade 0.
    """
    ranked_ids = jnp.asarray(ranked_ids, jnp.int32)
    rel_ids = jnp.asarray(rel_ids, jnp.int32)
    rel_grades = jnp.asarray(rel_grades, jnp.float32)
    match = (ranked_ids[..., :, None] == rel_ids[..., None, :]) \
        & (ranked_ids[..., :, None] >= 0) \
        & (rel_grades[..., None, :] > 0.0)
    return jnp.max(jnp.where(match, rel_grades[..., None, :], 0.0),
                   axis=-1)


def _discounts(k: int) -> Array:
    return 1.0 / jnp.log2(jnp.arange(k, dtype=jnp.float32) + 2.0)


@functools.partial(jax.jit, static_argnames=("k",))
def mrr_at_k(ranked_ids: Array, rel_ids: Array, rel_grades: Array,
             *, k: int) -> Array:
    """Per-query ``(B,)`` reciprocal rank of the first relevant doc."""
    g = ranked_grades(ranked_ids, rel_ids, rel_grades)[..., :k]
    hit = g > 0.0
    first = jnp.argmax(hit, axis=-1)                 # 0 when no hit
    rr = 1.0 / (first.astype(jnp.float32) + 1.0)
    return jnp.where(jnp.any(hit, axis=-1), rr, 0.0)


@functools.partial(jax.jit, static_argnames=("k",))
def ndcg_at_k(ranked_ids: Array, rel_ids: Array, rel_grades: Array,
              *, k: int) -> Array:
    """Per-query ``(B,)`` nDCG@k with graded exponential gains."""
    g = ranked_grades(ranked_ids, rel_ids, rel_grades)[..., :k]
    dcg = jnp.sum((jnp.exp2(g) - 1.0) * _discounts(g.shape[-1]),
                  axis=-1)
    grades = jnp.maximum(jnp.asarray(rel_grades, jnp.float32), 0.0)
    m = min(k, grades.shape[-1])
    ideal = jax.lax.top_k(grades, m)[0]
    idcg = jnp.sum((jnp.exp2(ideal) - 1.0) * _discounts(m), axis=-1)
    return jnp.where(idcg > 0.0, dcg / jnp.maximum(idcg, 1e-30), 0.0)


@functools.partial(jax.jit, static_argnames=("k",))
def recall_at_k(ranked_ids: Array, rel_ids: Array, rel_grades: Array,
                *, k: int) -> Array:
    """Per-query ``(B,)`` fraction of relevant docs in the top k."""
    g = ranked_grades(ranked_ids, rel_ids, rel_grades)[..., :k]
    hits = jnp.sum(g > 0.0, axis=-1).astype(jnp.float32)
    n_rel = jnp.sum(jnp.asarray(rel_grades, jnp.float32) > 0.0,
                    axis=-1).astype(jnp.float32)
    return jnp.where(n_rel > 0.0, hits / jnp.maximum(n_rel, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("k",))
def success_at_k(ranked_ids: Array, rel_ids: Array, rel_grades: Array,
                 *, k: int) -> Array:
    """Per-query ``(B,)`` indicator: any relevant doc in the top k."""
    g = ranked_grades(ranked_ids, rel_ids, rel_grades)[..., :k]
    return jnp.any(g > 0.0, axis=-1).astype(jnp.float32)


BATCHED = {"mrr": mrr_at_k, "ndcg": ndcg_at_k, "recall": recall_at_k,
           "success": success_at_k}


def compute_metrics(ranked_ids, qrels, *, ks: Tuple[int, ...] = (10,),
                    query_ids: Sequence[int] = None,
                    metrics: Tuple[str, ...] = METRIC_NAMES,
                    ) -> Dict[str, float]:
    """Mean metrics over a batch: ``{"mrr@10": 0.83, "ndcg@10": ...}``.

    ``ranked_ids`` is the ``(B, K)`` id array straight out of
    ``retrieve()`` / ``IndexBuilder.search`` (external ids, -1 pads);
    ``qrels`` a :class:`repro.eval.qrels.Qrels`. Row b is scored
    against ``query_ids[b]`` (default: ``qrels.query_ids`` in order —
    the common "one row per judged query" case).
    """
    ranked = np.asarray(ranked_ids)
    rel_ids, rel_grades = qrels.to_arrays(query_ids)
    if ranked.shape[0] != rel_ids.shape[0]:
        raise ValueError(
            f"{ranked.shape[0]} ranking rows for {rel_ids.shape[0]} "
            f"queries — pass query_ids= to align them")
    out: Dict[str, float] = {}
    for k in ks:
        for name in metrics:
            per_q = BATCHED[name](ranked, rel_ids, rel_grades, k=k)
            out[f"{name}@{k}"] = float(jnp.mean(per_q))
    return out
