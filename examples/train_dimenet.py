"""GNN example: train DimeNet on synthetic molecules (CPU-sized).

Exercises the triplet data pipeline (exact triplets + the dense (E, K)
capped layout), the segment-op substrate, and the AdamW training loop.

Run:  PYTHONPATH=src python examples/train_dimenet.py [--steps 60]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.specs import CellSpec
from repro.data.synthetic import molecule_batches
from repro.launch.steps import build_gnn_train_step, init_state
from repro.sparse.triplets import build_triplets, densify_triplets


def make_batch(seed: int, n_graphs=8, nodes=10, edges=24, cap=4):
    gen = molecule_batches(n_graphs=n_graphs, nodes_per_graph=nodes,
                           edges_per_graph=edges, seed=seed)
    b = next(gen)
    N = n_graphs * nodes
    t_in, t_out = build_triplets(b["edge_src"], b["edge_dst"], N,
                                 max_per_edge=cap)
    dense, mask = densify_triplets(t_in, t_out, len(b["edge_src"]), cap)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    batch["t_in_dense"] = jnp.asarray(dense)
    batch["t_mask_dense"] = jnp.asarray(mask)
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_config("dimenet").SMOKE
    state, _ = init_state("dimenet", jax.random.PRNGKey(0), smoke=True)
    cell = CellSpec("dimenet", "molecule", "gnn_train", {}, n_graphs=8)
    step = jax.jit(build_gnn_train_step(cfg, cell, lr=2e-3),
                   donate_argnums=(0,))

    losses = []
    for i in range(args.steps):
        batch = make_batch(seed=i % 8)   # cycle a small dataset
        state, m = step(state, batch)
        if i % 10 == 0:
            losses.append((i, float(m["loss"])))
    print("loss trajectory:", [(s, round(l, 4)) for s, l in losses])
    assert losses[-1][1] < losses[0][1], "no learning"
    print(f"done: {args.steps} steps, final loss {losses[-1][1]:.4f}")


if __name__ == "__main__":
    main()
