"""Fault-tolerant runner + serving loop behaviour (injected faults,
fake clocks — no real devices needed)."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.fault_tolerance import (ElasticMeshManager,
                                           FaultTolerantRunner,
                                           RunnerConfig, StragglerPolicy)
from repro.runtime.serving import (BatchedEncoder, BatchPolicy, Request,
                                   ServingLoop)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _counting_step(durations, clock):
    """A step whose (fake) duration comes from `durations`."""
    it = iter(durations)

    def step(state, batch):
        clock.advance(next(it, 0.1))
        return {"n": state["n"] + 1}, {"loss": 1.0 / (state["n"] + 1)}
    return step


def _batches():
    return itertools.repeat({"x": np.zeros((2,), np.float32)})


def test_runner_runs_and_checkpoints(tmp_path):
    clock = FakeClock()
    runner = FaultTolerantRunner(
        _counting_step([0.1] * 100, clock), {"n": jnp.array(0)},
        _batches(),
        config=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                            max_steps=10, log_every=1),
        clock=clock)
    state = runner.run()
    assert int(state["n"]) == 10
    assert len(runner.metrics_log) == 10
    assert runner.skipped_steps == []
    from repro.checkpoint.store import latest_step
    assert latest_step(str(tmp_path)) == 10


def test_runner_on_step_hook(tmp_path):
    """on_step fires after every successful step with the fresh state;
    a non-empty returned dict lands in metrics_log as its own entry."""
    clock = FakeClock()
    seen = []

    def hook(step, state):
        seen.append((step, int(state["n"])))
        return {"eval_x": step * 10} if step % 3 == 0 else None

    runner = FaultTolerantRunner(
        _counting_step([0.1] * 100, clock), {"n": jnp.array(0)},
        _batches(),
        config=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=0,
                            max_steps=6, log_every=0),
        on_step=hook, clock=clock)
    runner.run()
    # hook saw post-step state: after step i the counter is i+1
    assert seen == [(i, i + 1) for i in range(6)]
    assert runner.metrics_log == [{"step": 0, "eval_x": 0},
                                  {"step": 3, "eval_x": 30}]


def test_runner_on_step_skipped_on_straggler(tmp_path):
    """Straggled (skipped) steps must not fire the hook."""
    clock = FakeClock()
    fired = []
    # steps 0/1 fast (build EWMA), step 2 slow twice (retry + skip)
    durations = [0.1, 0.1, 9.0, 9.0] + [0.1] * 10
    runner = FaultTolerantRunner(
        _counting_step(durations, clock), {"n": jnp.array(0)},
        _batches(),
        config=RunnerConfig(
            ckpt_dir=str(tmp_path), ckpt_every=0, max_steps=5,
            log_every=0,
            straggler=StragglerPolicy(slack=2.0, min_deadline_s=0.05)),
        on_step=lambda s, st: fired.append(s),
        clock=clock)
    runner.run()
    assert runner.skipped_steps == [2]
    assert fired == [0, 1, 3, 4]


def test_runner_resume(tmp_path):
    clock = FakeClock()
    cfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_steps=5)
    r1 = FaultTolerantRunner(_counting_step([0.1] * 50, clock),
                             {"n": jnp.array(0)}, _batches(),
                             config=cfg, clock=clock)
    r1.run()
    # second run resumes at 5 and continues to 8
    cfg2 = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                        max_steps=8)
    r2 = FaultTolerantRunner(_counting_step([0.1] * 50, clock),
                             {"n": jnp.array(0)}, _batches(),
                             config=cfg2, clock=clock)
    assert r2.try_resume()
    assert r2.start_step == 5
    state = r2.run()
    assert int(state["n"]) == 8


def test_straggler_detection_and_skip(tmp_path):
    clock = FakeClock()
    # establish ~0.1s EWMA, then two huge stalls (initial + retry) => skip
    durations = [0.1] * 5 + [99.0, 99.0] + [0.1] * 20
    policy = StragglerPolicy(slack=3.0, max_retries=1,
                             suspect_threshold=100)
    runner = FaultTolerantRunner(
        _counting_step(durations, clock), {"n": jnp.array(0)},
        _batches(),
        config=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=0,
                            max_steps=10, straggler=policy),
        clock=clock)
    state = runner.run()
    assert runner.skipped_steps == [5]
    # the skipped step consumed a batch but not an update
    assert int(state["n"]) == 9


def test_remesh_triggered_after_repeated_suspects(tmp_path):
    clock = FakeClock()
    durations = [0.1] * 3 + [50.0, 50.0] * 3 + [0.1] * 30
    policy = StragglerPolicy(slack=3.0, max_retries=1, suspect_threshold=3)
    remesh_calls = []

    def on_remesh(state):
        remesh_calls.append(True)
        return _counting_step([0.1] * 50, clock), state

    runner = FaultTolerantRunner(
        _counting_step(durations, clock), {"n": jnp.array(0)},
        _batches(),
        config=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=0,
                            max_steps=12, straggler=policy),
        on_remesh=on_remesh, clock=clock)
    runner.run()
    assert len(remesh_calls) == 1
    assert len(runner.remesh_events) == 1


def test_step_exception_counts_as_failure(tmp_path):
    clock = FakeClock()
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        clock.advance(0.1)
        if calls["n"] == 3:
            raise RuntimeError("device lost")
        return {"n": state["n"] + 1}, {"loss": 0.0}

    runner = FaultTolerantRunner(
        flaky, {"n": jnp.array(0)}, _batches(),
        config=RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=0,
                            max_steps=6),
        clock=clock)
    state = runner.run()
    assert len(runner.skipped_steps) == 1
    assert int(state["n"]) == 5


def test_elastic_mesh_factorization():
    mgr = ElasticMeshManager(lambda shape: shape, model_axis=16)
    assert mgr.factorize(512) == (1, 32, 16)
    assert mgr.factorize(256) == (1, 16, 16)
    assert mgr.factorize(255) == (1, 8, 16)   # lost a device
    assert mgr.factorize(24) == (1, 1, 16)
    assert mgr.factorize(8) == (1, 1, 8)
    assert mgr.factorize(1) == (1, 1, 1)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _fake_encoder():
    def encode(tokens, mask):
        # "sparse rep" = bag of token counts over a fake 32-dim vocab
        B, S = tokens.shape
        out = np.zeros((B, 32), np.float32)
        for i in range(B):
            for t, m in zip(np.asarray(tokens[i]), np.asarray(mask[i])):
                if m:
                    out[i, int(t) % 32] += 1
        return out
    return encode


def test_serving_loop_batches_by_size():
    clock = FakeClock()
    enc = BatchedEncoder(_fake_encoder(),
                         policy=BatchPolicy(max_batch=4, max_wait_s=10.0))
    loop = ServingLoop(enc, clock=clock)
    for uid in range(10):
        loop.submit(Request(uid=uid, tokens=np.array([uid], np.int32)))
        loop.tick()
    loop.drain()
    assert len(loop.completed) == 10
    assert loop.batch_sizes[0] == 4  # size-triggered batches first
    assert sum(loop.batch_sizes) == 10


def test_serving_loop_deadline_trigger():
    clock = FakeClock()
    enc = BatchedEncoder(_fake_encoder(),
                         policy=BatchPolicy(max_batch=64, max_wait_s=0.005))
    loop = ServingLoop(enc, clock=clock)
    loop.submit(Request(uid=0, tokens=np.array([3], np.int32)))
    assert loop.tick() == 0        # too fresh
    clock.advance(0.01)
    assert loop.tick() == 1        # deadline hit, dispatched alone
    assert 0 in loop.completed


def test_take_pops_results_memory_bounded():
    """Results must be popped on read: a long-lived loop whose caller
    collects every result holds nothing afterwards — memory is bounded
    by in-flight work, not total traffic."""
    clock = FakeClock()
    enc = BatchedEncoder(_fake_encoder(),
                         policy=BatchPolicy(max_batch=4, max_wait_s=0.0))
    loop = ServingLoop(enc, clock=clock)
    high_water = 0
    for uid in range(64):
        loop.submit(Request(uid=uid, tokens=np.array([uid], np.int32)))
        clock.advance(0.01)
        loop.tick()
        high_water = max(high_water, len(loop.completed))
        if uid % 4 == 3:           # collect the finished micro-batch
            for u in range(uid - 3, uid + 1):
                rep = loop.take(u)
                assert rep.shape == (32,)
            assert len(loop.completed) == 0
    loop.drain()
    # never accumulated more than one dispatched batch
    assert high_water <= 4
    assert len(loop.completed) == 0


def test_take_raises_on_missing_and_double_take():
    clock = FakeClock()
    enc = BatchedEncoder(_fake_encoder(),
                         policy=BatchPolicy(max_batch=1, max_wait_s=0.0))
    loop = ServingLoop(enc, clock=clock)
    loop.submit(Request(uid=7, tokens=np.array([1], np.int32)))
    clock.advance(1.0)
    loop.tick()
    loop.take(7)
    with pytest.raises(KeyError):
        loop.take(7)               # a result is never handed out twice
    with pytest.raises(KeyError):
        loop.take(8)               # never completed


def test_serving_pads_and_masks_correctly():
    enc = BatchedEncoder(_fake_encoder(),
                         policy=BatchPolicy(pad_to_multiple=8))
    reqs = [Request(uid=0, tokens=np.array([1, 1, 1], np.int32)),
            Request(uid=1, tokens=np.array([2], np.int32))]
    out = enc.encode_batch(reqs)
    assert out[0][1] == 3.0   # three 1-tokens counted, padding ignored
    assert out[1][2] == 1.0
    assert out[1][0] == 0.0   # pad token 0 masked out
