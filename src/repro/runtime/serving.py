"""Serving runtime: batched sparse-encoding + retrieval, hardened.

The LSR serving path has two stages, both built on the paper's
machinery:

1. **Encode** — requests (token sequences) are micro-batched by a
   deadline/size policy and pushed through backbone + Sparton head
   (inference forward only stores the reduced (B, V) output — the
   paper's memory win applies to serving too; the argmax indices
   double as term-level attributions). With the config's rep knobs set
   (``rep_topk``/``rep_threshold``), the output is sparsified on
   device and each request completes as a ``SparseRep`` — only
   ``(B, K)`` crosses to host, never the dense ``(B, V)`` rep.
2. **Retrieve** — encoded queries score a candidate corpus through
   ``repro.retrieval.retrieve``: the inverted impact index is the
   sparse-native production path, the fused streaming kernel
   (``kernels.topk_score``) covers dense 1M-candidate workloads, and
   the dense einsum remains the tested fallback.

``ServingLoop`` is synchronous-deterministic (tests and the traffic
simulation drive it tick by tick). On top of the PR-3 micro-batching
it now carries the production-hardening layer (DESIGN.md §10):

* **SLO admission + shedding** — a ``Request`` may carry a relative
  ``deadline_s``. ``submit`` sheds (``Admission.SHED``) when the queue
  is full or the estimated queue delay (EWMA encode time × batches
  ahead) already blows the deadline; ``tick`` drops expired requests
  *before* wasting an encode. Shed requests complete with a
  ``ShedResult`` so callers never hang on ``take``.
* **Poison-batch isolation** — when ``encode_fn`` raises, the batch is
  bisect-retried to isolate the failing request(s): clean halves are
  served, the poisoned uid(s) fail with a structured ``FailedResult``,
  and ``tick`` never raises. OOM-shaped errors halve the adaptive
  batch cap (PowerAdaptativeBatcher's recovery move); the cap grows
  back after ``BatchPolicy.grow_after_clean`` clean dispatches.
* **Degradation ladder** — an attached ``DegradeController`` converts
  sustained queue pressure into retrieval-quality downshifts
  (exact → pruned → aggressive margins, shrinking query width) with
  hysteresis; retrieval callers read ``search_kwargs()`` /
  ``q_width()`` off the controller per request.
* **Observable health** — ``stats()`` reports queue depth,
  served/shed/failed counters, batch occupancy and the adaptive cap,
  p50/p99 latency over a bounded rolling reservoir, and the degrade
  state.
* **Continuous batching** (``continuous=True``, DESIGN.md §13) —
  instead of FIFO one-batch-per-tick, pending requests are admitted
  into the next batch in earliest-deadline-first order, and a tick
  dispatches when the batch fills **or** the most urgent deadline
  would no longer survive waiting (its slack has shrunk to one EWMA
  encode time), not just when the oldest request has waited
  ``max_wait_s``. Urgent requests jump the queue instead of expiring
  behind patient ones, so under mixed-SLO load the same encoder
  serves strictly more. All PR-6 guarantees hold unchanged: every
  uid completes exactly once (served/shed/failed) and ``tick`` never
  raises.

Completed results are handed out by ``take(uid)``, which *pops* — the
loop holds no reference after the caller reads a result, so memory is
bounded by in-flight work plus the fixed stats windows, not by total
traffic.

``CorpusEngine`` is the online-corpus half: it feeds document batches
through the same batched encoder into an incremental
``engine.IndexBuilder`` (add/remove/flush with tombstones and
compaction — DESIGN.md §8.4), so the served corpus grows online
instead of being rebuilt from scratch.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.faults import is_oom_error

Array = jax.Array


def make_config_encoder(params: Any, cfg: Any, *, spec: Any = None,
                        mesh: Any = None, jit: bool = True
                        ) -> Callable[[Array, Array], Any]:
    """Canonical ``(tokens, mask) -> reps`` encode fn from a config.

    The single serving-side seam over the unified head API: the
    encoder is built by ``make_encoder`` from ``cfg.head_spec()`` (or
    an explicit ``spec``), so ``head_impl``, pinned/autotuned blocks,
    ``final_logit_softcap`` AND the rep-sparsification knobs are all
    honored — serving paths must not hardcode a head implementation.
    Output is a ``SparseRep`` when the spec sets ``rep_topk`` /
    ``rep_threshold``, else the dense ``(B, V)`` array.
    """
    from repro.core.head_api import make_encoder
    from repro.models import transformer as tfm

    enc = make_encoder(spec if spec is not None else cfg.head_spec(),
                       mesh=mesh)

    def encode(tokens: Array, mask: Array):
        Hs, _ = tfm.forward_hidden(params, cfg, tokens, mask)
        E, b = tfm.head_weights(params, cfg)
        return enc(Hs, E.astype(Hs.dtype), b, mask)

    return jax.jit(encode) if jit else encode


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray          # (len,) int32
    arrival_t: float = 0.0
    deadline_s: Optional[float] = None   # relative SLO; None = best-effort


class Admission(enum.Enum):
    """``submit``'s verdict — SHED means the request was rejected up
    front and completed immediately with a ``ShedResult``."""
    ACCEPTED = "accepted"
    SHED = "shed"


@dataclasses.dataclass
class ShedResult:
    """Completion record for a request the loop refused to encode.

    ``reason`` is ``"queue_full"`` / ``"est_deadline"`` (admission
    control) or ``"expired"`` (deadline passed while queued).
    """
    uid: int
    reason: str
    waited_s: float = 0.0


@dataclasses.dataclass
class FailedResult:
    """Completion record for a request whose encode raised even in
    isolation (a poison request). ``oom`` marks OOM-shaped errors."""
    uid: int
    error: str
    oom: bool = False


@dataclasses.dataclass
class BatchPolicy:
    max_batch: int = 32
    max_wait_s: float = 0.005
    pad_to_multiple: int = 16
    # clean dispatches before a fault-halved batch cap doubles back up
    grow_after_clean: int = 4


@dataclasses.dataclass
class AdmissionPolicy:
    """When ``submit`` says no.

    ``max_queue_depth`` is the hard backpressure bound; the deadline
    estimate sheds earlier: a request whose ``deadline_s`` is already
    beaten by ``safety ×`` the estimated queue delay (EWMA encode time
    per batch × batches ahead of it) is rejected at submit time rather
    than queued to expire.
    """
    max_queue_depth: int = 1024
    safety: float = 1.0


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DegradeStep:
    """One rung: retrieval kwargs plus a query-width fraction.

    ``search_kwargs`` feed ``CorpusEngine.search`` /
    ``IndexBuilder.search`` (method + prune_margin); ``q_width_frac``
    scales the encode-side rep width (``q_width=`` in search truncates
    the query rep to its largest terms — fewer postings touched).
    """
    name: str
    search_kwargs: Dict[str, Any] = dataclasses.field(
        default_factory=dict)
    q_width_frac: float = 1.0


DEFAULT_LADDER: Tuple[DegradeStep, ...] = (
    DegradeStep("exact"),
    DegradeStep("pruned", {"method": "pruned", "prune_margin": 0.0}),
    DegradeStep("aggressive",
                {"method": "pruned", "prune_margin": 0.5}, 0.5),
    DegradeStep("minimal",
                {"method": "pruned", "prune_margin": 1.0}, 0.25),
)


@dataclasses.dataclass
class DegradePolicy:
    """Hysteresis thresholds for the ladder state machine.

    Pressure is ``max(est_queue_delay / slo, depth / max_queue,
    recent_shed_fraction)`` — dimensionless, 1.0 = the queue already
    costs a full SLO (or every recent submit bounced). The shed term
    matters under hard overload: admission shedding keeps the *queue*
    healthy, so queue-derived terms alone sit just under threshold
    while most traffic is refused. The
    controller steps *down* the ladder (degrades) after ``up_ticks``
    consecutive ticks above ``high`` and climbs back one rung after
    ``down_ticks`` consecutive ticks below ``low``; the band between
    the thresholds and the longer recovery streak are the hysteresis
    that stops flapping at the boundary.
    """
    slo_s: float = 0.1          # pressure reference when requests
                                # carry no deadline of their own
    high: float = 0.8
    low: float = 0.3
    up_ticks: int = 3
    down_ticks: int = 10
    ladder: Tuple[DegradeStep, ...] = DEFAULT_LADDER


class DegradeController:
    """The ladder state machine: feed it pressure, read the rung.

    ``observe(pressure)`` is called once per loop tick;
    ``search_kwargs()`` / ``q_width(base)`` expose the current rung to
    retrieval callers. ``transitions`` records ``(tick, from, to)``
    and ``ticks_at_level`` the dwell time per rung — both surface in
    ``ServingLoop.stats()`` and the serving bench.
    """

    def __init__(self, policy: Optional[DegradePolicy] = None):
        self.policy = policy or DegradePolicy()
        if not self.policy.ladder:
            raise ValueError("DegradePolicy.ladder must be non-empty")
        self.level = 0
        self.transitions: List[Tuple[int, int, int]] = []
        self.ticks_at_level = [0] * len(self.policy.ladder)
        self._tick = 0
        self._high_streak = 0
        self._low_streak = 0

    @property
    def step(self) -> DegradeStep:
        return self.policy.ladder[self.level]

    def search_kwargs(self) -> Dict[str, Any]:
        return dict(self.step.search_kwargs)

    def q_width(self, base_width: int) -> int:
        return max(1, int(base_width * self.step.q_width_frac))

    def observe(self, pressure: float) -> int:
        """One tick's pressure sample; returns the (possibly new)
        level. Mid-band samples reset both streaks — only *sustained*
        pressure moves the ladder."""
        pol = self.policy
        self._tick += 1
        self.ticks_at_level[self.level] += 1
        if pressure > pol.high:
            self._high_streak += 1
            self._low_streak = 0
        elif pressure < pol.low:
            self._low_streak += 1
            self._high_streak = 0
        else:
            self._high_streak = 0
            self._low_streak = 0
        if (self._high_streak >= pol.up_ticks
                and self.level < len(pol.ladder) - 1):
            self._move(self.level + 1)
            self._high_streak = 0
        elif self._low_streak >= pol.down_ticks and self.level > 0:
            self._move(self.level - 1)
            self._low_streak = 0
        return self.level

    def _move(self, to: int) -> None:
        self.transitions.append((self._tick, self.level, to))
        self.level = to

    def stats(self) -> Dict[str, Any]:
        return {
            "degrade_level": self.level,
            "degrade_name": self.step.name,
            "degrade_transitions": len(self.transitions),
            "degrade_ticks_at_level": list(self.ticks_at_level),
        }


class BatchedEncoder:
    """Pads + batches requests and runs the jitted encode fn.

    ``encode_fn(tokens (B, S), mask (B, S)) -> reps`` — either a dense
    ``(B, V)`` array or a batched ``SparseRep``; results are split per
    request (numpy row / single-row rep). Bucket padding: sequences are
    padded to the next multiple of ``pad_to_multiple`` so the jit
    cache stays small.
    """

    def __init__(self, encode_fn: Callable[[Array, Array], Any],
                 *, policy: Optional[BatchPolicy] = None):
        self.encode_fn = encode_fn
        self.policy = policy or BatchPolicy()

    def _pad_len(self, n: int) -> int:
        m = self.policy.pad_to_multiple
        return max(m, ((n + m - 1) // m) * m)

    def encode_batch(self, reqs: Sequence[Request]) -> Dict[int, Any]:
        if not reqs:
            return {}
        S = self._pad_len(max(len(r.tokens) for r in reqs))
        B = len(reqs)
        toks = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            n = len(r.tokens)
            toks[i, :n] = r.tokens
            mask[i, :n] = 1
        reps = self.encode_fn(jnp.asarray(toks), jnp.asarray(mask))
        from repro.retrieval.sparse_rep import SparseRep, split_rows

        if isinstance(reps, SparseRep):
            rows: Sequence[Any] = split_rows(reps)
        else:
            rows = np.asarray(reps)
        return {r.uid: rows[i] for i, r in enumerate(reqs)}


class ServingLoop:
    """Deadline/size micro-batching with admission control, fault
    isolation, and degrade signalling (module docstring).

    Contracts:

    * ``tick`` dispatches **at most one batch** per call (expiry
      shedding aside) — schedulers interleave ticks with arrivals and
      tests stay deterministic. ``drain`` loops forced ticks and is
      guaranteed to terminate: every forced tick either sheds expired
      requests or dispatches one batch, so ``pending`` strictly
      shrinks.
    * ``tick`` never raises on encode failure: faults are bisected
      down to the poisoned request(s), which complete as
      ``FailedResult``.
    * ``completed`` holds results only until the caller collects them
      with ``take(uid)`` — results are popped on read. The stats
      windows (``batch_sizes``, the latency reservoir) are bounded
      deques, so a long-lived loop's memory stays bounded by in-flight
      work.
    """

    def __init__(self, encoder: BatchedEncoder,
                 *, clock: Callable[[], float] = time.monotonic,
                 admission: Optional[AdmissionPolicy] = None,
                 degrade: Optional[DegradeController] = None,
                 continuous: bool = False,
                 ewma_alpha: float = 0.2,
                 window: int = 512,
                 shed_window: int = 64):
        self.encoder = encoder
        self.clock = clock
        self.continuous = continuous
        self.admission = admission or AdmissionPolicy()
        self.degrade = degrade
        self.pending: List[Request] = []
        self.completed: Dict[int, Any] = {}
        # bounded rolling windows (stats inputs) — a long-lived loop
        # must not grow with total traffic
        self.batch_sizes: collections.deque = collections.deque(
            maxlen=window)
        self._latencies: collections.deque = collections.deque(
            maxlen=window)
        # recent admission/expiry outcomes (1 = shed, 0 = accepted):
        # the shed fraction is a pressure signal — admission shedding
        # keeps the *queue* healthy, so queue depth alone under-reports
        # overload; what was refused must still push the degrade ladder
        self._shed_marks: collections.deque = collections.deque(
            maxlen=max(1, shed_window))
        self._ewma_alpha = ewma_alpha
        self._encode_ewma: Optional[float] = None   # s per dispatch
        self._batch_cap = self.encoder.policy.max_batch
        self._clean_batches = 0
        self.counters: collections.Counter = collections.Counter()

    # -- admission -------------------------------------------------------

    def _effective_cap(self) -> int:
        return max(1, min(self.encoder.policy.max_batch,
                          self._batch_cap))

    def estimated_queue_delay(self, depth: Optional[int] = None
                              ) -> float:
        """EWMA encode time × batches ahead — 0 until the first
        dispatch establishes a baseline."""
        if depth is None:
            depth = len(self.pending)
        if self._encode_ewma is None or depth <= 0:
            return 0.0
        batches = -(-depth // self._effective_cap())
        return batches * self._encode_ewma

    def submit(self, req: Request) -> Admission:
        req.arrival_t = self.clock()
        self.counters["submitted"] += 1
        if len(self.pending) >= self.admission.max_queue_depth:
            return self._shed(req, "queue_full")
        # Never starve: an idle server always accepts. The delay
        # estimate is a lagging EWMA — if it went stale above the
        # deadline (e.g. after an overload at full batches), shedding
        # on an empty queue would wedge the loop at 100% shed with no
        # dispatch left to refresh the estimate.
        if req.deadline_s is not None and self.pending:
            if self.continuous:
                # EDF admission: this request only waits behind
                # pending work that is at least as urgent
                key = self._edf_key(req)
                ahead = sum(1 for p in self.pending
                            if self._edf_key(p) <= key)
                est = self.estimated_queue_delay(ahead + 1)
            else:
                est = self.estimated_queue_delay(len(self.pending) + 1)
            if self.admission.safety * est > req.deadline_s:
                return self._shed(req, "est_deadline")
        self.pending.append(req)
        self._shed_marks.append(0)
        return Admission.ACCEPTED

    def _shed(self, req: Request, reason: str) -> Admission:
        key = ("shed_expired" if reason == "expired"
               else "shed_admission")
        self.counters[key] += 1
        self._shed_marks.append(1)
        self.completed[req.uid] = ShedResult(
            req.uid, reason, waited_s=self.clock() - req.arrival_t)
        return Admission.SHED

    # -- results ---------------------------------------------------------

    def take(self, uid: int) -> Any:
        """Pop and return the completed record for ``uid`` — the
        encoded rep when served, else a ``ShedResult`` /
        ``FailedResult``. Raises ``KeyError`` when the request hasn't
        completed (or was already taken) — the loop never hands out a
        result twice."""
        return self.completed.pop(uid)

    def latencies(self) -> np.ndarray:
        """Served latencies in the bounded rolling reservoir (s)."""
        return np.asarray(self._latencies, np.float64)

    # -- the loop --------------------------------------------------------

    @staticmethod
    def _edf_key(r: Request) -> Tuple[float, float, int]:
        """Earliest-deadline-first order: absolute deadline (best-
        effort requests sort last), then arrival, then uid — a total
        order, so batch selection is deterministic."""
        dl = (r.arrival_t + r.deadline_s if r.deadline_s is not None
              else float("inf"))
        return (dl, r.arrival_t, r.uid)

    def _should_dispatch(self, now: float, *, force: bool) -> bool:
        """The dispatch trigger: forced, full batch, oldest wait over
        ``max_wait_s``, or (continuous mode) the most urgent pending
        deadline's slack has shrunk to one EWMA encode time — waiting
        any longer would expire it."""
        if not self.pending:
            return False
        if force:
            return True
        if len(self.pending) >= self._effective_cap():
            return True
        oldest_wait = now - min(r.arrival_t for r in self.pending)
        if oldest_wait >= self.encoder.policy.max_wait_s:
            return True
        if self.continuous:
            urgent = min((r.arrival_t + r.deadline_s
                          for r in self.pending
                          if r.deadline_s is not None),
                         default=None)
            if urgent is not None and (
                    urgent - now <= (self._encode_ewma or 0.0)):
                return True
        return False

    def ready(self, *, force: bool = False) -> bool:
        """Would ``tick`` dispatch a batch right now? A non-mutating
        probe for schedulers (``TenantPool``) that must pick one loop
        to tick without side effects. Expired-but-still-queued
        requests count toward readiness — the tick that follows sheds
        them first and may then dispatch nothing."""
        return bool(self.pending) and (
            force or self._should_dispatch(self.clock(), force=False))

    def _drop_expired(self, now: float) -> int:
        """Shed queued requests whose deadline already passed — before
        an encode is wasted on them."""
        if not any(r.deadline_s is not None for r in self.pending):
            return 0
        keep, dropped = [], 0
        for r in self.pending:
            if (r.deadline_s is not None
                    and now - r.arrival_t > r.deadline_s):
                self._shed(r, "expired")
                dropped += 1
            else:
                keep.append(r)
        self.pending = keep
        return dropped

    def _pressure(self) -> float:
        slos = [r.deadline_s for r in self.pending
                if r.deadline_s is not None]
        slo = min(slos) if slos else (
            self.degrade.policy.slo_s if self.degrade else 0.1)
        delay_term = (self.estimated_queue_delay() / slo
                      if slo > 0 else 0.0)
        depth_term = (len(self.pending)
                      / max(1, self.admission.max_queue_depth))
        # fraction of recent submissions shed (admission or expiry):
        # under hard overload admission holds the queue at ~one batch,
        # so the queue-derived terms sit just under threshold — the
        # refused traffic is the honest overload signal
        shed_term = (sum(self._shed_marks) / len(self._shed_marks)
                     if self._shed_marks else 0.0)
        return max(delay_term, depth_term, shed_term)

    def _encode_isolated(self, batch: List[Request]
                         ) -> Tuple[Dict[int, Any], bool]:
        """Encode with bisect isolation: a failing batch is split in
        halves and retried until the poison request(s) stand alone;
        those fail structurally, everyone else is served. OOM-shaped
        errors additionally halve the adaptive batch cap."""
        results: Dict[int, Any] = {}
        had_fault = False

        def run(reqs: List[Request]) -> None:
            nonlocal had_fault
            try:
                results.update(self.encoder.encode_batch(reqs))
                return
            except Exception as e:      # noqa: BLE001 — tick never raises
                had_fault = True
                self.counters["faults"] += 1
                oom = is_oom_error(e)
                if oom:
                    self.counters["oom_faults"] += 1
                    self._batch_cap = max(1, self._effective_cap() // 2)
                    self._clean_batches = 0
                if len(reqs) == 1:
                    r = reqs[0]
                    results[r.uid] = FailedResult(r.uid, error=repr(e),
                                                  oom=oom)
                    self.counters["failed"] += 1
                    return
                mid = len(reqs) // 2
                run(reqs[:mid])
                run(reqs[mid:])

        run(batch)
        return results, had_fault

    def tick(self, *, force: bool = False) -> int:
        """Shed expired requests, then dispatch **at most one** batch
        if the size/deadline policy (or ``force``) triggers. Returns
        the dispatched batch size. Never raises on encode faults."""
        pol = self.encoder.policy
        now = self.clock()
        self._drop_expired(now)
        if self.degrade is not None:
            self.degrade.observe(self._pressure())
        if not self._should_dispatch(now, force=force):
            return 0
        cap = self._effective_cap()
        if self.continuous:
            # admit the cap most urgent requests into this batch
            order = sorted(range(len(self.pending)),
                           key=lambda i: self._edf_key(self.pending[i]))
            chosen = set(order[:cap])
            batch = [self.pending[i] for i in order[:cap]]
            self.pending = [r for i, r in enumerate(self.pending)
                            if i not in chosen]
        else:
            batch = self.pending[:cap]
            self.pending = self.pending[cap:]
        t0 = self.clock()
        results, had_fault = self._encode_isolated(batch)
        dt = self.clock() - t0
        a = self._ewma_alpha
        self._encode_ewma = (dt if self._encode_ewma is None
                             else (1 - a) * self._encode_ewma + a * dt)
        self.completed.update(results)
        done = self.clock()
        for r in batch:
            if not isinstance(results[r.uid], FailedResult):
                self.counters["served"] += 1
                self._latencies.append(done - r.arrival_t)
        self.batch_sizes.append(len(batch))
        if had_fault:
            self._clean_batches = 0
        else:
            self._clean_batches += 1
            if (self._batch_cap < pol.max_batch
                    and self._clean_batches >= pol.grow_after_clean):
                self._batch_cap = min(pol.max_batch,
                                      self._batch_cap * 2)
                self._clean_batches = 0
        return len(batch)

    def drain(self) -> None:
        """Force-dispatch until the queue is empty. One batch per
        forced tick (the tick contract); every iteration strictly
        shrinks ``pending`` (a dispatch or expiry sheds), so this
        always terminates."""
        while self.pending:
            before = len(self.pending)
            self.tick(force=True)
            if len(self.pending) >= before:   # pragma: no cover
                raise RuntimeError("tick(force=True) made no progress")

    # -- observability ---------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """Health snapshot: queue, outcome counters, batch occupancy,
        adaptive cap, p50/p99 latency over the bounded reservoir, and
        the degrade state when a controller is attached."""
        c = self.counters
        pol = self.encoder.policy
        lat = self.latencies()
        occupancy = (float(np.mean(self.batch_sizes))
                     / max(1, pol.max_batch)
                     if self.batch_sizes else 0.0)
        d: Dict[str, Any] = {
            "queue_depth": len(self.pending),
            "submitted": c["submitted"],
            "served": c["served"],
            "shed": c["shed_admission"] + c["shed_expired"],
            "shed_admission": c["shed_admission"],
            "shed_expired": c["shed_expired"],
            "failed": c["failed"],
            "faults": c["faults"],
            "oom_faults": c["oom_faults"],
            "batch_cap": self._effective_cap(),
            "continuous": self.continuous,
            "batch_occupancy": round(occupancy, 4),
            "encode_ewma_s": self._encode_ewma or 0.0,
            "p50_latency_s": (float(np.percentile(lat, 50))
                              if lat.size else 0.0),
            "p99_latency_s": (float(np.percentile(lat, 99))
                              if lat.size else 0.0),
        }
        if self.degrade is not None:
            d.update(self.degrade.stats())
        return d


class CorpusEngine:
    """Online corpus for the serving loop: encode + index + search.

    Couples a ``BatchedEncoder`` (documents go through the same
    batched encode path as queries) with an ``engine.IndexBuilder``,
    so the corpus grows and shrinks *while serving* instead of being
    frozen at build time:

        eng = CorpusEngine(encoder, vocab_size, quantize=True)
        ids = eng.add_docs(token_arrays)       # encode + buffer
        eng.remove_docs(ids[:3])               # tombstone
        vals, ext_ids = eng.search(q_rep, k)   # flushes, then scores

    ``search`` returns stable *external* doc ids (the ids ``add_docs``
    handed out), surviving compactions. ``keep_forward=True`` enables
    the pruned path (``search(..., method="pruned")``); with
    ``quantize=True`` the base segment is served compressed.
    ``method="fused"`` scores base and delta inside the fused Pallas
    kernel (in-kernel u4 dequant when the base is quantized, the exact
    psum path when it is term-sharded — ids identical either way).

    ``shard_axis``/``n_shards`` pick the base segment's partitioning:
    ``"doc"`` leaves the base a single index (doc sharding is a
    serving-topology choice, not a builder one), ``"term"`` serves it
    as a ``TermShardedIndex`` over ``n_shards`` vocab ranges — the
    large-|V| regime where per-term posting arrays outgrow one HBM
    (DESIGN.md §9). ``plan=`` (a ``ShardPlan`` from
    ``engine.shard2d.plan_placement``) supersedes both: the plan's
    term axis sets the vocab ranges and a genuinely 2D grid serves
    the base as a ``Shard2DIndex`` (DESIGN.md §14).
    """

    def __init__(self, encoder: "BatchedEncoder", vocab_size: int, *,
                 quantize: bool = False, keep_forward: bool = False,
                 merge_frac: float = 0.25,
                 compact_dead_frac: float = 0.25,
                 shard_axis: str = "doc", n_shards: int = 1,
                 plan=None):
        from repro.retrieval.engine import IndexBuilder

        if plan is not None:
            if shard_axis != "doc" or n_shards != 1:
                raise ValueError(
                    "pass either plan= or shard_axis/n_shards, not "
                    "both — the plan carries the shard topology")
            self.builder_kwargs = {"plan": plan}
        else:
            if shard_axis not in ("doc", "term"):
                raise ValueError(f"shard_axis must be 'doc' or "
                                 f"'term', got {shard_axis!r}")
            self.builder_kwargs = {
                "term_shards": n_shards if shard_axis == "term" else 0}
        self.encoder = encoder
        self.plan = plan
        self.builder = IndexBuilder(
            vocab_size, quantize=quantize, keep_forward=keep_forward,
            merge_frac=merge_frac, compact_dead_frac=compact_dead_frac,
            **self.builder_kwargs)
        self._next_uid = 0

    def add_docs(self, docs: Sequence[np.ndarray],
                 ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Encode token arrays through the batched encoder and buffer
        them into the index; returns their external doc ids.

        Documents are chunked by the encoder's ``policy.max_batch``
        (the policy governs document encoding exactly as it governs
        query micro-batching — one giant batch would blow the jit
        cache and device memory). The first chunk's rows are
        type-checked *before* the remaining chunks are encoded, so a
        misconfigured (dense) encoder fails fast instead of after
        burning encode time on the whole corpus."""
        from repro.retrieval.sparse_rep import SparseRep, stack_rows

        rows = []
        chunk = max(1, self.encoder.policy.max_batch)
        docs = list(docs)
        for lo in range(0, len(docs), chunk):
            reqs = []
            for tokens in docs[lo:lo + chunk]:
                reqs.append(Request(uid=self._next_uid,
                                    tokens=np.asarray(tokens, np.int32)))
                self._next_uid += 1
            by_uid = self.encoder.encode_batch(reqs)
            chunk_rows = [by_uid[r.uid] for r in reqs]
            if not all(isinstance(r, SparseRep) for r in chunk_rows):
                raise ValueError(
                    "CorpusEngine needs a sparse encoder — set the "
                    "config's rep_topk/rep_threshold knobs so encode "
                    "emits SparseReps")
            rows.extend(chunk_rows)
        if not rows:
            return np.zeros(0, np.int64)
        return self.builder.add(stack_rows(rows), ids=ids)

    def remove_docs(self, ids: Sequence[int]) -> int:
        return self.builder.remove(ids)

    def flush(self, **kw) -> None:
        self.builder.flush(**kw)

    def search(self, queries, k: int = 10, *, method: str = "auto",
               **kw) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k with external ids. Accepts the degrade-ladder knobs
        (``prune_margin``, ``q_width``) via ``IndexBuilder.search``."""
        return self.builder.search(queries, k, method=method, **kw)

    def stats(self) -> Dict[str, float]:
        return self.builder.stats()


def retrieve_topk(
    q_reps: Array,          # (B, V) query reps (dense or SparseRep)
    doc_matrix: Array,      # (N, V) document reps (or (N, D) dense)
    k: int = 10,
) -> Tuple[Array, Array]:
    """Dense-fallback retrieval: scores + top-k doc ids.

    Back-compat shim over the unified dispatcher — new code should
    call ``repro.retrieval.retrieve(queries, corpus, k, method=...)``
    directly (which also serves the inverted-index and streaming-kernel
    paths).
    """
    from repro.retrieval.score import retrieve

    return retrieve(q_reps, doc_matrix, k, method="dense")
