"""Sparton fused LM-head backward — Pallas TPU kernels.

The paper's Alg. 3 computes, per (b, v), the activation-derivative
factor ``g`` and scatters ``g*E[v]`` into ``dH[b, i_max]`` / gathers
``H[b, i_max]`` into ``dE[v]`` using *atomic* accumulation across GPU
thread blocks. TPU Pallas has no atomics; instead we exploit the
sequential grid to accumulate deterministically (DESIGN.md §3):

* ``dH`` kernel — grid ``(B/bb, S/bs, V/bv)``, vocab innermost: each
  ``(b, s)`` tile of ``dH`` is revisited across vocab blocks and
  accumulates ``sum_v g[b,v] * onehot(i_max[b,v], s) * E[v]``.
* ``dE`` kernel — grid ``(V/bv, B/bb, S/bs)``, batch/seq innermost:
  each vocab tile of ``dE`` accumulates
  ``sum_b g[b,v] * onehot(i_max[b,v], s) * H[b,s]``.

Gather/scatter by ``i_max`` is re-expressed as a *one-hot contraction*
(``onehot(i_max) @ E`` / ``(onehot*g)^T @ H``) so the irregular memory
access becomes an MXU matmul — the TPU-native replacement for GPU
scattered atomics. Positions whose argmax falls outside the current
sequence block simply produce an all-zero one-hot row, which is what
routes each gradient to exactly one sequence block.

``g`` (the derivative of ``log1p(relu(.))`` — and optionally of the
logit softcap — evaluated via the stored post-activation ``y``) is a
cheap elementwise ``(B, V)`` computation done in plain jnp by the
wrapper in ``ops.py``; fusing it here would save one small HBM read but
complicate block unification.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dh_kernel(
    g_ref,     # (bb, bv) f32 — upstream grad * activation derivative
    i_ref,     # (bb, bv) i32 — argmax sequence index
    e_ref,     # (bv, D)
    dh_ref,    # (bb, bs, D) out, accumulated over vocab grid dim
    *,
    n_v_blocks: int,
    block_s: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dh_ref[...] = jnp.zeros(dh_ref.shape, jnp.float32)

    bb, bs, d = dh_ref.shape
    bv = e_ref.shape[0]
    k = pl.program_id(1)

    local_i = i_ref[...] - k * block_s          # (bb, bv); in-range => hit
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bb, bs, bv), 1)
    onehot = (local_i[:, None, :] == s_iota).astype(jnp.float32)
    w = onehot * g_ref[...][:, None, :]          # (bb, bs, bv)
    # dH[b, s, :] += sum_v w[b, s, v] * E[v, :]  — one MXU contraction.
    contrib = jax.lax.dot_general(
        w.reshape(bb * bs, bv), e_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ).reshape(bb, bs, d)
    dh_ref[...] += contrib


def _de_kernel(
    g_ref,     # (bb, bv) f32
    i_ref,     # (bb, bv) i32
    h_ref,     # (bb, bs, D)
    de_ref,    # (bv, D) out, accumulated over (batch, seq) grid dims
    *,
    n_b_blocks: int,
    n_s_blocks: int,
    block_s: int,
):
    i = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        de_ref[...] = jnp.zeros(de_ref.shape, jnp.float32)

    bv, d = de_ref.shape
    bb, bs, _ = h_ref.shape

    local_i = i_ref[...] - k * block_s
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bb, bs, bv), 1)
    onehot = (local_i[:, None, :] == s_iota).astype(jnp.float32)
    w = (onehot * g_ref[...][:, None, :]).reshape(bb * bs, bv)
    # dE[v, :] += sum_{b,s} w[bs, v] * H[bs, :]
    contrib = jax.lax.dot_general(
        w, h_ref[...].reshape(bb * bs, d).astype(jnp.float32),
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    de_ref[...] += contrib


def _pad_to(x, axis, multiple, value=0):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_s", "block_v", "interpret"),
)
def sparton_backward(
    g: jax.Array,       # (B, V) f32 — dy * f'(raw max), zero where y <= 0
    i_max: jax.Array,   # (B, V) i32
    H: jax.Array,       # (B, S, D)
    E: jax.Array,       # (V, D)
    *,
    block_b: int = 8,
    block_s: int = 128,
    block_v: int = 128,
    interpret: bool = False,
):
    """Fused backward. Returns (dH (B,S,D) f32, dE (V,D) f32)."""
    B, S, D = H.shape
    V = E.shape[0]

    gp = _pad_to(_pad_to(g.astype(jnp.float32), 0, block_b), 1, block_v)
    # Padded batch rows must not route anywhere real: g is zero there, so
    # any index is safe; padded vocab cols likewise have g == 0.
    ip = _pad_to(_pad_to(i_max, 0, block_b), 1, block_v)
    Hp = _pad_to(_pad_to(H, 0, block_b), 1, block_s)
    Ep = _pad_to(E, 0, block_v)

    Bp, Sp, _ = Hp.shape
    Vp = Ep.shape[0]
    nb, ns, nv = Bp // block_b, Sp // block_s, Vp // block_v

    dH = pl.pallas_call(
        functools.partial(_dh_kernel, n_v_blocks=nv, block_s=block_s),
        grid=(nb, ns, nv),
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda i, k, j: (i, j)),
            pl.BlockSpec((block_b, block_v), lambda i, k, j: (i, j)),
            pl.BlockSpec((block_v, D), lambda i, k, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_b, block_s, D), lambda i, k, j: (i, k, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((Bp, Sp, D), jnp.float32),
        interpret=interpret,
    )(gp, ip, Ep)

    dE = pl.pallas_call(
        functools.partial(
            _de_kernel, n_b_blocks=nb, n_s_blocks=ns, block_s=block_s
        ),
        grid=(nv, nb, ns),
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda j, i, k: (i, j)),
            pl.BlockSpec((block_b, block_v), lambda j, i, k: (i, j)),
            pl.BlockSpec((block_b, block_s, D), lambda j, i, k: (i, k, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, D), lambda j, i, k: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((Vp, D), jnp.float32),
        interpret=interpret,
    )(gp, ip, Hp)

    return dH[:B, :S], dE[:V]
