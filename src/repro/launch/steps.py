"""Step-function builders for every cell family.

``build_step(arch_id, cell, mesh)`` returns
``(step_fn, state_spec_or_None, batch_sharding_overrides)``:

* ``lsr_train``   — SPLADE-style contrastive train step: backbone +
  Sparton head (vocab-sharded via shard_map when a mesh is given),
  InfoNCE + FLOPS regularizers, AdamW with ZeRO-sharded moments,
  gradient accumulation.
* ``lsr_prefill`` — document/query encoding forward (serving).
* ``decode``      — one autoregressive step with a KV cache.
* ``gnn_train``   — DimeNet MSE training step.
* ``recsys_train``— pointwise CTR training (BCE, Adagrad).
* ``recsys_serve``— CTR forward.
* ``retrieval``   — query embedding + streaming top-k over candidates.

The steps are pure (state, batch) -> (state, metrics) functions ready
for jax.jit with explicit shardings (launch/dryrun.py, launch/train.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import pcast
from repro.configs import get_config
from repro.configs.base import (DimeNetConfig, RecSysConfig,
                                TransformerConfig)
from repro.configs.specs import CellSpec
from repro.core.head_api import make_head
from repro.core.sharded import (sharded_flops_reg, sharded_infonce,
                                sharded_l1_reg, sharded_row_dots)
from repro.launch.mesh import batch_axes
from repro.launch.sharding import (batch_axes_for, batch_spec,
                                   dimenet_param_specs, recsys_param_specs,
                                   state_shardings, transformer_param_specs)
from repro.losses.contrastive import margin_mse_loss, splade_loss
from repro.models import dimenet as dimenet_model
from repro.models import recsys as recsys_model
from repro.models import transformer as tfm
from repro.optim.accumulation import microbatch_grads
from repro.optim.optimizers import adagrad, adamw, apply_updates
from repro.optim.schedules import linear_warmup_cosine

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# LM / LSR
# ---------------------------------------------------------------------------

def _moe_shard(cfg: TransformerConfig, mesh: Optional[Mesh]):
    if mesh is None or not cfg.is_moe:
        return None
    if cfg.n_experts % mesh.shape["model"] != 0:
        return None
    return (batch_axes(mesh), "model")


def _encode_fn(cfg: TransformerConfig, mesh: Optional[Mesh],
               n_batch: int, unroll: bool = False) -> Callable:
    """(params, tokens, mask) -> (Y, aux). Vocab-sharded when mesh.

    The head — any registered backend, Pallas kernel included — comes
    from the unified factory: ``make_head`` puts the selected impl
    inside the vocab-sharded shard_map body when a mesh is given (with
    kernel blocks resolved per *local* vocab shard) and handles the
    non-divisible-vocab fallback itself.
    """
    moe_shard = _moe_shard(cfg, mesh)
    layer_unroll = cfg.n_layers if unroll else 1
    spec = cfg.head_spec()
    if mesh is not None:
        head = make_head(spec, mesh=mesh,
                         batch_axes=batch_axes_for(mesh, n_batch))
    else:
        head = make_head(spec)

    def encode(params, tokens, mask):
        Hs, aux = tfm.forward_hidden(params, cfg, tokens, mask,
                                     moe_shard=moe_shard,
                                     unroll=layer_unroll)
        E, b = tfm.head_weights(params, cfg)
        y = head(Hs, E.astype(Hs.dtype), b, mask)
        return y, aux
    return encode


def build_lsr_train_step(
    cfg: TransformerConfig,
    mesh: Optional[Mesh],
    *,
    n_micro: int = 1,
    n_pairs: int,
    lr: float = 2e-5,
    total_steps: int = 100_000,
    unroll: bool = False,
    param_specs: Any = None,
    zero_specs: Any = None,
) -> Callable:
    shard_fn = None
    if zero_specs is not None:
        shard_fn = lambda t: jax.lax.with_sharding_constraint(t, zero_specs)
    opt = adamw(linear_warmup_cosine(lr, 1000, total_steps),
                shard_fn=shard_fn)
    # the head/loss shard_maps see the *micro* batch
    micro_pairs = max(1, n_pairs // n_micro)
    encode = _encode_fn(cfg, mesh, micro_pairs, unroll)

    if mesh is not None and cfg.vocab_size % mesh.shape["model"] == 0:
        # vocab-sharded reps never gather, so the objective is
        # composed from the sharded primitives (same math as
        # losses.splade_loss / margin_mse_loss on the full arrays)
        baxes = batch_axes_for(mesh, micro_pairs)
        infonce = sharded_infonce(mesh, batch_axes=baxes)
        flops = sharded_flops_reg(mesh, batch_axes=baxes)
        l1 = sharded_l1_reg(mesh, batch_axes=baxes)
        row_dots = sharded_row_dots(mesh, batch_axes=baxes)

        def mb_loss(params, mb):
            yq, aux_q = encode(params, mb["q_tokens"], mb["q_mask"])
            yd, aux_d = encode(params, mb["d_tokens"], mb["d_mask"])
            loss = infonce(yq, yd)
            loss = loss + cfg.lambda_q * flops(yq) \
                + cfg.lambda_d * flops(yd)
            if cfg.l1_weight:
                loss = loss + cfg.l1_weight * (l1(yq) + l1(yd))
            if cfg.distill_weight and "neg_tokens" in mb:
                yn, _ = encode(params, mb["neg_tokens"], mb["neg_mask"])
                margin = row_dots(yq, yd) - row_dots(yq, yn)
                mse = jnp.mean((margin - mb["teacher_margin"]) ** 2)
                loss = loss + cfg.distill_weight * mse
            return loss + cfg.aux_weight * (aux_q + aux_d)
    else:
        def mb_loss(params, mb):
            yq, aux_q = encode(params, mb["q_tokens"], mb["q_mask"])
            yd, aux_d = encode(params, mb["d_tokens"], mb["d_mask"])
            loss = splade_loss(yq, yd,
                               lambda_q=cfg.lambda_q,
                               lambda_d=cfg.lambda_d,
                               l1_weight=cfg.l1_weight,
                               aux_loss=aux_q + aux_d,
                               aux_weight=cfg.aux_weight)
            if cfg.distill_weight and "neg_tokens" in mb:
                yn, _ = encode(params, mb["neg_tokens"], mb["neg_mask"])
                loss = loss + cfg.distill_weight * margin_mse_loss(
                    yq, yd, yn, mb["teacher_margin"])
            return loss

    grad_fn = jax.value_and_grad(mb_loss)

    micro_unroll = n_micro if unroll else 1

    def step(state, batch):
        # ZeRO-2 boundary: per-micro grads reduce-scatter to the
        # optimizer sharding inside the accumulation scan, so the fp32
        # accumulator AND every fp32 update temp live batch-sharded
        loss, grads = microbatch_grads(
            grad_fn, state["params"], batch, n_micro=n_micro,
            unroll=micro_unroll, grad_specs=zero_specs)
        updates, opt_state = opt.update(
            grads, state["opt"], state["params"], state["step"])
        # cast at the ZeRO sharding, THEN all-gather in param dtype
        updates = jax.tree.map(lambda u, p: u.astype(p.dtype),
                               updates, state["params"])
        if param_specs is not None:
            updates = jax.lax.with_sharding_constraint(updates, param_specs)
        params = apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss}

    return step


def build_lsr_prefill_step(cfg: TransformerConfig, mesh: Optional[Mesh],
                           n_batch: int, unroll: bool = False) -> Callable:
    encode = _encode_fn(cfg, mesh, n_batch, unroll)

    def serve(params, batch):
        y, _ = encode(params, batch["tokens"], batch["mask"])
        return y
    return serve


def build_decode_step(cfg: TransformerConfig,
                      mesh: Optional[Mesh]) -> Callable:
    moe_shard = _moe_shard(cfg, mesh)

    def serve(params, batch):
        cache = {"k": batch["cache_k"], "v": batch["cache_v"]}
        logits, cache = tfm.decode_step(
            params, cfg, cache, batch["tokens"], batch["positions"],
            moe_shard=moe_shard)
        return logits, cache["k"], cache["v"]
    return serve


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def build_gnn_train_step(cfg: DimeNetConfig, cell: CellSpec,
                         *, lr: float = 1e-4,
                         shard_axes: Optional[Tuple[str, ...]] = None
                         ) -> Callable:
    opt = adamw(lr)

    def loss_fn(params, batch):
        if cell.n_graphs:
            pred = dimenet_model.forward_graph(
                params, cfg, batch, cell.n_graphs,
                shard_axes=shard_axes)
            err = pred - batch["target"]
            return jnp.mean(err * err)
        pred = dimenet_model.forward(params, cfg, batch,
                                     shard_axes=shard_axes)
        if "seed_ids" in batch:
            pred = jnp.take(pred, batch["seed_ids"], axis=0)
            err = pred - batch["target"]
            return jnp.mean(err * err)
        err = (pred - batch["target"]) \
            * batch["node_mask"].astype(pred.dtype)[:, None]
        return jnp.sum(err * err) / jnp.maximum(
            jnp.sum(batch["node_mask"]), 1.0)

    grad_fn = jax.value_and_grad(loss_fn)

    def step(state, batch):
        loss, grads = grad_fn(state["params"], batch)
        updates, opt_state = opt.update(
            grads, state["opt"], state["params"], state["step"])
        params = apply_updates(state["params"], updates)
        return ({"params": params, "opt": opt_state,
                 "step": state["step"] + 1}, {"loss": loss})
    return step


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

def build_recsys_train_step(cfg: RecSysConfig,
                            *, lr: float = 1e-2,
                            param_specs: Any = None,
                            zero_specs: Any = None) -> Callable:
    opt = adagrad(lr)

    def loss_fn(params, batch):
        logits = recsys_model.forward(params, cfg, batch)
        label = batch["label"]
        # numerically-stable BCE with logits
        loss = jnp.maximum(logits, 0) - logits * label \
            + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        return jnp.mean(loss)

    grad_fn = jax.value_and_grad(loss_fn)

    def step(state, batch):
        loss, grads = grad_fn(state["params"], batch)
        if zero_specs is not None:
            grads = jax.lax.with_sharding_constraint(grads, zero_specs)
        updates, opt_state = opt.update(
            grads, state["opt"], state["params"], state["step"])
        if param_specs is not None:
            updates = jax.lax.with_sharding_constraint(updates, param_specs)
        params = apply_updates(state["params"], updates)
        return ({"params": params, "opt": opt_state,
                 "step": state["step"] + 1}, {"loss": loss})
    return step


def build_recsys_serve_step(cfg: RecSysConfig) -> Callable:
    def serve(params, batch):
        return jax.nn.sigmoid(recsys_model.forward(params, cfg, batch))
    return serve


def streaming_topk(q: Array, C: Array, *, k: int,
                   tile: int = 65536,
                   vary_axes: Optional[Tuple[str, ...]] = None
                   ) -> Tuple[Array, Array]:
    """Pure-JAX streaming top-k over candidate tiles (same algorithm as
    kernels/topk_score.py; the SPMD-lowerable path for the dry-run).

    ``vary_axes``: when called inside shard_map over sharded candidates,
    the scan carry must be marked device-varying over those axes."""
    B, D = q.shape
    N = C.shape[0]
    pad = (-N) % tile
    Cp = jnp.pad(C, ((0, pad), (0, 0)))
    n_tiles = Cp.shape[0] // tile
    C_t = Cp.reshape(n_tiles, tile, D)

    from repro.kernels.topk_score import merge_topk

    def body(carry, xs):
        vals, idx = carry
        c_tile, t = xs
        scores = jnp.einsum("bd,nd->bn", q, c_tile,
                            preferred_element_type=jnp.float32)
        ids = t * tile + jnp.arange(tile, dtype=jnp.int32)[None]
        ids = jnp.broadcast_to(ids, scores.shape)
        # padded rows score q.0 = 0 and would beat real negatives
        scores = jnp.where(ids < N, scores, -1e30)
        return merge_topk(vals, idx, scores, ids, k), None

    init = (jnp.full((B, k), -1e30, jnp.float32),
            jnp.zeros((B, k), jnp.int32))
    if vary_axes:
        init = jax.tree.map(
            lambda x: pcast(x, vary_axes, to="varying"), init)
    (vals, idx), _ = jax.lax.scan(
        body, init, (C_t, jnp.arange(n_tiles, dtype=jnp.int32)))
    return vals, idx


def build_retrieval_step(cfg: RecSysConfig, mesh: Optional[Mesh],
                         *, k: int = 100) -> Callable:
    """Query trunk + fused streaming top-k over 1M candidates.

    With a mesh the candidates are row-sharded over every axis: each
    device streams its local rows (shard_map), then the per-shard
    winners (n_shards × k) are gathered and merged — the (B, N) score
    matrix never exists anywhere (Sparton's memory story transferred)."""

    if mesh is None:
        def serve(params, batch):
            qv = recsys_model.user_embedding(params, cfg, batch)
            return streaming_topk(qv, batch["candidates"], k=k)
        return serve

    axes = tuple(mesh.axis_names)

    def sharded_body(qv, cand):
        rows_local = cand.shape[0]
        vals, idx = streaming_topk(qv, cand, k=k,
                                   tile=min(65536, rows_local),
                                   vary_axes=axes)
        # local ids -> global ids
        offset = jax.lax.axis_index(axes) * rows_local
        idx = idx + offset
        # merge across shards: gather (n_shards*k) winners, re-top-k
        all_v = jax.lax.all_gather(vals, axes, axis=1, tiled=True)
        all_i = jax.lax.all_gather(idx, axes, axis=1, tiled=True)
        v2, pos = jax.lax.top_k(all_v, k)
        i2 = jnp.take_along_axis(all_i, pos, axis=1)
        return v2, i2

    from repro.compat import shard_map
    merged = shard_map(
        sharded_body, mesh=mesh,
        in_specs=(P(), P(axes, None)),
        out_specs=(P(), P()),
        # the final top_k after the full all_gather IS replicated, but
        # the vma system cannot prove it — skip the check
        check_vma=False,
    )

    def serve(params, batch):
        qv = recsys_model.user_embedding(params, cfg, batch)
        return merged(qv, batch["candidates"])
    return serve


# ---------------------------------------------------------------------------
# unified builder
# ---------------------------------------------------------------------------

def init_state(arch_id: str, key: jax.Array,
               smoke: bool = False) -> Tuple[PyTree, str]:
    """(state pytree, opt layout) for the arch's train family."""
    mod = get_config(arch_id)
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if isinstance(cfg, TransformerConfig):
        params = tfm.init_params(key, cfg)
        opt = adamw(1e-4)
        layout = "adamw"
    elif isinstance(cfg, DimeNetConfig):
        params = dimenet_model.init_params(key, cfg)
        opt = adamw(1e-4)
        layout = "adamw"
    else:
        params = recsys_model.init_params(key, cfg)
        opt = adagrad(1e-2)
        layout = "adagrad"
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    return state, layout


def arch_config_for_cell(arch_id: str, cell: CellSpec):
    """Per-cell config adaptation: DimeNet's input width is a property
    of the *shape* (atom types vs node-feature vectors)."""
    cfg = get_config(arch_id).CONFIG
    if isinstance(cfg, DimeNetConfig) and cfg.d_feat != cell.d_feat:
        cfg = dataclasses.replace(cfg, d_feat=cell.d_feat)
    return cfg


def build_step(arch_id: str, cell: CellSpec,
               mesh: Optional[Mesh], *, unroll: bool = False,
               param_specs: Any = None, zero_specs: Any = None
               ) -> Callable:
    cfg = arch_config_for_cell(arch_id, cell)
    kind = cell.step_kind
    if kind == "lsr_train":
        n_pairs = cell.batch["q_tokens"].shape[0]
        return build_lsr_train_step(cfg, mesh, n_micro=cell.n_micro,
                                    n_pairs=n_pairs, unroll=unroll,
                                    param_specs=param_specs,
                                    zero_specs=zero_specs)
    if kind == "lsr_prefill":
        return build_lsr_prefill_step(
            cfg, mesh, cell.batch["tokens"].shape[0], unroll=unroll)
    if kind == "decode":
        return build_decode_step(cfg, mesh)
    if kind == "gnn_train":
        shard_axes = None
        if mesh is not None:
            n_dev = mesh.devices.size
            if (cell.n_edges % n_dev == 0
                    and cell.n_triplets % n_dev == 0
                    and cell.n_nodes % n_dev == 0):
                shard_axes = tuple(mesh.axis_names)
        return build_gnn_train_step(cfg, cell, shard_axes=shard_axes)
    if kind == "recsys_train":
        return build_recsys_train_step(cfg, param_specs=param_specs,
                                       zero_specs=zero_specs)
    if kind == "recsys_serve":
        return build_recsys_serve_step(cfg)
    if kind == "retrieval":
        return build_retrieval_step(cfg, mesh)
    raise ValueError(f"unknown step kind {kind}")
