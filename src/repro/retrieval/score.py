"""Retrieval scoring — impact, pruned, quantized, fused, sharded,
streaming-kernel, and dense paths behind one ``retrieve()``
dispatcher.

Dispatch table (``method=``):

    method       queries            corpus             scoring
    ---------    ---------------    ---------------    -------------
    "impact"     SparseRep          InvertedIndex      exact segment-
                                                       sums into (B, N)
    "fused"      SparseRep          InvertedIndex      fused Pallas
                                    or QuantizedIndex  kernel: posting
                                                       window resident
                                                       in VMEM, per-
                                                       tile one-hot
                                                       MAC + running
                                                       top-k merge — no
                                                       (B, N) matrix;
                                                       u4 windows are
                                                       dequantized
                                                       inside the
                                                       kernel (kernels/
                                                       impact_score)
    "pruned"     SparseRep          InvertedIndex      two-tier MaxScore:
                                    (+ term_ubs and    upper-bound pass
                                    forward rows)      -> exact rescore
                                                       of candidates
                                                       (engine/pruning)
    "quantized"  SparseRep          QuantizedIndex     on-the-fly
                                                       dequantized
                                                       segment-sums
                                                       (engine/quantize)
    "sharded"    SparseRep          ShardedIndex       per-shard impact
                                                       + cross-shard
                                                       top-k merge
                                                       (engine/
                                                       sharded_index)
    "term_        SparseRep         TermShardedIndex   per-shard PARTIAL
     sharded"                                          sums over vocab
                                                       ranges, psum/
                                                       all-reduce, one
                                                       global top-k
                                                       (engine/
                                                       term_sharded)
    "shard2d"    SparseRep          Shard2DIndex       (doc x term) grid:
                                                       per-cell partials
                                                       psum'd over the
                                                       term axis into
                                                       exact chunk
                                                       scores, then the
                                                       doc axis merges
                                                       per-chunk top-k
                                                       via all_gather +
                                                       re-top-k (engine/
                                                       shard2d)
    "streaming"  dense or rep       dense (N, V)       fused Pallas
                                                       running top-k
    "dense"      dense or rep       dense (N, V)       (B, N) einsum
                                                       + lax.top_k
    "auto"       resolved from the corpus type:
                 * QuantizedIndex: "fused" for corpora >= AUTO_FUSED_N
                   docs (the (B, N) matrix stops being a rounding
                   error), "quantized" below that
                 * ShardedIndex                -> "sharded"
                 * TermShardedIndex            -> "term_sharded"
                 * Shard2DIndex                -> "shard2d"
                 * InvertedIndex with upper bounds AND forward rows
                   (an engine build)           -> "pruned"
                 * any other InvertedIndex: "fused" at >= AUTO_FUSED_N
                   docs, "impact" below
                 * dense matrix: "streaming" for corpora >=
                   AUTO_STREAMING_N rows, "dense" below that

Keyword arguments are validated against the *resolved* method: passing
a kwarg the method cannot honor (``mesh`` with ``"impact"``,
``prune_margin`` with ``"streaming"``) raises instead of being
silently ignored — a typo'd or misrouted tuning knob must not
masquerade as a no-op. The per-method table is ``_METHOD_KWARGS``.

Which *placement* to build in the first place is the upstream choice:
``engine.shard2d.plan_placement(stats, n_devices, per_device_hbm)``
returns a frozen ``ShardPlan`` ``(doc_shards, term_shards, replicas,
axis_order, reason)`` accounting posting bytes, the O(V) term
directory (replicated by doc sharding, divided by term sharding) and
forward-row storage — doc sharding merges cheap (all_gather of k
winners), term sharding splits the posting arrays exactly (the
|V|~250k multilingual regime) at the cost of an all-reduce over
partials, and the 2D grid composes both when neither axis alone fits.
Shard topology rides into ``retrieve`` through the one ``plan=``
kwarg (validated against the built index); the old string-returning
``choose_shard_axis`` survives as a deprecated shim.

All paths return ``(vals (B, k) f32, idx (B, k) i32)`` with identical
ids (scores within fp/quantization tolerance) for equivalent inputs —
the parity tests in ``tests/test_retrieval.py``,
``tests/test_kernels_impact.py`` and ``tests/test_engine.py`` pin that
down. ``pruned`` is id-identical to ``impact`` at the default safe
margin (0.0) with a sufficient candidate budget; ``prune_margin`` > 0
trades recall for speed.

The impact path is the sparse-native one: per query row it gathers the
posting lists of the query's active terms (padded to the index's
``max_postings`` static width) and reduces them with
``sparse/segment.py`` segment-sums — ``scores[d] = sum_t q[t] *
impact[t, d]`` — exactly the inverted-index formulation GPUSparse
serves LSR with. Work per query is ``O(Q * max_postings)``; the
padding cost is the usual TPU trade of ragged gathers for one static
dense gather + masked reduce. The fused path walks the *same* gathered
windows but scores and merges tile-by-tile inside one Pallas kernel
(DESIGN.md §12), so its peak scoring memory is the window plus the
(B, k) winners — independent of the corpus size.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.topk_score import topk_score
from repro.retrieval.index import InvertedIndex
from repro.retrieval.sparse_rep import SparseRep
from repro.sparse.segment import segment_sum

Array = jax.Array
Queries = Union[Array, SparseRep]
Corpus = Union[Array, InvertedIndex]

METHODS = ("auto", "impact", "fused", "pruned", "quantized", "sharded",
           "term_sharded", "shard2d", "streaming", "dense")
# methods that need an index-shaped corpus (not a dense matrix)
_INDEX_METHODS = ("impact", "fused", "pruned", "quantized", "sharded",
                  "term_sharded", "shard2d")
# corpora at or above this many rows route "auto" to the streaming
# kernel (the (B, N) score matrix stops being a rounding error)
AUTO_STREAMING_N = 16384
# indexed corpora at or above this many docs route "auto" to the fused
# impact kernel for the same reason: below it the dense (B, N) matrix
# is small enough that the plain segment-sum path's simplicity wins
AUTO_FUSED_N = 16384

# kwargs each resolved method can honor; everything else raises.
# ``interpret`` spans the Pallas-backed paths, block sizes go to the
# kernel they tune, pruning knobs to the two-tier paths. Shard
# topology rides in the one ``plan=`` kwarg (a ShardPlan, validated
# against the built index) + ``mesh=`` for the shard_map paths.
# impact/dense/quantized take no tuning kwargs.
_METHOD_KWARGS = {
    "impact": frozenset(),
    "dense": frozenset(),
    "quantized": frozenset(),
    "fused": frozenset({"interpret", "block_n", "block_w"}),
    "streaming": frozenset({"interpret", "block_b", "block_n"}),
    "pruned": frozenset({"prune_margin", "candidates"}),
    "sharded": frozenset({"mesh", "plan"}),
    "term_sharded": frozenset({"mesh", "plan", "prune_margin",
                               "candidates"}),
    "shard2d": frozenset({"mesh", "plan", "prune_margin",
                          "candidates"}),
}


@functools.lru_cache(maxsize=1)
def _engine():
    """Engine-type lookup, imported once per process.

    The engine package imports the index/rep modules this module also
    feeds, so the imports stay function-local to keep the import graph
    acyclic — but cached, not re-executed per ``retrieve()`` call like
    the old per-call ``from ... import`` blocks.
    """
    from repro.retrieval.engine import (pruning, quantize, shard2d,
                                        sharded_index, term_sharded)

    return {
        "QuantizedIndex": quantize.QuantizedIndex,
        "quantized_retrieve": quantize.quantized_retrieve,
        "fused_quantized_retrieve": quantize.fused_quantized_retrieve,
        "ShardedIndex": sharded_index.ShardedIndex,
        "sharded_retrieve": sharded_index.sharded_retrieve,
        "TermShardedIndex": term_sharded.TermShardedIndex,
        "term_sharded_retrieve": term_sharded.term_sharded_retrieve,
        "Shard2DIndex": shard2d.Shard2DIndex,
        "shard2d_retrieve": shard2d.shard2d_retrieve,
        "pruned_retrieve": pruning.pruned_retrieve,
    }


# ---------------------------------------------------------------------------
# impact scoring (inverted index)
# ---------------------------------------------------------------------------

def impact_scores(queries: SparseRep, index: InvertedIndex) -> Array:
    """Dense ``(B, n_docs)`` impact scores — no (N, V) matrix anywhere.

    Padded query slots (value 0) and posting-list padding both
    contribute exactly 0 to the segment-sums, so no masking state
    leaks into the scores.
    """
    l_max = index.max_postings
    p_total = index.postings_doc.shape[0]
    lane = jnp.arange(l_max, dtype=jnp.int32)

    def one(qv: Array, qi: Array) -> Array:
        starts = index.term_starts[qi]                     # (Q,)
        lens = index.term_lens[qi]                         # (Q,)
        pos = starts[:, None] + lane[None, :]              # (Q, Lmax)
        valid = (lane[None, :] < lens[:, None]) & (qv > 0)[:, None]
        pos = jnp.clip(pos, 0, p_total - 1)
        docs = jnp.where(valid, index.postings_doc[pos], 0)
        w = jnp.where(valid, index.postings_val[pos], 0.0) * qv[:, None]
        return segment_sum(w.ravel(), docs.ravel(), index.n_docs)

    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    return jax.vmap(one)(qv, qi)


# ---------------------------------------------------------------------------
# fused impact retrieval (Pallas kernel over gathered windows)
# ---------------------------------------------------------------------------

@jax.jit
def _fused_windows(queries: SparseRep, index: InvertedIndex
                   ) -> Tuple[Array, Array]:
    """Flat ``(B, Q * L_max)`` weight/doc windows for the fused kernel.

    The same padded gather as ``impact_scores`` — invalid lanes carry
    weight exactly 0 — but flattened to the kernel's posting axis
    instead of being segment-summed into (B, N).
    """
    l_max = index.max_postings
    p_total = index.postings_doc.shape[0]
    lane = jnp.arange(l_max, dtype=jnp.int32)
    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    starts = index.term_starts[qi]                         # (B, Q)
    lens = index.term_lens[qi]                             # (B, Q)
    pos = starts[:, :, None] + lane[None, None, :]         # (B, Q, L)
    valid = ((lane[None, None, :] < lens[:, :, None])
             & (qv > 0)[:, :, None])
    pos = jnp.clip(pos, 0, p_total - 1)
    docs = jnp.where(valid, index.postings_doc[pos], 0)
    w = jnp.where(valid, index.postings_val[pos], 0.0) * qv[:, :, None]
    b = w.shape[0]
    return w.reshape(b, -1), docs.reshape(b, -1)


def fused_retrieve(
    queries: SparseRep,
    index: InvertedIndex,
    k: int = 10,
    *,
    block_n: Optional[int] = None,
    block_w: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[Array, Array]:
    """Fused-kernel top-k over an ``InvertedIndex`` — id-identical to
    the ``impact`` path (pinned by tests/test_kernels_impact.py).

    None blocks resolve through the autotune cache/heuristic
    (``_impact`` keys); ``interpret`` defaults to the Pallas
    interpreter off-TPU.
    """
    from repro.kernels.autotune import resolve_impact_blocks
    from repro.kernels.impact_score import fused_impact_topk

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = queries.values.reshape(-1, queries.width).shape[0]
    block_n, block_w = resolve_impact_blocks(
        b, queries.width, index.max_postings, index.n_docs,
        block_n, block_w, variant="f32")
    w, docs = _fused_windows(queries, index)
    return fused_impact_topk(
        w, docs, n_docs=index.n_docs, k=min(k, index.n_docs),
        block_n=block_n, block_w=block_w, interpret=interpret)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def _dense_queries(queries: Queries, vocab_size: int) -> Array:
    if isinstance(queries, SparseRep):
        return queries.to_dense(vocab_size)
    return queries


def _resolve_method(method: str, corpus: Corpus) -> str:
    if method not in METHODS:
        raise ValueError(f"unknown retrieval method {method!r}; "
                         f"one of {list(METHODS)}")
    if method != "auto":
        return method
    eng = _engine()
    if isinstance(corpus, eng["QuantizedIndex"]):
        return ("fused" if corpus.n_docs >= AUTO_FUSED_N
                else "quantized")
    if isinstance(corpus, eng["ShardedIndex"]):
        return "sharded"
    if isinstance(corpus, eng["TermShardedIndex"]):
        return "term_sharded"
    if isinstance(corpus, eng["Shard2DIndex"]):
        return "shard2d"
    if isinstance(corpus, InvertedIndex):
        # an engine build (upper bounds + forward rows) can serve the
        # two-tier pruned path; a bare PR-3 index only the exact ones
        if corpus.has_upper_bounds and corpus.has_forward:
            return "pruned"
        return "fused" if corpus.n_docs >= AUTO_FUSED_N else "impact"
    return "streaming" if corpus.shape[0] >= AUTO_STREAMING_N else "dense"


def _check_kwargs(method: str, passed: dict) -> None:
    """Raise on kwargs the resolved method cannot honor."""
    allowed = _METHOD_KWARGS[method]
    stray = [name for name, value in passed.items()
             if value is not None and name not in allowed]
    if stray:
        raise ValueError(
            f"method={method!r} does not accept "
            f"{', '.join(sorted(stray))} (accepted: "
            f"{sorted(allowed) if allowed else 'no tuning kwargs'}); "
            "refusing to silently ignore a tuning knob")


@functools.partial(jax.jit, static_argnames=("k",))
def _dense_retrieve(q: Array, C: Array, k: int) -> Tuple[Array, Array]:
    scores = jnp.einsum("bv,nv->bn", q.astype(jnp.float32),
                        C.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def _impact_retrieve(queries: SparseRep, index: InvertedIndex, k: int
                     ) -> Tuple[Array, Array]:
    scores = impact_scores(queries, index)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def _check_plan(plan, method: str, doc_shards: int, term_shards: int
                ) -> None:
    """A ``plan=`` must describe the index it rides with: the grid the
    planner chose has to match the grid that was actually built."""
    if (plan.doc_shards, plan.term_shards) != (doc_shards, term_shards):
        raise ValueError(
            f"method={method!r}: plan grid "
            f"{plan.doc_shards}x{plan.term_shards} (doc x term) does "
            f"not match the built index "
            f"{doc_shards}x{term_shards} — rebuild from the plan or "
            f"re-plan from the corpus stats")


def retrieve(
    queries: Queries,           # (B, V) dense or SparseRep
    corpus: Corpus,             # (N, V) dense matrix or an index
    k: int = 10,
    *,
    method: str = "auto",
    interpret: Optional[bool] = None,
    block_b: Optional[int] = None,
    block_n: Optional[int] = None,
    block_w: Optional[int] = None,
    prune_margin: Optional[float] = None,
    candidates: Optional[int] = None,
    mesh=None,
    plan=None,
) -> Tuple[Array, Array]:
    """Top-k retrieval via the method table in the module docstring.

    ``k`` is clamped to the corpus size so every path returns the same
    ``(B, min(k, N))`` shape. Tuning kwargs are validated against the
    *resolved* method (``_METHOD_KWARGS``) — a kwarg the method cannot
    honor raises instead of being ignored. ``interpret`` affects the
    Pallas-backed paths (None = auto: interpreter off-TPU);
    ``block_b``/``block_n`` tune the streaming kernel and
    ``block_n``/``block_w`` the fused one (None = autotune cache /
    heuristic); ``prune_margin``/``candidates`` drive the pruned path
    (``engine.pruning``) and, for margins > 0, the sharded two-tier
    compositions; ``mesh`` runs the sharded paths under shard_map
    (None = single-device vmap over shards) and ``plan`` — a
    ``ShardPlan`` from ``engine.shard2d.plan_placement`` — carries the
    shard topology: it is validated against the built index, and for
    ``shard2d`` its ``axis_order`` maps the (doc, term) grid onto the
    mesh axes.
    """
    method = _resolve_method(method, corpus)
    _check_kwargs(method, {
        "interpret": interpret, "block_b": block_b, "block_n": block_n,
        "block_w": block_w, "prune_margin": prune_margin,
        "candidates": candidates, "mesh": mesh, "plan": plan,
    })

    if method in _INDEX_METHODS:
        eng = _engine()
        if not isinstance(queries, SparseRep):
            raise ValueError(
                f"method={method!r} needs SparseRep queries — sparsify "
                "with retrieval.sparse_rep.sparsify_topk/threshold "
                "(an explicit budget, not a silent one)")
        if method == "fused":
            if isinstance(corpus, eng["QuantizedIndex"]):
                return eng["fused_quantized_retrieve"](
                    queries, corpus, k, block_n=block_n,
                    block_w=block_w, interpret=interpret)
            if not isinstance(corpus, InvertedIndex):
                raise ValueError(
                    "method='fused' needs an InvertedIndex or "
                    "QuantizedIndex corpus — build one with "
                    "retrieval.index.build_inverted_index or "
                    "engine.quantize.quantize_index")
            return fused_retrieve(queries, corpus, k, block_n=block_n,
                                  block_w=block_w, interpret=interpret)
        if method == "quantized":
            if not isinstance(corpus, eng["QuantizedIndex"]):
                raise ValueError(
                    "method='quantized' needs a QuantizedIndex corpus "
                    "— compress one with engine.quantize.quantize_index")
            return eng["quantized_retrieve"](queries, corpus, k)
        if method == "sharded":
            if not isinstance(corpus, eng["ShardedIndex"]):
                raise ValueError(
                    "method='sharded' needs a ShardedIndex corpus — "
                    "build one with engine.sharded_index.shard_index")
            if plan is not None:
                _check_plan(plan, method, corpus.n_shards, 1)
            return eng["sharded_retrieve"](queries, corpus, k,
                                           mesh=mesh)
        if method == "term_sharded":
            if not isinstance(corpus, eng["TermShardedIndex"]):
                raise ValueError(
                    "method='term_sharded' needs a TermShardedIndex "
                    "corpus — build one with "
                    "engine.term_sharded.term_shard_index")
            if plan is not None:
                _check_plan(plan, method, 1, corpus.n_shards)
            # margin 0 routes to the exact psum path (identical ids,
            # no candidate budget to size); > 0 opts into the
            # two-tier composition and requires forward rows
            margin = prune_margin if prune_margin is not None else 0.0
            return eng["term_sharded_retrieve"](
                queries, corpus, k, mesh=mesh,
                prune_margin=margin if margin > 0 else None,
                candidates=candidates)
        if method == "shard2d":
            if not isinstance(corpus, eng["Shard2DIndex"]):
                raise ValueError(
                    "method='shard2d' needs a Shard2DIndex corpus — "
                    "build one with engine.shard2d.shard2d_index")
            if plan is not None:
                _check_plan(plan, method, corpus.doc_shards,
                            corpus.term_shards)
            margin = prune_margin if prune_margin is not None else 0.0
            return eng["shard2d_retrieve"](
                queries, corpus, k, mesh=mesh, plan=plan,
                prune_margin=margin if margin > 0 else None,
                candidates=candidates)
        if not isinstance(corpus, InvertedIndex):
            raise ValueError(
                f"method={method!r} needs an InvertedIndex corpus — "
                "build one with retrieval.index.build_inverted_index")
        if method == "pruned":
            return eng["pruned_retrieve"](
                queries, corpus, k,
                prune_margin=(prune_margin if prune_margin is not None
                              else 0.0),
                candidates=candidates)
        return _impact_retrieve(queries, corpus, min(k, corpus.n_docs))

    if isinstance(corpus, InvertedIndex) or not hasattr(corpus, "shape"):
        raise ValueError(
            f"method={method!r} needs a dense (N, V) corpus matrix; "
            f"got {type(corpus).__name__} (use an index method or "
            "'auto')")
    n_docs, vocab = corpus.shape
    q = _dense_queries(queries, vocab)
    k = min(k, n_docs)

    if method == "dense":
        return _dense_retrieve(q, corpus, k)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return topk_score(q, corpus, k=k,
                      block_b=block_b if block_b is not None else 8,
                      block_n=block_n if block_n is not None else 1024,
                      interpret=interpret)
