"""End-to-end effectiveness harness: encode → sparsify → build-index →
retrieve → score, for any engine configuration.

``evaluate_retrieval(encoder, corpus, qrels, ...)`` closes the quality
loop the ROADMAP names: every id-parity-tested serving path (exact
impact, two-tier pruned, u4 quantized, doc- and term-sharded, the
degrade ladder's aggressive margins + query narrowing) becomes a row
of MRR@k / nDCG@k numbers against graded qrels, so quality-vs-speed
knobs are *measured* instead of asserted id-identical.

Corpus forms (one dict, two shapes):

* **token corpus** — ``{"doc_tokens": (N, S), "q_tokens": (B, S)}``
  (+ optional ``doc_mask`` / ``q_mask``): rows go through ``encoder``
  (the ``(tokens, mask) -> reps`` callable of
  ``runtime.serving.make_config_encoder``) in fixed-size chunks; dense
  ``(B, V)`` outputs are sparsified with ``rep_topk``.
* **impact corpus** — ``{"docs": (N, V), "queries": (B, V)}`` dense
  impact matrices (``data.synthetic.lsr_impact_corpus``): no encoder
  needed, rows are sparsified directly.

Each :class:`MethodSpec` builds a fresh index for its engine config
(``IndexBuilder`` kwargs — quantize / keep_forward / term_shards — or
``doc_shards`` for the doc-sharded axis) and searches with its
``search`` kwargs (method / prune_margin / q_width), so one call
sweeps the whole method matrix on identical reps. Judgments are keyed
by **external** doc ids (``doc_ids``, default row order), the ids the
engine preserves across mutations — see ``qrels.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.eval.metrics import METRIC_NAMES, compute_metrics
from repro.eval.qrels import Qrels
from repro.retrieval.sparse_rep import (SparseRep, sparsify_topk,
                                        stack_rows)


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One evaluated retrieval configuration.

    ``engine`` kwargs feed ``IndexBuilder`` (``quantize=True``,
    ``keep_forward=True``, ``term_shards=n``); ``search`` kwargs feed
    ``IndexBuilder.search`` (``method=``, ``prune_margin=``,
    ``q_width=``). ``doc_shards > 0`` instead builds a doc-range
    ``ShardedIndex`` (the builder has no doc-sharded mode — doc
    sharding is a serving-topology choice, DESIGN.md §8.3).
    """
    name: str
    engine: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    search: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    doc_shards: int = 0


DEFAULT_METHODS: Tuple[MethodSpec, ...] = (
    MethodSpec("exact"),
    MethodSpec("pruned", engine={"keep_forward": True},
               search={"method": "pruned", "prune_margin": 0.0}),
    MethodSpec("quantized", engine={"quantize": True}),
)


def encode_reps(encoder: Callable[[Any, Any], Any], tokens, mask=None,
                *, batch: int = 32, rep_topk: int = 64) -> SparseRep:
    """Run a token matrix through ``encoder`` in fixed-size chunks and
    stack the rows into one ``(N, K)`` ``SparseRep``.

    Chunks are padded to exactly ``batch`` rows so every call shares
    one jit trace; dense ``(B, V)`` encoder outputs are reduced with
    ``sparsify_topk(rep_topk)`` (sparse-encoder outputs pass through).
    """
    toks = np.asarray(tokens, np.int32)
    msk = (np.ones_like(toks) if mask is None
           else np.asarray(mask, np.int32))
    n = toks.shape[0]
    rows = []
    for lo in range(0, n, batch):
        t = toks[lo:lo + batch]
        m = msk[lo:lo + batch]
        pad = batch - t.shape[0]
        if pad:
            t = np.pad(t, ((0, pad), (0, 0)))
            m = np.pad(m, ((0, pad), (0, 0)))
        reps = encoder(jnp.asarray(t), jnp.asarray(m))
        if not isinstance(reps, SparseRep):
            reps = sparsify_topk(reps, rep_topk)
        rows.append(reps)
    stacked = stack_rows(rows)
    if stacked.values.shape[0] != n:       # drop chunk padding rows
        stacked = SparseRep(stacked.values[:n], stacked.indices[:n],
                            stacked.nnz[:n])
    return stacked


def _corpus_reps(encoder, corpus: Mapping[str, Any], *,
                 batch: int, rep_topk: int
                 ) -> Tuple[SparseRep, SparseRep, int]:
    """(doc_reps, query_reps, vocab_size) from either corpus form."""
    if "docs" in corpus and "queries" in corpus:
        docs = jnp.asarray(corpus["docs"])
        queries = jnp.asarray(corpus["queries"])
        vocab = int(docs.shape[-1])
        return (sparsify_topk(docs, min(rep_topk, vocab)),
                sparsify_topk(queries, min(rep_topk, vocab)),
                vocab)
    if "doc_tokens" in corpus and "q_tokens" in corpus:
        if encoder is None:
            raise ValueError("a token corpus needs an encoder "
                             "(tokens, mask) -> reps")
        if "vocab_size" not in corpus:
            raise ValueError("a token corpus must carry vocab_size")
        vocab = int(corpus["vocab_size"])
        d = encode_reps(encoder, corpus["doc_tokens"],
                        corpus.get("doc_mask"), batch=batch,
                        rep_topk=rep_topk)
        q = encode_reps(encoder, corpus["q_tokens"],
                        corpus.get("q_mask"), batch=batch,
                        rep_topk=rep_topk)
        return d, q, vocab
    raise ValueError(
        "corpus must carry docs+queries (dense impacts) or "
        f"doc_tokens+q_tokens (+vocab_size); got {sorted(corpus)}")


def _search_one(spec: MethodSpec, doc_reps: SparseRep,
                q_reps: SparseRep, vocab: int, k: int,
                doc_ids: np.ndarray) -> np.ndarray:
    """External-id ``(B, k)`` ranking for one method config."""
    if spec.doc_shards:
        from repro.retrieval import retrieve, shard_index

        sidx = shard_index(doc_reps, vocab, spec.doc_shards)
        _, idx = retrieve(q_reps, sidx, k, method="sharded",
                          **dict(spec.search))
        idx = np.asarray(idx)
        ext = np.full(idx.shape, -1, np.int64)
        ok = idx >= 0
        ext[ok] = doc_ids[np.clip(idx, 0, doc_ids.shape[0] - 1)][ok]
        return ext
    from repro.retrieval import IndexBuilder

    builder = IndexBuilder(vocab, **dict(spec.engine))
    builder.add(doc_reps, ids=doc_ids)
    builder.flush()
    _, ext = builder.search(q_reps, k, **dict(spec.search))
    return np.asarray(ext)


def evaluate_retrieval(
    encoder: Optional[Callable[[Any, Any], Any]],
    corpus: Mapping[str, Any],
    qrels: Qrels,
    *,
    methods: Sequence[MethodSpec] = DEFAULT_METHODS,
    ks: Tuple[int, ...] = (10,),
    metrics: Tuple[str, ...] = METRIC_NAMES,
    doc_ids: Optional[Sequence[int]] = None,
    query_ids: Optional[Sequence[int]] = None,
    batch: int = 32,
    rep_topk: int = 64,
) -> Dict[str, Dict[str, float]]:
    """The full quality loop for every method: per-method metric dicts
    ``{"exact": {"mrr@10": ..., "ndcg@10": ...}, "pruned": {...}}``.

    ``doc_ids`` are the external ids documents are ingested under
    (default ``arange(N)``) — ``qrels`` must be keyed consistently.
    ``query_ids`` aligns ranking rows with qrels queries (default:
    query b of the corpus is qrels query b, i.e. ``range(B)``).
    Retrieval depth is ``max(ks)``; metrics at every ``k`` in ``ks``.
    """
    doc_reps, q_reps, vocab = _corpus_reps(
        encoder, corpus, batch=batch, rep_topk=rep_topk)
    n_docs = doc_reps.values.reshape(-1, doc_reps.width).shape[0]
    n_queries = q_reps.values.reshape(-1, q_reps.width).shape[0]
    ids = (np.arange(n_docs, dtype=np.int64) if doc_ids is None
           else np.asarray(list(doc_ids), np.int64))
    if ids.shape[0] != n_docs:
        raise ValueError(f"{ids.shape[0]} doc_ids for {n_docs} docs")
    qids = (list(range(n_queries)) if query_ids is None
            else list(query_ids))

    depth = max(ks)
    out: Dict[str, Dict[str, float]] = {}
    for spec in methods:
        ranked = _search_one(spec, doc_reps, q_reps, vocab, depth, ids)
        out[spec.name] = compute_metrics(ranked, qrels, ks=ks,
                                         query_ids=qids,
                                         metrics=metrics)
    return out
