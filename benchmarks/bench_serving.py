"""Serving-runtime traffic simulation: offered vs sustained QPS,
latency percentiles, shedding, degradation, and fault survival.

Drives the hardened ``ServingLoop`` (DESIGN.md §10) with an open-loop
Poisson arrival process on a **simulated clock** — encode and search
costs are deterministic time advances, so the record is bit-stable
across machines and CI runs (no wall-clock noise), while the loop
under test is the real production code path (admission control,
expiry shedding, bisect fault isolation, the degrade ladder).

Three experiments behind ``BENCH_serving.json``:

* ``phases`` — a warm → overload → recovery QPS ramp. Offered load in
  the overload phase exceeds exact-mode capacity ~2.3x: the loop must
  shed (admission + expiry) and walk the degrade ladder to survive,
  then climb back to ``exact`` when load drops. Each phase reports
  offered/sustained QPS, p50/p99 encode-completion latency, shed
  rate, and degrade transitions.
* ``degrade_quality`` — what each ladder rung costs in retrieval
  quality: nDCG@10 (shared ``repro.eval`` metrics) against the graded
  synthetic corpus's own qrels, searching with each rung's
  ``prune_margin``/``q_width`` knobs. Exact scores 1.0 by construction,
  so rung values read directly as absolute quality retained.
* ``faults`` — the same loop under an injected fault plan
  (``runtime/faults.py``): a persistent poison request, a transient
  OOM (exercises the adaptive batch cap), and a latency spike. The
  bar: zero lost requests — every submitted uid is exactly one of
  served/shed/failed, only poisoned uids fail.

``--smoke`` (or ``BENCH_SMOKE=1``) shortens the phases for CI;
``benchmarks/check.py`` gates the record, ``report.py`` trends it.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.workload import (ENCODE_BASE_S, ENCODE_ITEM_S,
                                 REP_WIDTH, VOCAB, SimClock,
                                 make_sim_encoder, poisson_arrivals,
                                 pump, uniform_query)
from repro.runtime.faults import inject_faults
from repro.runtime.serving import (AdmissionPolicy, BatchedEncoder,
                                   BatchPolicy, DegradeController,
                                   DegradePolicy, FailedResult, Request,
                                   ServingLoop, ShedResult)

DOC_LEN = 24
SLO_S = 0.05
MAX_BATCH = 16
MAX_WAIT_S = 0.005
MAX_QUEUE = 256
# simulated per-query search cost by ladder rung (exact -> minimal):
# the quality/latency trade the degrade ladder exploits
SEARCH_COST_S = (0.004, 0.0025, 0.0012, 0.0006)
# exact-mode capacity ≈ 1 / (ENCODE_ITEM_S + ENCODE_BASE_S/MAX_BATCH
# + SEARCH_COST_S[0]) ≈ 215 qps — the ramp brackets it
PHASES = (("warm", 80.0), ("overload", 500.0), ("recovery", 80.0))
FULL = dict(n_docs=1024, durations=(5.0, 8.0, 8.0), fault_s=4.0,
            fault_qps=150.0, n_probes=16)
SMOKE = dict(n_docs=256, durations=(1.5, 2.0, 2.5), fault_s=1.5,
             fault_qps=150.0, n_probes=8)
POISON_TOKEN = VOCAB + 7
POISON_EVERY = 40


def _pct(lat_s: np.ndarray, q: float) -> float:
    return float(np.percentile(lat_s, q)) * 1e3 if lat_s.size else 0.0


def run_traffic(durations) -> List[Dict]:
    clock = SimClock()
    ctl = DegradeController(DegradePolicy(slo_s=SLO_S))
    loop = ServingLoop(
        BatchedEncoder(
            make_sim_encoder(clock,
                             item_cost=lambda: SEARCH_COST_S[ctl.level]),
            policy=BatchPolicy(max_batch=MAX_BATCH,
                               max_wait_s=MAX_WAIT_S)),
        clock=clock,
        admission=AdmissionPolicy(max_queue_depth=MAX_QUEUE),
        degrade=ctl, window=1 << 16)
    rng = np.random.default_rng(1)
    uid = 0
    phases = []
    for (name, qps), dur in zip(PHASES, durations):
        t0, c0 = clock.t, dict(loop.counters)
        lat0, tr0 = loop.latencies().size, len(ctl.transitions)
        t_end = t0 + dur
        n_offered = 0
        for t_arr in poisson_arrivals(rng, qps, t0, t_end):
            pump(loop, clock, t_arr)
            toks = uniform_query(rng)
            loop.submit(Request(uid=uid, tokens=toks,
                                deadline_s=SLO_S))
            uid += 1
            n_offered += 1
        pump(loop, clock, t_end)
        if name == PHASES[-1][0]:
            while loop.pending:            # settle the tail
                loop.tick(force=True)
        c1 = loop.counters
        lat = loop.latencies()[lat0:]
        span = max(clock.t - t0, 1e-9)
        served = c1["served"] - c0.get("served", 0)
        shed = (c1["shed_admission"] + c1["shed_expired"]
                - c0.get("shed_admission", 0)
                - c0.get("shed_expired", 0))
        phases.append({
            "name": name,
            "offered_qps": round(n_offered / span, 2),
            "sustained_qps": round(served / span, 2),
            "served": served,
            "shed": shed,
            "failed": c1["failed"] - c0.get("failed", 0),
            "shed_rate": round(shed / max(1, n_offered), 4),
            "p50_ms": round(_pct(lat, 50), 3),
            "p99_ms": round(_pct(lat, 99), 3),
            "degrade_transitions": len(ctl.transitions) - tr0,
            "degrade_state_end": ctl.level,
            "degrade_name_end": ctl.step.name,
        })
    # every uid accounted for (served results pile up in completed)
    assert len(loop.completed) == uid, "lost/duplicated uids in sim"
    return phases


def run_degrade_quality(n_docs: int, n_probes: int, k: int = 10
                        ) -> Dict[str, float]:
    """nDCG@10 per ladder rung on the graded synthetic corpus.

    Scored with the shared ``repro.eval`` metrics against the corpus's
    own qrels (not top-k overlap vs the exact rung, which can't see
    *ranking* damage among the overlapping ids). The planted geometry
    makes the exact rung score exactly 1.0 — doc_nnz=32 / q_nnz=24 /
    graded=7 at this vocab is wide enough that no background doc
    outscores a planted grade — so every lower rung's number reads
    directly as "quality paid for that rung's latency".
    """
    import jax.numpy as jnp

    from repro.data.synthetic import lsr_impact_corpus
    from repro.eval import Qrels
    from repro.eval.metrics import compute_metrics
    from repro.retrieval import IndexBuilder
    from repro.retrieval.sparse_rep import sparsify_topk

    corpus = lsr_impact_corpus(n_docs=n_docs, vocab=VOCAB, doc_nnz=32,
                               n_queries=n_probes, q_nnz=24, graded=7,
                               seed=0)
    qrels = Qrels.from_triples(corpus["qrels"])
    doc_reps = sparsify_topk(jnp.asarray(corpus["docs"]), 32)
    probes = sparsify_topk(jnp.asarray(corpus["queries"]), 24)
    builder = IndexBuilder(VOCAB, keep_forward=True)
    builder.add(doc_reps)
    builder.flush()
    out = {}
    for step in DegradePolicy().ladder:
        kw = dict(step.search_kwargs)
        if step.q_width_frac < 1.0:
            kw["q_width"] = max(1, int(probes.width
                                       * step.q_width_frac))
        _, ids = builder.search(probes, k, **kw)
        m = compute_metrics(np.asarray(ids), qrels, ks=(k,),
                            metrics=("ndcg",))
        out[step.name] = round(m[f"ndcg@{k}"], 4)
    return out


def run_faults(duration: float, qps: float) -> Dict:
    clock = SimClock()
    plan = [
        # one poison request shape: any batch containing the token
        # fails, forever — bisect isolation must carve it out
        {"on": {"token": POISON_TOKEN}, "exc": "fault"},
        # a transient OOM: the adaptive cap halves, the batch is
        # served on retry, the cap grows back
        {"on": {"call": 10}, "exc": "oom", "times": 1},
        # a latency spike (not a failure)
        {"on": {"call": 25}, "do": "delay", "delay_s": 0.08,
         "times": 1},
    ]
    faulty = inject_faults(
        make_sim_encoder(clock,
                         item_cost=lambda: SEARCH_COST_S[0]),
        plan, seed=0, sleep=clock.advance)
    loop = ServingLoop(
        BatchedEncoder(faulty,
                       policy=BatchPolicy(max_batch=MAX_BATCH,
                                          max_wait_s=MAX_WAIT_S)),
        clock=clock,
        admission=AdmissionPolicy(max_queue_depth=MAX_QUEUE),
        window=1 << 16)
    rng = np.random.default_rng(2)
    uid, poisoned = 0, []
    min_cap = MAX_BATCH
    for t_arr in poisson_arrivals(rng, qps, 0.0, duration):
        pump(loop, clock, t_arr)
        min_cap = min(min_cap, loop.stats()["batch_cap"])
        toks = uniform_query(rng)
        if uid % POISON_EVERY == 7:
            toks[0] = POISON_TOKEN
            poisoned.append(uid)
        loop.submit(Request(uid=uid, tokens=toks, deadline_s=SLO_S))
        uid += 1
    while loop.pending:
        loop.tick(force=True)
    served = shed = 0
    failed_uids = []
    for u in range(uid):
        res = loop.take(u)      # KeyError here == a lost uid
        if isinstance(res, FailedResult):
            failed_uids.append(u)
        elif isinstance(res, ShedResult):
            shed += 1
        else:
            served += 1
    lost = uid - served - shed - len(failed_uids)
    return {
        "submitted": uid,
        "served": served,
        "shed": shed,
        "failed": len(failed_uids),
        "lost": lost,
        "poisoned": len(poisoned),
        "poisoned_failed": sum(1 for u in failed_uids
                               if u in set(poisoned)),
        "failed_outside_poison": sum(1 for u in failed_uids
                                     if u not in set(poisoned)),
        "encode_faults": int(loop.counters["faults"]),
        "oom_faults": int(loop.counters["oom_faults"]),
        "min_batch_cap": int(min_cap),
        "end_batch_cap": int(loop.stats()["batch_cap"]),
        "injector_firings": len(faulty.log),
    }


def run(smoke: bool = False, json_path: str = None):
    smoke = smoke or os.environ.get("BENCH_SMOKE") == "1"
    p = SMOKE if smoke else FULL

    phases = run_traffic(p["durations"])
    quality = run_degrade_quality(p["n_docs"], p["n_probes"])
    faults = run_faults(p["fault_s"], p["fault_qps"])

    record = {
        "shape": {"vocab": VOCAB, "rep_width": REP_WIDTH,
                  "n_docs": p["n_docs"], "max_batch": MAX_BATCH,
                  "max_queue": MAX_QUEUE},
        "slo_ms": SLO_S * 1e3,
        "search_cost_ms": [c * 1e3 for c in SEARCH_COST_S],
        "phases": phases,
        "quality_metric": "ndcg@10",
        "degrade_quality": quality,
        "faults": faults,
    }

    print("phase,offered_qps,sustained_qps,p50_ms,p99_ms,shed_rate,"
          "degrade_end")
    for ph in phases:
        print(f"{ph['name']},{ph['offered_qps']},"
              f"{ph['sustained_qps']},{ph['p50_ms']},{ph['p99_ms']},"
              f"{ph['shed_rate']},{ph['degrade_name_end']}")
    print("degrade quality (nDCG@10 vs qrels): "
          + ", ".join(f"{n}={v}" for n, v in quality.items()))
    print(f"faults: {faults['submitted']} submitted -> "
          f"{faults['served']} served / {faults['shed']} shed / "
          f"{faults['failed']} failed ({faults['lost']} lost, "
          f"{faults['poisoned_failed']}/{faults['poisoned']} poisoned "
          f"isolated, cap {MAX_BATCH}->{faults['min_batch_cap']}->"
          f"{faults['end_batch_cap']})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="emit BENCH_serving.json-style record here")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)
