"""Tests for the hardened serving runtime (DESIGN.md §10).

Everything runs on a fake clock and a numpy encode stub — no jit, no
accelerator. The acceptance bar from the issue is pinned verbatim in
``test_persistent_poison_isolated_exactly``: a persistent
single-request fault inside a full batch serves every other request,
fails exactly the poisoned uid with a ``FailedResult``, and never
raises out of ``tick()``.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.retrieval.sparse_rep import SparseRep, truncate_width
from repro.runtime.faults import (FaultError, FaultInjector,
                                  ResourceExhausted, TransientFault,
                                  inject_faults, is_oom_error)
from repro.runtime.serving import (Admission, AdmissionPolicy,
                                   BatchedEncoder, BatchPolicy,
                                   CorpusEngine, DegradeController,
                                   DegradePolicy, DegradeStep,
                                   FailedResult, Request, ServingLoop,
                                   ShedResult)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def np_encoder(width=4, cost=0.0, clock=None, vocab=64):
    """Pure-numpy encode fn: top-``width`` token counts per row."""

    def encode(tokens, mask):
        toks = np.asarray(tokens)
        msk = np.asarray(mask)
        if clock is not None and cost:
            clock.advance(cost)
        B = toks.shape[0]
        vals = np.zeros((B, width), np.float32)
        idxs = np.zeros((B, width), np.int32)
        for i in range(B):
            ids, counts = np.unique(toks[i][msk[i] > 0] % vocab,
                                    return_counts=True)
            order = np.argsort(-counts, kind="stable")[:width]
            vals[i, :order.size] = counts[order]
            idxs[i, :order.size] = ids[order]
        return SparseRep(vals, idxs,
                         (vals > 0).sum(axis=1).astype(np.int32))

    return encode


def make_loop(clock, *, encode=None, max_batch=8, max_wait_s=10.0,
              admission=None, degrade=None, **kw):
    return ServingLoop(
        BatchedEncoder(encode or np_encoder(),
                       policy=BatchPolicy(max_batch=max_batch,
                                          max_wait_s=max_wait_s)),
        clock=clock, admission=admission, degrade=degrade, **kw)


def req(uid, deadline_s=None, token=None):
    toks = np.arange(1, 9, dtype=np.int32)
    if token is not None:
        toks = toks.copy()
        toks[0] = token
    return Request(uid=uid, tokens=toks, deadline_s=deadline_s)


# ---------------------------------------------------------------------------
# fault plans (runtime/faults.py)
# ---------------------------------------------------------------------------

def test_call_trigger_fires_once_at_index():
    inj = FaultInjector(lambda x: x, [{"on": {"call": 2}}])
    assert inj(0) == 0 and inj(1) == 1
    with pytest.raises(FaultError):
        inj(2)
    assert inj(3) == 3          # "call" matches one index only
    assert inj.log == [(2, 0, "raise")]


def test_every_trigger_and_times_budget():
    inj = FaultInjector(lambda x: x,
                        [{"on": {"every": 2}, "times": 2,
                          "exc": "transient"}])
    outcomes = []
    for i in range(8):
        try:
            inj(i)
            outcomes.append("ok")
        except TransientFault:
            outcomes.append("boom")
    # fires on calls 1 and 3 (every 2nd), then the budget is spent
    assert outcomes == ["ok", "boom", "ok", "boom",
                        "ok", "ok", "ok", "ok"]


def test_token_trigger_matches_first_arg_contents():
    inj = FaultInjector(lambda t, m: "enc", [{"on": {"token": 99}}])
    assert inj(np.array([[1, 2], [3, 4]]), None) == "enc"
    with pytest.raises(FaultError):
        inj(np.array([[1, 99]]), None)


def test_prob_trigger_is_seed_deterministic():
    def firing_calls(seed):
        inj = FaultInjector(lambda x: x,
                            [{"on": {"prob": 0.3}}], seed=seed)
        fired = []
        for i in range(50):
            try:
                inj(i)
            except FaultError:
                fired.append(i)
        return fired

    a, b = firing_calls(7), firing_calls(7)
    assert a == b and a          # same seed -> same calls, and some fire
    assert firing_calls(8) != a  # different seed -> different stream


def test_delay_rule_sleeps_and_proceeds():
    clock = FakeClock()
    inj = FaultInjector(lambda x: x * 2,
                        [{"on": {"call": 0}, "do": "delay",
                          "delay_s": 0.5}], sleep=clock.advance)
    assert inj(21) == 42        # spike, not a failure
    assert clock.t == 0.5
    assert inj.log == [(0, 0, "delay")]


def test_plan_validation():
    with pytest.raises(ValueError, match="exactly one"):
        FaultInjector(lambda: None, [{"on": {}}])
    with pytest.raises(ValueError, match="exactly one"):
        FaultInjector(lambda: None, [{"on": {"call": 0, "every": 2}}])
    with pytest.raises(ValueError, match="unknown do"):
        FaultInjector(lambda: None, [{"on": {"call": 0}, "do": "x"}])
    with pytest.raises(ValueError, match="unknown exc"):
        FaultInjector(lambda: None, [{"on": {"call": 0}, "exc": "x"}])


def test_is_oom_error_shapes():
    assert is_oom_error(ResourceExhausted("nope"))
    assert is_oom_error(RuntimeError("RESOURCE_EXHAUSTED: 2.1GiB"))
    assert is_oom_error(RuntimeError("cuda out of memory"))
    assert not is_oom_error(RuntimeError("shape mismatch"))
    assert not is_oom_error(TransientFault("blip"))


# ---------------------------------------------------------------------------
# admission + shedding
# ---------------------------------------------------------------------------

def test_queue_full_sheds_with_result():
    clock = FakeClock()
    loop = make_loop(clock,
                     admission=AdmissionPolicy(max_queue_depth=2))
    assert loop.submit(req(0)) is Admission.ACCEPTED
    assert loop.submit(req(1)) is Admission.ACCEPTED
    assert loop.submit(req(2)) is Admission.SHED
    r = loop.take(2)
    assert isinstance(r, ShedResult) and r.reason == "queue_full"
    assert loop.stats()["shed_admission"] == 1


def test_est_deadline_shed_uses_ewma():
    clock = FakeClock()
    loop = make_loop(clock, encode=np_encoder(cost=1.0, clock=clock),
                     max_batch=2)
    # establish the EWMA: one dispatched batch costing 1s
    loop.submit(req(0))
    loop.tick(force=True)
    assert loop.estimated_queue_delay(1) == pytest.approx(1.0)
    # queue one batch's worth; the next submit would wait ~2 batches
    loop.submit(req(1))
    loop.submit(req(2))
    assert loop.submit(req(3, deadline_s=0.5)) is Admission.SHED
    assert loop.take(3).reason == "est_deadline"
    # a lax deadline clears the same estimate
    assert loop.submit(req(4, deadline_s=10.0)) is Admission.ACCEPTED


def test_idle_loop_never_sheds_on_stale_estimate():
    clock = FakeClock()
    loop = make_loop(clock, encode=np_encoder(cost=5.0, clock=clock))
    loop.submit(req(0))
    loop.tick(force=True)       # EWMA is now 5s > any deadline below
    # empty queue: the never-starve rule admits despite the estimate
    assert loop.submit(req(1, deadline_s=0.1)) is Admission.ACCEPTED


def test_expired_requests_shed_before_encode():
    clock = FakeClock()
    calls = []
    base = np_encoder()

    def encode(tokens, mask):
        calls.append(np.asarray(tokens).shape[0])
        return base(tokens, mask)

    loop = make_loop(clock, encode=encode, max_batch=4)
    loop.submit(req(0, deadline_s=1.0))
    loop.submit(req(1))                      # best-effort neighbour
    clock.advance(2.0)                       # uid 0 is now dead
    assert loop.tick(force=True) == 1        # only uid 1 dispatched
    assert calls == [1]                      # no encode wasted on uid 0
    assert loop.take(0).reason == "expired"
    assert loop.take(0 + 1) is not None
    assert loop.stats()["shed_expired"] == 1


# ---------------------------------------------------------------------------
# poison isolation + adaptive cap
# ---------------------------------------------------------------------------

def test_persistent_poison_isolated_exactly():
    """The issue's acceptance test: a persistent single-request fault
    in a full batch serves all others, fails exactly the poisoned uid,
    and never raises out of tick()."""
    clock = FakeClock()
    POISON = 999
    encode = inject_faults(np_encoder(vocab=2048),
                           [{"on": {"token": POISON}}])
    loop = make_loop(clock, encode=encode, max_batch=8)
    for uid in range(8):
        loop.submit(req(uid, token=POISON if uid == 3 else None))
    assert loop.tick(force=True) == 8        # did not raise
    for uid in range(8):
        r = loop.take(uid)
        if uid == 3:
            assert isinstance(r, FailedResult)
            assert "fault" in r.error and not r.oom
        else:
            assert isinstance(r, SparseRep)
    st = loop.stats()
    assert st["served"] == 7 and st["failed"] == 1
    assert st["faults"] >= 2                 # full batch + bisect legs


def test_two_poisons_both_isolated():
    clock = FakeClock()
    POISON = 999
    encode = inject_faults(np_encoder(vocab=2048),
                           [{"on": {"token": POISON}}])
    loop = make_loop(clock, encode=encode, max_batch=8)
    for uid in range(8):
        loop.submit(req(uid, token=POISON if uid in (0, 7) else None))
    loop.tick(force=True)
    failed = {u for u in range(8)
              if isinstance(loop.take(u), FailedResult)}
    assert failed == {0, 7}


def test_transient_fault_batch_fully_served():
    clock = FakeClock()
    encode = inject_faults(np_encoder(),
                           [{"on": {"call": 0}, "exc": "transient",
                             "times": 1}])
    loop = make_loop(clock, encode=encode, max_batch=4)
    for uid in range(4):
        loop.submit(req(uid))
    loop.tick(force=True)
    # the retry halves hit a healed fn: everyone served, none failed
    assert all(isinstance(loop.take(u), SparseRep) for u in range(4))
    assert loop.stats()["failed"] == 0


def test_oom_halves_cap_and_regrows():
    clock = FakeClock()
    encode = inject_faults(np_encoder(),
                           [{"on": {"call": 0}, "exc": "oom",
                             "times": 1}])
    loop = make_loop(clock, encode=encode, max_batch=8)
    for uid in range(8):
        loop.submit(req(uid))
    loop.tick(force=True)
    st = loop.stats()
    assert st["oom_faults"] == 1 and st["batch_cap"] == 4
    assert st["served"] == 8                 # retry halves healed
    # grow_after_clean=4 clean dispatches double the cap back: 4 -> 8
    for round_ in range(8):
        for uid in range(100 + round_ * 4, 104 + round_ * 4):
            loop.submit(req(uid))
        loop.tick(force=True)
    assert loop.stats()["batch_cap"] == 8
    loop.drain()


def test_cap_feeds_dispatch_size():
    clock = FakeClock()
    encode = inject_faults(np_encoder(),
                           [{"on": {"call": 0}, "exc": "oom",
                             "times": 1}])
    loop = make_loop(clock, encode=encode, max_batch=8)
    for uid in range(16):
        loop.submit(req(uid))
    assert loop.tick(force=True) == 8        # pre-fault cap
    assert loop.tick(force=True) == 4        # halved by the OOM
    loop.drain()
    assert loop.stats()["served"] == 16


# ---------------------------------------------------------------------------
# degrade ladder
# ---------------------------------------------------------------------------

def test_controller_hysteresis_streaks():
    ctl = DegradeController(DegradePolicy(up_ticks=3, down_ticks=4))
    assert ctl.observe(0.9) == 0
    assert ctl.observe(0.9) == 0
    assert ctl.observe(0.9) == 1             # 3rd high sample degrades
    assert ctl.step.name == "pruned"
    # mid-band samples reset the recovery streak
    ctl.observe(0.1), ctl.observe(0.1), ctl.observe(0.1)
    assert ctl.observe(0.5) == 1             # streak broken
    for _ in range(3):
        ctl.observe(0.1)
    assert ctl.observe(0.1) == 0             # 4 consecutive lows recover
    assert ctl.transitions == [(3, 0, 1), (11, 1, 0)]


def test_controller_clamps_at_ladder_ends():
    ctl = DegradeController(DegradePolicy(up_ticks=1, down_ticks=1))
    n = len(ctl.policy.ladder)
    for _ in range(n + 3):
        ctl.observe(0.95)
    assert ctl.level == n - 1                # stuck at "minimal"
    for _ in range(n + 3):
        ctl.observe(0.0)
    assert ctl.level == 0


def test_step_kwargs_and_q_width():
    ctl = DegradeController()
    assert ctl.search_kwargs() == {}
    assert ctl.q_width(48) == 48
    ctl.level = 2
    assert ctl.search_kwargs() == {"method": "pruned",
                                   "prune_margin": 0.5}
    assert ctl.q_width(48) == 24
    ctl.level = 3
    assert ctl.q_width(1) == 1               # floor at one term


def test_loop_pressure_reaches_controller():
    clock = FakeClock()
    ctl = DegradeController(DegradePolicy(slo_s=1.0, up_ticks=2))
    loop = make_loop(clock, encode=np_encoder(cost=2.0, clock=clock),
                     max_batch=1, degrade=ctl,
                     admission=AdmissionPolicy(max_queue_depth=100))
    for uid in range(4):
        loop.submit(req(uid))
    # each tick serves one 2s batch; est delay for the rest >> slo
    loop.tick(force=True)
    loop.tick(force=True)
    loop.tick(force=True)
    assert ctl.level >= 1                    # sustained pressure degraded
    assert loop.stats()["degrade_level"] == ctl.level
    assert loop.stats()["degrade_name"] == ctl.step.name


def test_shed_fraction_is_a_pressure_signal():
    clock = FakeClock()
    ctl = DegradeController(DegradePolicy(up_ticks=2))
    loop = make_loop(clock, degrade=ctl,
                     admission=AdmissionPolicy(max_queue_depth=2))
    # bounce enough submits that the shed fraction alone is high,
    # while the queue itself stays tiny (2 deep of 2 max is depth
    # pressure 1.0 too, so drain between — the shed marks persist)
    for uid in range(40):
        loop.submit(req(uid))                # 38 of 40 shed
    loop.drain()
    assert loop.tick() == 0 and loop.tick() == 0   # observe on empty q
    assert ctl.level >= 1


def test_truncate_width_keeps_largest_terms():
    rep = SparseRep(
        np.array([[1.0, 5.0, 3.0, 0.0]], np.float32),
        np.array([[10, 11, 12, 13]], np.int32),
        np.array([3], np.int32))
    cut = truncate_width(rep, 2)
    assert cut.width == 2
    assert cut.indices.tolist() == [[11, 12]]
    assert cut.values.tolist() == [[5.0, 3.0]]
    assert cut.nnz.tolist() == [2]
    assert truncate_width(rep, 8) is rep     # widening is a no-op
    with pytest.raises(ValueError):
        truncate_width(rep, 0)


# ---------------------------------------------------------------------------
# stats, bounded windows, drain, engine fail-fast
# ---------------------------------------------------------------------------

def test_stats_keys_and_percentiles():
    clock = FakeClock()
    loop = make_loop(clock, encode=np_encoder(cost=0.5, clock=clock),
                     max_batch=4)
    for uid in range(4):
        loop.submit(req(uid))
    loop.tick(force=True)
    st = loop.stats()
    for key in ("queue_depth", "submitted", "served", "shed",
                "shed_admission", "shed_expired", "failed", "faults",
                "oom_faults", "batch_cap", "batch_occupancy",
                "encode_ewma_s", "p50_latency_s", "p99_latency_s"):
        assert key in st, key
    assert st["served"] == 4 and st["queue_depth"] == 0
    assert st["batch_occupancy"] == 1.0
    assert st["p50_latency_s"] == pytest.approx(0.5)
    assert st["encode_ewma_s"] == pytest.approx(0.5)


def test_stats_windows_are_bounded():
    clock = FakeClock()
    loop = make_loop(clock, max_batch=1, window=8)
    for uid in range(50):
        loop.submit(req(uid))
        loop.tick(force=True)
    assert len(loop.batch_sizes) == 8
    assert loop.latencies().size == 8
    assert loop.stats()["served"] == 50      # counters still exact


def test_drain_one_batch_per_forced_tick():
    clock = FakeClock()
    loop = make_loop(clock, max_batch=4)
    for uid in range(10):
        loop.submit(req(uid))
    sizes = []
    while loop.pending:
        sizes.append(loop.tick(force=True))
    assert sizes == [4, 4, 2]
    loop2 = make_loop(clock, max_batch=4)
    for uid in range(10):
        loop2.submit(req(uid))
    loop2.drain()
    assert not loop2.pending and len(loop2.completed) == 10


def test_corpus_engine_fail_fast_on_dense_encoder():
    calls = []

    def dense_encode(tokens, mask):
        calls.append(np.asarray(tokens).shape[0])
        return np.zeros((np.asarray(tokens).shape[0], 8), np.float32)

    eng = CorpusEngine(
        BatchedEncoder(dense_encode,
                       policy=BatchPolicy(max_batch=4)), 64)
    docs = [np.arange(1, 9, dtype=np.int32)] * 12
    with pytest.raises(ValueError, match="sparse encoder"):
        eng.add_docs(docs)
    assert calls == [4]          # first chunk only — no wasted encodes


# ---------------------------------------------------------------------------
# the completion invariant (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       max_batch=st.integers(min_value=1, max_value=6),
       max_queue=st.integers(min_value=1, max_value=12))
def test_every_uid_completes_exactly_once(seed, max_batch, max_queue):
    """Under random interleavings of submits/ticks/time, with poison
    requests, tight deadlines and an OOM, every submitted uid ends as
    exactly one of served / shed / failed — nothing lost, nothing
    duplicated, nothing raised."""
    rng = np.random.default_rng(seed)
    clock = FakeClock()
    POISON = 999
    encode = inject_faults(
        np_encoder(cost=0.05, clock=clock, vocab=2048),
        [{"on": {"token": POISON}},
         {"on": {"call": 3}, "exc": "oom", "times": 1}])
    loop = make_loop(clock, encode=encode, max_batch=max_batch,
                     max_wait_s=0.01,
                     admission=AdmissionPolicy(max_queue_depth=max_queue))
    uid = 0
    poisoned = set()
    for _ in range(60):
        op = rng.integers(0, 4)
        if op == 0:
            deadline = (float(rng.uniform(0.01, 0.5))
                        if rng.random() < 0.5 else None)
            poison = rng.random() < 0.15
            loop.submit(req(uid, deadline_s=deadline,
                            token=POISON if poison else None))
            if poison:
                poisoned.add(uid)
            uid += 1
        elif op == 1:
            loop.tick()
        elif op == 2:
            clock.advance(float(rng.uniform(0.0, 0.1)))
        else:
            loop.tick(force=True)
    loop.drain()

    outcomes = {u: loop.take(u) for u in range(uid)}
    assert not loop.completed                # exactly once: take pops
    for u, r in outcomes.items():
        if isinstance(r, FailedResult):
            # the one-shot OOM may land on a singleton batch (which
            # cannot bisect further); every *non-OOM* failure must be
            # a poisoned uid — isolation never leaks
            assert u in poisoned or r.oom
        else:
            assert isinstance(r, (SparseRep, ShedResult))
    st = loop.stats()
    assert st["served"] + st["shed"] + st["failed"] == uid
