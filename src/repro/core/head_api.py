"""Unified head API — one spec, one registry, one factory (DESIGN.md §6).

The paper contributes a single operator (Eq. 1), but the repo grew four
divergent surfaces for it: the pure-JAX ladder in ``core.lm_head``, the
Pallas wrapper in ``kernels.ops`` (with its own kwarg spellings), the
shard_map factory in ``core.sharded``, and per-call-site if/else
ladders in ``launch``/``benchmarks``/``examples``. This module is the
single seam where "which impl, which blocks, which mesh" is decided:

* ``HeadSpec``            — frozen, hashable description of a head
  configuration (impl name, Pallas blocks, scan tile, softcap, ...).
* ``register_head_impl``  — registry of backends with ONE normalized
  calling convention ``fn(H, E, b, mask, *, spec) -> Y``. ``naive``,
  ``tiled``, ``sparton`` (pure JAX) and ``kernel`` (Pallas) ship
  registered; new backends (two-pass backward, per-kernel blocks) are
  one ``register_head_impl`` call, not another if/else.
* ``make_head(spec, mesh=...)`` — factory returning one canonical
  callable ``head(H, E, b=None, mask=None) -> Y`` regardless of
  backend or sharding. With a mesh, the *selected impl* runs inside
  the vocab-sharded ``shard_map`` body — including the Pallas kernel,
  whose block sizes resolve against the **local** vocab shard
  ``V // n_model`` (the shapes the kernel actually sees), so the
  autotune cache is keyed per shard, not per global vocab.

Sharding contract (global view), identical to ``core.sharded``:

    H    (B, S, D)  — batch over ``batch_axes``, replicated over model
    E    (V, D)     — rows over ``axis_name``
    b    (V,)       — over ``axis_name``
    Y    (B, V)     — batch over ``batch_axes``, vocab over ``axis_name``

The streaming max is per-vocab-column independent, so the sharded
forward needs zero collectives and ``∇E`` is shard-local; the single
``∇H`` psum over ``axis_name`` is inserted by shard_map's transpose.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import lm_head as _lm

Array = jax.Array

# Registered backend convention: fn(H, E, b, mask, *, spec) -> (B, V).
# H (B, S, D); E (V, D); b (V,) f32; mask (B, S) int32/bool — all
# concrete (make_head fills the b/mask defaults before dispatch).
HeadFn = Callable[..., Array]


@dataclasses.dataclass(frozen=True)
class HeadSpec:
    """Everything needed to build a Sparton head, in one hashable value.

    ``impl``            registry name: naive | tiled | sparton | kernel
                        (plus anything registered at runtime).
    ``block_b/s/v``     Pallas kernel blocks; None = autotuner cache /
                        heuristic for the call shape (local shard shape
                        under a mesh). Ignored by the pure-JAX impls.
    ``vocab_tile``      streaming-scan tile of the pure-JAX impls.
    ``logit_softcap``   gemma-2 style ``c * tanh(x / c)`` on the raw
                        logits; the ONE canonical spelling (the legacy
                        ``softcap=`` kwarg is deprecated).
    ``out_dtype``       output dtype; None = H.dtype.
    ``interpret``       Pallas interpreter toggle; None = auto
                        (interpret off-TPU, compiled on TPU).
    ``bwd_batch_chunk`` batch chunking of the pure-JAX backward scan.
    ``unroll``          scan unroll of the pure-JAX impls (cost probes).
    ``rep_topk``        sparsify the (B, V) head output to its top-k
                        terms per row (Unified-LSR model knob); the
                        reduction runs on-device via the streaming
                        merge, so the dense rep never reaches host.
    ``rep_threshold``   drop rep entries at or below this impact
                        weight. Composes with ``rep_topk``; alone it
                        caps rows at ``rep_max_nnz`` slots (largest
                        entries win).
    ``rep_max_nnz``     static slot budget of threshold-only
                        sparsification. Both rep knobs None = dense
                        (B, V) output, the pre-sparse default.
    """

    impl: str = "sparton"
    block_b: Optional[int] = None
    block_s: Optional[int] = None
    block_v: Optional[int] = None
    vocab_tile: int = 4096
    logit_softcap: Optional[float] = None
    out_dtype: Optional[str] = None
    interpret: Optional[bool] = None
    bwd_batch_chunk: int = 8
    unroll: int = 1
    rep_topk: Optional[int] = None
    rep_threshold: Optional[float] = None
    rep_max_nnz: int = 256

    @property
    def sparse_reps(self) -> bool:
        """Whether encoders built from this spec emit SparseReps."""
        return self.rep_topk is not None or self.rep_threshold is not None

    def replace(self, **kw) -> "HeadSpec":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, HeadFn] = {}


def register_head_impl(name: str, fn: HeadFn) -> None:
    """Register (or override) a head backend under ``name``.

    ``fn(H, E, b, mask, *, spec: HeadSpec) -> (B, V)`` with concrete
    ``b``/``mask`` — the factory normalizes the optional arguments
    before dispatch, so backends never see ``None``.
    """
    _REGISTRY[name] = fn


def available_impls() -> Tuple[str, ...]:
    """Registered backend names (the user-facing impl enumeration)."""
    return tuple(sorted(_REGISTRY))


def get_head_impl(name: str) -> HeadFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown head impl {name!r}; one of {list(available_impls())}"
        ) from None


def normalize_softcap_kwarg(
    logit_softcap: Optional[float],
    softcap: Optional[float],
    where: str,
) -> Optional[float]:
    """Fold the deprecated ``softcap=`` spelling into ``logit_softcap``."""
    if softcap is None:
        return logit_softcap
    warnings.warn(
        f"{where}: the 'softcap' kwarg is deprecated; use "
        "'logit_softcap' (one normalized name across every head "
        "surface)", DeprecationWarning, stacklevel=3)
    if logit_softcap is not None and logit_softcap != softcap:
        raise ValueError(
            f"{where}: conflicting logit_softcap={logit_softcap!r} and "
            f"deprecated softcap={softcap!r}")
    return softcap


def _cast_out(y: Array, H: Array, spec: HeadSpec) -> Array:
    return y.astype(jnp.dtype(spec.out_dtype) if spec.out_dtype else H.dtype)


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

def _naive_impl(H, E, b, mask, *, spec: HeadSpec) -> Array:
    y = _lm.lm_head_naive(H, E, b, mask, logit_softcap=spec.logit_softcap)
    return _cast_out(y, H, spec)


def _tiled_impl(H, E, b, mask, *, spec: HeadSpec) -> Array:
    y = _lm.lm_head_tiled(H, E, b, mask, vocab_tile=spec.vocab_tile,
                          logit_softcap=spec.logit_softcap)
    return _cast_out(y, H, spec)


def _sparton_impl(H, E, b, mask, *, spec: HeadSpec) -> Array:
    y = _lm.lm_head_sparton(
        H, E, b, mask, vocab_tile=spec.vocab_tile,
        logit_softcap=spec.logit_softcap,
        bwd_batch_chunk=spec.bwd_batch_chunk, unroll=spec.unroll)
    return _cast_out(y, H, spec)


def _kernel_impl(H, E, b, mask, *, spec: HeadSpec) -> Array:
    # Lazy import: keep core importable without pulling Pallas until a
    # kernel head is actually built.
    from repro.kernels.ops import sparton_head

    interpret = spec.interpret
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # Block resolution happens here, against the shapes this call sees:
    # under shard_map that is the LOCAL vocab shard (V // n_model), so
    # the autotune cache key matches the shard the kernel runs on.
    y = sparton_head(
        H, E, b, mask,
        block_b=spec.block_b, block_s=spec.block_s, block_v=spec.block_v,
        logit_softcap=spec.logit_softcap, interpret=interpret,
        out_dtype=jnp.dtype(spec.out_dtype) if spec.out_dtype else None)
    return y


register_head_impl("naive", _naive_impl)
register_head_impl("tiled", _tiled_impl)
register_head_impl("sparton", _sparton_impl)
register_head_impl("kernel", _kernel_impl)


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def _with_defaults(H: Array, E: Array, b: Optional[Array],
                   mask: Optional[Array]) -> Tuple[Array, Array]:
    if b is None:
        b = jnp.zeros((E.shape[0],), jnp.float32)
    if mask is None:
        mask = jnp.ones(H.shape[:2], jnp.int32)
    return b, mask


def make_head(
    spec: HeadSpec,
    mesh: Optional[Mesh] = None,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
) -> Callable[..., Array]:
    """One canonical ``head(H, E, b=None, mask=None) -> Y`` callable.

    Without a mesh: the registered backend, called directly.

    With a mesh: the backend wrapped in the vocab-sharded shard_map
    body (E/b rows over ``axis_name``, H/Y batch over ``batch_axes``).
    Vocab divisibility is a property of the *call* (``E.shape[0]``),
    not the factory, so the returned callable dispatches per call:
    divisible vocab runs the sharded body; a non-divisible vocab falls
    back to the unsharded GSPMD-partitionable path — demoting
    ``impl="kernel"`` to ``"sparton"`` there, because ``pallas_call``
    has no SPMD partitioning rule outside shard_map.
    """
    impl_fn = get_head_impl(spec.impl)

    if mesh is None:
        def head(H, E, b=None, mask=None):
            b, mask = _with_defaults(H, E, b, mask)
            return impl_fn(H, E, b, mask, spec=spec)
        return head

    n_shard = mesh.shape[axis_name]

    def body(h, e, b_, m_):
        return impl_fn(h, e, b_, m_, spec=spec)

    sharded = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),   # H — replicated over model
            P(axis_name, None),          # E — vocab rows sharded
            P(axis_name),                # b
            P(batch_axes, None),         # mask
        ),
        out_specs=P(batch_axes, axis_name),
        check_vma=False,  # custom_vjp inside: skip replication check
    )

    if spec.impl == "kernel":
        # pallas_call only partitions via shard_map; the unsharded
        # fallback must stay GSPMD-lowerable under the caller's jit.
        fallback_spec = spec.replace(impl="sparton")
        fallback_fn = get_head_impl("sparton")
    else:
        fallback_spec, fallback_fn = spec, impl_fn

    def head(H, E, b=None, mask=None):
        b, mask = _with_defaults(H, E, b, mask)
        if E.shape[0] % n_shard == 0:
            return sharded(H, E, b, mask)
        warnings.warn(
            f"make_head: vocab {E.shape[0]} not divisible by "
            f"{n_shard} {axis_name!r} shards — running the unsharded "
            f"{fallback_spec.impl!r} head under GSPMD")
        return fallback_fn(H, E, b, mask, spec=fallback_spec)

    return head


def make_sparsifier(spec: HeadSpec) -> Optional[Callable[[Array], "object"]]:
    """The spec's rep sparsifier ``(B, V) -> SparseRep``, or None when
    both rep knobs are off (dense output)."""
    if not spec.sparse_reps:
        return None
    # lazy: keep core importable without pulling the retrieval package
    from repro.retrieval.sparse_rep import (sparsify_threshold,
                                            sparsify_topk)

    if spec.rep_topk is not None:
        topk, thr = spec.rep_topk, spec.rep_threshold or 0.0
        return lambda y: sparsify_topk(y, topk, threshold=thr)
    threshold, max_nnz = spec.rep_threshold, spec.rep_max_nnz
    return lambda y: sparsify_threshold(y, threshold, max_nnz=max_nnz)


def make_encoder(
    spec: HeadSpec,
    mesh: Optional[Mesh] = None,
    *,
    axis_name: str = "model",
    batch_axes: Tuple[str, ...] = ("pod", "data"),
) -> Callable[..., "object"]:
    """Head + fused rep sparsifier: the post-head currency seam.

    Returns ``encode(H, E, b=None, mask=None)`` producing a
    ``SparseRep`` when the spec's ``rep_topk``/``rep_threshold`` knobs
    are set, else the dense ``(B, V)`` array (identical to
    ``make_head`` — the tested fallback). The sparsifier runs on the
    head output *before* any host transfer, so a sparse encoder never
    ships more than ``(B, K)`` per batch.
    """
    head = make_head(spec, mesh=mesh, axis_name=axis_name,
                     batch_axes=batch_axes)
    sparsify = make_sparsifier(spec)
    if sparsify is None:
        return head

    def encode(H, E, b=None, mask=None):
        return sparsify(head(H, E, b, mask))

    return encode
