"""Multi-device semantics: the vocab-sharded Sparton head, sharded
InfoNCE/FLOPS, expert-parallel MoE and compressed all-reduce must match
their single-device references bit-for-bit (up to fp tolerance).

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single CPU device (per the
assignment: never set the flag globally).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    from repro.compat import set_mesh
    from repro.core.lm_head import lm_head_sparton
    from repro.core.sharded import (sharded_sparton_head, sharded_infonce,
                                    sharded_flops_reg)
    from repro.losses.contrastive import infonce_loss, flops_regularizer

    B, S, D, V = 4, 24, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    H = jax.random.normal(ks[0], (B, S, D))
    E = jax.random.normal(ks[1], (V, D)) * 0.3
    b = jax.random.normal(ks[2], (V,)) * 0.1
    mask = (jax.random.uniform(ks[3], (B, S)) > 0.2).astype(jnp.int32)
    mask = mask.at[:, 0].set(1)

    # ---- sharded sparton head == local head --------------------------
    head = sharded_sparton_head(mesh, batch_axes=("data",), vocab_tile=16)
    with set_mesh(mesh):
        y_sharded = jax.jit(head)(H, E, b, mask)
    y_local = lm_head_sparton(H, E, b, mask, vocab_tile=16)
    np.testing.assert_allclose(np.asarray(y_sharded), np.asarray(y_local),
                               atol=1e-5, rtol=1e-5)
    print("OK sharded head forward")

    # ---- gradients through the sharded head --------------------------
    def loss_sharded(H, E, b):
        return jnp.sum(jnp.sin(head(H, E, b, mask)))
    def loss_local(H, E, b):
        return jnp.sum(jnp.sin(lm_head_sparton(H, E, b, mask,
                                               vocab_tile=16)))
    with set_mesh(mesh):
        gs = jax.jit(jax.grad(loss_sharded, (0, 1, 2)))(H, E, b)
    gl = jax.grad(loss_local, (0, 1, 2))(H, E, b)
    for a, c in zip(gs, gl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-4, rtol=1e-4)
    print("OK sharded head grads")

    # ---- sharded infonce == plain infonce ----------------------------
    yq = jax.random.normal(ks[4], (B, V))
    yd = jax.random.normal(jax.random.PRNGKey(9), (B, V))
    inf = sharded_infonce(mesh, batch_axes=("data",))
    with set_mesh(mesh):
        l_sharded = jax.jit(inf)(yq, yd)
    l_plain = infonce_loss(yq, yd)
    np.testing.assert_allclose(float(l_sharded), float(l_plain), atol=1e-5)
    print("OK sharded infonce")

    # ---- sharded flops reg == plain -----------------------------------
    fl = sharded_flops_reg(mesh, batch_axes=("data",))
    with set_mesh(mesh):
        f_sharded = jax.jit(fl)(jnp.abs(yq))
    f_plain = flops_regularizer(jnp.abs(yq))
    np.testing.assert_allclose(float(f_sharded), float(f_plain),
                               atol=1e-4, rtol=1e-5)
    print("OK sharded flops")

    # ---- sharded l1 reg == plain --------------------------------------
    from repro.core.sharded import sharded_l1_reg, sharded_row_dots
    from repro.losses.contrastive import l1_regularizer, gathered_infonce
    l1 = sharded_l1_reg(mesh, batch_axes=("data",))
    with set_mesh(mesh):
        l1_sharded = jax.jit(l1)(jnp.abs(yq))
    np.testing.assert_allclose(float(l1_sharded),
                               float(l1_regularizer(jnp.abs(yq))),
                               atol=1e-4, rtol=1e-5)
    print("OK sharded l1")

    # ---- sharded row dots == per-row einsum ---------------------------
    rd = sharded_row_dots(mesh, batch_axes=("data",))
    with set_mesh(mesh):
        dots = jax.jit(rd)(yq, yd)
    np.testing.assert_allclose(np.asarray(dots),
                               np.asarray(jnp.einsum("bv,bv->b", yq, yd)),
                               atol=1e-4, rtol=1e-5)
    print("OK sharded row dots")

    # ---- gathered infonce over the data axis == global infonce --------
    from repro.compat import shard_map as _shard_map
    gi = _shard_map(
        lambda a, c: gathered_infonce(a, c, axis_names=("data",)),
        mesh=mesh, in_specs=(P("data", None), P("data", None)),
        out_specs=P(), check_vma=False)
    with set_mesh(mesh):
        l_gathered = jax.jit(gi)(yq, yd)
    np.testing.assert_allclose(float(l_gathered),
                               float(infonce_loss(yq, yd)), atol=1e-5)
    print("OK gathered infonce")

    # ---- expert-parallel MoE == local MoE -----------------------------
    from repro.models.moe import moe_ffn, moe_ffn_local_experts
    from repro.compat import shard_map
    T, Dm, F, Eexp = 16, 8, 12, 4
    x = jax.random.normal(jax.random.PRNGKey(11), (T, Dm))
    router = jax.random.normal(jax.random.PRNGKey(12), (Dm, Eexp))
    wg = jax.random.normal(jax.random.PRNGKey(13), (Eexp, Dm, F)) * 0.3
    wu = jax.random.normal(jax.random.PRNGKey(14), (Eexp, Dm, F)) * 0.3
    wd = jax.random.normal(jax.random.PRNGKey(15), (Eexp, F, Dm)) * 0.3
    out_local, aux_local = moe_ffn(x, router, wg, wu, wd, top_k=2,
                                   capacity_factor=8.0)
    import functools
    body = functools.partial(moe_ffn_local_experts, top_k=2,
                             capacity_factor=8.0, expert_axis="model",
                             token_axes=("data",))
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("data", None), P(None, None),
                             P("model", None, None), P("model", None, None),
                             P("model", None, None)),
                   out_specs=(P("data", None), P()))
    with set_mesh(mesh):
        out_ep, aux_ep = jax.jit(fn)(x, router, wg, wu, wd)
    # high capacity => no drops on either path => identical outputs
    np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_local),
                               atol=1e-4, rtol=1e-4)
    print("OK expert-parallel moe")

    # ---- compressed all-reduce ~= mean --------------------------------
    from repro.optim.compression import compressed_allreduce
    g_tree = {"w": jax.random.normal(jax.random.PRNGKey(20), (8, 64)),
              "b": jax.random.normal(jax.random.PRNGKey(21), (8, 16))}

    def car(gw, gb):
        mean, resid = compressed_allreduce({"w": gw, "b": gb}, None,
                                           "data")
        return mean["w"], mean["b"]
    fn2 = shard_map(car, mesh=mesh,
                    in_specs=(P("data", None), P("data", None)),
                    out_specs=(P(None, None), P(None, None)),
                    check_vma=False)
    with set_mesh(mesh):
        mw, mb = jax.jit(fn2)(g_tree["w"], g_tree["b"])
    # each data shard holds 4 rows; mean over the 2 shards
    ref_w = (np.asarray(g_tree["w"][:4]) + np.asarray(g_tree["w"][4:])) / 2
    rel = np.abs(np.asarray(mw) - ref_w).max() / np.abs(ref_w).max()
    assert rel < 0.03, f"int8 allreduce rel err {rel}"
    print("OK compressed allreduce")

    # ---- distributed gather/scatter (GNN §Perf machinery) -------------
    from repro.sparse.distributed import (distributed_take_local,
                                          distributed_segment_sum_local)
    axes2 = ("data", "model")
    rows, dd, R = 64, 16, 4096
    src2 = jax.random.normal(jax.random.PRNGKey(30), (rows, dd))
    idx2 = jax.random.randint(jax.random.PRNGKey(31), (R,), 0, rows)
    take2 = shard_map(
        lambda s, i: distributed_take_local(s, i, axis_names=axes2),
        mesh=mesh, in_specs=(P(axes2, None), P(axes2)),
        out_specs=(P(axes2, None), P()), check_vma=False)
    with set_mesh(mesh):
        got, ndrop = jax.jit(take2)(src2, idx2)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.take(src2, idx2, axis=0)),
                               atol=1e-6)
    assert int(ndrop) == 0
    print("OK distributed take")

    vals2 = jax.random.normal(jax.random.PRNGKey(32), (R, dd))
    dst2 = jax.random.randint(jax.random.PRNGKey(33), (R,), 0, rows)
    scat2 = shard_map(
        lambda v, i: distributed_segment_sum_local(
            v, i, rows // 8, axis_names=axes2),
        mesh=mesh, in_specs=(P(axes2, None), P(axes2)),
        out_specs=(P(axes2, None), P()), check_vma=False)
    with set_mesh(mesh):
        out3, ndrop3 = jax.jit(scat2)(vals2, dst2)
    np.testing.assert_allclose(
        np.asarray(out3),
        np.asarray(jax.ops.segment_sum(vals2, dst2, num_segments=rows)),
        atol=1e-4)
    assert int(ndrop3) == 0
    print("OK distributed scatter")

    # ---- row-sharded embedding lookup ---------------------------------
    from repro.sparse.sharded_embedding import make_sharded_lookup
    table = jax.random.normal(jax.random.PRNGKey(22), (32, 8))
    idx = jnp.array([0, 5, 17, 31, 8])
    lookup = make_sharded_lookup(mesh, axis_name="model")
    with set_mesh(mesh):
        out = jax.jit(lookup)(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.take(table, idx, axis=0)),
                               atol=1e-6)
    print("OK sharded embedding")

    print("ALL_SHARDED_TESTS_PASSED")
""")


def test_sharded_semantics_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL_SHARDED_TESTS_PASSED" in proc.stdout
