"""End-to-end driver: train a SPLADE-style sparse encoder (~CPU-sized)
for a few hundred steps with the Sparton head, full substrate engaged:
synthetic LSR data pipeline -> fault-tolerant runner (async atomic
checkpoints, straggler policy) -> InfoNCE + FLOPS objective -> AdamW.

Run:  PYTHONPATH=src python examples/train_splade.py [--steps 200]

This is the paper's Table-3 setup scaled to the container; on a real
pod the same code path runs under launch/train.py with the production
mesh and the vocab-sharded head.
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.loader import HostShardedLoader
from repro.data.synthetic import lsr_pair_batches
from repro.launch.steps import build_lsr_train_step, init_state
from repro.runtime.fault_tolerance import FaultTolerantRunner, RunnerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=24)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="splade_ckpt_")
    cfg = get_config("splade_bert").SMOKE
    state, _ = init_state("splade_bert", jax.random.PRNGKey(0), smoke=True)

    step = build_lsr_train_step(cfg, None, n_micro=2,
                                n_pairs=args.batch, lr=args.lr,
                                total_steps=args.steps)
    jitted = jax.jit(step, donate_argnums=(0,))

    def make_iter(shard, n_shards):
        gen = lsr_pair_batches(batch=args.batch, q_len=args.seq_len,
                               d_len=args.seq_len, vocab=cfg.vocab_size,
                               shard=shard)
        for b in gen:
            yield b

    loader = HostShardedLoader(make_iter)
    runner = FaultTolerantRunner(
        jitted, state, iter(loader),
        config=RunnerConfig(ckpt_dir=ckpt_dir, ckpt_every=50,
                            max_steps=args.steps, log_every=20),
        place_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )
    if runner.try_resume():
        print(f"resumed from checkpoint at step {runner.start_step}")
    state = runner.run()

    losses = [(m["step"], float(m["loss"])) for m in runner.metrics_log]
    print("loss trajectory:", [(s, round(l, 3)) for s, l in losses])
    assert losses[-1][1] < losses[0][1], "training did not reduce loss"

    # quick retrieval sanity: does query i retrieve doc i?
    from repro.runtime.serving import make_config_encoder
    gen = lsr_pair_batches(batch=32, q_len=args.seq_len,
                           d_len=args.seq_len, vocab=cfg.vocab_size,
                           seed=123)
    b = next(gen)
    enc = make_config_encoder(state["params"], cfg)

    def encode(toks, mask):
        return enc(jnp.asarray(toks), jnp.asarray(mask))

    yq = encode(b["q_tokens"], b["q_mask"])
    yd = encode(b["d_tokens"], b["d_mask"])
    scores = np.asarray(jnp.einsum("qv,dv->qd", yq, yd))
    acc = float((scores.argmax(1) == np.arange(32)).mean())
    nnz = float(jnp.mean(jnp.sum(yq > 0, axis=-1)))
    print(f"in-batch retrieval acc@1: {acc:.2f}  "
          f"(chance {1 / 32:.3f}); mean active dims {nnz:.0f}")
    loader.close()
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
