"""Fused streaming top-k retrieval scoring — Pallas TPU kernel.

The Sparton idea transferred to recsys retrieval (DESIGN.md §4): score
one query block against N candidates (``q @ C^T``) and keep only a
running top-k — the ``(B, N)`` score matrix is never materialized, just
as Sparton never materializes the ``(B, S, V)`` logit tensor. For the
assigned ``retrieval_cand`` shape (1 query × 1,000,000 candidates) the
dense score row is 4 MB/query; at serving batch sizes the full matrix
would be GBs, all discarded except k winners.

Grid: ``(B/bb, N/bn)`` with candidates innermost. Each candidate block
computes its ``(bb, bn)`` score tile on the MXU, merges it with the
running ``(bb, k)`` top-k via sort (bitonic-friendly shapes), and the
final block writes scores + indices.

Merge strategy per step: concatenate running top-k values with the new
tile's *blockwise* scores, take ``lax.top_k`` of the union. k is kept
small (≤ 256) so the working set stays in VMEM; the asymptotic work is
O(N·(k+bn)/bn · log) vs O(N log N) for full sort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._common import NEG_INF, pad_to


def merge_topk(
    run_vals: jax.Array,    # (B, k) running top-k values
    run_idx: jax.Array,     # (B, k) running top-k ids
    new_vals: jax.Array,    # (B, m) this block's values
    new_idx: jax.Array,     # (B, m) this block's ids
    k: int,
):
    """One step of the running top-k merge: union + re-top-k.

    The single reduction shared by every streaming top-k in the repo —
    the Pallas kernel below, the pure-JAX ``streaming_topk`` scan in
    ``launch/steps.py``, and the rep sparsifiers in
    ``retrieval/sparse_rep.py``. ``lax.top_k`` is stable, and the
    running set is concatenated *before* the new block, so when blocks
    are visited in ascending-id order, equal values tie-break toward
    the lowest id (first occurrence) — the invariant the parity tests
    rely on.
    """
    all_vals = jnp.concatenate([run_vals, new_vals], axis=1)
    all_idx = jnp.concatenate([run_idx, new_idx], axis=1)
    top_vals, pos = jax.lax.top_k(all_vals, k)
    return top_vals, jnp.take_along_axis(all_idx, pos, axis=1)


def _topk_kernel(
    q_ref,      # (bb, D)
    c_ref,      # (bn, D)
    val_ref,    # (bb, k) out — running top-k values
    idx_ref,    # (bb, k) out — running top-k candidate ids
    *,
    k: int,
    block_n: int,
    n_blocks: int,
    n_real: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, NEG_INF, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    bb, d = q_ref.shape
    bn = c_ref.shape[0]

    scores = jax.lax.dot_general(
        q_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # (bb, bn)
    cand_ids = j * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (bb, bn), 1)
    # padded rows (id >= n_real) score q.0 = 0, which would beat real
    # negative scores — mask them to -inf so they can never be selected
    scores = jnp.where(cand_ids < n_real, scores, NEG_INF)

    # merge: union of running top-k and this block, re-top-k
    top_vals, top_idx = merge_topk(val_ref[...], idx_ref[...], scores,
                                   cand_ids, k)
    val_ref[...] = top_vals
    idx_ref[...] = top_idx


@functools.partial(
    jax.jit, static_argnames=("k", "block_b", "block_n", "interpret")
)
def topk_score(
    q: jax.Array,       # (B, D) queries
    C: jax.Array,       # (N, D) candidates
    *,
    k: int = 100,
    block_b: int = 8,
    block_n: int = 1024,
    interpret: bool = False,
):
    """Fused scoring + streaming top-k. Returns (vals (B,k), idx (B,k)).

    Contract for the degenerate ``k > N`` case: the first ``N`` columns
    are the full descending ranking of the corpus; columns beyond ``N``
    carry ``NEG_INF`` values (their ids are meaningless). Ties between
    equal scores resolve to the lowest candidate id (blocks are visited
    in ascending-id order and the merge is stable).
    """
    B, D = q.shape
    N = C.shape[0]

    qp = pad_to(q.astype(jnp.float32), 0, block_b)
    Cp = pad_to(C.astype(jnp.float32), 0, block_n)

    Bp = qp.shape[0]
    Np = Cp.shape[0]
    grid = (Bp // block_b, Np // block_n)

    vals, idx = pl.pallas_call(
        functools.partial(
            _topk_kernel, k=k, block_n=block_n, n_blocks=grid[1],
            n_real=N,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, k), jnp.float32),
            jax.ShapeDtypeStruct((Bp, k), jnp.int32),
        ],
        interpret=interpret,
    )(qp, Cp)

    # padded ids were masked to -inf inside the kernel and can only
    # appear if k > N (degenerate); callers see clean (B, k) results
    return vals[:B], idx[:B]
