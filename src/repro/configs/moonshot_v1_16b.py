"""moonshot-v1-16b-a3b (kimi/moonlight) — MoE 64e top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) d_ff=1408(per expert) vocab=163840,
64 experts top-6. The Sparton head is backbone-agnostic (DESIGN.md §4);
experts shard over the model axis (EP).
"""

from repro.configs.base import TransformerConfig, shapes_lm

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    top_k=6,
    rope_theta=50000.0,
    tie_embeddings=True,
    param_dtype="bfloat16",
    attn_chunk=2048,   # §Perf: -4% memory term vs 512

)

SMOKE = TransformerConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=64,
    vocab_size=512,
    n_experts=8,
    top_k=2,
    tie_embeddings=True,
    remat=False,
)

SHAPES = shapes_lm(
    long_ok=False,
    long_skip_reason="pure full attention; 524k-token decode needs "
                     "sub-quadratic attention (assignment rule)",
)
