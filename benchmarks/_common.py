"""Shared benchmark utilities: timing, CSV output, memory proxies.

Wall-clock here is CPU-container time — meaningful for RELATIVE
comparisons between implementations of the same op at the same shape
(the paper's tables compare implementations, which is preserved), not
as absolute TPU numbers. Peak-memory comparisons use the analytic
activation/residual byte counts (exact for XLA's plan via
``memory_analysis`` where available).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 3, iters: int = 10,
            **kw) -> float:
    """Median wall time (ms) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def compiled_peak_bytes(fn: Callable, *abstract_args) -> float:
    """Peak-memory estimate from XLA's buffer assignment."""
    compiled = jax.jit(fn).lower(*abstract_args).compile()
    ma = compiled.memory_analysis()
    if ma is None:
        return float("nan")
    return float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes)


def csv_print(header: Iterable[str], rows: List[Iterable]) -> None:
    print(",".join(str(h) for h in header))
    for r in rows:
        print(",".join(str(x) for x in r))
