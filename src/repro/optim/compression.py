"""Int8 gradient compression with error feedback for DP all-reduce.

At 1000+ nodes the DP gradient all-reduce is the dominant collective;
int8 quantization cuts its payload 4× vs fp32 (2× vs bf16). Per-leaf
symmetric scaling (max-abs / 127) keeps the quantizer cheap; the
*error-feedback residual* (Seide et al. / EF-SGD) accumulates the
quantization error into the next step's gradient so convergence is
provably unaffected for smooth objectives.

``compressed_allreduce`` is written as a shard_map-compatible function:
quantize -> psum the int8 payload widened to int32 (exact — sums of
≤2^15 int8 values fit int32) -> dequantize with psum'd scales.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

PyTree = Any


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (f32/bf16) -> (int8 payload, f32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: PyTree, residual: Optional[PyTree]
                  ) -> Tuple[PyTree, PyTree, PyTree]:
    """Error-feedback compression of a gradient pytree.

    Returns (quantized payloads, scales, new residuals).
    """
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def comp(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = compress_int8(corrected)
        new_r = corrected - decompress_int8(q, s)
        return q, s, new_r

    out = jax.tree.map(comp, grads, residual)
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    qs = jax.tree.map(lambda o: o[0], out, is_leaf=is_triple)
    ss = jax.tree.map(lambda o: o[1], out, is_leaf=is_triple)
    rs = jax.tree.map(lambda o: o[2], out, is_leaf=is_triple)
    return qs, ss, rs


def _flatten(grads: PyTree) -> Tuple[jax.Array, Any]:
    leaves, treedef = jax.tree.flatten(grads)
    shapes = [l.shape for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    return flat, (treedef, shapes)


def _unflatten(flat: jax.Array, spec) -> PyTree:
    treedef, shapes = spec
    leaves, off = [], 0
    for shp in shapes:
        n = 1
        for s in shp:
            n *= s
        leaves.append(flat[off:off + n].reshape(shp))
        off += n
    return jax.tree.unflatten(treedef, leaves)


def compressed_allreduce(
    grads: PyTree,
    residual: Optional[jax.Array],
    axis_name: str,
) -> Tuple[PyTree, jax.Array]:
    """Inside-shard_map DP all-reduce with a true int8 wire format.

    Ring-psum of fp32 moves ~2x payload_fp32 bytes per device; this
    scheme moves ~2x payload_int8 — a 4x wire saving:

      1. error-feedback int8-quantize the flattened gradient,
      2. reduce-scatter: ``all_to_all`` the int8 payload (each device
         receives shard i of every peer), sum dequantized shards,
      3. requantize the reduced shard to int8,
      4. ``all_gather`` the int8 result + fp32 scales; dequantize.

    ``residual`` is the flat fp32 error-feedback buffer (None at step
    0). Returns (mean grads pytree, new residual).
    """
    n = axis_size(axis_name)
    flat, spec = _flatten(grads)
    size = flat.shape[0]
    pad = (-size) % n
    flat_p = jnp.pad(flat, (0, pad))
    if residual is None:
        residual = jnp.zeros_like(flat_p)

    corrected = flat_p + residual
    q, s = compress_int8(corrected)                    # int8 payload
    new_residual = corrected - decompress_int8(q, s)

    # 2. reduce-scatter via all_to_all on the int8 wire
    chunk = flat_p.shape[0] // n
    q_chunks = q.reshape(n, chunk)
    recv = jax.lax.all_to_all(q_chunks, axis_name, split_axis=0,
                              concat_axis=0, tiled=False)  # (n, chunk) int8
    s_all = jax.lax.all_gather(s, axis_name)               # (n,) f32
    part = jnp.sum(recv.astype(jnp.float32) * s_all[:, None], axis=0) / n

    # 3-4. requantize the reduced shard; all_gather int8 + scales
    q2, s2 = compress_int8(part)
    q2_all = jax.lax.all_gather(q2, axis_name)             # (n, chunk) int8
    s2_all = jax.lax.all_gather(s2, axis_name)             # (n,) f32
    mean_flat = (q2_all.astype(jnp.float32)
                 * s2_all[:, None]).reshape(-1)[:size]
    return _unflatten(mean_flat, spec), new_residual
