"""Architecture registry — ``--arch <id>`` resolves here.

Each module exports CONFIG (exact assigned config), SMOKE (reduced
same-family config for CPU tests) and SHAPES (the assigned input-shape
cells). ``get_config(id)`` returns the module.
"""

import importlib
from typing import List

from repro.configs.base import (DimeNetConfig, RecSysConfig, ShapeSpec,
                                TransformerConfig)

ARCH_IDS: List[str] = [
    # LM family (assigned)
    "llama3_2_3b",
    "gemma2_27b",
    "phi3_mini",
    "moonshot_v1_16b",
    "phi3_5_moe",
    # GNN (assigned)
    "dimenet",
    # RecSys (assigned)
    "dlrm_mlperf",
    "xdeepfm",
    "dien",
    "wide_deep",
    # the paper's own models
    "splade_bert",
    "splade_xlmr",
]

# external ids (with dots/dashes) -> module names
ALIASES = {
    "llama3.2-3b": "llama3_2_3b",
    "gemma2-27b": "gemma2_27b",
    "phi3-mini-3.8b": "phi3_mini",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "dimenet": "dimenet",
    "dlrm-mlperf": "dlrm_mlperf",
    "xdeepfm": "xdeepfm",
    "dien": "dien",
    "wide-deep": "wide_deep",
    "splade-bert": "splade_bert",
    "splade-xlmr": "splade_xlmr",
}


def get_config(arch_id: str):
    """Returns the config module for an architecture id."""
    name = ALIASES.get(arch_id, arch_id)
    if name not in ARCH_IDS:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def all_cells(include_paper_models: bool = False):
    """Yields (arch_id, shape_name, ShapeSpec) for the dry-run matrix."""
    ids = ARCH_IDS if include_paper_models else ARCH_IDS[:10]
    for arch in ids:
        mod = get_config(arch)
        for shape_name, spec in mod.SHAPES.items():
            yield arch, shape_name, spec
