"""Incremental index builder: online corpus growth without full
rebuilds (DESIGN.md §8.4).

The PR-3 pipeline froze the corpus at build time — growing it meant
re-encoding and re-sorting everything. ``IndexBuilder`` keeps the
served index live under three operations:

* ``add(reps)``    — append a batch of document rows. Buffered
                     host-side; the next ``flush()`` packs only the
                     *new* rows into a small **delta segment** (an
                     ordinary ``InvertedIndex`` over the tail doc
                     range). The big **base segment** is untouched.
* ``remove(ids)``  — tombstone documents by external id. A tombstone
                     in the base segment is applied in place at flush
                     time by zeroing the doc's postings (an O(P) mask,
                     no re-sort): the doc then scores 0 and its slot
                     is reclaimed at the next compaction. Per-term
                     upper bounds stay *valid* (zeroing only lowers
                     true impacts), just looser.
* ``flush()``      — make pending adds/removes visible to ``search``.
                     When the delta outgrows ``merge_frac`` of the
                     base, or tombstones exceed ``compact_dead_frac``
                     of the corpus, flush escalates to ``compact()``:
                     one full rebuild over the live rows (the
                     amortized LSM-style merge).

``search`` scores base and delta segments independently and merges
their top-k with the shared ``merge_topk`` reduction, then maps
internal slots back to stable **external ids** (compaction renumbers
slots, never external ids; tombstoned slots surface as id -1).
With ``quantize=True`` the base segment is served compressed
(``QuantizedIndex``) while the hot delta stays raw — the classic
read-optimized/write-optimized split.

Every mutation that can change what ``search`` returns (``add`` /
``remove`` / a dirty ``flush`` / ``compact``) bumps ``generation`` —
the monotone counter the serving-frontier caches key their entries on
(DESIGN.md §13). ``compact`` bumps even though the *logical* corpus is
unchanged: it reorders postings, and fp summation order shifts scores
by ulps, so a result cached across a compaction would no longer be
bit-identical to a fresh search.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.index import InvertedIndex, build_inverted_index
from repro.retrieval.sparse_rep import SparseRep, device_get

Array = jax.Array


def _host_rows(reps: SparseRep) -> Tuple[np.ndarray, np.ndarray]:
    host = device_get(reps) if isinstance(reps.values, jax.Array) else reps
    k = host.width
    v = np.asarray(host.values, np.float32).reshape(-1, k)
    i = np.asarray(host.indices, np.int32).reshape(-1, k)
    return v, i


class IndexBuilder:
    """Incremental add/remove/flush over an LSR corpus (see module
    docstring). Not thread-safe; callers serialize like the serving
    loop does."""

    def __init__(self, vocab_size: int, *, quantize: bool = False,
                 keep_forward: bool = False, merge_frac: float = 0.25,
                 compact_dead_frac: float = 0.25, term_shards: int = 0,
                 plan=None):
        # a ShardPlan (engine.shard2d.plan_placement) is the one
        # placement input going forward: its term axis sets
        # term_shards, a genuinely 2D grid makes the base segment a
        # Shard2DIndex, and a doc-only plan keeps the monolithic base
        # (the builder's base is storage — doc sharding is a serving-
        # mesh concern until the base itself outgrows one device).
        if plan is not None:
            if term_shards:
                raise ValueError(
                    "pass either plan= or term_shards=, not both — "
                    "the plan carries the shard topology")
            if plan.doc_shards > 1 and plan.term_shards > 1:
                self._grid = (plan.doc_shards, plan.term_shards)
                term_shards = 0
            else:
                self._grid = None
                term_shards = (plan.term_shards
                               if plan.term_shards > 1 else 0)
        else:
            self._grid = None
        if (term_shards or self._grid) and quantize:
            raise ValueError(
                "sharded plans and quantize are exclusive — the base "
                "segment is either partitioned or compressed")
        self.plan = plan
        self.vocab_size = vocab_size
        self.quantize = quantize
        self.keep_forward = keep_forward
        self.merge_frac = merge_frac
        self.compact_dead_frac = compact_dead_frac
        # > 0: the base segment is served as a TermShardedIndex over
        # this many vocab ranges (the hot delta stays a raw single
        # index — same read-optimized/write-optimized split as
        # quantize). Search dispatches per segment via "auto".
        self.term_shards = term_shards

        self._values: Optional[np.ndarray] = None    # (N, K) live rows
        self._indices: Optional[np.ndarray] = None   # (N, K)
        self._ext_ids = np.zeros(0, np.int64)        # slot -> external
        self._alive = np.zeros(0, bool)
        self._slot: Dict[int, int] = {}              # external -> slot
        self._next_ext = 0

        self._base: Union[InvertedIndex, "QuantizedIndex",
                          "TermShardedIndex", None] = None
        self._base_raw: Union[InvertedIndex, "TermShardedIndex",
                              None] = None
        self._base_n = 0          # slots [0, _base_n) live in the base
        self._delta: Optional[InvertedIndex] = None
        self._delta_dirty = False      # adds/removes touching the tail
        self._base_removals: List[int] = []   # tombstoned base slots
        self.n_compactions = 0
        # bumped by every visible mutation (module docstring) — the
        # frontier caches' invalidation signal
        self.generation = 0

    # -- bookkeeping -----------------------------------------------------

    @property
    def n_slots(self) -> int:
        return self._ext_ids.shape[0]

    @property
    def n_alive(self) -> int:
        return int(self._alive.sum())

    @property
    def n_dead(self) -> int:
        return self.n_slots - self.n_alive

    @property
    def dirty(self) -> bool:
        return (self._delta_dirty or bool(self._base_removals)
                or (self._base is None and self.n_slots > 0))

    def stats(self) -> Dict[str, float]:
        return {
            "n_slots": self.n_slots,
            "n_alive": self.n_alive,
            "n_dead": self.n_dead,
            "base_docs": self._base_n,
            "delta_docs": self.n_slots - self._base_n,
            "n_compactions": self.n_compactions,
            "quantized_base": bool(self.quantize and self._base
                                   is not None),
            "term_shards": self.term_shards,
            "doc_shards": self._grid[0] if self._grid else 0,
            "grid_term_shards": self._grid[1] if self._grid else 0,
            "generation": self.generation,
        }

    def memory_bytes(self) -> int:
        """Approximate resident bytes: the host row store plus the
        served base/delta segments (their own ``memory_bytes``
        accounting). The tenancy layer's shared-budget check reads
        this; it is a host-side estimate, not a device HBM measure."""
        total = int(self._ext_ids.nbytes + self._alive.nbytes)
        if self._values is not None:
            total += int(self._values.nbytes + self._indices.nbytes)
        for seg in (self._base, self._delta):
            if seg is not None and hasattr(seg, "memory_bytes"):
                total += int(seg.memory_bytes())
        return total

    # -- mutation --------------------------------------------------------

    def add(self, reps: SparseRep,
            ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Append a batch of document rows; returns their external ids
        (auto-assigned monotonically unless ``ids`` is given)."""
        v, i = _host_rows(reps)
        n = v.shape[0]
        if ids is None:
            ids = np.arange(self._next_ext, self._next_ext + n,
                            dtype=np.int64)
            self._next_ext += n
        else:
            ids = np.asarray(list(ids), np.int64)
            if ids.shape[0] != n:
                raise ValueError(f"{ids.shape[0]} ids for {n} rows")
            dup = [int(e) for e in ids if int(e) in self._slot]
            if dup:
                raise ValueError(f"duplicate external ids: {dup[:5]}")
            self._next_ext = max(self._next_ext, int(ids.max()) + 1)

        base_slot = self.n_slots
        if self._values is None:
            self._values, self._indices = v.copy(), i.copy()
        else:
            k_old, k_new = self._values.shape[1], v.shape[1]
            width = max(k_old, k_new)
            if k_old < width:
                pad = width - k_old
                self._values = np.pad(self._values, ((0, 0), (0, pad)))
                self._indices = np.pad(self._indices, ((0, 0), (0, pad)))
            if k_new < width:
                pad = width - k_new
                v = np.pad(v, ((0, 0), (0, pad)))
                i = np.pad(i, ((0, 0), (0, pad)))
            self._values = np.concatenate([self._values, v])
            self._indices = np.concatenate([self._indices, i])
        self._ext_ids = np.concatenate([self._ext_ids, ids])
        self._alive = np.concatenate([self._alive, np.ones(n, bool)])
        for off, e in enumerate(ids):
            self._slot[int(e)] = base_slot + off
        self._delta_dirty = True
        self.generation += 1
        return ids

    def remove(self, ids: Sequence[int]) -> int:
        """Tombstone documents by external id; unknown or already
        removed ids are ignored. Returns the number tombstoned.

        The external id is released immediately (a later ``add`` may
        reuse it, whether or not the dead slot has been compacted
        away yet)."""
        n = 0
        for e in ids:
            slot = self._slot.pop(int(e), None)
            if slot is None or not self._alive[slot]:
                continue
            self._alive[slot] = False
            if slot < self._base_n:
                self._base_removals.append(slot)
            else:
                self._delta_dirty = True
            n += 1
        if n:
            self.generation += 1
        return n

    # -- flush / compaction ----------------------------------------------

    def _tail_rep(self) -> SparseRep:
        v = self._values[self._base_n:].copy()
        i = self._indices[self._base_n:]
        v[~self._alive[self._base_n:]] = 0.0
        return SparseRep(v, i, (v > 0).sum(axis=1).astype(np.int32))

    def _pack_base(self, values: np.ndarray, indices: np.ndarray
                   ) -> None:
        rep = SparseRep(values, indices,
                        (values > 0).sum(axis=1).astype(np.int32))
        if self._grid is not None:
            from repro.retrieval.engine.shard2d import shard2d_index

            d, t = self._grid
            # compaction can shrink the live rows below the planned
            # doc-chunk count; clamp rather than refuse to serve
            d = min(d, values.shape[0])
            self._base_raw = shard2d_index(
                rep, self.vocab_size, d, t,
                keep_forward=self.keep_forward)
            self._base = self._base_raw
            return
        if self.term_shards:
            from repro.retrieval.engine.term_sharded import \
                term_shard_index
            # postings_doc carries global slot ids on every shard, so
            # the tombstone-zeroing flush path applies unchanged
            self._base_raw = term_shard_index(
                rep, self.vocab_size, self.term_shards,
                keep_forward=self.keep_forward)
            self._base = self._base_raw
            return
        raw = build_inverted_index(rep, self.vocab_size,
                                   keep_forward=self.keep_forward)
        self._base_raw = raw
        if self.quantize:
            from repro.retrieval.engine.quantize import quantize_index
            self._base = quantize_index(raw)
        else:
            self._base = raw

    def compact(self) -> None:
        """Full rebuild over live rows: tombstoned slots are dropped,
        internal slots renumber, external ids are untouched."""
        keep = self._alive
        self._values = (self._values[keep] if self._values is not None
                        else None)
        self._indices = (self._indices[keep] if self._indices is not None
                         else None)
        self._ext_ids = self._ext_ids[keep]
        self._alive = np.ones(self._ext_ids.shape[0], bool)
        self._slot = {int(e): s for s, e in enumerate(self._ext_ids)}
        self._base_n = self._ext_ids.shape[0]
        self._base_removals = []
        self._delta = None
        self._delta_dirty = False
        self.n_compactions += 1
        self.generation += 1
        if self._base_n:
            self._pack_base(self._values, self._indices)
        else:
            self._base = self._base_raw = None

    def flush(self, *, force_compact: bool = False) -> None:
        """Make pending adds/removes visible to ``search``.

        Cheap paths first: base tombstones are zeroed in place, adds
        rebuild only the delta segment. Escalates to ``compact()``
        when the delta outgrows ``merge_frac`` of the base or dead
        slots exceed ``compact_dead_frac`` of the corpus.
        """
        if self.dirty or force_compact:
            self.generation += 1
        n_delta = self.n_slots - self._base_n
        needs_compact = (
            force_compact
            or (self.n_slots > 0
                and self.n_dead > self.compact_dead_frac * self.n_slots)
            or (self._base_n > 0
                and n_delta > self.merge_frac * self._base_n))
        if needs_compact:
            self.compact()
            return

        if self._base_removals and self._base_raw is not None:
            import dataclasses

            from repro.retrieval.engine.shard2d import Shard2DIndex

            dead = np.asarray(self._base_removals, np.int64)
            if isinstance(self._base_raw, Shard2DIndex):
                # 2D cells carry chunk-LOCAL doc ids — the index's own
                # per-chunk remap applies the tombstones
                self._base_raw = self._base_raw.zero_docs(dead)
            else:
                # base/term-sharded postings carry global slot ids
                pdoc = np.asarray(self._base_raw.postings_doc)
                pval = np.asarray(self._base_raw.postings_val).copy()
                pval[np.isin(pdoc, dead)] = 0.0
                kw = {"postings_val": jnp.asarray(pval)}
                if self._base_raw.doc_values is not None:
                    dv = np.asarray(self._base_raw.doc_values).copy()
                    dv[dead] = 0.0
                    kw["doc_values"] = jnp.asarray(dv)
                self._base_raw = dataclasses.replace(self._base_raw,
                                                     **kw)
            if self.quantize:
                from repro.retrieval.engine.quantize import quantize_index
                self._base = quantize_index(self._base_raw)
            else:
                self._base = self._base_raw
            self._base_removals = []

        if self._base is None and self._base_n == 0 and self.n_slots:
            # first flush: everything becomes the base segment
            self._base_n = self.n_slots
            self._pack_base(self._values.copy(), self._indices)
            self._delta = None
            self._delta_dirty = False
            # zero tombstones that arrived before the first flush
            if not self._alive.all():
                self._base_removals = list(
                    np.flatnonzero(~self._alive))
                self.flush()
            return

        if self._delta_dirty:
            tail = self._tail_rep()
            self._delta = (build_inverted_index(
                tail, self.vocab_size, keep_forward=self.keep_forward)
                if tail.values.shape[0] else None)
            self._delta_dirty = False

    # -- search ----------------------------------------------------------

    def _base_method(self, method: str) -> str:
        """The method name the base segment is actually scored with
        (before ``auto`` resolution): a term-sharded or 2D base serves
        pruning through its own two-tier composition (per-shard/cell
        ceilings + rescore; margin 0 routes to the exact psum path —
        same ids) and the fused kernel has no sharded-index entry
        point, so both remap to the base's sharded method."""
        if method in ("pruned", "fused"):
            if self._grid is not None:
                return "shard2d"
            if self.term_shards:
                return "term_sharded"
        return method

    def resolved_method(self, method: str = "auto") -> str:
        """The concrete method ``search(method=...)`` will score the
        base segment with (the delta if there is no base) — the name
        strict kwarg validation reports and the frontier's hot-window
        scorer keys its engage-decision on."""
        from repro.retrieval.score import _resolve_method

        if self._base is not None:
            return _resolve_method(self._base_method(method), self._base)
        if method != "auto":
            return method
        if self._delta is not None:
            return _resolve_method("auto", self._delta)
        return "impact"

    def _check_search_kwargs(self, method: str, kw: dict) -> str:
        """Strict kwarg parity with the ``retrieve()`` dispatcher:
        unknown names and names the *resolved* method cannot honor
        raise ``TypeError`` instead of being silently swallowed (a
        typo'd tuning knob must not masquerade as a no-op). Returns
        the resolved method name."""
        from repro.retrieval.score import _METHOD_KWARGS

        resolved = self.resolved_method(method)
        every = frozenset().union(*_METHOD_KWARGS.values())
        allowed = _METHOD_KWARGS.get(resolved, frozenset())
        unknown = sorted(n for n in kw if n not in every)
        stray = sorted(n for n, v in kw.items()
                       if n in every and v is not None
                       and n not in allowed)
        if unknown or stray:
            what = []
            if unknown:
                what.append(f"unknown kwargs {', '.join(unknown)}")
            if stray:
                what.append(f"kwargs {', '.join(stray)} that "
                            f"method={resolved!r} does not accept")
            raise TypeError(
                f"search(method={method!r}) resolved to "
                f"{resolved!r}: " + "; ".join(what)
                + f" (accepted: "
                f"{sorted(allowed) if allowed else 'no tuning kwargs'})")
        return resolved

    def search(self, queries: SparseRep, k: int = 10, *,
               method: str = "auto", q_width: Optional[int] = None,
               base_scorer=None,
               **kw) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over base + delta segments; returns ``(vals, ids)``
        with **external** doc ids (-1 marks below-top-k padding or
        tombstoned slots). Flushes pending mutations first.

        ``q_width`` truncates queries to their ``q_width``
        largest-value terms before scoring (the serving degrade
        ladder's query-narrowing knob — DESIGN.md §10); remaining
        ``kw`` (``prune_margin``, ``candidates``, ...) pass through to
        ``retrieve`` for the base segment after strict validation
        against the resolved method (``_check_search_kwargs``).

        ``base_scorer`` is the frontier's hot-window seam (DESIGN.md
        §13): called as ``base_scorer(queries, base, k, resolved, kw)``
        before the dispatcher; returning ``None`` declines and the
        normal ``retrieve`` path runs — so a scorer that only serves
        one (method, index-type) combination stays bit-compatible."""
        from repro.kernels.topk_score import merge_topk
        from repro.retrieval.score import retrieve
        from repro.retrieval.sparse_rep import truncate_width

        if q_width is not None:
            queries = truncate_width(queries, q_width)

        if self.dirty:
            self.flush()
        resolved = self._check_search_kwargs(method, kw)
        if self.n_slots == 0 or (self._base is None
                                 and self._delta is None):
            b = queries.values.reshape(-1, queries.width).shape[0]
            return (np.full((b, k), -np.inf, np.float32),
                    np.full((b, k), -1, np.int64))

        parts = []   # (vals (B, k'), global slots (B, k'))
        if self._base is not None:
            bm = self._base_method(method)
            k_base = min(k, self._base.n_docs)
            out = None
            if base_scorer is not None:
                out = base_scorer(queries, self._base, k_base,
                                  resolved, dict(kw))
            if out is None:
                out = retrieve(queries, self._base, k_base,
                               method=bm, **kw)
            parts.append(out)
        if self._delta is not None:
            # the hot delta is always a raw single InvertedIndex —
            # base-only methods fall back to exact impact scoring
            # ("fused" passes through: the kernel scores a raw index,
            # honoring the same fused tuning kwargs as the base)
            dm = ("impact" if method in ("pruned", "quantized",
                                         "sharded", "term_sharded",
                                         "shard2d")
                  else method)
            dkw = kw if (dm == "fused" and resolved == "fused") else {}
            dv, di = retrieve(queries, self._delta,
                              min(k, self._delta.n_docs), method=dm,
                              **dkw)
            parts.append((dv, di + self._base_n))

        vals, idx = parts[0]
        for nv, ni in parts[1:]:
            vals, idx = merge_topk(vals, idx, nv, ni,
                                   min(k, vals.shape[1] + nv.shape[1]))
        vals = np.asarray(vals)
        idx = np.asarray(idx)
        if vals.shape[1] < k:
            pad = k - vals.shape[1]
            vals = np.pad(vals, ((0, 0), (0, pad)),
                          constant_values=-np.inf)
            idx = np.pad(idx, ((0, 0), (0, pad)), constant_values=-1)

        ext = np.full(idx.shape, -1, np.int64)
        ok = idx >= 0
        slots = np.clip(idx, 0, self.n_slots - 1)
        ext[ok] = self._ext_ids[slots][ok]
        ext[ok & ~self._alive[slots]] = -1      # tombstoned slots
        return vals, ext
