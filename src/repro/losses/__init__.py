from repro.losses.contrastive import (
    flops_regularizer,
    infonce_loss,
    l1_regularizer,
    margin_mse_loss,
    splade_loss,
)
