"""Serving-frontier benchmark: caches, tenancy, continuous batching.

Drives the frontier subsystem (DESIGN.md §13) on the same simulated
clock and workload machinery as ``bench_serving`` — deterministic,
bit-stable records, real production code under test. Four experiments
behind ``BENCH_frontier.json``:

* ``zipf_replay`` — the same Zipf-skewed query stream served cache-off
  and cache-on (result cache + hot posting windows over the fused
  scorer). Reports hit rate, p50/p99, sustained QPS both ways, and a
  **parity** bit: cached results must be id- and value-identical to
  the uncached engine on a probe batch. Offered load sits above the
  cache-off capacity, so the cache-on sustained-QPS win is the point
  of the experiment, not noise.
* ``churn`` — interleaves add/remove/flush/compact with cached
  searches; after every mutation the cached frontend is compared
  against the raw engine on the same builder. ``mismatches`` must be
  0 — generation invalidation means a stale entry is *never* served.
* ``tenancy`` — three tenants (weights 2/1/1) saturating one shared
  encoder, one of them submitting poison batches. Checks stride-fair
  capacity splits (the weight-2 tenant serves ~2× the weight-1s
  during the contended window) and isolation: only the poisoned
  tenant records failures, the victims' shed/failed stay 0.
* ``continuous`` — the same bursty mixed-SLO arrival sequence into a
  one-batch-per-tick loop and a ``continuous=True`` loop. EDF
  admission lets tight-deadline requests jump the queue instead of
  shedding behind patient ones, so continuous must sustain strictly
  higher QPS at no worse shed rate.

``--smoke`` (or ``BENCH_SMOKE=1``) shortens everything for CI;
``benchmarks/check.py`` gates the record, ``report.py`` trends it.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import numpy as np

from benchmarks.workload import (VOCAB, SimClock, ZipfQueries,
                                 make_sim_encoder, poisson_arrivals,
                                 pump, uniform_query)
from repro.runtime.faults import inject_faults
from repro.runtime.frontier import (CachedEngine, HotPostingCache,
                                    QueryResultCache, TenantPool,
                                    TenantQuota)
from repro.runtime.serving import (AdmissionPolicy, BatchedEncoder,
                                   BatchPolicy, CorpusEngine,
                                   FailedResult, Request, ServingLoop,
                                   ShedResult)

K = 10
MAX_BATCH = 16
MAX_WAIT_S = 0.005
CATALOG = 64                 # distinct Zipf query texts
ZIPF_ALPHA = 1.1
CACHE_BYTES = 1 << 20
HOT_BYTES = 1 << 16
HIT_COST_S = 0.0002          # simulated serve-from-cache cost
MISS_COST_S = 0.004          # simulated full-search cost
POISON_TOKEN = VOCAB + 7

FULL = dict(n_docs=512, replay_s=4.0, replay_qps=300.0,
            churn_rounds=40, tenant_s=1.5, tenant_qps=150.0,
            cont_cycles=8)
SMOKE = dict(n_docs=192, replay_s=2.0, replay_qps=300.0,
             churn_rounds=16, tenant_s=1.0, tenant_qps=150.0,
             cont_cycles=4)


def _sim_corpus_engine(clock: SimClock, n_docs: int,
                       **engine_kw) -> CorpusEngine:
    """A ``CorpusEngine`` over the sim encoder, pre-loaded with
    ``n_docs`` deterministic documents; the clock is rezeroed so
    corpus setup doesn't bill the experiment."""
    be = BatchedEncoder(make_sim_encoder(clock),
                        policy=BatchPolicy(max_batch=MAX_BATCH,
                                           max_wait_s=MAX_WAIT_S))
    eng = CorpusEngine(be, VOCAB)
    rng = np.random.default_rng(0)
    eng.add_docs(list(rng.integers(1, VOCAB, size=(n_docs, 24))
                      .astype(np.int32)))
    eng.flush()
    clock.t = 0.0
    return eng


def _encode_one(eng: CorpusEngine, toks: np.ndarray):
    """Encode one query through the engine's (clock-advancing)
    encoder."""
    toks = np.asarray(toks, np.int32)[None, :]
    return eng.encoder.encode_fn(toks, np.ones_like(toks))


def run_zipf_replay(n_docs: int, duration: float, qps: float) -> Dict:
    """The same skewed stream, cache-off then cache-on."""
    out: Dict = {}
    for mode in ("off", "on"):
        clock = SimClock()
        eng = _sim_corpus_engine(clock, n_docs)
        cache = hot = None
        if mode == "on":
            cache = QueryResultCache(CACHE_BYTES)
            hot = HotPostingCache(HOT_BYTES)
            frontend = CachedEngine(eng, result_cache=cache,
                                    hot_cache=hot, tag="replay")
        else:
            frontend = eng
        zipf = ZipfQueries(CATALOG, alpha=ZIPF_ALPHA, seed=3)
        rng = np.random.default_rng(4)
        lats, served = [], 0
        for t_arr in poisson_arrivals(rng, qps, 0.0, duration):
            # closed single-server replay: the serving point can't
            # start before the query arrives or the previous finishes
            clock.t = max(clock.t, t_arr)
            _, toks = zipf.sample(rng)
            rep = _encode_one(eng, toks)
            h0 = cache.counters["hits"] if cache is not None else 0
            frontend.search(rep, K, method="fused")
            hit = cache is not None and cache.counters["hits"] > h0
            clock.advance(HIT_COST_S if hit else MISS_COST_S)
            lats.append(clock.t - t_arr)
            served += 1
        lat_ms = np.asarray(lats) * 1e3
        span = max(clock.t, duration)
        rec = {
            "offered_qps": round(served / duration, 2),
            "sustained_qps": round(served / span, 2),
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        }
        if mode == "on":
            rec["hit_rate"] = cache.stats()["hit_rate"]
            rec["cache"] = cache.stats()
            rec["hot"] = hot.stats()
            # the hard invariant, checked on this very corpus: cached
            # vs raw engine, id- and value-identical
            probes = eng.encoder.encode_fn(
                zipf.tokens[:8], np.ones_like(zipf.tokens[:8]))
            cv, ci = frontend.search(probes, K, method="fused")
            rv, ri = eng.search(probes, K, method="fused")
            rec["parity"] = bool(
                np.array_equal(cv, np.asarray(rv))
                and np.array_equal(ci, np.asarray(ri)))
        out[f"cache_{mode}"] = rec
    return out


def run_churn(n_docs: int, rounds: int) -> Dict:
    """Mutations interleaved with cached searches; cache-on must
    match cache-off after every single step."""
    clock = SimClock()
    eng = _sim_corpus_engine(clock, n_docs)
    cache = QueryResultCache(CACHE_BYTES)
    cached = CachedEngine(eng, result_cache=cache,
                          hot_cache=HotPostingCache(HOT_BYTES),
                          tag="churn")
    zipf = ZipfQueries(CATALOG, alpha=ZIPF_ALPHA, seed=3)
    rng = np.random.default_rng(5)
    live = list(eng.builder.external_ids()) if hasattr(
        eng.builder, "external_ids") else []
    mismatches = 0
    ops = {"add": 0, "remove": 0, "flush": 0, "compact": 0, "none": 0}
    removable: list = []
    for _ in range(rounds):
        op = ("add", "remove", "flush", "compact",
              "none")[int(rng.integers(0, 5))]
        ops[op] += 1
        if op == "add":
            ids = eng.add_docs(list(
                rng.integers(1, VOCAB, size=(6, 24)).astype(np.int32)))
            removable.extend(int(i) for i in ids)
        elif op == "remove" and removable:
            n = min(3, len(removable))
            eng.remove_docs(removable[:n])
            removable = removable[n:]
        elif op == "flush":
            eng.flush()
        elif op == "compact":
            eng.flush(force_compact=True)
        qidx = rng.integers(0, CATALOG, size=4)
        probes = eng.encoder.encode_fn(
            zipf.tokens[qidx], np.ones((4, zipf.tokens.shape[1]),
                                       np.int32))
        cv, ci = cached.search(probes, K)
        rv, ri = eng.search(probes, K)
        if not (np.array_equal(cv, np.asarray(rv))
                and np.array_equal(ci, np.asarray(ri))):
            mismatches += 1
    st = cache.stats()
    return {
        "rounds": rounds,
        "ops": ops,
        "mismatches": mismatches,
        "end_generation": eng.builder.generation,
        "invalidations": st["invalidations"],
        "hits": st["hits"],
        "misses": st["misses"],
        "live_docs": int(eng.builder.stats()["n_alive"]),
    }


def _pool_pump(pool: TenantPool, clock: SimClock,
               until_t: float) -> None:
    """``workload.pump`` lifted to the pool scheduler."""
    while clock.t < until_t:
        _, n = pool.tick()
        if n:
            continue
        trigs = [t.loop.pending[0].arrival_t
                 + t.loop.encoder.policy.max_wait_s
                 for t in (pool.tenant(nm) for nm in pool.names())
                 if t.loop.pending]
        if not trigs:
            clock.t = until_t
            return
        clock.t = min(max(min(trigs), clock.t + 1e-4), until_t)


def run_tenancy(duration: float, qps_each: float) -> Dict:
    """Weighted fairness under saturation + poison isolation."""
    clock = SimClock()
    # fold a search-sized per-item cost in so the shared encoder is
    # the contended resource; tenant "c" poisons every 10th request
    faulty = inject_faults(
        make_sim_encoder(clock, item_cost=lambda: MISS_COST_S),
        [{"on": {"token": POISON_TOKEN}, "exc": "fault"}],
        seed=0, sleep=clock.advance)
    be = BatchedEncoder(faulty,
                        policy=BatchPolicy(max_batch=MAX_BATCH,
                                           max_wait_s=MAX_WAIT_S))
    pool = TenantPool(be, clock=clock, cache_bytes=CACHE_BYTES)
    weights = {"a": 2.0, "b": 1.0, "c": 1.0}
    for name, w in weights.items():
        pool.add_tenant(name, VOCAB, quota=TenantQuota(weight=w),
                        keep_forward=True)
    rng = np.random.default_rng(7)
    for name in pool.names():
        pool.add_docs(name, list(
            rng.integers(1, VOCAB, size=(12, 24)).astype(np.int32)))
    clock.t = 0.0
    uid, n_poison = 0, 0
    names = ("a", "b", "c")
    for t_arr in poisson_arrivals(rng, 3 * qps_each, 0.0, duration):
        _pool_pump(pool, clock, t_arr)
        name = names[uid % 3]
        toks = uniform_query(rng)
        if name == "c" and uid % 30 == 2:
            toks[0] = POISON_TOKEN
            n_poison += 1
        pool.submit(name, Request(uid=uid, tokens=toks))
        uid += 1
    # fairness is read *inside* the contended window — drain serves
    # the backlog and would equalize totals
    contended = {n: int(pool.tenant(n).loop.counters["served"])
                 for n in names}
    pool.drain()
    per = {}
    for n in names:
        c = pool.tenant(n).loop.counters
        per[n] = {
            "weight": weights[n],
            "served_contended": contended[n],
            "served": int(c["served"]),
            "shed": int(c["shed_admission"] + c["shed_expired"]),
            "failed": int(c["failed"]),
        }
    fair = (contended["a"] / max(1, contended["b"]))
    return {
        "tenants": per,
        "fairness_ratio_ab": round(fair, 3),
        "weight_ratio_ab": weights["a"] / weights["b"],
        "poison_submitted": n_poison,
        "pool_memory_bytes": pool.memory_bytes(),
    }


def run_continuous(cycles: int) -> Dict:
    """Bursty mixed-SLO traffic: one-batch-per-tick vs continuous."""
    burst_s, calm_s = 0.25, 0.75
    burst_qps, calm_qps = 600.0, 40.0
    tight_s, loose_s = 0.04, 1.0

    def run(continuous: bool) -> Dict:
        clock = SimClock()
        be = BatchedEncoder(
            make_sim_encoder(clock, item_cost=lambda: 0.002),
            policy=BatchPolicy(max_batch=MAX_BATCH,
                               max_wait_s=MAX_WAIT_S))
        loop = ServingLoop(be, clock=clock,
                           admission=AdmissionPolicy(
                               max_queue_depth=256),
                           continuous=continuous, window=1 << 16)
        rng = np.random.default_rng(6)
        uid = 0
        t0 = 0.0
        for _ in range(cycles):
            for qps, dur in ((burst_qps, burst_s),
                             (calm_qps, calm_s)):
                for t_arr in poisson_arrivals(rng, qps, t0, t0 + dur):
                    pump(loop, clock, t_arr)
                    toks = uniform_query(rng)
                    deadline = tight_s if uid % 2 else loose_s
                    loop.submit(Request(uid=uid, tokens=toks,
                                        deadline_s=deadline))
                    uid += 1
                pump(loop, clock, t0 + dur)
                t0 += dur
        while loop.pending:
            loop.tick(force=True)
        served = shed = failed = 0
        for u in range(uid):
            res = loop.take(u)          # KeyError == lost uid
            if isinstance(res, ShedResult):
                shed += 1
            elif isinstance(res, FailedResult):
                failed += 1
            else:
                served += 1
        span = max(clock.t, 1e-9)
        lat = loop.latencies() * 1e3
        return {
            "submitted": uid,
            "served": served,
            "shed": shed,
            "failed": failed,
            "lost": uid - served - shed - failed,
            "sustained_qps": round(served / span, 2),
            "shed_rate": round(shed / max(1, uid), 4),
            "p50_ms": (round(float(np.percentile(lat, 50)), 3)
                       if lat.size else 0.0),
            "p99_ms": (round(float(np.percentile(lat, 99)), 3)
                       if lat.size else 0.0),
        }

    return {"one_batch": run(False), "continuous": run(True)}


def run(smoke: bool = False, json_path: str = None):
    smoke = smoke or os.environ.get("BENCH_SMOKE") == "1"
    p = SMOKE if smoke else FULL

    replay = run_zipf_replay(p["n_docs"], p["replay_s"],
                             p["replay_qps"])
    churn = run_churn(p["n_docs"], p["churn_rounds"])
    tenancy = run_tenancy(p["tenant_s"], p["tenant_qps"])
    continuous = run_continuous(p["cont_cycles"])

    record = {
        "shape": {"vocab": VOCAB, "n_docs": p["n_docs"],
                  "catalog": CATALOG, "zipf_alpha": ZIPF_ALPHA,
                  "max_batch": MAX_BATCH,
                  "cache_bytes": CACHE_BYTES,
                  "hot_bytes": HOT_BYTES},
        "zipf_replay": replay,
        "churn": churn,
        "tenancy": tenancy,
        "continuous": continuous,
    }

    on, off = replay["cache_on"], replay["cache_off"]
    print("zipf replay: hit_rate="
          f"{on['hit_rate']} parity={on['parity']} "
          f"qps on/off={on['sustained_qps']}/{off['sustained_qps']} "
          f"p99 on/off={on['p99_ms']}/{off['p99_ms']} ms")
    print(f"churn: {churn['rounds']} rounds, "
          f"{churn['mismatches']} mismatches, "
          f"gen={churn['end_generation']}, "
          f"invalidations={churn['invalidations']}")
    t = tenancy["tenants"]
    print("tenancy: contended served "
          + ", ".join(f"{n}={t[n]['served_contended']}" for n in t)
          + f" (ratio a/b={tenancy['fairness_ratio_ab']}), "
          + f"poison c failed={t['c']['failed']}, "
          + f"victims shed+failed="
          f"{t['a']['shed'] + t['a']['failed'] + t['b']['shed'] + t['b']['failed']}")
    cb, ob = continuous["continuous"], continuous["one_batch"]
    print(f"continuous: qps {ob['sustained_qps']} -> "
          f"{cb['sustained_qps']}, shed_rate {ob['shed_rate']} -> "
          f"{cb['shed_rate']}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="emit BENCH_frontier.json-style record here")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)
