"""RecSys architecture smokes: all four families train/serve on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import recsys_batches
from repro.launch.steps import (build_recsys_serve_step,
                                build_recsys_train_step, init_state,
                                streaming_topk)
from repro.models import recsys

ARCHS = ["dlrm_mlperf", "xdeepfm", "dien", "wide_deep"]


def _batch(cfg, B=16, seed=0):
    gen = recsys_batches(batch=B, n_dense=cfg.n_dense,
                         n_sparse=cfg.n_sparse,
                         table_sizes=cfg.table_sizes,
                         seq_len=cfg.seq_len, seed=seed)
    return {k: jnp.asarray(v) for k, v in next(gen).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(0), smoke=True)
    batch = _batch(cfg)
    logits = recsys.forward(state["params"], cfg, batch)
    assert logits.shape == (16,)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_learns(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(1), smoke=True)
    batch = _batch(cfg, seed=2)
    step = jax.jit(build_recsys_train_step(cfg, lr=0.05))
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step_probabilities(arch):
    cfg = get_config(arch).SMOKE
    state, _ = init_state(arch, jax.random.PRNGKey(0), smoke=True)
    serve = jax.jit(build_recsys_serve_step(cfg))
    p = serve(state["params"], _batch(cfg))
    p = np.asarray(p)
    assert ((p >= 0) & (p <= 1)).all()


def test_user_embedding_and_retrieval():
    cfg = get_config("dlrm_mlperf").SMOKE
    state, _ = init_state("dlrm_mlperf", jax.random.PRNGKey(0), smoke=True)
    batch = _batch(cfg, B=2)
    qv = recsys.user_embedding(state["params"], cfg, batch)
    assert qv.shape == (2, cfg.embed_dim)
    cands = jax.random.normal(jax.random.PRNGKey(3), (500, cfg.embed_dim))
    vals, idx = streaming_topk(qv, cands, k=7, tile=128)
    assert vals.shape == (2, 7)
    # verify against dense top-k
    dense = jnp.einsum("bd,nd->bn", qv, cands)
    ref_vals, ref_idx = jax.lax.top_k(dense, 7)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(ref_vals),
                               atol=1e-5)


def test_table_padding_invariant():
    """Padded table rows must never be selected by real ids."""
    from repro.models.recsys import padded_rows
    assert padded_rows(100) == 4096
    assert padded_rows(4096) == 4096
    assert padded_rows(4097) == 8192
    cfg = get_config("dlrm_mlperf").SMOKE
    state, _ = init_state("dlrm_mlperf", jax.random.PRNGKey(0), smoke=True)
    for t, raw in zip(state["params"]["tables"], cfg.table_sizes):
        assert t.shape[0] == padded_rows(raw)


def test_dien_unroll_invariance():
    cfg = get_config("dien").SMOKE
    state, _ = init_state("dien", jax.random.PRNGKey(0), smoke=True)
    batch = _batch(cfg)
    y1 = recsys.forward(state["params"], cfg, batch, unroll=1)
    y2 = recsys.forward(state["params"], cfg, batch, unroll=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
