"""Eval subsystem: ranking metrics (reference + batched JAX parity),
Qrels containers, and the end-to-end evaluate_retrieval harness.

The reference implementations are pinned against hand-computed
values; the batched path is pinned against the references on random
instances (so a broadcast bug can't hide behind a symmetric formula);
properties (ideal ranking, irrelevant-permutation invariance, recall
monotonicity) run under the hypothesis stub.
"""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.eval import (BATCHED, METRIC_NAMES, MethodSpec, Qrels,
                        REFERENCE, compute_metrics, encode_reps,
                        evaluate_retrieval, mrr_ref, ndcg_ref,
                        ranked_grades, recall_ref, success_ref)

# ---------------------------------------------------------------------------
# reference metrics: hand-computed values
# ---------------------------------------------------------------------------

# query with graded judgments: doc 3 grade 2, doc 7 grade 1, doc 9
# grade 3 (the most relevant), docs 0/5 unjudged
RELS = {3: 2.0, 7: 1.0, 9: 3.0}


def test_mrr_hand_computed():
    assert mrr_ref([0, 5, 3, 9], RELS, 10) == pytest.approx(1 / 3)
    assert mrr_ref([9, 0, 5, 3], RELS, 10) == 1.0
    assert mrr_ref([0, 5, 3, 9], RELS, 2) == 0.0      # first hit at 3
    assert mrr_ref([0, 5], RELS, 10) == 0.0
    assert mrr_ref([-1, 9], RELS, 10) == 0.5          # pad not a match


def test_ndcg_hand_computed():
    # ranked [9, 3, 7]: dcg = 7/log2(2) + 3/log2(3) + 1/log2(4)
    dcg = 7.0 + 3.0 / math.log2(3) + 0.5
    assert ndcg_ref([9, 3, 7], RELS, 10) == pytest.approx(1.0)
    # worst relevant order [7, 3, 9]
    got = 1.0 + 3.0 / math.log2(3) + 7.0 / 2.0
    assert ndcg_ref([7, 3, 9], RELS, 10) == pytest.approx(got / dcg)
    # unjudged docs at the top push gains to deeper discounts
    deep = 7.0 / math.log2(3) + 3.0 / 2.0 + 1.0 / math.log2(5)
    assert ndcg_ref([0, 9, 3, 7], RELS, 10) == pytest.approx(deep / dcg)
    assert ndcg_ref([0, 5], RELS, 10) == 0.0
    assert ndcg_ref([9], {}, 10) == 0.0               # nothing judged


def test_recall_success_hand_computed():
    assert recall_ref([9, 0, 3], RELS, 10) == pytest.approx(2 / 3)
    assert recall_ref([9, 0, 3], RELS, 1) == pytest.approx(1 / 3)
    assert recall_ref([0, 5], RELS, 10) == 0.0
    assert recall_ref([9], {}, 10) == 0.0
    assert success_ref([0, 5, 7], RELS, 10) == 1.0
    assert success_ref([0, 5], RELS, 10) == 0.0


def test_negative_grade_is_not_relevant():
    rels = {3: -1.0, 7: 2.0}
    assert mrr_ref([3, 7], rels, 10) == 0.5
    assert recall_ref([3], rels, 10) == 0.0
    assert ndcg_ref([3, 7], rels, 10) == pytest.approx(
        (3.0 / math.log2(3)) / 3.0)


# ---------------------------------------------------------------------------
# batched JAX path: parity with the references
# ---------------------------------------------------------------------------

def _random_instance(rng, n_docs=30, b=6, k=8, r=5):
    ranked = np.stack([rng.permutation(n_docs)[:k] for _ in range(b)])
    ranked[rng.random(ranked.shape) < 0.15] = -1      # padding holes
    qrels = {}
    for q in range(b):
        docs = rng.permutation(n_docs)[:rng.integers(0, r + 1)]
        qrels[q] = {int(d): float(rng.integers(1, 4)) for d in docs}
    return ranked, Qrels(qrels)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), k=st.integers(1, 8))
def test_batched_matches_reference(seed, k):
    rng = np.random.default_rng(seed)
    ranked, qrels = _random_instance(rng)
    rel_ids, rel_grades = qrels.to_arrays()
    for name in METRIC_NAMES:
        got = np.asarray(BATCHED[name](ranked, rel_ids, rel_grades,
                                       k=k))
        want = [REFERENCE[name](ranked[q], qrels.relevant(q), k)
                for q in range(ranked.shape[0])]
        np.testing.assert_allclose(got, want, atol=1e-5,
                                   err_msg=f"{name}@{k} seed={seed}")


def test_ranked_grades_broadcast():
    ranked = np.array([[9, -1, 3], [7, 7, 0]])
    rel_ids = np.array([[3, 9], [7, -1]])
    rel_grades = np.array([[2.0, 3.0], [1.0, 0.0]])
    np.testing.assert_allclose(
        np.asarray(ranked_grades(ranked, rel_ids, rel_grades)),
        [[3.0, 0.0, 2.0], [1.0, 1.0, 0.0]])


# ---------------------------------------------------------------------------
# metric properties (hypothesis stub)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_property_ideal_ranking_is_perfect(seed):
    """Relevant docs ranked by descending grade ⇒ nDCG = MRR = 1."""
    rng = np.random.default_rng(seed)
    docs = rng.permutation(50)[:rng.integers(1, 8)]
    rels = {int(d): float(g) for d, g in
            zip(docs, rng.integers(1, 5, size=docs.size))}
    ideal = sorted(rels, key=rels.get, reverse=True)
    assert ndcg_ref(ideal, rels, 10) == pytest.approx(1.0)
    assert mrr_ref(ideal, rels, 10) == 1.0
    assert recall_ref(ideal, rels, 10) == 1.0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_property_irrelevant_permutation_invariance(seed):
    """Shuffling docs *below* every relevant one changes nothing."""
    rng = np.random.default_rng(seed)
    rels = {3: 2.0, 8: 1.0}
    tail = list(rng.permutation([10, 11, 12, 13, 14]))
    a = [3, 8] + [10, 11, 12, 13, 14]
    b = [3, 8] + [int(t) for t in tail]
    for name in METRIC_NAMES:
        assert REFERENCE[name](a, rels, 7) == pytest.approx(
            REFERENCE[name](b, rels, 7))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_property_recall_monotone_in_k(seed):
    rng = np.random.default_rng(seed)
    ranked, qrels = _random_instance(rng, b=1)
    rels = qrels.relevant(0)
    vals = [recall_ref(ranked[0], rels, k) for k in range(1, 9)]
    assert all(a <= b + 1e-12 for a, b in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# Qrels container
# ---------------------------------------------------------------------------

def test_qrels_from_triples_keeps_highest_grade():
    q = Qrels.from_triples([(0, 5, 1.0), (0, 5, 3.0), (1, 2, 2.0),
                            (0, 5, 2.0)])
    assert q.grade(0, 5) == 3.0
    assert q.grade(1, 2) == 2.0
    assert q.grade(1, 5) == 0.0
    assert q.query_ids == [0, 1]
    assert q.n_judged == 2
    # (M, 3) float array form (what lsr_impact_corpus emits)
    arr = np.array([[0, 3, 2.0], [2, 4, 1.0]], np.float32)
    q2 = Qrels.from_triples(arr)
    assert q2.grade(0, 3) == 2.0 and q2.grade(2, 4) == 1.0


def test_qrels_paired():
    q = Qrels.paired(3, doc_ids=[10, 20, 30], grade=2.0)
    assert q.relevant(1) == {20: 2.0}
    assert q.max_relevant == 1
    with pytest.raises(ValueError, match="doc ids"):
        Qrels.paired(3, doc_ids=[1, 2])


def test_qrels_remap_docs():
    q = Qrels({0: {5: 1.0, 6: 2.0}})
    r = q.remap_docs({5: 50, 6: 60})
    assert r.relevant(0) == {50: 1.0, 60: 2.0}
    with pytest.raises(KeyError, match="no entry"):
        q.remap_docs({5: 50})
    dropped = q.remap_docs({5: 50}, strict=False)
    assert dropped.relevant(0) == {50: 1.0}


def test_qrels_to_arrays_padding():
    q = Qrels({0: {3: 2.0}, 4: {1: 1.0, 2: 3.0}})
    ids, grades = q.to_arrays()
    assert ids.shape == (2, 2)
    np.testing.assert_array_equal(ids, [[3, -1], [1, 2]])
    np.testing.assert_allclose(grades, [[2.0, 0.0], [1.0, 3.0]])
    # explicit query order incl. an unjudged query
    ids, grades = q.to_arrays([4, 7], width=3)
    np.testing.assert_array_equal(ids, [[1, 2, -1], [-1, -1, -1]])
    with pytest.raises(ValueError, match="width"):
        q.to_arrays([4], width=1)


def test_compute_metrics_row_alignment():
    qrels = Qrels.paired(2)
    ranked = np.array([[0, 5], [1, 5], [9, 9]])
    with pytest.raises(ValueError, match="ranking rows"):
        compute_metrics(ranked, qrels)
    out = compute_metrics(ranked[:2], qrels, ks=(1, 2))
    assert out["mrr@1"] == 1.0 and out["mrr@2"] == 1.0
    # reversed alignment: query 0 scored against qrels query 1
    out = compute_metrics(ranked[:2], qrels, ks=(2,),
                          query_ids=[1, 0])
    assert out["mrr@2"] == 0.0


# ---------------------------------------------------------------------------
# harness: encode → index → search → score
# ---------------------------------------------------------------------------

def test_evaluate_retrieval_impact_corpus_methods_agree():
    from repro.data.synthetic import lsr_impact_corpus

    corpus = lsr_impact_corpus(n_docs=96, vocab=1024, doc_nnz=32,
                               n_queries=8, q_nnz=26, graded=12,
                               seed=3)
    qrels = Qrels.from_triples(corpus["qrels"])
    methods = (MethodSpec("exact"),
               MethodSpec("pruned", engine={"keep_forward": True},
                          search={"method": "pruned",
                                  "prune_margin": 0.0}),
               MethodSpec("quantized", engine={"quantize": True}),
               MethodSpec("doc_sharded", doc_shards=3))
    res = evaluate_retrieval(None, corpus, qrels, methods=methods,
                             ks=(10,), metrics=("mrr", "ndcg"))
    assert res["exact"]["ndcg@10"] == pytest.approx(1.0)
    assert res["exact"]["mrr@10"] == pytest.approx(1.0)
    for name in ("pruned", "quantized", "doc_sharded"):
        for m in ("mrr@10", "ndcg@10"):
            assert res[name][m] == pytest.approx(res["exact"][m],
                                                 abs=1e-6), name


def test_evaluate_retrieval_token_corpus_and_external_ids():
    """A toy sparse 'encoder' (token histogram) + shifted external doc
    ids: the harness must key rankings by the ids qrels use."""
    import jax.numpy as jnp

    vocab = 64

    def encoder(tokens, mask):
        oh = jnp.zeros((tokens.shape[0], vocab))
        rows = jnp.repeat(jnp.arange(tokens.shape[0]),
                          tokens.shape[1])
        oh = oh.at[rows, tokens.reshape(-1)].add(mask.reshape(-1))
        return oh

    rng = np.random.default_rng(0)
    n_docs, n_q, s = 12, 4, 6
    doc_tokens = np.stack([rng.permutation(vocab)[:s]
                           for _ in range(n_docs)]).astype(np.int32)
    q_tokens = doc_tokens[:n_q]          # query q == doc q's tokens
    corpus = {"doc_tokens": doc_tokens, "q_tokens": q_tokens,
              "vocab_size": vocab}
    doc_ids = 100 + np.arange(n_docs)
    qrels = Qrels.paired(n_q, doc_ids=doc_ids[:n_q])
    res = evaluate_retrieval(encoder, corpus, qrels,
                             methods=(MethodSpec("exact"),), ks=(3,),
                             doc_ids=doc_ids, batch=5, rep_topk=8)
    assert res["exact"]["mrr@3"] == pytest.approx(1.0)


def test_encode_reps_chunking_single_trace():
    """Chunk padding must be trimmed and every chunk share a shape."""
    shapes = []

    def encoder(tokens, mask):
        shapes.append(tuple(tokens.shape))
        return np.eye(tokens.shape[0], 32, dtype=np.float32) * 2.0

    reps = encode_reps(encoder, np.zeros((11, 4), np.int32), batch=4,
                       rep_topk=8)
    assert reps.values.shape[0] == 11
    assert set(shapes) == {(4, 4)}       # one trace shape, padded tail


def test_evaluate_retrieval_rejects_bad_corpus():
    with pytest.raises(ValueError, match="corpus must carry"):
        evaluate_retrieval(None, {"docs": np.ones((2, 4))},
                           Qrels.paired(1))
    with pytest.raises(ValueError, match="needs an encoder"):
        evaluate_retrieval(None, {"doc_tokens": np.ones((2, 4)),
                                  "q_tokens": np.ones((1, 4))},
                           Qrels.paired(1))


def test_synthetic_corpus_qrels_grades():
    """lsr_impact_corpus emits (query, doc, grade) triples matching
    its planted geometry: graded docs per query, top grade first."""
    from repro.data.synthetic import lsr_impact_corpus

    c = lsr_impact_corpus(n_docs=40, vocab=256, doc_nnz=16,
                          n_queries=3, q_nnz=12, graded=4, seed=0)
    q = Qrels.from_triples(c["qrels"])
    assert q.n_queries == 3
    for b in range(3):
        rels = q.relevant(b)
        assert len(rels) == 4
        assert sorted(rels.values(), reverse=True) == [4.0, 3.0, 2.0,
                                                       1.0]
        assert rels[b * 4] == 4.0        # doc b*graded+i has grade g-i
