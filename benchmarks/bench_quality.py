"""Retrieval-quality benchmark: the effectiveness axis of every
speed/memory knob, behind ``BENCH_quality.json``.

The paper's headline claim is *"fast with no effectiveness loss"* —
every other bench in this repo measures the "fast" half (latency,
memory, id-parity). This one measures the loss: MRR@10 / nDCG@10 via
``repro.eval`` on the synthetic graded corpus
(``data.synthetic.lsr_impact_corpus`` emits its qrels), whose planted
geometry makes exact retrieval score nDCG@10 = 1.0 by construction —
so any quality deficit in a record is attributable to the knob under
test, not to corpus noise.

Four experiments:

* ``method_quality`` — the full method matrix (exact, two-tier pruned
  at the default margin, u4 quantized, term-sharded, doc-sharded, and
  an aggressive prune margin) on identical reps. The first three must
  match exact within tolerance (the "no effectiveness loss" gate);
  the aggressive margin is *allowed* to trade quality and the record
  shows what it pays.
* ``ladder_quality`` — nDCG@10 per degrade-ladder rung
  (``runtime.serving.DEFAULT_LADDER``: margin + query-narrowing
  knobs), gated monotone non-increasing: each rung may only buy
  latency with quality, never lose both.
* ``rep_topk_sweep`` — quality vs representation width (the
  Unified-LSR sparsification knob): exact retrieval with reps
  truncated to top-w impacts per row.
* ``trained_vs_init`` — the *model* half of the loop: a short SPLADE
  smoke-config training run (InfoNCE + FLOPS via
  ``build_lsr_train_step``) must beat its untrained init on MRR@10 /
  nDCG@10 over a held-out paired batch. Short queries (the held-out
  pair generator splices ``q_len//2`` tokens) keep the untrained
  lexical-overlap prior weak enough that learning is visible.

Everything is seeded and deterministic; ``check.py check_quality``
gates the record, ``report.py`` trends it. ``--smoke`` (or
``BENCH_SMOKE=1``) shrinks the corpus for CI.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import numpy as np

# the graded corpus: seed 3 verified to put every planted grade in
# exact score order (nDCG@10 = 1.0) at both sizes — see check_quality
CORPUS = dict(vocab=1024, doc_nnz=32, q_nnz=26, graded=12, seed=3)
FULL = dict(n_docs=512, n_queries=16, rep_topks=(8, 16, 32, 64),
            train_steps=250)
SMOKE = dict(n_docs=256, n_queries=8, rep_topks=(16, 64),
             train_steps=250)
KS = (10,)
# trained_vs_init recipe (verified improving at these exact settings)
TRAIN = dict(batch=16, q_len=8, d_len=32, n_micro=2, lr=3e-4,
             eval_queries=32, eval_seed=9173)


def _graded_corpus(p):
    from repro.data.synthetic import lsr_impact_corpus
    from repro.eval import Qrels

    corpus = lsr_impact_corpus(n_docs=p["n_docs"],
                               n_queries=p["n_queries"], **CORPUS)
    return corpus, Qrels.from_triples(corpus["qrels"])


def run_method_quality(p) -> Dict[str, Dict[str, float]]:
    """The engine method matrix scored on the graded corpus."""
    from repro.eval import DEFAULT_METHODS, MethodSpec, evaluate_retrieval

    corpus, qrels = _graded_corpus(p)
    methods = DEFAULT_METHODS + (
        MethodSpec("term_sharded", engine={"term_shards": 4}),
        MethodSpec("doc_sharded", doc_shards=4),
        MethodSpec("aggressive", engine={"keep_forward": True},
                   search={"method": "pruned", "prune_margin": 0.5}),
    )
    res = evaluate_retrieval(None, corpus, qrels, methods=methods,
                             ks=KS)
    return {m: {k: round(v, 4) for k, v in d.items()}
            for m, d in res.items()}


def run_ladder_quality(p, k: int = 10) -> Dict[str, float]:
    """nDCG@10 down the serving degrade ladder (shared rung knobs)."""
    import jax.numpy as jnp

    from repro.eval.metrics import compute_metrics
    from repro.retrieval import IndexBuilder
    from repro.retrieval.sparse_rep import sparsify_topk
    from repro.runtime.serving import DegradePolicy

    corpus, qrels = _graded_corpus(p)
    doc_reps = sparsify_topk(jnp.asarray(corpus["docs"]),
                             CORPUS["doc_nnz"])
    q_reps = sparsify_topk(jnp.asarray(corpus["queries"]),
                           CORPUS["q_nnz"])
    builder = IndexBuilder(CORPUS["vocab"], keep_forward=True)
    builder.add(doc_reps)
    builder.flush()
    out = {}
    for step in DegradePolicy().ladder:
        kw = dict(step.search_kwargs)
        if step.q_width_frac < 1.0:
            kw["q_width"] = max(1, int(q_reps.width * step.q_width_frac))
        _, ids = builder.search(q_reps, k, **kw)
        m = compute_metrics(np.asarray(ids), qrels, ks=(k,),
                            metrics=("ndcg",))
        out[step.name] = round(m[f"ndcg@{k}"], 4)
    return out


def run_rep_topk_sweep(p) -> Dict[str, Dict[str, float]]:
    """Exact-retrieval quality vs rep width (top-w impacts kept)."""
    from repro.eval import MethodSpec, evaluate_retrieval

    corpus, qrels = _graded_corpus(p)
    out = {}
    for w in p["rep_topks"]:
        res = evaluate_retrieval(None, corpus, qrels,
                                 methods=(MethodSpec("exact"),),
                                 ks=KS, rep_topk=w)
        out[str(w)] = {k: round(v, 4) for k, v in res["exact"].items()}
    return out


def run_trained_vs_init(p) -> Dict:
    """Short training run vs its untrained init on held-out pairs."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.synthetic import lsr_pair_batches
    from repro.eval import MethodSpec, Qrels, evaluate_retrieval
    from repro.launch.steps import _encode_fn, build_lsr_train_step
    from repro.models import transformer as tfm
    from repro.optim.optimizers import adamw

    t = TRAIN
    cfg = get_config("splade_bert").SMOKE
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-4)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = build_lsr_train_step(cfg, None, n_micro=t["n_micro"],
                                n_pairs=t["batch"], lr=t["lr"])
    jitted = jax.jit(step)

    held = next(lsr_pair_batches(batch=t["eval_queries"],
                                 q_len=t["q_len"], d_len=t["d_len"],
                                 vocab=cfg.vocab_size,
                                 seed=t["eval_seed"]))
    corpus = {"doc_tokens": held["d_tokens"], "doc_mask": held["d_mask"],
              "q_tokens": held["q_tokens"], "q_mask": held["q_mask"],
              "vocab_size": cfg.vocab_size}
    qrels = Qrels.paired(t["eval_queries"])
    encode = _encode_fn(cfg, None, 32)
    enc_jit = jax.jit(lambda pp, tt, mm: encode(pp, tt, mm)[0])

    def evaluate(st):
        res = evaluate_retrieval(
            lambda tt, mm: enc_jit(st["params"], tt, mm), corpus,
            qrels, methods=(MethodSpec("exact"),), ks=KS,
            metrics=("mrr", "ndcg"), batch=32)
        return {k: round(v, 4) for k, v in res["exact"].items()}

    init_m = evaluate(state)
    it = lsr_pair_batches(batch=t["batch"], q_len=t["q_len"],
                          d_len=t["d_len"], vocab=cfg.vocab_size,
                          seed=0)
    losses = []
    for _ in range(p["train_steps"]):
        state, m = jitted(state, {k: jnp.asarray(v)
                                  for k, v in next(it).items()})
        losses.append(float(m["loss"]))
    trained_m = evaluate(state)
    return {
        "steps": p["train_steps"],
        "loss_first": round(losses[0], 4),
        "loss_last": round(losses[-1], 4),
        "init": init_m,
        "trained": trained_m,
        "delta": {k: round(trained_m[k] - init_m[k], 4)
                  for k in trained_m},
    }


def run(smoke: bool = False, json_path: str = None):
    smoke = smoke or os.environ.get("BENCH_SMOKE") == "1"
    p = SMOKE if smoke else FULL

    import warnings
    warnings.filterwarnings(
        "ignore", message=".*stopword-like term.*")

    methods = run_method_quality(p)
    ladder = run_ladder_quality(p)
    sweep = run_rep_topk_sweep(p)
    trained = run_trained_vs_init(p)

    record = {
        "corpus": {**CORPUS, "n_docs": p["n_docs"],
                   "n_queries": p["n_queries"]},
        "quality_metric": "ndcg@10",
        "method_quality": methods,
        "ladder_quality": ladder,
        "rep_topk_sweep": sweep,
        "trained_vs_init": trained,
    }

    print("method,mrr@10,ndcg@10,recall@10,success@10")
    for m, d in methods.items():
        print(f"{m},{d['mrr@10']},{d['ndcg@10']},{d['recall@10']},"
              f"{d['success@10']}")
    print("ladder nDCG@10: " + ", ".join(f"{n}={v}"
                                         for n, v in ladder.items()))
    print("rep_topk nDCG@10: " + ", ".join(
        f"w{w}={d['ndcg@10']}" for w, d in sweep.items()))
    tv = trained
    print(f"trained vs init ({tv['steps']} steps, loss "
          f"{tv['loss_first']}->{tv['loss_last']}): "
          + " ".join(f"{k} {tv['init'][k]}->{tv['trained'][k]}"
                     f"({tv['delta'][k]:+})" for k in tv["init"]))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="emit BENCH_quality.json-style record here")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)
