"""Sparton LM head — the paper's core contribution (pure JAX + sharded).

``head_api`` is the unified entry point: ``make_head(HeadSpec(...),
mesh=...)`` returns one canonical callable for every backend
(naive/tiled/sparton/kernel) and sharding (DESIGN.md §6).
"""

from repro.core.head_api import (
    HeadSpec,
    available_impls,
    get_head_impl,
    make_head,
    register_head_impl,
)
from repro.core.lm_head import (
    lm_head,
    lm_head_naive,
    lm_head_sparton,
    lm_head_tiled,
    sparton_forward_with_indices,
)
from repro.core.sharded import (
    head_shardings,
    sharded_flops_reg,
    sharded_similarity,
    sharded_sparton_head,
)
