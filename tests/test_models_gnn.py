"""DimeNet smoke + property tests (reduced config, CPU)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.specs import CellSpec
from repro.data.synthetic import make_synthetic_graph, molecule_batches
from repro.launch.steps import build_gnn_train_step, init_state
from repro.models import dimenet
from repro.sparse.triplets import build_triplets, count_triplets


def _molecule_batch(n_graphs=4, nodes=8, edges=16, seed=0):
    gen = molecule_batches(n_graphs=n_graphs, nodes_per_graph=nodes,
                           edges_per_graph=edges, seed=seed)
    b = next(gen)
    n_total = n_graphs * nodes
    t_in, t_out = build_triplets(b["edge_src"], b["edge_dst"], n_total,
                                 max_per_edge=4)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    batch["t_in"] = jnp.asarray(t_in)
    batch["t_out"] = jnp.asarray(t_out)
    batch["t_mask"] = jnp.ones((len(t_in),), jnp.int32)
    return batch, n_total


def test_forward_shapes_and_finite():
    cfg = get_config("dimenet").SMOKE
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    batch, n = _molecule_batch()
    out = dimenet.forward(params, cfg, batch)
    assert out.shape == (n, cfg.n_targets)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_graph_readout_shape():
    cfg = get_config("dimenet").SMOKE
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    batch, _ = _molecule_batch(n_graphs=3)
    out = dimenet.forward_graph(params, cfg, batch, 3)
    assert out.shape == (3, cfg.n_targets)


def test_translation_invariance():
    """DimeNet consumes only distances/angles: translating every
    coordinate must not change the output."""
    cfg = get_config("dimenet").SMOKE
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    batch, _ = _molecule_batch(seed=3)
    out1 = dimenet.forward(params, cfg, batch)
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] + jnp.array([5.0, -3.0, 2.0])
    out2 = dimenet.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-4, rtol=1e-4)


def test_rotation_invariance():
    cfg = get_config("dimenet").SMOKE
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    batch, _ = _molecule_batch(seed=4)
    out1 = dimenet.forward(params, cfg, batch)
    theta = 0.7
    R = jnp.array([[np.cos(theta), -np.sin(theta), 0],
                   [np.sin(theta), np.cos(theta), 0],
                   [0, 0, 1.0]], jnp.float32)
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ R.T
    out2 = dimenet.forward(params, cfg, batch2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-3, rtol=1e-3)


def test_train_step_decreases_loss():
    cfg = get_config("dimenet").SMOKE
    state, _ = init_state("dimenet", jax.random.PRNGKey(0), smoke=True)
    batch, _ = _molecule_batch()
    cell = CellSpec("dimenet", "molecule", "gnn_train", {}, n_graphs=4)
    step = jax.jit(build_gnn_train_step(cfg, cell, lr=3e-3))
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_feature_input_mode():
    """d_feat > 0 switches from atom-type embedding to dense features."""
    cfg = dataclasses.replace(get_config("dimenet").SMOKE, d_feat=12)
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    batch, n = _molecule_batch()
    batch["node_feat"] = jax.random.normal(jax.random.PRNGKey(5), (n, 12))
    out = dimenet.forward(params, cfg, batch)
    assert out.shape == (n, cfg.n_targets)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_dense_triplet_path_matches_flat():
    """forward_dense_triplets (the §Perf-optimized layout) must equal
    the flat segment-sum path when no triplets overflow the cap."""
    from repro.sparse.triplets import densify_triplets

    cfg = get_config("dimenet").SMOKE  # cap 4
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    batch, n = _molecule_batch(seed=2)
    out_flat = dimenet.forward(params, cfg, batch)

    n_edges = batch["edge_src"].shape[0]
    dense, mask = densify_triplets(np.asarray(batch["t_in"]),
                                   np.asarray(batch["t_out"]),
                                   n_edges, 4)
    batch_dense = {k: v for k, v in batch.items()
                   if not k.startswith("t_")}
    batch_dense["t_in_dense"] = jnp.asarray(dense)
    batch_dense["t_mask_dense"] = jnp.asarray(mask)
    out_dense = dimenet.forward(params, cfg, batch_dense)
    np.testing.assert_allclose(np.asarray(out_flat),
                               np.asarray(out_dense), atol=1e-5)


def test_triplet_construction_correct():
    src = np.array([0, 1, 2, 1])
    dst = np.array([1, 2, 0, 0])
    # edges: e0: 0->1, e1: 1->2, e2: 2->0, e3: 1->0
    t_in, t_out = build_triplets(src, dst, 3)
    # triplets (k->j->i): for e1 (1->2): incoming to 1 is e0 (0->1), k=0 != i=2 ok
    pairs = set(zip(t_in.tolist(), t_out.tolist()))
    assert (0, 1) in pairs            # 0->1->2
    assert (2, 0) in pairs            # 2->0->1
    # excluded: k == i cases, e.g. e2 (2->0) has incoming e1 (1->2), k=1, i=0 ok
    assert (1, 2) in pairs
    # e3 (1->0): only incoming is e0 (0->1) with k=0 == i => excluded
    assert not any(t == 3 for t in t_out.tolist())
    # counting helper is an upper bound (ignores the k==i exclusion)
    assert len(t_in) <= count_triplets(src, dst, 3)


def test_triplet_cap_respected():
    src, dst = make_synthetic_graph(50, 600, seed=1)
    src32, dst32 = src.astype(np.int32), dst.astype(np.int32)
    t_in, t_out = build_triplets(src32, dst32, 50, max_per_edge=3)
    counts = np.bincount(t_out, minlength=len(src32))
    assert counts.max() <= 3
