"""Serving runtime: batched sparse-encoding + retrieval.

The LSR serving path has two stages, both built on the paper's
machinery:

1. **Encode** — requests (token sequences) are micro-batched by a
   deadline/size policy and pushed through backbone + Sparton head
   (inference forward only stores the reduced (B, V) output — the
   paper's memory win applies to serving too; the argmax indices
   double as term-level attributions).
2. **Retrieve** — encoded queries score a candidate corpus. The dense
   fallback is a matmul + top_k; the fused streaming kernel
   (``kernels.topk_score``) is the production path for 1M-candidate
   ``retrieval_cand`` workloads.

``ServingLoop`` is synchronous-deterministic (tests drive it tick by
tick); a thread wrapper is provided for the example server.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_config_encoder(params: Any, cfg: Any, *, spec: Any = None,
                        mesh: Any = None, jit: bool = True
                        ) -> Callable[[Array, Array], Array]:
    """Canonical ``(tokens, mask) -> (B, V)`` encode fn from a config.

    The single serving-side seam over the unified head API: the head is
    built by ``make_head`` from ``cfg.head_spec()`` (or an explicit
    ``spec``), so ``head_impl``, pinned/autotuned blocks and
    ``final_logit_softcap`` are all honored — serving paths must not
    hardcode a head implementation.
    """
    from repro.core.head_api import make_head
    from repro.models import transformer as tfm

    head = make_head(spec if spec is not None else cfg.head_spec(),
                     mesh=mesh)

    def encode(tokens: Array, mask: Array) -> Array:
        Hs, _ = tfm.forward_hidden(params, cfg, tokens, mask)
        E, b = tfm.head_weights(params, cfg)
        return head(Hs, E.astype(Hs.dtype), b, mask)

    return jax.jit(encode) if jit else encode


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray          # (len,) int32
    arrival_t: float = 0.0


@dataclasses.dataclass
class BatchPolicy:
    max_batch: int = 32
    max_wait_s: float = 0.005
    pad_to_multiple: int = 16


class BatchedEncoder:
    """Pads + batches requests and runs the jitted encode fn.

    ``encode_fn(tokens (B, S), mask (B, S)) -> (B, V) sparse reps``.
    Bucket padding: sequences are padded to the next multiple of
    ``pad_to_multiple`` so the jit cache stays small.
    """

    def __init__(self, encode_fn: Callable[[Array, Array], Array],
                 *, policy: Optional[BatchPolicy] = None):
        self.encode_fn = encode_fn
        self.policy = policy or BatchPolicy()

    def _pad_len(self, n: int) -> int:
        m = self.policy.pad_to_multiple
        return max(m, ((n + m - 1) // m) * m)

    def encode_batch(self, reqs: Sequence[Request]) -> Dict[int, np.ndarray]:
        if not reqs:
            return {}
        S = self._pad_len(max(len(r.tokens) for r in reqs))
        B = len(reqs)
        toks = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            n = len(r.tokens)
            toks[i, :n] = r.tokens
            mask[i, :n] = 1
        reps = np.asarray(self.encode_fn(jnp.asarray(toks),
                                         jnp.asarray(mask)))
        return {r.uid: reps[i] for i, r in enumerate(reqs)}


class ServingLoop:
    """Deadline/size micro-batching over a request queue."""

    def __init__(self, encoder: BatchedEncoder,
                 *, clock: Callable[[], float] = time.monotonic):
        self.encoder = encoder
        self.clock = clock
        self.pending: List[Request] = []
        self.completed: Dict[int, np.ndarray] = {}
        self.batch_sizes: List[int] = []

    def submit(self, req: Request) -> None:
        req.arrival_t = self.clock()
        self.pending.append(req)

    def tick(self, *, force: bool = False) -> int:
        """Dispatch one batch if policy triggers. Returns batch size."""
        pol = self.encoder.policy
        if not self.pending:
            return 0
        oldest_wait = self.clock() - self.pending[0].arrival_t
        if (len(self.pending) < pol.max_batch
                and oldest_wait < pol.max_wait_s and not force):
            return 0
        batch = self.pending[:pol.max_batch]
        self.pending = self.pending[pol.max_batch:]
        self.completed.update(self.encoder.encode_batch(batch))
        self.batch_sizes.append(len(batch))
        return len(batch)

    def drain(self) -> None:
        while self.pending:
            self.tick(force=True)


def retrieve_topk(
    q_reps: Array,          # (B, V) sparse query reps
    doc_matrix: Array,      # (N, V) document reps (or (N, D) dense)
    k: int = 10,
) -> Tuple[Array, Array]:
    """Dense-fallback retrieval: scores + top-k doc ids."""
    scores = jnp.einsum("bv,nv->bn", q_reps, doc_matrix,
                        preferred_element_type=jnp.float32)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
