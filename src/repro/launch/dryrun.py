import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input
shape) cell on the production meshes and record memory / cost /
collective analysis (EXPERIMENTS.md §Dry-run, §Roofline).

MUST be the very first thing this module does: force 512 placeholder
CPU devices (above), before any jax import, so ``jax.make_mesh`` can
build the (2, 16, 16) production mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_27b
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod    # 512 chips
    PYTHONPATH=src python -m repro.launch.dryrun --json out.json
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import set_mesh

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import (DimeNetConfig, RecSysConfig,
                                TransformerConfig)
from repro.configs.specs import CellSpec, cell_spec
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.sharding import (batch_shardings, batch_spec,
                                   dimenet_param_specs, recsys_param_specs,
                                   state_shardings,
                                   transformer_param_specs)
from repro.launch.steps import build_step, init_state
from repro.models import dimenet as dimenet_model
from repro.models import recsys as recsys_model
from repro.models import transformer as tfm


def _abstract_state(arch_id: str, mesh, cell: Optional[CellSpec] = None
                    ) -> Any:
    """Abstract (ShapeDtypeStruct) train state with shardings attached."""
    from repro.launch.steps import arch_config_for_cell
    if cell is not None:
        cfg = arch_config_for_cell(arch_id, cell)
    else:
        cfg = get_config(arch_id).CONFIG

    if isinstance(cfg, TransformerConfig):
        init = lambda k: tfm.init_params(k, cfg)
        specs = transformer_param_specs(cfg, mesh)
        layout = "adamw"
    elif isinstance(cfg, DimeNetConfig):
        init = lambda k: dimenet_model.init_params(k, cfg)
        specs = dimenet_param_specs(cfg, mesh)
        layout = "adamw"
    else:
        init = lambda k: recsys_model.init_params(k, cfg)
        specs = recsys_param_specs(cfg, mesh)
        layout = "adagrad"

    params_shape = jax.eval_shape(init, jax.ShapeDtypeStruct((2,),
                                                             jnp.uint32))
    shardings = state_shardings(specs, params_shape, layout, mesh)

    def to_f32(l):
        return jax.ShapeDtypeStruct(l.shape, jnp.float32)

    params_abs = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_shape, shardings["params"])
    if layout == "adamw":
        opt_abs = {
            "mu": jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, jnp.float32,
                                                  sharding=s),
                params_shape, shardings["opt"]["mu"]),
            "nu": jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, jnp.float32,
                                                  sharding=s),
                params_shape, shardings["opt"]["nu"]),
        }
    else:
        opt_abs = {
            "acc": jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, jnp.float32,
                                                  sharding=s),
                params_shape, shardings["opt"]["acc"]),
        }
    step_abs = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=shardings["step"])
    state_abs = {"params": params_abs, "opt": opt_abs, "step": step_abs}
    zero_sh = (shardings["opt"]["mu"] if layout == "adamw"
               else shardings["opt"]["acc"])
    return state_abs, shardings["params"], zero_sh


def _abstract_params_only(arch_id: str, mesh,
                          cell: Optional[CellSpec] = None) -> Any:
    return _abstract_state(arch_id, mesh, cell)[0]["params"]


def _batch_overrides(arch_id: str, cell: CellSpec, mesh
                     ) -> Dict[str, P]:
    """Non-default input shardings (caches, candidates, graph arrays)."""
    cfg = get_config(arch_id).CONFIG
    axes = tuple(mesh.axis_names)
    baxes = batch_axes(mesh)
    ov: Dict[str, P] = {}
    if cell.step_kind == "decode":
        B = cell.batch["tokens"].shape[0]
        # model axis goes on KV heads when divisible, else on d_head —
        # keeps the per-position cache scatter local (sequence-sharded
        # caches force an all-gather around the update; DESIGN.md §5)
        if cfg.n_kv_heads % mesh.shape["model"] == 0:
            head_part = ("model", None)
        elif cfg.d_head % mesh.shape["model"] == 0:
            head_part = (None, "model")
        else:
            head_part = (None, None)
        if B == 1:
            # long-context single stream: batch axes are free — put
            # them on the sequence dim (bounded local cache slices)
            seq_axes = baxes
            ov["cache_k"] = P(None, None, seq_axes, *head_part)
            ov["cache_v"] = P(None, None, seq_axes, *head_part)
            ov["tokens"] = P(None, None)
            ov["positions"] = P(None)
        else:
            ov["cache_k"] = P(None, baxes, None, *head_part)
            ov["cache_v"] = P(None, baxes, None, *head_part)
    elif cell.step_kind == "retrieval":
        ov["candidates"] = P(axes, None)
        for k in ("dense", "sparse_idx", "hist_idx", "target_idx"):
            if k in cell.batch:
                ov[k] = P(*([None] * cell.batch[k].ndim))
    elif cell.step_kind == "gnn_train":
        # edge/triplet arrays shard over every axis; node arrays too
        # when padded-divisible (specs pad to 512)
        n_dev = 1
        for a in axes:
            n_dev *= mesh.shape[a]
        for k, sds in cell.batch.items():
            if sds.shape[0] % n_dev == 0:
                ov[k] = P(axes, *([None] * (sds.ndim - 1)))
            else:
                ov[k] = P(*([None] * sds.ndim))
    return ov


def _out_shardings(cell: CellSpec, state_abs, mesh):
    if cell.step_kind.endswith("_train"):
        state_sh = jax.tree.map(lambda l: l.sharding, state_abs)
        return (state_sh, {"loss": NamedSharding(mesh, P())})
    return None  # serve paths: let the partitioner choose outputs


def run_cell(arch_id: str, shape_name: str, mesh,
             *, verbose: bool = True) -> Dict[str, Any]:
    mod = get_config(arch_id)
    spec = mod.SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch_id, "shape": shape_name,
                           "mesh": "x".join(str(s) for s in
                                            tuple(mesh.devices.shape))}
    if spec.skip:
        rec["status"] = "skipped"
        rec["reason"] = spec.skip_reason
        return rec

    t0 = time.time()
    cell = cell_spec(arch_id, shape_name)
    # NOTE: scans stay rolled here (fast compile, exact memory analysis,
    # real collective schedule). cost_analysis() counts each scan body
    # once — benchmarks/roofline.py recovers exact totals with unrolled
    # per-layer/per-head probes and composes them analytically.
    needs_state = cell.step_kind.endswith("_train")
    param_sh = zero_sh = None
    state_abs = None
    if needs_state:
        state_abs, param_sh, zero_sh = _abstract_state(arch_id, mesh, cell)
    step = build_step(arch_id, cell, mesh, unroll=False,
                      param_specs=param_sh, zero_specs=zero_sh)

    overrides = _batch_overrides(arch_id, cell, mesh)
    batch_sh = batch_shardings(mesh, cell.batch, overrides)
    batch_abs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=batch_sh[k])
        for k, v in cell.batch.items()
    }

    with set_mesh(mesh):
        if needs_state:
            # donate the train state: params/opt update in place
            jitted = jax.jit(step, donate_argnums=(0,),
                             out_shardings=_out_shardings(cell, state_abs,
                                                          mesh))
            lowered = jitted.lower(state_abs, batch_abs)
        else:
            params_abs = _abstract_params_only(arch_id, mesh, cell)
            # donate the batch on decode (KV cache updates in place)
            donate = (1,) if cell.step_kind == "decode" else ()
            jitted = jax.jit(step, donate_argnums=donate)
            lowered = jitted.lower(params_abs, batch_abs)
        compiled = lowered.compile()

    rec["compile_s"] = round(time.time() - t0, 1)
    flops, hbm = hlo.cost_analysis_terms(compiled)
    coll = hlo.parse_collectives(compiled.as_text())
    mem = hlo.memory_analysis_bytes(compiled)

    model_flops = _model_flops(arch_id, cell, mesh)
    roof = hlo.roofline_terms(flops, hbm, coll, model_flops=model_flops)

    rec.update({
        "status": "ok",
        "step_kind": cell.step_kind,
        "n_micro": cell.n_micro,
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm,
        "collective_operand_bytes": coll.total_operand_bytes,
        "collective_wire_bytes": coll.total_wire_bytes,
        "collective_ops": coll.op_counts,
        "memory_analysis": mem,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "bottleneck": roof.bottleneck,
        "model_flops_per_device": model_flops,
        "useful_ratio": roof.useful_ratio,
    })
    if verbose:
        peak = (mem or {}).get("peak_estimate_bytes", float("nan"))
        print(f"  [{rec['mesh']}] {arch_id}/{shape_name}: "
              f"compile {rec['compile_s']}s  "
              f"flops/dev {flops:.3e}  hbm/dev {hbm:.3e}  "
              f"coll wire {coll.total_wire_bytes:.3e}  "
              f"peak {peak:.3e}  bottleneck {roof.bottleneck}",
              flush=True)
    return rec


def _model_flops(arch_id: str, cell: CellSpec, mesh) -> float:
    """Useful model flops per device: 6*N*D (train) / 2*N*D (fwd) for
    LMs (N = active params); family-appropriate estimates otherwise."""
    cfg = get_config(arch_id).CONFIG
    n_dev = mesh.devices.size
    if isinstance(cfg, TransformerConfig):
        n_active = cfg.n_active_params
        if cell.step_kind == "lsr_train":
            B, S = cell.batch["q_tokens"].shape
            tokens = 2 * B * S  # queries + docs
            return 6.0 * n_active * tokens / n_dev
        if cell.step_kind == "lsr_prefill":
            B, S = cell.batch["tokens"].shape
            return 2.0 * n_active * B * S / n_dev
        if cell.step_kind == "decode":
            B = cell.batch["tokens"].shape[0]
            # one token per sequence + attention over the cache
            attn = (2 * cfg.n_layers * cell.cache_len
                    * cfg.n_heads * cfg.d_head * 2)
            return (2.0 * n_active + attn) * B / n_dev
        return 0.0
    if isinstance(cfg, DimeNetConfig):
        # per block, per edge: msg_in/msg_out/out projections (~6 d^2)
        # + the factored bilinear (2 K nb d + 2 nb d^2); K-sum layout
        d, nb = cfg.d_hidden, cfg.n_bilinear
        K = max(1, cell.n_triplets // max(1, cell.n_edges))
        per_edge = cfg.n_blocks * (6 * d * d + 2 * K * nb * d
                                   + 2 * nb * d * d)
        fwd = cell.n_edges * per_edge
        return 3.0 * fwd / n_dev  # fwd+bwd ~ 3x fwd
    # recsys: interaction op + MLPs (embedding gathers are bytes,
    # not flops)
    if cell.step_kind == "retrieval":
        return 2.0 * cell.n_candidates * cfg.embed_dim / n_dev
    B = next(iter(cell.batch.values())).shape[0]
    d = cfg.embed_dim
    per_ex = 0.0
    if cfg.interaction == "dot":
        dims = cfg.bot_mlp + (cfg.n_sparse + 1 + 351,) + cfg.top_mlp
        n_f = cfg.n_sparse + 1
        per_ex += 2 * n_f * n_f * d            # pairwise dots
        for i in range(len(cfg.bot_mlp) - 1):
            per_ex += 2 * cfg.bot_mlp[i] * cfg.bot_mlp[i + 1]
        tops = (479,) + cfg.top_mlp
        for i in range(len(tops) - 1):
            per_ex += 2 * tops[i] * tops[i + 1]
    elif cfg.interaction == "cin":
        m_f = cfg.n_sparse
        h_prev = m_f
        for h_k in cfg.cin_layers:
            per_ex += 2 * h_prev * m_f * d     # z outer products
            per_ex += 2 * h_prev * m_f * h_k * d
            h_prev = h_k
        dnn = (m_f * d,) + cfg.mlp
        for i in range(len(dnn) - 1):
            per_ex += 2 * dnn[i] * dnn[i + 1]
    elif cfg.interaction == "augru":
        g = cfg.gru_dim
        per_ex += cfg.seq_len * 2 * (2 * 3 * g * (d + g))  # 2 GRU passes
        mlp = (2 * g + d,) + cfg.mlp + (1,)
        for i in range(len(mlp) - 1):
            per_ex += 2 * mlp[i] * mlp[i + 1]
    else:  # concat
        mlp = (cfg.n_sparse * d,) + cfg.mlp + (1,)
        for i in range(len(mlp) - 1):
            per_ex += 2 * mlp[i] * mlp[i + 1]
    mult = 3.0 if cell.step_kind.endswith("train") else 1.0
    return mult * B * per_ex / n_dev


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="single architecture (default: all)")
    ap.add_argument("--shape", default=None, help="single shape")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512 chip) mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="write records here")
    args = ap.parse_args(argv)

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    archs = [args.arch] if args.arch else ARCH_IDS[:10]
    records = []
    failed = 0
    for mesh in meshes:
        for arch in archs:
            mod = get_config(arch)
            shapes = [args.shape] if args.shape else list(mod.SHAPES)
            for shape in shapes:
                try:
                    records.append(run_cell(arch, shape, mesh))
                except Exception:
                    failed += 1
                    records.append({
                        "arch": arch, "shape": shape,
                        "mesh": "x".join(
                            str(s) for s in tuple(mesh.devices.shape)),
                        "status": "FAILED",
                        "error": traceback.format_exc(limit=20),
                    })
                    print(f"  FAILED {arch}/{shape}", flush=True)
                    traceback.print_exc(limit=8)

    ok = sum(1 for r in records if r.get("status") == "ok")
    sk = sum(1 for r in records if r.get("status") == "skipped")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {failed} failed, "
          f"{len(records)} total", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1, default=str)
        print(f"wrote {args.json}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
