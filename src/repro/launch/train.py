"""Training driver: ``python -m repro.launch.train --arch <id> ...``

Wires configs -> data pipeline -> jitted train step (with shardings
when devices allow a mesh) -> fault-tolerant runner (checkpoint/
restart, straggler policy, elastic re-mesh).

On the CPU container this runs the SMOKE config end-to-end (the
assigned full configs are exercised by the dry-run); on a real pod the
same driver takes ``--full`` and the production mesh.

XLA flags for collective overlap (latency-hiding scheduler) are set
before jax initializes when --overlap is passed.
"""

import argparse
import os
import sys


def _set_overlap_flags() -> None:
    flags = os.environ.get("XLA_FLAGS", "")
    flags += (
        " --xla_tpu_enable_async_collective_fusion=true"
        " --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
        " --xla_tpu_overlap_compute_collective_tc=true"
        " --xla_enable_async_all_gather=true"
        " --xla_enable_async_all_reduce=true"
    )
    os.environ["XLA_FLAGS"] = flags


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="use the full (assigned) config, not SMOKE")
    ap.add_argument("--overlap", action="store_true",
                    help="set XLA latency-hiding scheduler flags")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--lambda-q", type=float, default=None,
                    help="FLOPS regularizer weight on query reps "
                         "(default: config's lambda_q)")
    ap.add_argument("--lambda-d", type=float, default=None,
                    help="FLOPS regularizer weight on doc reps "
                         "(default: config's lambda_d)")
    ap.add_argument("--l1-weight", type=float, default=None,
                    help="L1 rep regularizer weight "
                         "(default: config's l1_weight)")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="every N steps, run retrieval eval (MRR@10/"
                         "nDCG@10 on a held-out paired batch) and log "
                         "it; also evals the untrained init and prints "
                         "the improvement at the end. 0 = off")
    ap.add_argument("--eval-queries", type=int, default=32,
                    help="held-out (query, positive-doc) pairs scored "
                         "by --eval-every")
    ap.add_argument("--head-impl", default=None,
                    help="LSR head implementation (default: config's; "
                         "any registered backend — validated against "
                         "repro.core.head_api.available_impls after "
                         "startup so runtime-registered impls work)")
    ap.add_argument("--autotune-head", action="store_true",
                    help="measure Pallas head block candidates for this "
                         "run shape and persist the winner before "
                         "building the train step")
    args = ap.parse_args(argv)

    if args.overlap:
        _set_overlap_flags()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import (DimeNetConfig, RecSysConfig,
                                    TransformerConfig)
    from repro.data.loader import HostShardedLoader
    from repro.data.synthetic import lsr_pair_batches, recsys_batches
    from repro.launch.steps import (build_lsr_train_step,
                                    build_recsys_train_step, init_state)
    from repro.runtime.fault_tolerance import (FaultTolerantRunner,
                                               RunnerConfig)

    mod = get_config(args.arch)
    cfg = mod.CONFIG if args.full else mod.SMOKE
    state, _ = init_state(args.arch, jax.random.PRNGKey(0),
                          smoke=not args.full)

    if isinstance(cfg, TransformerConfig):
        import dataclasses

        reg = {name: getattr(args, name) for name in
               ("lambda_q", "lambda_d", "l1_weight")
               if getattr(args, name) is not None}
        if reg:
            cfg = dataclasses.replace(cfg, **reg)

    if isinstance(cfg, TransformerConfig) and args.head_impl:
        import dataclasses

        from repro.core.head_api import available_impls
        if args.head_impl not in ("jax",) + available_impls():
            raise SystemExit(
                f"--head-impl {args.head_impl!r}: unknown head impl; "
                f"one of {('jax',) + available_impls()}")
        cfg = dataclasses.replace(cfg, head_impl=args.head_impl)

    if isinstance(cfg, TransformerConfig) and args.autotune_head:
        import dataclasses

        from repro.kernels.autotune import autotune_kernel_blocks
        if cfg.head_impl != "kernel":
            # tuned blocks are only read by the Pallas head — don't
            # spend a timing sweep on a config that would ignore them
            print("--autotune-head implies --head-impl kernel "
                  f"(config had {cfg.head_impl!r})")
            cfg = dataclasses.replace(cfg, head_impl="kernel")
        # Per-kernel winners (fwd vs dH vs dE) land in the autotune
        # cache, where ops.sparton_head's per-kernel resolution reads
        # them — the config's head_block_* stay unpinned on purpose
        # (pinning would force one joint triple onto all three).
        winners = autotune_kernel_blocks(
            args.batch, args.seq_len, cfg.d_model, cfg.vocab_size,
            dtype=jnp.dtype(cfg.compute_dtype),
            softcap=cfg.final_logit_softcap)
        print(f"autotuned head blocks (B={args.batch} S={args.seq_len} "
              f"D={cfg.d_model} V={cfg.vocab_size}): " +
              ", ".join(f"{kn}={blk}" for kn, blk in winners.items()))

    eval_hook = None
    run_eval = None
    eval_log = []
    if isinstance(cfg, TransformerConfig):
        step = build_lsr_train_step(cfg, None, n_micro=1,
                                    n_pairs=args.batch, lr=args.lr)

        def make_iter(shard, n_shards):
            it = lsr_pair_batches(
                batch=args.batch, q_len=args.seq_len, d_len=args.seq_len,
                vocab=cfg.vocab_size, shard=shard)
            for b in it:
                yield {"q_tokens": b["q_tokens"], "q_mask": b["q_mask"],
                       "d_tokens": b["d_tokens"], "d_mask": b["d_mask"]}

        if args.eval_every:
            from repro.eval import MethodSpec, Qrels, evaluate_retrieval
            from repro.launch.steps import _encode_fn

            # held-out pairs: a seed no training shard ever draws, so
            # eval measures generalization, not batch memorization
            held_out = next(lsr_pair_batches(
                batch=args.eval_queries, q_len=args.seq_len,
                d_len=args.seq_len, vocab=cfg.vocab_size, seed=9173))
            corpus = {"doc_tokens": held_out["d_tokens"],
                      "doc_mask": held_out["d_mask"],
                      "q_tokens": held_out["q_tokens"],
                      "q_mask": held_out["q_mask"],
                      "vocab_size": cfg.vocab_size}
            qrels = Qrels.paired(args.eval_queries)
            enc_batch = min(32, args.eval_queries)
            encode = _encode_fn(cfg, None, enc_batch)
            enc_jit = jax.jit(lambda p, t, m: encode(p, t, m)[0])

            def run_eval(state):
                params = state["params"]
                res = evaluate_retrieval(
                    lambda t, m: enc_jit(params, t, m), corpus, qrels,
                    methods=(MethodSpec("exact"),), ks=(10,),
                    metrics=("mrr", "ndcg"), batch=enc_batch)
                return res["exact"]

            def eval_hook(step_idx, state):
                done = step_idx + 1
                if done % args.eval_every and done != args.steps:
                    return None
                m = run_eval(state)
                eval_log.append((done, m))
                print(f"eval @ step {done}: " + " ".join(
                    f"{k} {v:.4f}" for k, v in m.items()))
                return {f"eval_{k}": v for k, v in m.items()}
    elif isinstance(cfg, RecSysConfig):
        step = build_recsys_train_step(cfg)

        def make_iter(shard, n_shards):
            return recsys_batches(
                batch=args.batch, n_dense=cfg.n_dense,
                n_sparse=cfg.n_sparse, table_sizes=cfg.table_sizes,
                seq_len=cfg.seq_len, shard=shard)
    else:
        raise SystemExit(
            "use examples/train_dimenet.py for the GNN family")

    loader = HostShardedLoader(make_iter)
    jitted = jax.jit(step, donate_argnums=(0,))

    def place(batch):
        return {k: jnp.asarray(v) for k, v in batch.items()}

    runner = FaultTolerantRunner(
        jitted, state, iter(loader),
        config=RunnerConfig(ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every,
                            max_steps=args.steps),
        place_batch=place,
        on_step=eval_hook,
    )
    if args.resume and runner.try_resume():
        print(f"resumed from step {runner.start_step}")
    init_metrics = run_eval(state) if run_eval is not None else None
    if init_metrics:
        print("eval @ init: " + " ".join(
            f"{k} {v:.4f}" for k, v in init_metrics.items()))
    runner.run()
    loss_entries = [m for m in runner.metrics_log if "loss" in m]
    if loss_entries:
        print(f"step {loss_entries[-1]['step']}: "
              f"loss {float(loss_entries[-1]['loss']):.4f} "
              f"(first {float(loss_entries[0]['loss']):.4f})")
    if init_metrics and eval_log:
        final = eval_log[-1][1]
        print("eval improvement over init: " + " ".join(
            f"{k} {init_metrics[k]:.4f}->{final[k]:.4f}"
            f"({final[k] - init_metrics[k]:+.4f})" for k in final))
    print(f"done: {args.steps} steps, "
          f"{len(runner.skipped_steps)} skipped, "
          f"{len(runner.remesh_events)} re-mesh events")
    loader.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
