"""Sparton LM head — the paper's core contribution (pure JAX + sharded)."""

from repro.core.lm_head import (
    lm_head,
    lm_head_naive,
    lm_head_sparton,
    lm_head_tiled,
    sparton_forward_with_indices,
)
from repro.core.sharded import (
    head_shardings,
    sharded_flops_reg,
    sharded_similarity,
    sharded_sparton_head,
)
