from repro.runtime.fault_tolerance import (
    ElasticMeshManager,
    FaultTolerantRunner,
    RunnerConfig,
    StragglerPolicy,
)
from repro.runtime.faults import (
    FaultError,
    FaultInjector,
    ResourceExhausted,
    TransientFault,
    inject_faults,
    is_oom_error,
)
from repro.runtime.frontier import (
    CachedEngine,
    HotPostingCache,
    QueryResultCache,
    TenantPool,
    TenantQuota,
)
from repro.runtime.serving import (
    Admission,
    AdmissionPolicy,
    BatchedEncoder,
    BatchPolicy,
    CorpusEngine,
    DegradeController,
    DegradePolicy,
    DegradeStep,
    FailedResult,
    Request,
    ServingLoop,
    ShedResult,
)
