"""Retrieval-path benchmark: dense einsum vs fused streaming kernel vs
inverted impact index, over the same synthetic LSR corpus.

The three paths behind ``repro.retrieval.retrieve`` score identical
inputs (the corpus is generated as SparseReps; the dense matrix is its
densification), so the comparison isolates the scoring machinery:

* ``dense``     — (B, N) einsum + top_k over the dense (N, V) matrix
                  (the memory-hungry fallback; corpus bytes = N*V*4);
* ``streaming`` — the ``kernels.topk_score`` Pallas kernel (same dense
                  corpus, but the (B, N) score matrix never exists);
* ``impact``    — inverted-index segment-sums (corpus bytes = the
                  postings, O(total nnz));
* ``fused``     — the ``kernels.impact_score`` fused Pallas kernel
                  over the same inverted index: posting windows scored
                  and top-k-merged tile-by-tile, no (B, N) matrix
                  (DESIGN.md §12).

Emits ``BENCH_retrieval.json`` with per-method median ms + corpus
bytes + analytic peak *scoring* bytes (``_common.scoring_peak_bytes``
— the (B, N)-vs-windows comparison the fused gate checks) and the
cross-method top-k agreement flags, tracked by CI alongside
``BENCH_kernels.json``. ``--smoke`` (or ``BENCH_SMOKE=1``) shrinks the
workload for CI latency; off-TPU the Pallas kernels run through the
interpreter, so timings order implementations rather than predict
hardware (DESIGN.md §5 caveat applies — ``benchmarks/check.py`` only
enforces the fused-latency bar on real backends).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._common import scoring_peak_bytes, time_fn
from repro.retrieval import build_inverted_index, retrieve, sparsify_topk

# full-size operating point (CPU-feasible stand-in for the paper-scale
# corpus): 20k docs, 4k vocab, 64 active terms/doc
FULL = dict(n_docs=20000, vocab=4096, batch=16, k=10, doc_nnz=64,
            q_nnz=32, block_n=2048)
SMOKE = dict(n_docs=2000, vocab=1024, batch=4, k=10, doc_nnz=32,
             q_nnz=16, block_n=512)


def _sparse_batch(rng, n, vocab, nnz):
    """Random non-negative LSR-style reps as a dense matrix."""
    m = np.zeros((n, vocab), np.float32)
    rows = np.repeat(np.arange(n), nnz)
    cols = np.stack([rng.choice(vocab, size=nnz, replace=False)
                     for _ in range(n)]).ravel()
    m[rows, cols] = rng.uniform(0.1, 2.0, size=rows.shape[0])
    return m


def run(smoke: bool = False, json_path: str = None):
    smoke = smoke or os.environ.get("BENCH_SMOKE") == "1"
    p = SMOKE if smoke else FULL
    iters = 3 if smoke else 10
    rng = np.random.default_rng(0)

    q_dense = jnp.asarray(_sparse_batch(rng, p["batch"], p["vocab"],
                                        p["q_nnz"]))
    d_dense = jnp.asarray(_sparse_batch(rng, p["n_docs"], p["vocab"],
                                        p["doc_nnz"]))
    q_rep = sparsify_topk(q_dense, p["q_nnz"]).block_until_ready()
    d_rep = sparsify_topk(d_dense, p["doc_nnz"]).block_until_ready()
    index = build_inverted_index(d_rep, p["vocab"])

    k = p["k"]
    interpret = jax.default_backend() != "tpu"

    mem = dict(B=p["batch"], N=p["n_docs"], k=k, Q=p["q_nnz"],
               L=index.max_postings)
    methods = {
        "dense": (lambda: retrieve(q_dense, d_dense, k, method="dense"),
                  int(d_dense.nbytes),
                  scoring_peak_bytes("dense", **mem)),
        "streaming": (lambda: retrieve(
            q_dense, d_dense, k, method="streaming",
            block_b=min(8, p["batch"]), block_n=p["block_n"],
            interpret=interpret), int(d_dense.nbytes),
            scoring_peak_bytes("streaming", **mem)),
        "impact": (lambda: retrieve(q_rep, index, k, method="impact"),
                   index.memory_bytes(),
                   scoring_peak_bytes("impact", **mem)),
        "fused": (lambda: retrieve(q_rep, index, k, method="fused",
                                   interpret=interpret),
                  index.memory_bytes(),
                  scoring_peak_bytes("fused", **mem)),
    }

    record = {
        "shape": {"N": p["n_docs"], "V": p["vocab"], "B": p["batch"],
                  "k": k, "doc_nnz": p["doc_nnz"], "q_nnz": p["q_nnz"]},
        "backend": jax.default_backend(),
        "interpret": interpret,
        "methods": {},
    }
    ids = {}
    rows = []
    for name, (fn, corpus_bytes, peak_bytes) in methods.items():
        t = time_fn(fn, iters=iters)
        vals, idx = fn()
        ids[name] = np.asarray(idx)
        record["methods"][name] = {
            "median_ms": round(t, 3),
            "corpus_bytes": corpus_bytes,
            "peak_scoring_bytes": peak_bytes,
        }
        rows.append((name, round(t, 2), corpus_bytes, peak_bytes))

    agree = bool(
        np.array_equal(ids["dense"], ids["streaming"])
        and np.array_equal(ids["dense"], ids["impact"]))
    fused_agree = bool(np.array_equal(ids["impact"], ids["fused"]))
    record["parity"] = {"topk_ids_equal": agree,
                       "fused_ids_equal": fused_agree}

    print("method,median_ms,corpus_bytes,peak_scoring_bytes")
    for r in rows:
        print(",".join(str(x) for x in r))
    print(f"top-k ids identical across methods: {agree}")
    print(f"fused ids identical to impact: {fused_agree}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized workload")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="emit BENCH_retrieval.json-style record here")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)
