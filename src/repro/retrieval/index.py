"""Inverted impact index over SparseReps — the sparse-native corpus.

GPUSparse-style LSR serving scores queries against *posting lists*:
for every vocab term, the (doc id, impact) pairs of the documents that
activate it. The corpus then costs ``O(total nnz)`` memory instead of
the dense ``(N, V)`` matrix (which at V≈250k cannot hold a real N),
and a query only touches the lists of its own active terms.

Layout: padded CSC over the vocabulary (terms are the major axis),
flattened into three arrays —

    term_starts  (V,) i32  — offset of each term's postings
    term_lens    (V,) i32  — posting-list length per term
    postings_doc (P,) i32  — doc ids, grouped by term
    postings_val (P,) f32  — impact weights, same order

plus the static aux ``(n_docs, vocab_size, max_postings)``.
``max_postings`` (the longest posting list) is the static gather width
the JAX scorer pads every touched list to — see ``score.py``. The
index is a pytree, so scoring jits over it; the *build* is host-side
numpy (indexing is the offline half of the pipeline).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import numpy as np

from repro.retrieval.sparse_rep import SparseRep, device_get

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class InvertedIndex:
    term_starts: Array      # (V,) i32
    term_lens: Array        # (V,) i32
    postings_doc: Array     # (P,) i32
    postings_val: Array     # (P,) f32
    n_docs: int             # static
    vocab_size: int         # static
    max_postings: int       # static — longest posting list (>= 1)

    def tree_flatten(self):
        children = (self.term_starts, self.term_lens,
                    self.postings_doc, self.postings_val)
        aux = (self.n_docs, self.vocab_size, self.max_postings)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def n_postings(self) -> int:
        return self.postings_doc.shape[0]

    def memory_bytes(self) -> int:
        """Index footprint (the number to compare with N*V*4 dense)."""
        return int(sum(np.asarray(a).nbytes for a in (
            self.term_starts, self.term_lens,
            self.postings_doc, self.postings_val)))

    def stats(self) -> Dict[str, float]:
        lens = np.asarray(self.term_lens)
        active = lens > 0
        return {
            "n_docs": self.n_docs,
            "vocab_size": self.vocab_size,
            "n_postings": self.n_postings,
            "active_terms": int(active.sum()),
            "max_postings": self.max_postings,
            "mean_postings": float(lens[active].mean()) if active.any()
            else 0.0,
            "memory_bytes": self.memory_bytes(),
        }


def build_inverted_index(reps: SparseRep, vocab_size: int
                         ) -> InvertedIndex:
    """Build the index from a batched ``(N, K)`` corpus rep (host-side).

    Active slots (``value > 0``) are flattened to (term, doc, impact)
    triples, stably sorted by term (so each posting list is ordered by
    doc id), and packed into the CSC arrays. An all-empty corpus still
    yields valid (length-1, zero-impact) postings so the scorer's
    static shapes never degenerate.
    """
    host = device_get(reps) if isinstance(reps.values, jax.Array) else reps
    k = host.width
    v = np.asarray(host.values, np.float32).reshape(-1, k)
    i = np.asarray(host.indices, np.int32).reshape(-1, k)
    n_docs = v.shape[0]

    active = v > 0
    terms = i[active]
    if (terms < 0).any() or (terms >= vocab_size).any():
        raise ValueError(
            f"build_inverted_index: term ids outside [0, {vocab_size})")
    vals = v[active]
    docs = np.broadcast_to(np.arange(n_docs, dtype=np.int32)[:, None],
                           i.shape)[active]

    order = np.argsort(terms, kind="stable")
    terms, vals, docs = terms[order], vals[order], docs[order]

    lens = np.bincount(terms, minlength=vocab_size).astype(np.int32)
    starts = np.zeros(vocab_size, np.int64)
    np.cumsum(lens[:-1], out=starts[1:])

    if terms.size == 0:
        docs = np.zeros(1, np.int32)
        vals = np.zeros(1, np.float32)

    # device arrays: the scorer indexes these under jit/vmap tracing
    import jax.numpy as jnp

    return InvertedIndex(
        term_starts=jnp.asarray(starts.astype(np.int32)),
        term_lens=jnp.asarray(lens),
        postings_doc=jnp.asarray(docs.astype(np.int32)),
        postings_val=jnp.asarray(vals.astype(np.float32)),
        n_docs=n_docs,
        vocab_size=vocab_size,
        max_postings=max(int(lens.max(initial=0)), 1),
    )
