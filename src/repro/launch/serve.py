"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the sparse-native LSR serving pipeline end-to-end:

1. index  — encode a synthetic corpus through backbone + Sparton head,
            sparsify on-device (``rep_topk``), build the inverted
            impact index (no dense (N, V) corpus matrix anywhere).
            With ``--engine`` the corpus is grown *online* through the
            incremental ``CorpusEngine``/``IndexBuilder`` (batches are
            added and flushed as they arrive; ``--remove-frac``
            tombstones a slice mid-stream to exercise the lifecycle),
            optionally compressed (``--quantize``) or served through
            the two-tier pruned scorer (``--prune-margin``).
2. serve  — stream queries through the deadline/size micro-batching
            loop (results popped via ``take``), reporting latency and
            achieved batch sizes. ``--deadline-ms`` attaches an SLO to
            every request (the hardened loop may shed; shed/failed
            uids are reported and excluded from retrieval) and
            ``--max-queue`` bounds the admission queue;
3. retrieve — top-k via the unified dispatcher (``--method`` selects
            the path; see repro.retrieval.retrieve's dispatch table).
            ``--shard-axis doc|term|2d|auto`` picks the placement for
            ``--method sharded`` builds and ``--engine`` bases: doc
            ranges with a top-k merge, vocab ranges with the
            partial-sum (psum) merge (DESIGN.md §9), the (doc x term)
            grid composing both, or the ShardPlan planner sizing the
            grid from posting mass vs the O(V) directory
            (DESIGN.md §14).
"""

import argparse
import sys
import time


def _grid_plan(n_shards: int):
    """The most balanced (doc x term) factorization of an explicit
    ``--shard-axis 2d`` request: largest doc divisor <= sqrt(n), the
    term axis takes the rest (prime counts degenerate to 1 x n)."""
    from repro.retrieval import ShardPlan

    d = max(f for f in range(1, int(n_shards ** 0.5) + 1)
            if n_shards % f == 0)
    return ShardPlan(doc_shards=d, term_shards=n_shards // d,
                     reason=f"--shard-axis 2d: balanced factorization "
                            f"of {n_shards} devices")


def main(argv=None) -> int:
    from repro.retrieval import METHODS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="splade_bert")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--corpus", type=int, default=1000)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--rep-topk", type=int, default=64,
                    help="per-row term budget of the on-device rep "
                         "sparsifier; 0 = dense reps (legacy path)")
    ap.add_argument("--method", default="auto", choices=list(METHODS),
                    help="retrieval path (see repro.retrieval.retrieve)")
    ap.add_argument("--shards", type=int, default=2,
                    help="--method sharded/term_sharded: shard count "
                         "(single-device vmap path unless a mesh is "
                         "wired in)")
    ap.add_argument("--shard-axis", default="doc",
                    choices=("auto", "doc", "term", "2d"),
                    help="sharding axis for --method sharded or an "
                         "--engine base: doc = contiguous doc ranges "
                         "(all_gather+re-top-k merge), term = vocab "
                         "ranges with full posting lists (partial-sum "
                         "psum merge; the huge-|V| regime), 2d = the "
                         "(doc x term) grid composing both, auto = "
                         "let engine.shard2d.plan_placement pick the "
                         "(doc_shards, term_shards, replicas) grid "
                         "from posting bytes vs the O(V) directory "
                         "(frozen builds size the real index; "
                         "--engine plans from the requested corpus "
                         "size and rep budget)")
    ap.add_argument("--index-batch", type=int, default=64,
                    help="corpus encoding batch size")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    metavar="MS",
                    help="per-request SLO: the loop sheds requests "
                         "whose estimated or actual queue delay blows "
                         "this deadline (default: best-effort, never "
                         "shed)")
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission bound on queue depth; submits "
                         "beyond it are shed with a ShedResult")
    ap.add_argument("--head-impl", default=None,
                    help="override the config's head backend (any "
                         "registered impl; see "
                         "repro.core.head_api.available_impls)")
    ap.add_argument("--engine", action="store_true",
                    help="grow the corpus online through the "
                         "incremental IndexBuilder instead of one "
                         "frozen build")
    ap.add_argument("--quantize", action="store_true",
                    help="engine mode: serve the base segment as a "
                         "compressed QuantizedIndex")
    ap.add_argument("--prune-margin", type=float, default=None,
                    metavar="M",
                    help="engine mode: retrieve through the two-tier "
                         "pruned scorer with this margin (0 = safe)")
    ap.add_argument("--remove-frac", type=float, default=0.0,
                    help="engine mode: tombstone this fraction of the "
                         "corpus mid-stream (exercises remove + "
                         "compaction)")
    ap.add_argument("--cache-mb", type=float, default=0.0,
                    metavar="MB",
                    help="engine mode: serve searches through the "
                         "frontier result cache (plus a hot-posting-"
                         "window cache) with this byte budget "
                         "(DESIGN.md §13); 0 = off")
    ap.add_argument("--tenants", type=int, default=0, metavar="N",
                    help="engine mode: serve N weighted tenants over "
                         "one encoder through the TenantPool "
                         "scheduler instead of a single corpus")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: admit requests into "
                         "the next batch in earliest-deadline-first "
                         "order instead of FIFO one-batch-per-tick")
    args = ap.parse_args(argv)
    # method/rep compatibility is knowable before spending minutes
    # encoding the corpus — reject bad combinations at argparse time
    if args.method in ("dense", "streaming") and args.rep_topk > 0:
        ap.error(f"--method {args.method} needs the dense corpus "
                 f"matrix; pass --rep-topk 0 to keep it (or use "
                 f"--method impact/auto with the sparse index)")
    if args.method in ("impact", "pruned", "quantized", "sharded",
                       "term_sharded", "shard2d") and args.rep_topk <= 0:
        ap.error(f"--method {args.method} needs SparseRep queries and "
                 f"an index; pass a positive --rep-topk")
    if args.shard_axis in ("term", "2d") and args.quantize:
        ap.error(f"--shard-axis {args.shard_axis} and --quantize are "
                 "exclusive (the base segment is either partitioned "
                 "or compressed)")
    if (args.quantize or args.prune_margin is not None
            or args.remove_frac) and not args.engine:
        ap.error("--quantize/--prune-margin/--remove-frac need "
                 "--engine")
    if args.engine and args.rep_topk <= 0:
        ap.error("--engine needs sparse reps; pass a positive "
                 "--rep-topk")
    if args.engine and args.quantize and args.prune_margin is not None:
        ap.error("--quantize and --prune-margin are exclusive (the "
                 "pruned rescorer reads raw forward rows)")
    if args.engine and args.method != "auto":
        ap.error("--engine picks its retrieval path from "
                 "--quantize/--prune-margin; drop --method (the "
                 "builder's segments are searched via 'auto')")
    if (args.cache_mb > 0 or args.tenants > 0) and not args.engine:
        ap.error("--cache-mb/--tenants need --engine (cache keys and "
                 "tenant corpora live on the IndexBuilder)")
    if args.tenants < 0:
        ap.error("--tenants must be >= 0")

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.launch.steps import init_state
    from repro.retrieval import build_inverted_index, retrieve, stack_rows
    from repro.runtime.serving import (AdmissionPolicy, BatchedEncoder,
                                       BatchPolicy, CorpusEngine,
                                       Request, ServingLoop,
                                       make_config_encoder)

    mod = get_config(args.arch)
    cfg = mod.SMOKE
    overrides = {}
    if args.head_impl:
        overrides["head_impl"] = args.head_impl
    if args.rep_topk > 0:
        overrides["rep_topk"] = args.rep_topk
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sparse = args.rep_topk > 0
    state, _ = init_state(args.arch, jax.random.PRNGKey(0), smoke=True)
    params = state["params"]

    # Built from the config via the unified head factory: head_impl,
    # final_logit_softcap and the rep-sparsify knobs are all honored.
    encode = make_config_encoder(params, cfg)

    rng = np.random.default_rng(0)
    bs = args.index_batch

    # --- tenant mode: N corpora over one encoder (DESIGN.md §13) -----
    if args.tenants > 0:
        from repro.runtime.frontier import TenantPool, TenantQuota

        cache_bytes = int(args.cache_mb * 2**20)
        pool = TenantPool(
            BatchedEncoder(encode, policy=BatchPolicy(max_batch=bs)),
            cache_bytes=cache_bytes,
            hot_cache_bytes=cache_bytes // 4,
            continuous=args.continuous)
        names = [f"t{i}" for i in range(args.tenants)]
        for i, name in enumerate(names):
            pool.add_tenant(name, cfg.vocab_size,
                            quota=TenantQuota(weight=float(i + 1)),
                            keep_forward=args.prune_margin is not None)
        t0 = time.monotonic()
        per = max(1, args.corpus // args.tenants)
        for name in names:
            pool.add_docs(name, [
                rng.integers(1, cfg.vocab_size, size=16)
                .astype(np.int32) for _ in range(per)])
        print(f"provisioned {args.tenants} tenants x {per} docs in "
              f"{(time.monotonic() - t0) * 1e3:.1f} ms "
              f"({pool.memory_bytes() / 2**20:.2f} MiB pooled)")
        deadline = (args.deadline_ms / 1e3
                    if args.deadline_ms is not None else None)
        for uid in range(args.requests):
            n = int(rng.integers(4, 24))
            pool.submit(names[uid % args.tenants],
                        Request(uid=uid, tokens=rng.integers(
                            1, cfg.vocab_size, size=n)
                            .astype(np.int32), deadline_s=deadline))
            pool.tick()
        pool.drain()
        from repro.runtime.serving import FailedResult, ShedResult

        by_tenant = {name: [] for name in names}
        for uid in range(args.requests):
            res = pool.take(names[uid % args.tenants], uid)
            if not isinstance(res, (ShedResult, FailedResult)):
                by_tenant[names[uid % args.tenants]].append(res)
        # search twice per tenant: the second pass demonstrates (and
        # reports) result-cache hits when --cache-mb is set. Forcing
        # the fused path (auto would pick impact at demo corpus sizes)
        # also engages the hot-posting-window cache.
        for name in names:
            rows = by_tenant[name][:4]
            if not rows:
                continue
            for _ in range(2 if cache_bytes else 1):
                pool.search(name, stack_rows(rows), args.topk,
                            method="fused")
        st = pool.stats()
        for name in names:
            t = st["tenants"][name]
            line = (f"tenant {name}: weight {t['weight']}, "
                    f"{t['live_docs']} docs, served {t['served']} / "
                    f"shed {t['shed']} / failed {t['failed']}")
            if "cache" in t:
                c = t["cache"]["results"]
                line += (f", cache hits {c['hits']}/"
                         f"{c['hits'] + c['misses']}")
                if "hot" in t["cache"]:
                    line += (f", {t['cache']['hot']['bytes_pinned']} "
                             f"B pinned")
            print(line)
        if "result_cache" in st:
            rc = st["result_cache"]
            print(f"shared result cache: hit ratio {rc['hit_rate']}, "
                  f"{rc['bytes_used']}/{rc['capacity_bytes']} B used, "
                  f"{rc['evictions']} evictions, "
                  f"{rc['invalidations']} invalidations")
        return 0

    # --- 1. index the corpus (batched; never a dense (N, V) matrix) --
    t0 = time.monotonic()
    engine = None
    if args.engine:
        plan = None
        if args.shard_axis == "auto" and not args.quantize:
            # no corpus exists before the build, but the planner only
            # needs sizes: the requested doc count and the sparsifier's
            # per-row term budget bound the posting mass
            from repro.retrieval import CorpusStats, plan_placement

            est = CorpusStats(
                posting_bytes=8 * args.corpus * min(16, args.rep_topk),
                vocab_size=cfg.vocab_size, n_docs=args.corpus)
            plan = plan_placement(est, args.shards)
            print(f"auto shard plan (estimated stats) -> "
                  f"{plan.describe()}: {plan.reason}")
        elif args.shard_axis == "auto":
            print("auto shard axis with --quantize: the base is "
                  "compressed, not partitioned -> doc (single-index "
                  "base)")
        elif args.shard_axis == "2d":
            plan = _grid_plan(args.shards)
            print(f"2d shard plan -> {plan.describe()}")
        engine = CorpusEngine(
            BatchedEncoder(encode,
                           policy=BatchPolicy(max_batch=bs)),
            cfg.vocab_size, quantize=args.quantize,
            keep_forward=args.prune_margin is not None,
            **({"plan": plan} if plan is not None else
               {"shard_axis": ("term" if args.shard_axis == "term"
                               else "doc"),
                "n_shards": args.shards}))
        for lo in range(0, args.corpus, bs):
            n = min(bs, args.corpus - lo)
            toks = [rng.integers(1, cfg.vocab_size, size=16)
                    .astype(np.int32) for _ in range(n)]
            engine.add_docs(toks)
            engine.flush()       # online growth: visible batch by batch
        if args.remove_frac > 0:
            drop = rng.choice(args.corpus,
                              size=int(args.remove_frac * args.corpus),
                              replace=False)
            engine.remove_docs(drop.tolist())
            engine.flush()
        st = engine.stats()
        print(f"engine-indexed {st['n_alive']} live docs "
              f"({st['n_dead']} tombstoned, "
              f"{st['n_compactions']} compactions, "
              f"quantized base: {st['quantized_base']}, "
              f"term shards: {st['term_shards']}) in "
              f"{(time.monotonic() - t0) * 1e3:.1f} ms")
    else:
        doc_parts, dense_parts = [], []
        for lo in range(0, args.corpus, bs):
            n = min(bs, args.corpus - lo)
            toks = rng.integers(1, cfg.vocab_size,
                                size=(n, 16)).astype(np.int32)
            reps = encode(jnp.asarray(toks), jnp.ones((n, 16), jnp.int32))
            if sparse:
                doc_parts.append(reps)
            else:
                dense_parts.append(np.asarray(reps))
        if sparse:
            corpus_rep = stack_rows(doc_parts)
            index = build_inverted_index(
                corpus_rep, cfg.vocab_size,
                keep_forward=args.method == "pruned")
            corpus = index
            st = index.stats()
            print(f"indexed {st['n_docs']} docs in "
                  f"{(time.monotonic() - t0) * 1e3:.1f} ms: "
                  f"{st['n_postings']} postings over "
                  f"{st['active_terms']} terms, "
                  f"{st['memory_bytes'] / 2**20:.2f} MiB "
                  f"(dense (N, V) would be "
                  f"{args.corpus * cfg.vocab_size * 4 / 2**20:.2f} MiB)")
            if args.method == "quantized":
                from repro.retrieval import quantize_index

                corpus = quantize_index(index)
                print(f"quantized index: "
                      f"{corpus.memory_bytes() / 2**20:.2f} MiB "
                      f"(1/{index.memory_bytes() / corpus.memory_bytes():.2f} "
                      f"of raw)")
            elif args.method in ("sharded", "term_sharded", "shard2d"):
                plan = None
                axis = {"term_sharded": "term",
                        "shard2d": "2d"}.get(args.method,
                                             args.shard_axis)
                if axis == "auto":
                    from repro.retrieval import (CorpusStats,
                                                 plan_placement)

                    plan = plan_placement(CorpusStats.from_index(index),
                                          args.shards)
                    axis = plan.axis
                    print(f"auto shard plan -> {plan.describe()}: "
                          f"{plan.reason}")
                if axis == "2d":
                    from repro.retrieval import shard2d_index

                    if plan is None:
                        plan = _grid_plan(args.shards)
                    corpus = shard2d_index(
                        corpus_rep, cfg.vocab_size, plan.doc_shards,
                        plan.term_shards)
                    args.method = "shard2d"
                    print(f"2d-sharded index: {plan.doc_shards} doc "
                          f"chunks x {plan.term_shards} vocab ranges "
                          f"(psum over terms, top-k merge over docs)")
                elif axis == "term":
                    from repro.retrieval import term_shard_index

                    corpus = term_shard_index(corpus_rep,
                                              cfg.vocab_size,
                                              args.shards)
                    args.method = "term_sharded"
                    print(f"term-sharded index: {args.shards} shards "
                          f"x {corpus.local_vocab} vocab terms "
                          f"(partial-sum merge)")
                else:
                    from repro.retrieval import shard_index

                    corpus = shard_index(corpus_rep, cfg.vocab_size,
                                         args.shards)
                    args.method = "sharded"
                    print(f"sharded index: {args.shards} shards x "
                          f"{corpus.docs_per_shard} docs")
        else:
            corpus = jnp.asarray(np.concatenate(dense_parts))
            print(f"indexed {corpus.shape[0]} docs dense in "
                  f"{(time.monotonic() - t0) * 1e3:.1f} ms "
                  f"({corpus.nbytes / 2**20:.2f} MiB)")

    # the frontier cache fronts the engine: repeated searches hit the
    # result cache, the fused path reads pinned hot posting windows
    cached = None
    if args.cache_mb > 0:
        from repro.runtime.frontier import (CachedEngine,
                                            HotPostingCache,
                                            QueryResultCache)

        cache_bytes = int(args.cache_mb * 2**20)
        cached = CachedEngine(
            engine, result_cache=QueryResultCache(cache_bytes),
            hot_cache=HotPostingCache(cache_bytes // 4))

    # --- 2. serve queries through the batching loop ------------------
    loop = ServingLoop(
        BatchedEncoder(encode, policy=BatchPolicy(max_batch=16,
                                                  max_wait_s=0.002)),
        admission=AdmissionPolicy(max_queue_depth=args.max_queue),
        continuous=args.continuous)
    deadline = (args.deadline_ms / 1e3
                if args.deadline_ms is not None else None)
    t0 = time.monotonic()
    for uid in range(args.requests):
        n = int(rng.integers(4, 24))
        loop.submit(Request(uid=uid, tokens=rng.integers(
            1, cfg.vocab_size, size=n).astype(np.int32),
            deadline_s=deadline))
        loop.tick()
    loop.drain()
    dt = time.monotonic() - t0
    # the hardened loop completes every uid, but under a deadline some
    # may carry ShedResult/FailedResult — retrieval gets the served reps
    from repro.runtime.serving import FailedResult, ShedResult

    outcomes = {uid: loop.take(uid) for uid in range(args.requests)}
    assert not loop.completed, "take() must leave nothing behind"
    results = [r for r in outcomes.values()
               if not isinstance(r, (ShedResult, FailedResult))]
    st = loop.stats()
    print(f"encoded {len(results)}/{args.requests} requests in "
          f"{dt*1e3:.1f} ms ({st['shed']} shed, {st['failed']} "
          f"failed), batches: {list(loop.batch_sizes)}, "
          f"occupancy {st['batch_occupancy']:.2f}, "
          f"p99 {st['p99_latency_s'] * 1e3:.1f} ms")
    if not results:
        print("every request shed — deadline too tight for this "
              "host; nothing to retrieve")
        return 0

    # --- 3. retrieval through the unified dispatcher ------------------
    n_q = min(8, len(results))
    if sparse:
        queries = stack_rows(results[:n_q])
    else:
        queries = jnp.asarray(np.stack(results[:n_q]))
    t0 = time.monotonic()
    if engine is not None:
        kw = {}
        if args.prune_margin is not None:
            kw = {"method": "pruned",
                  "prune_margin": args.prune_margin}
        surface = cached if cached is not None else engine
        if cached is not None and not kw:
            # force the fused path (auto picks impact at demo corpus
            # sizes) so the hot-posting-window cache engages too
            kw = {"method": "fused"}
        vals, idx = surface.search(queries, args.topk, **kw)
        if cached is not None:
            # the second pass is pure cache: every row keyed identically
            vals, idx = surface.search(queries, args.topk, **kw)
        tag = "engine" + ("/pruned" if args.prune_margin is not None
                          else "")
        tag += "/cached" if cached is not None else ""
    else:
        vals, idx = retrieve(queries, corpus, args.topk,
                             method=args.method)
        tag = args.method
    jax.block_until_ready(vals)
    print(f"retrieval[{tag}]: top-{args.topk} for {n_q} queries "
          f"in {(time.monotonic() - t0) * 1e3:.1f} ms, "
          f"best scores {np.asarray(vals)[:, 0].round(2).tolist()}")
    if cached is not None:
        cs = cached.stats()
        rc, hot = cs["results"], cs.get("hot")
        line = (f"frontier cache: hit ratio {rc['hit_rate']}, "
                f"{rc['bytes_used']}/{rc['capacity_bytes']} B used, "
                f"{rc['evictions']} evictions, "
                f"{rc['invalidations']} invalidations")
        if hot is not None:
            line += (f"; hot windows: {hot['pinned_terms']} terms, "
                     f"{hot['bytes_pinned']} B pinned")
        print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())
