"""Jit'd, differentiable wrappers around the Pallas Sparton kernels.

``sparton_lm_head_kernel`` is the drop-in kernel-backed equivalent of
``repro.core.lm_head.lm_head_sparton``: a ``jax.custom_vjp`` whose
forward runs the fused Pallas forward (saving only ``(y, i_max)``) and
whose backward runs the two fused Pallas accumulation kernels.

On this CPU container the kernels run with ``interpret=True`` (the
kernel body executed by the Pallas interpreter); on TPU the same code
compiles to Mosaic. ``interpret`` is threaded through as a static
argument so tests/benchmarks choose explicitly.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.sparton import sparton_forward
from repro.kernels.sparton_bwd import sparton_backward


def _bwd_factor(y, dy, softcap):
    """dY/d(raw max logit) from the stored post-activation y.

    See core/lm_head.py::_sparton_bwd_factor — duplicated here to keep
    the kernels package importable standalone.
    """
    g = dy.astype(jnp.float32) * jnp.exp(-y)
    if softcap is not None:
        c = jnp.expm1(y)
        g = g * (1.0 - (c / softcap) ** 2)
    return jnp.where(y > 0, g, 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def sparton_lm_head_kernel(
    H: jax.Array,
    E: jax.Array,
    b: jax.Array,
    mask: jax.Array,
    block_b: int = 8,
    block_s: int = 128,
    block_v: int = 128,
    softcap: Optional[float] = None,
    interpret: bool = False,
    out_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    y, _ = sparton_forward(
        H, E, b, mask,
        block_b=block_b, block_s=block_s, block_v=block_v,
        softcap=softcap, interpret=interpret,
    )
    return y.astype(out_dtype or H.dtype)


def _fwd(H, E, b, mask, block_b, block_s, block_v, softcap, interpret,
         out_dtype):
    y, i_max = sparton_forward(
        H, E, b, mask,
        block_b=block_b, block_s=block_s, block_v=block_v,
        softcap=softcap, interpret=interpret,
    )
    return y.astype(out_dtype or H.dtype), (H, E, y, i_max)


def _bwd(block_b, block_s, block_v, softcap, interpret, out_dtype, res, dy):
    H, E, y, i_max = res
    g = _bwd_factor(y, dy, softcap)
    dH, dE = sparton_backward(
        g, i_max, H, E,
        block_b=block_b, block_s=block_s, block_v=block_v,
        interpret=interpret,
    )
    db = jnp.sum(g, axis=0)
    return dH.astype(H.dtype), dE.astype(E.dtype), db, None


sparton_lm_head_kernel.defvjp(_fwd, _bwd)


def sparton_head(
    H: jax.Array,
    E: jax.Array,
    b: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    *,
    block_b: int = 8,
    block_s: int = 128,
    block_v: int = 128,
    softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Convenience entry point with optional bias/mask (kernel-backed)."""
    B, S, _ = H.shape
    V = E.shape[0]
    if b is None:
        b = jnp.zeros((V,), jnp.float32)
    if mask is None:
        mask = jnp.ones((B, S), jnp.int32)
    return sparton_lm_head_kernel(
        H, E, b, mask, block_b, block_s, block_v, softcap, interpret, None
    )
