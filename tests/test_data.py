"""Data pipeline: determinism, shard disjointness, shapes, loader."""

import numpy as np

from repro.data.loader import HostShardedLoader, length_bucket
from repro.data.synthetic import (lm_token_batches, lsr_pair_batches,
                                  molecule_batches, recsys_batches)


def test_lsr_batches_deterministic_per_shard_step():
    g1 = lsr_pair_batches(batch=4, q_len=8, d_len=12, vocab=100, seed=1)
    g2 = lsr_pair_batches(batch=4, q_len=8, d_len=12, vocab=100, seed=1)
    b1, b2 = next(g1), next(g2)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])


def test_lsr_shards_are_disjoint():
    b0 = next(lsr_pair_batches(batch=4, q_len=8, d_len=8, vocab=1000,
                               seed=1, shard=0))
    b1 = next(lsr_pair_batches(batch=4, q_len=8, d_len=8, vocab=1000,
                               seed=1, shard=1))
    assert not np.array_equal(b0["q_tokens"], b1["q_tokens"])


def test_lsr_masks_and_overlap():
    b = next(lsr_pair_batches(batch=8, q_len=16, d_len=16, vocab=500))
    assert b["q_mask"].shape == (8, 16)
    assert ((b["q_mask"] == 0) | (b["q_mask"] == 1)).all()
    # positives share a token prefix with their query (learnability)
    n_copy = 8
    np.testing.assert_array_equal(b["d_tokens"][:, :4] * b["d_mask"][:, :4],
                                  b["q_tokens"][:, :4] * b["d_mask"][:, :4])


def test_lm_batches_next_token_alignment():
    b = next(lm_token_batches(batch=2, seq_len=10, vocab=50))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_recsys_ids_in_range():
    sizes = (100, 5, 1000)
    b = next(recsys_batches(batch=32, n_dense=3, n_sparse=3,
                            table_sizes=sizes))
    for f, rows in enumerate(sizes):
        col = b["sparse_idx"][:, f]
        assert (col >= 0).all() and (col < rows).all()
    assert set(np.unique(b["label"])) <= {0.0, 1.0}


def test_molecule_batches_structure():
    b = next(molecule_batches(n_graphs=3, nodes_per_graph=6,
                              edges_per_graph=10))
    N = 18
    assert b["positions"].shape == (N, 3)
    assert b["node_graph_id"].max() == 2
    e_valid = b["edge_mask"].astype(bool)
    assert (b["edge_src"][e_valid] < N).all()
    # edges connect nodes within the same graph
    g_src = b["node_graph_id"][b["edge_src"][e_valid]]
    g_dst = b["node_graph_id"][b["edge_dst"][e_valid]]
    np.testing.assert_array_equal(g_src, g_dst)


def test_host_sharded_loader_prefetch():
    def make_iter(shard, n_shards):
        for i in range(5):
            yield {"x": np.full((2,), i)}

    loader = HostShardedLoader(make_iter, prefetch=2)
    got = [b["x"][0] for b in loader]
    assert got == [0, 1, 2, 3, 4]


def test_length_bucket():
    buckets = length_bucket([3, 10, 64, 7, 100], [8, 32])
    assert buckets[0] == [0, 3]     # <= 8
    assert buckets[1] == [1]        # <= 32
    assert buckets[2] == [2, 4]     # > 32
