"""Serving example: the sparse-native retrieval pipeline end-to-end.

1. Index a synthetic corpus with the Sparton head (document side):
   encode -> on-device top-k sparsify (SparseRep) -> inverted impact
   index. No dense (N, V) corpus matrix is ever materialized. With
   ``--engine`` the corpus is instead grown *online* through the
   incremental ``CorpusEngine``/``IndexBuilder`` (add/flush per batch,
   a mid-stream remove, compaction), optionally quantized.
2. Serve queries through the deadline/size micro-batching loop;
   results come back as SparseReps and are popped with ``take``.
3. Retrieve top-k through the unified dispatcher: inverted-index
   impact scoring (the production sparse path), cross-checked against
   the dense fallback built *from the same SparseReps*, plus the fused
   streaming top-k kernel on the 1M-candidate-style dense workload.
   ``--prune-margin M`` additionally exercises the two-tier pruned
   scorer (M = 0 is the safe margin: ids identical to impact).

Run:  PYTHONPATH=src python examples/serve_retrieval.py
      PYTHONPATH=src python examples/serve_retrieval.py \\
          --engine --quantize
      PYTHONPATH=src python examples/serve_retrieval.py \\
          --engine --prune-margin 0.0
      PYTHONPATH=src python examples/serve_retrieval.py \\
          --engine --cache-mb 4
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.topk_score import topk_score
from repro.launch.steps import init_state, streaming_topk
from repro.retrieval import build_inverted_index, retrieve, stack_rows
from repro.runtime.serving import (BatchedEncoder, BatchPolicy,
                                   CorpusEngine, Request, ServingLoop,
                                   make_config_encoder)

ap = argparse.ArgumentParser()
ap.add_argument("--engine", action="store_true",
                help="grow the corpus online via CorpusEngine/"
                     "IndexBuilder instead of one frozen build")
ap.add_argument("--quantize", action="store_true",
                help="with --engine: serve the base segment as a "
                     "compressed QuantizedIndex")
ap.add_argument("--prune-margin", type=float, default=None, metavar="M",
                help="with --engine: search through the two-tier "
                     "pruned scorer at this margin (0 = safe)")
ap.add_argument("--cache-mb", type=float, default=0.0, metavar="MB",
                help="with --engine: also search through the frontier "
                     "result + hot-posting caches at this byte budget "
                     "and assert cache-on == cache-off (DESIGN.md §13)")
args = ap.parse_args()
if (args.quantize or args.prune_margin is not None
        or args.cache_mb > 0) and not args.engine:
    ap.error("--quantize/--prune-margin/--cache-mb need --engine")
if args.quantize and args.prune_margin is not None:
    ap.error("--quantize and --prune-margin are exclusive")

CORPUS, QUERIES, K, REP_TOPK = 512, 24, 5, 48

cfg = get_config("splade_bert").SMOKE
# the Unified-LSR knob: reps leave the head as top-48 SparseRep rows
cfg = dataclasses.replace(cfg, rep_topk=REP_TOPK)
state, _ = init_state("splade_bert", jax.random.PRNGKey(0), smoke=True)
params = state["params"]

# The encoder comes from the config through the unified head factory
# (core.head_api.make_encoder) — head_impl, blocks, logit softcap and
# the rep sparsifier are all taken from cfg instead of hardcoding.
encode = make_config_encoder(params, cfg)

rng = np.random.default_rng(0)

# --- 1. index the corpus (sparse; never a dense (N, V) matrix) --------
doc_tokens = rng.integers(1, cfg.vocab_size, size=(CORPUS, 24))
doc_tokens = doc_tokens.astype(np.int32)
engine = None
if args.engine:
    engine = CorpusEngine(
        BatchedEncoder(encode, policy=BatchPolicy(max_batch=64)),
        cfg.vocab_size, quantize=args.quantize,
        keep_forward=args.prune_margin is not None)
    for lo in range(0, CORPUS, 64):
        engine.add_docs(list(doc_tokens[lo:lo + 64]))
        engine.flush()          # online growth: visible batch by batch
    # exercise the lifecycle: tombstone a tail slice, then compact
    engine.remove_docs(range(CORPUS - 32, CORPUS))
    engine.flush(force_compact=True)
    st = engine.stats()
    print(f"engine-indexed {st['n_alive']} live docs "
          f"({st['n_compactions']} compactions, quantized base: "
          f"{st['quantized_base']})")
doc_parts = []
for lo in range(0, CORPUS, 64):
    reps = encode(jnp.asarray(doc_tokens[lo:lo + 64]),
                  jnp.ones((min(64, CORPUS - lo), 24), jnp.int32))
    doc_parts.append(reps)
corpus_rep = stack_rows(doc_parts)
index = build_inverted_index(corpus_rep, cfg.vocab_size)
st = index.stats()
print(f"indexed {st['n_docs']} docs; mean active terms "
      f"{st['n_postings'] / st['n_docs']:.0f} / {cfg.vocab_size}; "
      f"index {st['memory_bytes'] / 2**10:.0f} KiB vs dense "
      f"{CORPUS * cfg.vocab_size * 4 / 2**10:.0f} KiB")

# --- 2. serve queries through the batching loop ----------------------
loop = ServingLoop(BatchedEncoder(
    encode, policy=BatchPolicy(max_batch=8, max_wait_s=0.002)))
t0 = time.monotonic()
for uid in range(QUERIES):
    # query uid re-encodes doc uid's tokens: exact-duplicate retrieval
    # sanity (untrained weights carry no prefix semantics). The
    # deadline is deliberately generous — this example pins the happy
    # path (everything served); the overload/shedding behavior is the
    # traffic simulation's job (benchmarks/bench_serving.py).
    toks = doc_tokens[uid].copy()
    loop.submit(Request(uid=uid, tokens=toks, deadline_s=60.0))
    loop.tick()
loop.drain()
q_rep = stack_rows([loop.take(u) for u in range(QUERIES)])
assert not loop.completed, "take() pops — nothing may accumulate"
st = loop.stats()
assert st["served"] == QUERIES and st["shed"] == st["failed"] == 0
print(f"served {QUERIES} queries in "
      f"{(time.monotonic() - t0) * 1e3:.1f} ms; "
      f"batch sizes {list(loop.batch_sizes)}; "
      f"occupancy {st['batch_occupancy']:.2f}; "
      f"p99 {st['p99_latency_s'] * 1e3:.1f} ms")

# --- 3a. retrieval: inverted impact index (sparse path) ---------------
vals, idx = retrieve(q_rep, index, K, method="impact")
hits = float(np.mean(np.asarray(idx)[:, 0] == np.arange(QUERIES)))
print(f"top-1 self-retrieval rate: {hits:.2f} (exact-duplicate queries)")

# parity: the dense fallback over the SAME SparseReps must agree
d_dense = corpus_rep.to_dense(cfg.vocab_size)
vals_d, idx_d = retrieve(q_rep, d_dense, K, method="dense")
assert np.array_equal(np.asarray(idx), np.asarray(idx_d))
assert np.allclose(np.asarray(vals), np.asarray(vals_d), atol=1e-4)
print("impact scoring == dense fallback (same SparseReps): True")

if engine is not None:
    # the online-built engine must agree with the frozen build
    # (external ids == positions here: adds were in order, compaction
    # dropped only the tombstoned tail) — on query rows whose frozen
    # top-K contains no tombstoned doc
    kw = ({"method": "pruned", "prune_margin": args.prune_margin}
          if args.prune_margin is not None else {})
    vals_e, ids_e = engine.search(q_rep, K, **kw)
    rows_ok = (np.asarray(idx) < CORPUS - 32).all(axis=1)
    tag = "pruned" if kw else ("quantized" if args.quantize
                               else "impact")
    if args.quantize or (args.prune_margin or 0) > 0:
        # lossy modes on an untrained random-rep corpus: pin the top-1
        # (exact-duplicate queries give it a huge score gap)
        assert np.array_equal(ids_e[rows_ok, 0],
                              np.asarray(idx)[rows_ok, 0]), \
            "engine search lost the exact-duplicate top-1"
        print(f"engine search [{tag}] top-1 == frozen-index top-1: "
              f"True")
    else:
        assert np.array_equal(ids_e[rows_ok],
                              np.asarray(idx)[rows_ok]), \
            "engine search disagrees with the frozen index"
        print(f"engine search [{tag}] == frozen-index retrieval on "
              f"live docs: True")
    if args.cache_mb > 0:
        # the frontier cache is a transparent layer: cache-on must be
        # id- AND value-identical to cache-off, miss pass (cold) and
        # hit pass (every row served from the cache) alike
        from repro.runtime.frontier import (CachedEngine,
                                            HotPostingCache,
                                            QueryResultCache)

        cache_bytes = int(args.cache_mb * 2**20)
        cached = CachedEngine(
            engine, result_cache=QueryResultCache(cache_bytes),
            hot_cache=HotPostingCache(cache_bytes // 4))
        for pss in ("miss", "hit"):
            vals_c, ids_c = cached.search(q_rep, K, **kw)
            assert np.array_equal(ids_c, ids_e), \
                f"cached search ids diverge on the {pss} pass"
            assert np.array_equal(vals_c, vals_e), \
                f"cached search values diverge on the {pss} pass"
        cs = cached.stats()
        rc, hot = cs["results"], cs["hot"]
        assert rc["hits"] == QUERIES and rc["misses"] == QUERIES
        print(f"cached engine search == uncached (miss + hit pass): "
              f"True; hit ratio {rc['hit_rate']}, "
              f"{rc['bytes_used']} B cached, "
              f"{hot['pinned_terms']} hot terms / "
              f"{hot['bytes_pinned']} B pinned")

# --- 3b. the 1M-candidate regime: fused streaming top-k ---------------
cand = jax.random.normal(jax.random.PRNGKey(1), (20000, 64))
qv = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
v_stream, i_stream = streaming_topk(qv, cand, k=K, tile=4096)
v_kernel, i_kernel = topk_score(qv, cand, k=K, block_b=4, block_n=2048,
                                interpret=True)
assert np.allclose(np.asarray(v_stream), np.asarray(v_kernel), atol=1e-5)
print("streaming top-k == fused Pallas kernel (interpret):",
      np.array_equal(np.asarray(i_stream), np.asarray(i_kernel)))
print("done.")
