"""Jit'd, differentiable wrappers around the Pallas Sparton kernels.

``sparton_lm_head_kernel`` is the drop-in kernel-backed equivalent of
``repro.core.lm_head.lm_head_sparton``: a ``jax.custom_vjp`` whose
forward runs the fused Pallas forward (saving only ``(y, i_max)``) and
whose backward runs the two fused Pallas accumulation kernels. The v2
backward consumes the raw cotangent directly — the activation-
derivative factor ``g = dy * f'(y)`` and the bias gradient
``db = sum_b g`` are computed inside the kernels, so no standalone
``(B, V)`` elementwise pass (and no HBM round-trip of ``g``) remains.

Block sizes default to ``None`` = auto: the autotuner's cached winner
**per kernel** (fwd vs dH vs dE — each contraction has its own cache
entry and heuristic), else the analytic heuristic
(``repro.kernels.autotune``). Passing ints pins the same triple across
all three kernels (the legacy joint behavior).

On this CPU container the kernels run with ``interpret=True`` (the
kernel body executed by the Pallas interpreter); on TPU the same code
compiles to Mosaic. ``interpret`` is threaded through as a static
argument so tests/benchmarks choose explicitly.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sparton import sparton_forward
from repro.kernels.sparton_bwd import sparton_backward

Blocks = Tuple[int, int, int]


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(4, 5, 6, 7, 8, 9, 10, 11))
def sparton_lm_head_kernel(
    H: jax.Array,
    E: jax.Array,
    b: jax.Array,
    mask: jax.Array,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    softcap: Optional[float] = None,
    interpret: bool = False,
    out_dtype: Optional[jnp.dtype] = None,
    dh_blocks: Optional[Blocks] = None,
    de_blocks: Optional[Blocks] = None,
) -> jax.Array:
    y, _ = sparton_forward(
        H, E, b, mask,
        block_b=block_b, block_s=block_s, block_v=block_v,
        softcap=softcap, interpret=interpret,
    )
    return y.astype(out_dtype or H.dtype)


def _fwd(H, E, b, mask, block_b, block_s, block_v, softcap, interpret,
         out_dtype, dh_blocks, de_blocks):
    y, i_max = sparton_forward(
        H, E, b, mask,
        block_b=block_b, block_s=block_s, block_v=block_v,
        softcap=softcap, interpret=interpret,
    )
    return y.astype(out_dtype or H.dtype), (H, E, y, i_max)


def _bwd(block_b, block_s, block_v, softcap, interpret, out_dtype,
         dh_blocks, de_blocks, res, dy):
    H, E, y, i_max = res
    # v2: dy and y go straight into the kernels; g and db are computed
    # tile-wise in their epilogues. Each backward contraction runs with
    # its own blocks (explicit triples win; else block_* pins apply
    # jointly; else per-kernel autotune cache).
    dH, dE, db = sparton_backward(
        dy, y, i_max, H, E,
        block_b=block_b, block_s=block_s, block_v=block_v,
        dh_blocks=dh_blocks, de_blocks=de_blocks,
        softcap=softcap, interpret=interpret,
    )
    return dH.astype(H.dtype), dE.astype(E.dtype), db, None


sparton_lm_head_kernel.defvjp(_fwd, _bwd)


def sparton_head(
    H: jax.Array,
    E: jax.Array,
    b: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    *,
    block_b: Optional[int] = None,
    block_s: Optional[int] = None,
    block_v: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    interpret: bool = False,
    out_dtype: Optional[jnp.dtype] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Convenience entry point with optional bias/mask (kernel-backed).

    With the default ``block_* = None`` the block sizes are resolved
    once here **per kernel** — cache hit (``_fwd``/``_dh``/``_de``
    entries, legacy joint entries as fallback) or per-kernel heuristic,
    keyed on the shapes of THIS call (under shard_map: the local vocab
    shard) — so forward and backward are guaranteed to agree even if
    the autotune cache changes mid-step. Explicit ints pin one joint
    triple across all three kernels.

    ``softcap`` is the deprecated spelling of ``logit_softcap`` (kept
    so pre-registry callers don't break). Prefer building heads through
    ``repro.core.head_api.make_head``.
    """
    from repro.core.head_api import normalize_softcap_kwarg

    logit_softcap = normalize_softcap_kwarg(logit_softcap, softcap,
                                            "sparton_head")
    B, S, D = H.shape
    V = E.shape[0]
    dh_blocks = de_blocks = None
    if block_b is None or block_s is None or block_v is None:
        from repro.kernels.autotune import resolve_blocks

        # cache dtype keys on each kernel's own weight/activation
        # operand — the rule sparton_bwd's standalone wrappers share
        pins = (block_b, block_s, block_v)
        block_b, block_s, block_v = resolve_blocks(
            B, S, D, V, H.dtype, *pins, kernel="fwd")
        dh_blocks = resolve_blocks(B, S, D, V, E.dtype, *pins,
                                   kernel="dh")
        de_blocks = resolve_blocks(B, S, D, V, H.dtype, *pins,
                                   kernel="de")
    if b is None:
        b = jnp.zeros((V,), jnp.float32)
    if mask is None:
        mask = jnp.ones((B, S), jnp.int32)
    return sparton_lm_head_kernel(
        H, E, b, mask, block_b, block_s, block_v, logit_softcap,
        interpret, out_dtype, dh_blocks, de_blocks
    )
