"""Gradient accumulation (microbatching) as a lax.scan.

The global batch is split into ``n_micro`` microbatches along axis 0;
the loss/grad function runs per microbatch inside a scan, grads are
averaged. This bounds activation memory to one microbatch while
keeping the *optimizer* step at the global batch size — the standard
trick that, combined with the Sparton head, sets the achievable batch
size story of the paper's Table 3.

XLA's latency-hiding scheduler overlaps the DP gradient all-reduce of
microbatch i with the backward compute of microbatch i+1 when the
scan is unrolled (``unroll > 1``) — flags set in launch/train.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def microbatch_grads(
    loss_and_grad_fn: Callable[..., Tuple[jax.Array, PyTree]],
    params: PyTree,
    batch: PyTree,
    *,
    n_micro: int,
    unroll: int = 1,
    grad_specs: Any = None,
) -> Tuple[jax.Array, PyTree]:
    """Splits ``batch`` (leading axis) into ``n_micro`` chunks; returns
    (mean loss, mean grads).

    ``grad_specs``: optional sharding constraints (ZeRO specs) applied
    to each microbatch's gradients AND the fp32 accumulator — ZeRO-2
    style: the reduce-scatter happens per micro step, so the fp32
    accumulator lives batch-sharded instead of param-sharded (for a
    26B-param MoE that is 6.6 GB/device -> 0.4 GB/device).
    """

    def constrain(g):
        if grad_specs is None:
            return g
        return jax.lax.with_sharding_constraint(g, grad_specs)

    if n_micro == 1:
        loss, grads = loss_and_grad_fn(params, batch)
        return loss, constrain(grads)

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} not divisible by {n_micro}"
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = loss_and_grad_fn(params, mb)
        grads = constrain(grads)
        grad_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_micro,
            grad_acc, grads)
        # constrain the carry too: the partitioner otherwise places the
        # fp32 accumulator at the (coarser) param sharding
        grad_acc = constrain(grad_acc)
        return (loss_acc + loss / n_micro, grad_acc), None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros = constrain(zeros)
    (loss, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), micro, unroll=unroll)
    return loss, grads


@dataclasses.dataclass
class GradAccumulator:
    """Stateful host-side accumulator for the fault-tolerant runner:
    lets the straggler path drop a microbatch from the window without
    recompiling (normalizes by the count actually accumulated)."""

    grads: PyTree = None
    count: int = 0

    def add(self, grads: PyTree) -> None:
        if self.grads is None:
            self.grads = grads
            self.count = 1
        else:
            self.grads = jax.tree.map(jnp.add, self.grads, grads)
            self.count += 1

    def mean_and_reset(self) -> PyTree:
        assert self.count > 0, "no gradients accumulated"
        out = jax.tree.map(lambda g: g / self.count, self.grads)
        self.grads, self.count = None, 0
        return out
