"""Inverted impact index over SparseReps — the sparse-native corpus.

GPUSparse-style LSR serving scores queries against *posting lists*:
for every vocab term, the (doc id, impact) pairs of the documents that
activate it. The corpus then costs ``O(total nnz)`` memory instead of
the dense ``(N, V)`` matrix (which at V≈250k cannot hold a real N),
and a query only touches the lists of its own active terms.

Layout: padded CSC over the vocabulary (terms are the major axis),
flattened into three arrays —

    term_starts  (V,) i32  — offset of each term's postings
    term_lens    (V,) i32  — posting-list length per term
    postings_doc (P,) i32  — doc ids, grouped by term
    postings_val (P,) f32  — impact weights, same order

plus the static aux ``(n_docs, vocab_size, max_postings)``.
``max_postings`` (the longest posting list) is the static gather width
the JAX scorer pads every touched list to — see ``score.py``. The
index is a pytree, so scoring jits over it; the *build* is host-side
numpy (indexing is the offline half of the pipeline).

Engine extensions (``retrieval/engine/``, DESIGN.md §8):

* ``term_ubs`` (V,) f32 — per-term score upper bounds (the max impact
  in each posting list), the MaxScore/WAND ingredient the two-tier
  pruned scorer needs. Cheap (4 bytes/term), so the build always
  stores them.
* ``doc_values``/``doc_indices`` (N, K) — the *forward* rep of the
  corpus (the SparseRep rows the index was built from), kept only when
  ``keep_forward=True``: the pruned path rescores candidate docs
  exactly from the forward rows instead of re-walking posting lists.
* ``posting_percentiles`` — static (p50, p90, p99, max) posting-list
  lengths over active terms. A stopword-like term active in most docs
  drags ``max_postings`` toward N and pads *every* query gather to it;
  the build warns when that happens, and the engine's pruning planner
  (``engine.pruning.default_candidates``) consumes the skew.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.retrieval.sparse_rep import SparseRep, device_get

Array = jax.Array

# build warns when one posting list covers more than this fraction of
# the corpus (every query gather is padded to max_postings, so a
# stopword-like term makes *all* queries pay ~N)
STOPWORD_WARN_FRAC = 0.5


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class InvertedIndex:
    term_starts: Array      # (V,) i32
    term_lens: Array        # (V,) i32
    postings_doc: Array     # (P,) i32
    postings_val: Array     # (P,) f32
    n_docs: int             # static
    vocab_size: int         # static
    max_postings: int       # static — longest posting list (>= 1)
    term_ubs: Optional[Array] = None      # (V,) f32 — max impact/term
    doc_values: Optional[Array] = None    # (N, K) f32 — forward rep
    doc_indices: Optional[Array] = None   # (N, K) i32 — forward rep
    # static (p50, p90, p99, max) posting lengths over active terms
    posting_percentiles: Tuple[float, ...] = ()

    def tree_flatten(self):
        children = (self.term_starts, self.term_lens,
                    self.postings_doc, self.postings_val,
                    self.term_ubs, self.doc_values, self.doc_indices)
        aux = (self.n_docs, self.vocab_size, self.max_postings,
               self.posting_percentiles)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_docs, vocab_size, max_postings, pct = aux
        return cls(*children[:4], n_docs=n_docs, vocab_size=vocab_size,
                   max_postings=max_postings, term_ubs=children[4],
                   doc_values=children[5], doc_indices=children[6],
                   posting_percentiles=pct)

    @property
    def n_postings(self) -> int:
        return self.postings_doc.shape[0]

    @property
    def has_upper_bounds(self) -> bool:
        return self.term_ubs is not None

    @property
    def has_forward(self) -> bool:
        return self.doc_values is not None and self.doc_indices is not None

    def memory_bytes(self) -> int:
        """Index footprint (the number to compare with N*V*4 dense).

        Counts every stored array — posting lists, upper bounds, and
        the forward rep when kept — so the quantized-vs-raw comparison
        in ``engine/quantize.py`` is apples to apples.
        """
        arrays = [self.term_starts, self.term_lens,
                  self.postings_doc, self.postings_val]
        for opt in (self.term_ubs, self.doc_values, self.doc_indices):
            if opt is not None:
                arrays.append(opt)
        return int(sum(np.asarray(a).nbytes for a in arrays))

    def stats(self) -> Dict[str, float]:
        lens = np.asarray(self.term_lens)
        active = lens > 0
        out = {
            "n_docs": self.n_docs,
            "vocab_size": self.vocab_size,
            "n_postings": self.n_postings,
            "active_terms": int(active.sum()),
            "max_postings": self.max_postings,
            "mean_postings": float(lens[active].mean()) if active.any()
            else 0.0,
            "memory_bytes": self.memory_bytes(),
        }
        if self.posting_percentiles:
            for name, v in zip(("p50", "p90", "p99", "max"),
                               self.posting_percentiles):
                out[f"postings_{name}"] = v
        return out


def _posting_percentiles(lens: np.ndarray) -> Tuple[float, ...]:
    active = lens[lens > 0]
    if active.size == 0:
        return (0.0, 0.0, 0.0, 0.0)
    p50, p90, p99 = np.percentile(active, (50, 90, 99))
    return (float(p50), float(p90), float(p99), float(active.max()))


def build_inverted_index(reps: SparseRep, vocab_size: int, *,
                         keep_forward: bool = False,
                         with_upper_bounds: bool = True,
                         stopword_warn_frac: float = STOPWORD_WARN_FRAC,
                         vocab_range: Optional[Tuple[int, int]] = None,
                         ) -> InvertedIndex:
    """Build the index from a batched ``(N, K)`` corpus rep (host-side).

    Active slots (``value > 0``) are flattened to (term, doc, impact)
    triples, stably sorted by term (so each posting list is ordered by
    doc id), and packed into the CSC arrays. An all-empty corpus still
    yields valid (length-1, zero-impact) postings so the scorer's
    static shapes never degenerate.

    ``keep_forward=True`` additionally stores the (N, K) forward rows
    on the index — required by the engine's pruned rescoring path.
    Per-term upper bounds and posting-length percentiles are always
    computed (both are O(V) extras); a ``UserWarning`` with the
    percentile stats fires when the longest posting list covers more
    than ``stopword_warn_frac`` of the corpus, since that term pads
    every query gather to ~N.

    ``vocab_range=(lo, hi)`` builds a *term shard*: only terms in
    ``[lo, hi)`` are indexed, remapped to local ids ``t - lo``, and
    the resulting index's ``vocab_size`` is ``hi - lo``. Doc ids stay
    global — every term shard scores the full corpus (partial sums).
    Incompatible with ``keep_forward`` (forward rows carry global term
    ids; the term-sharded engine stores them once, not per shard).
    """
    host = device_get(reps) if isinstance(reps.values, jax.Array) else reps
    k = host.width
    v = np.asarray(host.values, np.float32).reshape(-1, k)
    i = np.asarray(host.indices, np.int32).reshape(-1, k)
    n_docs = v.shape[0]

    active = v > 0
    terms = i[active]
    if (terms < 0).any() or (terms >= vocab_size).any():
        raise ValueError(
            f"build_inverted_index: term ids outside [0, {vocab_size})")
    vals = v[active]
    docs = np.broadcast_to(np.arange(n_docs, dtype=np.int32)[:, None],
                           i.shape)[active]

    if vocab_range is not None:
        lo, hi = vocab_range
        if not 0 <= lo < hi <= vocab_size:
            raise ValueError(
                f"vocab_range {vocab_range} outside [0, {vocab_size})")
        if keep_forward:
            raise ValueError(
                "vocab_range is incompatible with keep_forward — "
                "forward rows carry global term ids (store them once "
                "on the term-sharded index instead)")
        sel = (terms >= lo) & (terms < hi)
        terms = terms[sel] - lo              # remap to local ids
        vals, docs = vals[sel], docs[sel]
        vocab_size = hi - lo

    order = np.argsort(terms, kind="stable")
    terms, vals, docs = terms[order], vals[order], docs[order]

    lens = np.bincount(terms, minlength=vocab_size).astype(np.int32)
    starts = np.zeros(vocab_size, np.int64)
    np.cumsum(lens[:-1], out=starts[1:])

    ubs = np.zeros(vocab_size, np.float32)
    if terms.size:
        np.maximum.at(ubs, terms, vals)

    if terms.size == 0:
        docs = np.zeros(1, np.int32)
        vals = np.zeros(1, np.float32)

    pct = _posting_percentiles(lens)
    max_postings = max(int(lens.max(initial=0)), 1)
    if n_docs and max_postings > stopword_warn_frac * n_docs:
        warnings.warn(
            f"build_inverted_index: longest posting list covers "
            f"{max_postings}/{n_docs} docs (> {stopword_warn_frac:.0%} "
            f"of the corpus) — a stopword-like term pads every query "
            f"gather to ~N. Posting-length percentiles (active terms): "
            f"p50={pct[0]:.0f} p90={pct[1]:.0f} p99={pct[2]:.0f} "
            f"max={pct[3]:.0f}. Consider a higher sparsifier threshold "
            f"or dropping the offending terms.",
            UserWarning, stacklevel=2)

    # device arrays: the scorer indexes these under jit/vmap tracing
    import jax.numpy as jnp

    return InvertedIndex(
        term_starts=jnp.asarray(starts.astype(np.int32)),
        term_lens=jnp.asarray(lens),
        postings_doc=jnp.asarray(docs.astype(np.int32)),
        postings_val=jnp.asarray(vals.astype(np.float32)),
        n_docs=n_docs,
        vocab_size=vocab_size,
        max_postings=max_postings,
        term_ubs=jnp.asarray(ubs) if with_upper_bounds else None,
        doc_values=jnp.asarray(v) if keep_forward else None,
        doc_indices=jnp.asarray(i) if keep_forward else None,
        posting_percentiles=pct,
    )
