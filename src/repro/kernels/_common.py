"""Shared helpers for the Sparton Pallas kernels and their wrappers.

Everything here is dependency-light (jnp only) so it can be imported by
the kernel modules, the differentiable wrappers in ``ops.py``, and the
pure-JAX reference head in ``core/lm_head.py`` without cycles.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# Finite stand-in for -inf: keeps the streaming max/argmax well-defined
# in bf16 and lets padded/masked lanes lose every comparison.
NEG_INF = -1e30


def pad_to(x: jax.Array, axis: int, multiple: int, value=0) -> jax.Array:
    """Zero-pad (or ``value``-pad) ``axis`` of ``x`` up to a multiple."""
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def bwd_factor(y: jax.Array, dy: jax.Array,
               softcap: Optional[float]) -> jax.Array:
    """g = dY/d(raw max logit), from the *stored post-activation* y.

    f(x) = log1p(relu(c(x))),   c = softcap or identity.
    With m = relu-input value at the max: exp(y) = 1 + relu(c(m)), and
    y > 0  <=>  c(m) > 0  <=>  m > 0 (softcap is sign-preserving).
        df/dc = exp(-y)         on c > 0, else 0
        dc/dm = 1 - (c/cap)^2   (tanh derivative), c = expm1(y)

    Elementwise and branch-free, so it fuses into the backward kernels'
    epilogue (computed per VMEM tile, never materialized in HBM).
    """
    g = dy.astype(jnp.float32) * jnp.exp(-y)
    if softcap is not None:
        c = jnp.expm1(y)
        g = g * (1.0 - (c / softcap) ** 2)
    return jnp.where(y > 0, g, 0.0)


def onehot_weights(g: jax.Array, local_i: jax.Array,
                   block_s: int) -> jax.Array:
    """The weighted one-hot tile both backward contractions contract with.

    ``w[b, s, v] = g[b, v] * 1[local_i[b, v] == s]`` for a ``(bb, bv)``
    gradient-factor tile and sequence-local argmax indices. Positions
    whose argmax falls outside the current sequence block produce an
    all-zero row, which is exactly what routes each gradient to one
    sequence block. The irregular gather/scatter of the paper's Alg. 3
    becomes a dense MXU contraction against this tile.
    """
    bb, bv = g.shape
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (bb, block_s, bv), 1)
    onehot = (local_i[:, None, :] == s_iota).astype(jnp.float32)
    return onehot * g[:, None, :]          # (bb, bs, bv)
