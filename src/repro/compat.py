"""Version-compat shims over the moving parts of the JAX API.

The framework targets the current JAX API (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh``, ``jax.lax.pcast``) but must also run
on the pinned 0.4.x toolchain in the CPU container, where those spell
``jax.experimental.shard_map.shard_map(check_rep=...)``, the ``Mesh``
context manager, and nothing (replication casts are implicit when the
rep-check is off). Import the symbols from here instead of ``jax``:

    from repro.compat import shard_map, set_mesh, pcast
"""

from __future__ import annotations

import contextlib
import inspect

import jax

try:  # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *args, **kwargs):
    """``jax.shard_map`` accepting either ``check_vma`` or ``check_rep``.

    New-API callers pass ``check_vma``; on 0.4.x it is forwarded as
    ``check_rep`` (same meaning: disable the replication/varying-axis
    check around bodies the tracer cannot prove replicated).
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, *args, **kwargs)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh):
        """0.4.x fallback: the Mesh object is its own context manager."""
        with mesh:
            yield mesh


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        """0.4.x fallback: psum of the literal 1 folds to the static size."""
        return jax.lax.psum(1, axis_name)


if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
else:
    def pcast(x, axis_name, *, to=None):
        """0.4.x fallback: no varying-axis tracking => identity.

        On 0.4.x ``shard_map(check_rep=False)`` performs no replication
        bookkeeping, so marking a value device-varying is a no-op.
        """
        del axis_name, to
        return x
