"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the sparse-native LSR serving pipeline end-to-end:

1. index  — encode a synthetic corpus through backbone + Sparton head,
            sparsify on-device (``rep_topk``), build the inverted
            impact index (no dense (N, V) corpus matrix anywhere);
2. serve  — stream queries through the deadline/size micro-batching
            loop (results popped via ``take``), reporting latency and
            achieved batch sizes;
3. retrieve — top-k via the unified dispatcher (``--method impact``
            by default; ``dense``/``streaming`` remain for A/B runs —
            both need the dense corpus, which ``--rep-topk 0`` keeps).
"""

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="splade_bert")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--corpus", type=int, default=1000)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--rep-topk", type=int, default=64,
                    help="per-row term budget of the on-device rep "
                         "sparsifier; 0 = dense reps (legacy path)")
    ap.add_argument("--method", default="auto",
                    choices=["auto", "impact", "streaming", "dense"],
                    help="retrieval path (see repro.retrieval.retrieve)")
    ap.add_argument("--index-batch", type=int, default=64,
                    help="corpus encoding batch size")
    ap.add_argument("--head-impl", default=None,
                    help="override the config's head backend (any "
                         "registered impl; see "
                         "repro.core.head_api.available_impls)")
    args = ap.parse_args(argv)
    # method/rep compatibility is knowable before spending minutes
    # encoding the corpus — reject bad combinations at argparse time
    if args.method in ("dense", "streaming") and args.rep_topk > 0:
        ap.error(f"--method {args.method} needs the dense corpus "
                 f"matrix; pass --rep-topk 0 to keep it (or use "
                 f"--method impact/auto with the sparse index)")
    if args.method == "impact" and args.rep_topk <= 0:
        ap.error("--method impact needs SparseRep queries and the "
                 "inverted index; pass a positive --rep-topk")

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.launch.steps import init_state
    from repro.retrieval import build_inverted_index, retrieve, stack_rows
    from repro.runtime.serving import (BatchedEncoder, BatchPolicy, Request,
                                       ServingLoop, make_config_encoder)

    mod = get_config(args.arch)
    cfg = mod.SMOKE
    overrides = {}
    if args.head_impl:
        overrides["head_impl"] = args.head_impl
    if args.rep_topk > 0:
        overrides["rep_topk"] = args.rep_topk
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sparse = args.rep_topk > 0
    state, _ = init_state(args.arch, jax.random.PRNGKey(0), smoke=True)
    params = state["params"]

    # Built from the config via the unified head factory: head_impl,
    # final_logit_softcap and the rep-sparsify knobs are all honored.
    encode = make_config_encoder(params, cfg)

    rng = np.random.default_rng(0)

    # --- 1. index the corpus (batched; never a dense (N, V) matrix) --
    t0 = time.monotonic()
    doc_parts, dense_parts = [], []
    bs = args.index_batch
    for lo in range(0, args.corpus, bs):
        n = min(bs, args.corpus - lo)
        toks = rng.integers(1, cfg.vocab_size, size=(n, 16)).astype(np.int32)
        reps = encode(jnp.asarray(toks), jnp.ones((n, 16), jnp.int32))
        if sparse:
            doc_parts.append(reps)
        else:
            dense_parts.append(np.asarray(reps))
    if sparse:
        corpus_rep = stack_rows(doc_parts)
        index = build_inverted_index(corpus_rep, cfg.vocab_size)
        corpus = index
        st = index.stats()
        print(f"indexed {st['n_docs']} docs in "
              f"{(time.monotonic() - t0) * 1e3:.1f} ms: "
              f"{st['n_postings']} postings over {st['active_terms']} "
              f"terms, {st['memory_bytes'] / 2**20:.2f} MiB "
              f"(dense (N, V) would be "
              f"{args.corpus * cfg.vocab_size * 4 / 2**20:.2f} MiB)")
    else:
        corpus = jnp.asarray(np.concatenate(dense_parts))
        print(f"indexed {corpus.shape[0]} docs dense in "
              f"{(time.monotonic() - t0) * 1e3:.1f} ms "
              f"({corpus.nbytes / 2**20:.2f} MiB)")

    # --- 2. serve queries through the batching loop ------------------
    loop = ServingLoop(BatchedEncoder(
        encode, policy=BatchPolicy(max_batch=16, max_wait_s=0.002)))
    t0 = time.monotonic()
    for uid in range(args.requests):
        n = int(rng.integers(4, 24))
        loop.submit(Request(uid=uid, tokens=rng.integers(
            1, cfg.vocab_size, size=n).astype(np.int32)))
        loop.tick()
    loop.drain()
    dt = time.monotonic() - t0
    results = [loop.take(uid) for uid in range(args.requests)]
    assert not loop.completed, "take() must leave nothing behind"
    print(f"encoded {len(results)} requests in {dt*1e3:.1f} ms, "
          f"batches: {loop.batch_sizes}")

    # --- 3. retrieval through the unified dispatcher ------------------
    n_q = min(8, args.requests)
    if sparse:
        queries = stack_rows(results[:n_q])
    else:
        queries = jnp.asarray(np.stack(results[:n_q]))
    t0 = time.monotonic()
    vals, idx = retrieve(queries, corpus, args.topk, method=args.method)
    jax.block_until_ready(vals)
    print(f"retrieval[{args.method}]: top-{args.topk} for {n_q} queries "
          f"in {(time.monotonic() - t0) * 1e3:.1f} ms, "
          f"best scores {np.asarray(vals)[:, 0].round(2).tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
