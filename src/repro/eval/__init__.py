"""Ranking-quality evaluation: metrics, qrels, end-to-end harness."""

from repro.eval.harness import (DEFAULT_METHODS, MethodSpec, encode_reps,
                                evaluate_retrieval)
from repro.eval.metrics import (BATCHED, METRIC_NAMES, REFERENCE,
                                compute_metrics, mrr_at_k, mrr_ref,
                                ndcg_at_k, ndcg_ref, ranked_grades,
                                recall_at_k, recall_ref, success_at_k,
                                success_ref)
from repro.eval.qrels import Qrels

__all__ = [
    "BATCHED",
    "DEFAULT_METHODS",
    "METRIC_NAMES",
    "MethodSpec",
    "Qrels",
    "REFERENCE",
    "compute_metrics",
    "encode_reps",
    "evaluate_retrieval",
    "mrr_at_k",
    "mrr_ref",
    "ndcg_at_k",
    "ndcg_ref",
    "ranked_grades",
    "recall_at_k",
    "recall_ref",
    "success_at_k",
    "success_ref",
]
