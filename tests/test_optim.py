"""Optimizers, schedules, accumulation, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.accumulation import GradAccumulator, microbatch_grads
from repro.optim.compression import compress_int8, compress_tree, \
    decompress_int8
from repro.optim.optimizers import (adagrad, adamw, apply_updates,
                                    clip_by_global_norm, sgd_momentum)
from repro.optim.schedules import (constant_schedule, cosine_schedule,
                                   linear_warmup_cosine,
                                   linear_warmup_linear_decay)


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}


def _quadratic_grads(params):
    return jax.grad(
        lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)


@pytest.mark.parametrize("opt_fn", [
    lambda: adamw(0.1, weight_decay=0.0, max_grad_norm=None),
    lambda: adagrad(0.5),
    lambda: sgd_momentum(0.05),
])
def test_optimizers_descend_quadratic(opt_fn):
    opt = opt_fn()
    params = _quadratic_params()
    state = opt.init(params)
    loss0 = float(jnp.sum(params["w"] ** 2) + params["b"] ** 2)
    step = jnp.zeros((), jnp.int32)
    for i in range(60):
        grads = _quadratic_grads(params)
        updates, state = opt.update(grads, state, params, step + i)
        params = apply_updates(params, updates)
    loss1 = float(jnp.sum(params["w"] ** 2) + params["b"] ** 2)
    assert loss1 < loss0 * 0.05


def test_adamw_first_step_is_lr_sized():
    """After bias correction the first AdamW step is ~lr * sign(g)."""
    opt = adamw(0.1, weight_decay=0.0, max_grad_norm=None)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.array([123.0])}, state, params,
                            jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.1], atol=1e-5)


def test_clip_by_global_norm():
    grads = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert abs(float(gn) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               atol=1e-6)
    # under the limit: untouched
    clipped2, _ = clip_by_global_norm(grads, 10.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), [3.0, 4.0])


def test_schedules():
    s = constant_schedule(0.1)
    assert float(s(jnp.array(100))) == pytest.approx(0.1)
    c = cosine_schedule(1.0, 100, final_fraction=0.0)
    assert float(c(jnp.array(0))) == pytest.approx(1.0)
    assert float(c(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)
    w = linear_warmup_cosine(1.0, 10, 100)
    # warmup counts from step+1 so step 0 is never lr=0
    assert float(w(jnp.array(4))) == pytest.approx(0.5)
    assert float(w(jnp.array(0))) == pytest.approx(0.1)
    assert float(w(jnp.array(10))) == pytest.approx(1.0, abs=1e-2)
    d = linear_warmup_linear_decay(1.0, 10, 110)
    assert float(d(jnp.array(60))) == pytest.approx(0.5)


def test_microbatch_grads_equals_full_batch():
    params = {"w": jnp.ones((4, 3))}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(0), (8, 4)),
             "y": jax.random.normal(jax.random.PRNGKey(1), (8, 3))}

    def loss_and_grad(p, b):
        def loss(p):
            pred = b["x"] @ p["w"]
            return jnp.mean((pred - b["y"]) ** 2)
        l, g = jax.value_and_grad(loss)(p)
        return l, g

    l_full, g_full = loss_and_grad(params, batch)
    l_micro, g_micro = microbatch_grads(loss_and_grad, params, batch,
                                        n_micro=4)
    np.testing.assert_allclose(float(l_full), float(l_micro), atol=1e-6)
    np.testing.assert_allclose(np.asarray(g_full["w"]),
                               np.asarray(g_micro["w"]), atol=1e-6)


def test_grad_accumulator_renormalizes():
    acc = GradAccumulator()
    acc.add({"w": jnp.array(2.0)})
    acc.add({"w": jnp.array(4.0)})
    out = acc.mean_and_reset()
    assert float(out["w"]) == pytest.approx(3.0)
    assert acc.count == 0


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    q, s = compress_int8(x)
    assert q.dtype == jnp.int8
    err = jnp.max(jnp.abs(decompress_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    grads = {"w": jnp.array([0.001, 1.0])}
    qs, ss, rs = compress_tree(grads, None)
    # small value quantizes to 0; its full value must land in residual
    deq = decompress_int8(qs["w"], ss["w"])
    np.testing.assert_allclose(np.asarray(rs["w"]),
                               np.asarray(grads["w"] - deq), atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_property_compression_relative_error(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = compress_int8(x)
    rel = float(jnp.max(jnp.abs(decompress_int8(q, s) - x))) / max(
        float(jnp.max(jnp.abs(x))), 1e-12)
    assert rel <= 1.0 / 127 + 1e-6
