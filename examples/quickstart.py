"""Quickstart: the Sparton head through the unified head API.

The paper's core contribution (Eq. 1) behind one seam: a ``HeadSpec``
describes the head, a registry holds the backends (naive / tiled /
sparton / kernel), and ``make_head`` returns one canonical callable —
pure JAX or Pallas, single-device or vocab-sharded, same call.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.head_api import HeadSpec, available_impls, make_head
from repro.core.lm_head import sparton_forward_with_indices

B, S, D, V = 4, 64, 128, 30522  # bert-base-uncased vocabulary

key = jax.random.PRNGKey(0)
kh, ke, kb, km = jax.random.split(key, 4)
H = jax.random.normal(kh, (B, S, D))          # backbone hidden states
E = jax.random.normal(ke, (V, D)) * 0.05      # vocab embedding matrix
b = jax.random.normal(kb, (V,)) * 0.05        # head bias
mask = (jax.random.uniform(km, (B, S)) > 0.1).astype(jnp.int32)

# --- one spec, every backend ------------------------------------------
print("registered head impls:", available_impls())
spec = HeadSpec(impl="sparton", vocab_tile=4096)
head = make_head(spec)

y_sparton = head(H, E, b, mask)
y_naive = make_head(spec.replace(impl="naive"))(H, E, b, mask)
print("output shape:", y_sparton.shape)
print("max |sparton - naive|:",
      float(jnp.max(jnp.abs(y_sparton - y_naive))))
nnz = float(jnp.mean(jnp.sum(y_sparton > 0, axis=-1)))
print(f"active vocab dims per example: {nnz:.0f} / {V} "
      "(untrained weights are dense; the FLOPS regularizer induces "
      "sparsity during training — see examples/train_splade.py)")

# --- the Pallas kernel is just another registry entry -----------------
# (interpret=True runs the kernel body through the Pallas interpreter
# on CPU; on TPU the same spec compiles to Mosaic.)
kernel_head = make_head(spec.replace(impl="kernel", interpret=True,
                                     block_b=4, block_s=64, block_v=2048))
y_kernel = kernel_head(H, E, b, mask)
print("max |kernel - sparton|:",
      float(jnp.max(jnp.abs(y_kernel - y_sparton))))

# --- the memory story: residuals are (y, i_max), not (B, S, V) --------
def contrastive_ish_loss(H, E, b):
    y = head(H, E, b, mask)
    return jnp.sum(y * y)

grads = jax.grad(contrastive_ish_loss, argnums=(0, 1, 2))(H, E, b)
print("grad shapes:", [g.shape for g in grads])

# --- interpretability: which token activated each vocab dim -----------
y, i_max = sparton_forward_with_indices(H, E, b, mask)
top_dims = jnp.argsort(-y[0])[:5]
print("example 0 — top vocab dims:", top_dims.tolist(),
      "activated at tokens:", i_max[0, top_dims].tolist())
