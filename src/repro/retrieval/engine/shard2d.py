"""2D hybrid (doc × term) sharding behind the ShardPlan placement API
(DESIGN.md §14).

Doc sharding (``sharded_index.py``) and term sharding
(``term_sharded.py``) are exclusive single-axis layouts: the first
splits documents and replicates the ``O(V)`` term directory on every
device, the second splits the vocabulary and pays a ``(B, N)``
partial-sum all-reduce at query time. The paper's large-|V| regime
(the ~250k-vocab multilingual backbone) wants *both*: enough term
shards to tame the replicated directory, enough doc shards to keep the
psum small and the corpus growing with device count.

``Shard2DIndex`` composes the two axes on a (doc × term) grid: device
``(i, j)`` owns the complete posting lists of vocab range ``j``
restricted to the documents of contiguous doc chunk ``i``. The merge
algebra composes the two single-axis reductions in the only order that
is exact:

1. **psum over the term axis** — within one doc chunk a document's
   score is spread across the ``T`` vocab ranges, so the per-cell
   ``(B, docs_per_chunk)`` partial sums are all-reduced first (the
   ``term_sharded`` algebra, but over a chunk instead of the whole
   corpus — the psum payload shrinks by the doc-shard factor);
2. **top-k merge over the doc axis** — after the psum each doc row
   holds *exact* chunk scores, so per-chunk top-k + ``all_gather`` +
   re-top-k finishes the query (the ``sharded_index`` algebra,
   unchanged).

Running the reductions in the other order would be wrong: per-cell
top-k before the psum would rank documents by partial scores.

Two-tier MaxScore composes across both axes the same way: per-cell
*ceiling* partials (from each cell's local upper bounds) are psum'd
over the term axis into exact chunk ceilings, gathered over the doc
axis into the global ``(B, N)`` bound, and the surviving candidates
are rescored exactly from forward rows stored once on the index
(``pruning.select_and_rescore`` — the same tier 2 every other path
uses).

Placement is no longer a string choice. ``plan_placement(stats,
n_devices, per_device_hbm)`` grows the old ``choose_shard_axis``
heuristic into a real planner over frozen ``ShardPlan`` tuples
``(doc_shards, term_shards, replicas, axis_order, reason)``: it
accounts the per-device posting bytes, the directory slice (doc
sharding replicates all ``DIR_BYTES_PER_TERM * V`` of it, term
sharding divides it by ``term_shards``) and the replicated forward
rows, picks the smallest grid that fits the HBM budget (preferring few
term shards — the psum is the expensive merge), and spends the
leftover devices on whole-grid throughput replicas. Term-range cuts
are balanced by cumulative posting *mass* (``mass_balanced_
boundaries``), not vocab width, so one stopword-heavy range cannot
drag every shard's padded posting array to its own length.

Like the 1D indexes, the same semantics run on two paths: ``mesh``
given — ``shard_map`` over a 2-axis mesh (``psum`` + ``all_gather``);
``mesh=None`` — a nested ``vmap`` on one device (a work partition,
used by tests and CPU benches).
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.engine.sharded_index import (NEG_INF,
                                                  resolve_mesh_axes,
                                                  shard_mapped)
from repro.retrieval.index import InvertedIndex, build_inverted_index
from repro.retrieval.sparse_rep import SparseRep

Array = jax.Array

# term_starts + term_lens + term_ubs per vocab entry — the per-device
# term-directory cost the planner accounts (doc sharding replicates
# it, term sharding divides it by term_shards)
DIR_BYTES_PER_TERM = 12
# one posting = i32 doc id + f32 impact
POSTING_BYTES = 8


# ---------------------------------------------------------------------------
# corpus statistics — the planner's input
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CorpusStats:
    """The sizes that drive placement: posting mass, vocab width, and
    the replicated extras. Build one ``from_index``/``from_rep`` for a
    live corpus or fill the fields directly to plan a hypothetical one
    (the bench's 30k-vs-250k vocab probe does the latter)."""

    posting_bytes: int        # total posting-array bytes (docs + vals)
    vocab_size: int           # |V| — the directory is O(V) per replica
    n_docs: int
    forward_bytes: int = 0    # (N, K) forward rows, replicated per dev

    @classmethod
    def from_index(cls, index: InvertedIndex) -> "CorpusStats":
        fwd = 0
        if index.has_forward:
            fwd = int(np.asarray(index.doc_values).nbytes
                      + np.asarray(index.doc_indices).nbytes)
        return cls(posting_bytes=POSTING_BYTES * index.n_postings,
                   vocab_size=index.vocab_size, n_docs=index.n_docs,
                   forward_bytes=fwd)

    @classmethod
    def from_rep(cls, reps: SparseRep, vocab_size: int, *,
                 keep_forward: bool = False) -> "CorpusStats":
        from repro.retrieval.sparse_rep import device_get

        host = (device_get(reps) if isinstance(reps.values, jax.Array)
                else reps)
        v = np.asarray(host.values, np.float32).reshape(-1, host.width)
        nnz = int((v > 0).sum())
        fwd = 2 * 4 * v.size if keep_forward else 0
        return cls(posting_bytes=POSTING_BYTES * max(nnz, 1),
                   vocab_size=vocab_size, n_docs=v.shape[0],
                   forward_bytes=fwd)


# ---------------------------------------------------------------------------
# ShardPlan — the placement API
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A frozen placement: a (doc × term) grid replicated ``replicas``
    times for throughput. ``axis_order`` names the logical axes in
    *mesh* order — ``("doc", "term")`` means mesh axis 0 carries the
    doc dimension; flip it to run the same index on a transposed mesh.
    ``reason`` is the planner's human-readable accounting trail."""

    doc_shards: int
    term_shards: int
    replicas: int = 1
    axis_order: Tuple[str, str] = ("doc", "term")
    reason: str = ""

    def __post_init__(self):
        for name in ("doc_shards", "term_shards", "replicas"):
            if getattr(self, name) < 1:
                raise ValueError(f"ShardPlan.{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        if tuple(sorted(self.axis_order)) != ("doc", "term"):
            raise ValueError(
                f"axis_order must be a permutation of ('doc', 'term'), "
                f"got {self.axis_order!r}")

    @property
    def grid(self) -> int:
        return self.doc_shards * self.term_shards

    @property
    def n_devices(self) -> int:
        return self.grid * self.replicas

    @property
    def axis(self) -> str:
        """The 1D axis name this plan degenerates to — what the
        deprecated ``choose_shard_axis`` shim returns. A genuinely 2D
        grid reports ``"2d"``."""
        if self.term_shards == 1:
            return "doc"
        if self.doc_shards == 1:
            return "term"
        return "2d"

    def per_device_bytes(self, stats: CorpusStats) -> float:
        """The planner's accounting model for one device of this grid:
        an even posting-mass slice (mass-balanced term cuts + contiguous
        doc chunks make that the design point, not an assumption), this
        device's directory slice, and the replicated forward rows."""
        return (stats.posting_bytes / self.grid
                + DIR_BYTES_PER_TERM * stats.vocab_size
                / self.term_shards
                + stats.forward_bytes)

    def describe(self) -> str:
        return (f"{self.doc_shards}x{self.term_shards} (doc x term)"
                + (f" x{self.replicas} replicas" if self.replicas > 1
                   else ""))


def _grid_candidates(n_devices: int):
    """All (doc_shards, term_shards) grids of size <= n_devices,
    ordered smallest grid first, then fewest term shards (the psum is
    the expensive merge), then fewest doc shards."""
    grids = [(d, t) for d in range(1, n_devices + 1)
             for t in range(1, n_devices // d + 1)]
    return sorted(grids, key=lambda g: (g[0] * g[1], g[1], g[0]))


def plan_placement(stats: CorpusStats, n_devices: int,
                   per_device_hbm: Optional[int] = None) -> ShardPlan:
    """Plan a (doc × term × replica) placement for this corpus.

    With an HBM budget: the smallest grid whose per-device footprint
    (``ShardPlan.per_device_bytes``) fits wins — few term shards
    preferred, since the doc axis merges k winners while the term axis
    all-reduces chunk-sized partials — and every leftover device
    becomes a whole-grid throughput replica. If nothing fits, the
    full-device grid with the smallest footprint is returned (serving
    may still spill; the ``reason`` says so loudly).

    Without a budget, only the directory-vs-postings ratio can decide:
    doc-only when the replicated O(V) directory is a rounding error
    next to a per-device posting slice, else just enough term shards
    that each device's directory slice stops dominating its postings —
    the huge-vocab sparse regime ("The Role of Vocabularies") where
    posting mass, not device count, drives placement.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    directory = DIR_BYTES_PER_TERM * stats.vocab_size
    post_slice = stats.posting_bytes / n_devices

    if per_device_hbm is None:
        if directory <= post_slice:
            return ShardPlan(
                doc_shards=n_devices, term_shards=1,
                reason=f"doc-only: replicated directory "
                       f"({directory} B) fits beside the per-device "
                       f"posting slice ({post_slice:.0f} B)")
        for t in range(2, n_devices + 1):
            if n_devices % t == 0 and directory / t <= post_slice:
                return ShardPlan(
                    doc_shards=n_devices // t, term_shards=t,
                    reason=f"{n_devices // t}x{t}: {t} term shards "
                           f"cut the directory to {directory / t:.0f} "
                           f"B <= the posting slice "
                           f"({post_slice:.0f} B)")
        return ShardPlan(
            doc_shards=1, term_shards=n_devices,
            reason=f"term-only: directory ({directory} B) dominates "
                   f"the posting slice ({post_slice:.0f} B) at every "
                   f"narrower cut")

    feasible = [(d, t) for d, t in _grid_candidates(n_devices)
                if ShardPlan(d, t).per_device_bytes(stats)
                <= per_device_hbm]
    if not feasible:
        full = [(d, t) for d, t in _grid_candidates(n_devices)
                if d * t == n_devices]
        d, t = min(full, key=lambda g: ShardPlan(*g)
                   .per_device_bytes(stats))
        need = ShardPlan(d, t).per_device_bytes(stats)
        return ShardPlan(
            doc_shards=d, term_shards=t,
            reason=f"OVER BUDGET: smallest per-device footprint "
                   f"{need:.0f} B still exceeds {per_device_hbm} B — "
                   f"needs more devices or a smaller corpus")
    d, t = feasible[0]
    plan = ShardPlan(d, t)
    replicas = n_devices // plan.grid
    used = plan.per_device_bytes(stats)
    return dataclasses.replace(
        plan, replicas=replicas,
        reason=f"{d}x{t} grid fits ({used:.0f} of {per_device_hbm} B "
               f"per device)"
               + (f"; {replicas} throughput replicas from the "
                  f"{n_devices - plan.grid} spare devices"
                  if replicas > 1 else ""))


def choose_shard_axis(posting_bytes: int, vocab_size: int,
                      n_shards: int,
                      per_device_bytes: Optional[int] = None) -> str:
    """Deprecated string shim over ``plan_placement`` — returns
    ``plan.axis`` (``"doc"``/``"term"``/``"2d"``). Migrate to the
    ``ShardPlan`` object; the string cannot express 2D grids or
    replicas."""
    warnings.warn(
        "choose_shard_axis is deprecated: use plan_placement(...) and "
        "read the ShardPlan (doc_shards/term_shards/replicas) instead "
        "of a string axis",
        DeprecationWarning, stacklevel=2)
    stats = CorpusStats(posting_bytes=posting_bytes,
                        vocab_size=vocab_size, n_docs=0)
    return plan_placement(stats, n_shards, per_device_bytes).axis


# ---------------------------------------------------------------------------
# mass-balanced vocab cuts (shared with term_sharded)
# ---------------------------------------------------------------------------

def mass_balanced_boundaries(term_counts: np.ndarray, n_shards: int
                             ) -> Tuple[int, ...]:
    """Vocab cuts that equalize cumulative posting *mass* per range.

    Width-balanced cuts give every shard ``V / n`` terms; with a
    skewed DF distribution (one stopword-heavy term owning a large
    slice of all postings) one shard's posting array then dwarfs the
    rest and — because the stacked layout pads to the widest shard —
    every shard pays for it. Cutting at the mass quantiles instead
    bounds each range near ``total / n`` postings (within one term:
    a single list is never split). Cuts are strictly increasing; with
    zero total mass the width cuts are returned.
    """
    counts = np.asarray(term_counts, np.int64)
    v = counts.shape[0]
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > v:
        raise ValueError(f"n_shards={n_shards} exceeds vocab size {v}")
    total = int(counts.sum())
    if total == 0:
        return tuple(s * v // n_shards for s in range(n_shards + 1))
    cum = np.cumsum(counts)
    bounds = [0]
    for s in range(1, n_shards):
        target = s * total / n_shards
        b = int(np.searchsorted(cum, target))
        # keep cuts strictly increasing with enough terms left for the
        # remaining shards
        b = max(b, bounds[-1] + 1)
        b = min(b, v - (n_shards - s))
        bounds.append(b)
    bounds.append(v)
    return tuple(bounds)


def _validate_boundaries(boundaries, n_parts: int, size: int,
                         what: str) -> Tuple[int, ...]:
    boundaries = tuple(int(b) for b in boundaries)
    if (len(boundaries) != n_parts + 1 or boundaries[0] != 0
            or boundaries[-1] != size
            or any(a >= b for a, b in zip(boundaries, boundaries[1:]))):
        raise ValueError(
            f"{what} must be {n_parts + 1} strictly increasing cuts "
            f"from 0 to {size}, got {list(boundaries)}")
    return boundaries


# ---------------------------------------------------------------------------
# the 2D index
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Shard2DIndex:
    """(doc × term) grid of posting-list cells (module docstring).

    Cell ``(i, j)`` indexes doc chunk ``i`` restricted to vocab range
    ``j``: term ids are local to the range (``t - term_lo[j]``), doc
    ids are local to the chunk (``d - chunk_starts[i]``). Stacked on
    two leading grid axes, padded to the widest cell."""

    term_starts: Array      # (D, T, Vloc) i32 — local term offsets
    term_lens: Array        # (D, T, Vloc) i32
    postings_doc: Array     # (D, T, Pmax) i32 — LOCAL (chunk) doc ids
    postings_val: Array     # (D, T, Pmax) f32
    term_ubs: Array         # (D, T, Vloc) f32 — per-cell upper bounds
    term_lo: Array          # (T,) i32 — vocab range starts
    term_hi: Array          # (T,) i32 — vocab range ends (exclusive)
    chunk_starts: Array     # (D,) i32 — first global doc id per chunk
    chunk_counts: Array     # (D,) i32 — real docs per chunk
    doc_shards: int         # static — D
    term_shards: int        # static — T
    n_docs: int             # static — total real docs
    vocab_size: int         # static — global V
    local_vocab: int        # static — padded per-range vocab width
    docs_per_chunk: int     # static — padded chunk width
    max_postings: int       # static — longest list over all cells
    term_boundaries: Tuple[int, ...] = ()   # static — the vocab cuts
    doc_boundaries: Tuple[int, ...] = ()    # static — the doc cuts
    doc_values: Optional[Array] = None      # (N, K) f32 — stored once
    doc_indices: Optional[Array] = None     # (N, K) i32

    def tree_flatten(self):
        children = (self.term_starts, self.term_lens,
                    self.postings_doc, self.postings_val,
                    self.term_ubs, self.term_lo, self.term_hi,
                    self.chunk_starts, self.chunk_counts,
                    self.doc_values, self.doc_indices)
        aux = (self.doc_shards, self.term_shards, self.n_docs,
               self.vocab_size, self.local_vocab, self.docs_per_chunk,
               self.max_postings, self.term_boundaries,
               self.doc_boundaries)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children[:9], *aux, doc_values=children[9],
                   doc_indices=children[10])

    @property
    def has_forward(self) -> bool:
        return self.doc_values is not None and self.doc_indices is not None

    def memory_bytes(self) -> int:
        arrays = [self.term_starts, self.term_lens, self.postings_doc,
                  self.postings_val, self.term_ubs, self.term_lo,
                  self.term_hi, self.chunk_starts, self.chunk_counts]
        for opt in (self.doc_values, self.doc_indices):
            if opt is not None:
                arrays.append(opt)
        return int(sum(np.asarray(a).nbytes for a in arrays))

    def stats(self) -> Dict[str, float]:
        return {
            "doc_shards": self.doc_shards,
            "term_shards": self.term_shards,
            "n_docs": self.n_docs,
            "vocab_size": self.vocab_size,
            "local_vocab": self.local_vocab,
            "docs_per_chunk": self.docs_per_chunk,
            "max_postings": self.max_postings,
            "memory_bytes": self.memory_bytes(),
        }

    def zero_docs(self, global_ids: Sequence[int]) -> "Shard2DIndex":
        """Tombstone documents in place: zero their posting impacts in
        every cell of their doc chunk (and their forward rows). Doc
        ids in the cells are chunk-local, so each chunk masks against
        its own local slice of ``global_ids`` — the builder's
        base-removal flush path (DESIGN.md §8.4) for 2D bases."""
        dead = np.asarray(sorted(set(int(g) for g in global_ids)),
                          np.int64)
        pdoc = np.asarray(self.postings_doc)
        pval = np.asarray(self.postings_val).copy()
        starts = np.asarray(self.chunk_starts)
        bounds = self.doc_boundaries
        for i in range(self.doc_shards):
            local = dead[(dead >= bounds[i])
                         & (dead < bounds[i + 1])] - starts[i]
            if local.size:
                pval[i][np.isin(pdoc[i], local)] = 0.0
        kw = {"postings_val": jnp.asarray(pval)}
        if self.doc_values is not None:
            dv = np.asarray(self.doc_values).copy()
            dv[dead] = 0.0
            kw["doc_values"] = jnp.asarray(dv)
        return dataclasses.replace(self, **kw)


def shard2d_index(reps: SparseRep, vocab_size: int, doc_shards: int,
                  term_shards: int, *,
                  doc_boundaries: Optional[Sequence[int]] = None,
                  term_boundaries: Optional[Sequence[int]] = None,
                  balance: str = "mass",
                  keep_forward: bool = False) -> Shard2DIndex:
    """Build the (doc × term) grid from a batched corpus rep
    (host-side).

    Docs are cut into ``doc_shards`` contiguous chunks (default: even
    chunks of ``ceil(N / D)``; pass ``doc_boundaries`` for uneven
    ones), the vocabulary into ``term_shards`` ranges (default cut by
    posting mass — ``balance="mass"`` — or evenly with
    ``balance="width"``; explicit ``term_boundaries`` win). Every
    (chunk, range) cell is indexed independently via
    ``build_inverted_index(vocab_range=...)`` over the chunk's rows —
    local term ids AND local doc ids — then padded to the widest cell.

    ``keep_forward=True`` stores the (N, K) forward rows once (global
    term ids, global doc rows), enabling the two-tier pruned path.
    """
    if doc_shards < 1 or term_shards < 1:
        raise ValueError(f"shard counts must be >= 1, got "
                         f"{doc_shards}x{term_shards}")
    if term_shards > vocab_size:
        raise ValueError(f"term_shards={term_shards} exceeds vocab "
                         f"size {vocab_size}")
    if balance not in ("mass", "width"):
        raise ValueError(f"balance must be 'mass' or 'width', got "
                         f"{balance!r}")

    from repro.retrieval.sparse_rep import device_get

    host = device_get(reps) if isinstance(reps.values, jax.Array) else reps
    kw = host.width
    v = np.asarray(host.values, np.float32).reshape(-1, kw)
    i = np.asarray(host.indices, np.int32).reshape(-1, kw)
    n = np.asarray(host.nnz, np.int32).reshape(-1)
    n_docs = v.shape[0]
    if doc_shards > n_docs:
        raise ValueError(
            f"doc_shards={doc_shards} exceeds corpus size {n_docs}")

    if doc_boundaries is None:
        dps = -(-n_docs // doc_shards)
        doc_boundaries = [min(s * dps, n_docs)
                          for s in range(doc_shards + 1)]
        doc_boundaries[-1] = n_docs
    doc_bounds = _validate_boundaries(doc_boundaries, doc_shards,
                                      n_docs, "doc_boundaries")

    if term_boundaries is None:
        if balance == "mass":
            counts = np.bincount(i[v > 0].ravel(),
                                 minlength=vocab_size)
            term_boundaries = mass_balanced_boundaries(counts,
                                                       term_shards)
        else:
            term_boundaries = [s * vocab_size // term_shards
                               for s in range(term_shards + 1)]
    term_bounds = _validate_boundaries(term_boundaries, term_shards,
                                       vocab_size, "term_boundaries")

    cells = []      # (D, T) grid of per-cell InvertedIndex
    for d in range(doc_shards):
        lo_d, hi_d = doc_bounds[d], doc_bounds[d + 1]
        chunk = SparseRep(v[lo_d:hi_d], i[lo_d:hi_d], n[lo_d:hi_d])
        cells.append([build_inverted_index(
            chunk, vocab_size,
            vocab_range=(term_bounds[t], term_bounds[t + 1]),
            stopword_warn_frac=1.1) for t in range(term_shards)])

    v_loc = max(c.vocab_size for row in cells for c in row)
    p_max = max(c.n_postings for row in cells for c in row)
    dpc = max(b - a for a, b in zip(doc_bounds, doc_bounds[1:]))
    D, T = doc_shards, term_shards
    starts = np.zeros((D, T, v_loc), np.int32)
    lens = np.zeros((D, T, v_loc), np.int32)
    ubs = np.zeros((D, T, v_loc), np.float32)
    pdoc = np.zeros((D, T, p_max), np.int32)
    pval = np.zeros((D, T, p_max), np.float32)
    for d in range(D):
        for t in range(T):
            c = cells[d][t]
            starts[d, t, :c.vocab_size] = np.asarray(c.term_starts)
            lens[d, t, :c.vocab_size] = np.asarray(c.term_lens)
            ubs[d, t, :c.vocab_size] = np.asarray(c.term_ubs)
            pdoc[d, t, :c.n_postings] = np.asarray(c.postings_doc)
            pval[d, t, :c.n_postings] = np.asarray(c.postings_val)

    return Shard2DIndex(
        term_starts=jnp.asarray(starts),
        term_lens=jnp.asarray(lens),
        postings_doc=jnp.asarray(pdoc),
        postings_val=jnp.asarray(pval),
        term_ubs=jnp.asarray(ubs),
        term_lo=jnp.asarray(term_bounds[:-1], dtype=jnp.int32),
        term_hi=jnp.asarray(term_bounds[1:], dtype=jnp.int32),
        chunk_starts=jnp.asarray(doc_bounds[:-1], dtype=jnp.int32),
        chunk_counts=jnp.asarray(
            np.diff(np.asarray(doc_bounds)).astype(np.int32)),
        doc_shards=D,
        term_shards=T,
        n_docs=n_docs,
        vocab_size=vocab_size,
        local_vocab=v_loc,
        docs_per_chunk=dpc,
        max_postings=max(c.max_postings for row in cells for c in row),
        term_boundaries=term_bounds,
        doc_boundaries=doc_bounds,
        doc_values=jnp.asarray(v) if keep_forward else None,
        doc_indices=jnp.asarray(i) if keep_forward else None,
    )


# ---------------------------------------------------------------------------
# scoring — psum over the term axis, then top-k merge over the doc axis
# ---------------------------------------------------------------------------

def _route(qv: Array, qi: Array, lo: Array, hi: Array,
           local_vocab: int) -> Tuple[Array, Array]:
    """Mask the query's active terms to one vocab range and remap to
    local ids (same contract as term_sharded._route: masked slots
    carry value 0 and contribute exactly 0 to the partials)."""
    in_range = (qi >= lo) & (qi < hi)
    lqv = jnp.where(in_range, qv, 0.0)
    lqi = jnp.clip(qi - lo, 0, local_vocab - 1)
    return lqv, lqi


def _cell_index(st: Array, ln: Array, pd: Array, pv: Array,
                index: Shard2DIndex, ubs: Optional[Array] = None
                ) -> InvertedIndex:
    return InvertedIndex(
        term_starts=st, term_lens=ln, postings_doc=pd, postings_val=pv,
        n_docs=index.docs_per_chunk, vocab_size=index.local_vocab,
        max_postings=index.max_postings, term_ubs=ubs)


def _cell_partial(qv: Array, qi: Array, st: Array, ln: Array,
                  pd: Array, pv: Array, lo: Array, hi: Array,
                  index: Shard2DIndex) -> Array:
    """(B, docs_per_chunk) PARTIAL scores of one grid cell — the
    contribution of vocab range [lo, hi) to its doc chunk."""
    from repro.retrieval.score import impact_scores

    lqv, lqi = _route(qv, qi, lo, hi, index.local_vocab)
    rep = SparseRep(lqv, lqi,
                    jnp.sum((lqv > 0).astype(jnp.int32), axis=-1))
    return impact_scores(rep, _cell_index(st, ln, pd, pv, index))


def _cell_ub_partial(qv: Array, qi: Array, st: Array, ln: Array,
                     pd: Array, pv: Array, ubs: Array, lo: Array,
                     hi: Array, index: Shard2DIndex) -> Array:
    """(B, docs_per_chunk) partial MaxScore ceilings of one cell."""
    from repro.retrieval.engine.pruning import upper_bound_scores

    lqv, lqi = _route(qv, qi, lo, hi, index.local_vocab)
    rep = SparseRep(lqv, lqi,
                    jnp.sum((lqv > 0).astype(jnp.int32), axis=-1))
    return upper_bound_scores(
        rep, _cell_index(st, ln, pd, pv, index, ubs))


def _grid_map(fn, index: Shard2DIndex, with_ubs: bool = False):
    """vmap ``fn`` over both grid axes -> (D, T, B, docs_per_chunk)."""
    args = [index.term_starts, index.term_lens, index.postings_doc,
            index.postings_val]
    if with_ubs:
        args.append(index.term_ubs)
    over_t = jax.vmap(fn, in_axes=tuple([0] * len(args)) + (0, 0))
    over_d = jax.vmap(
        lambda *cell: over_t(*cell, index.term_lo, index.term_hi),
        in_axes=tuple([0] * len(args)))
    return over_d(*args)


def _mask_pad(chunk_scores: Array, counts: Array, dpc: int) -> Array:
    """NEG_INF the padded tail of every chunk: (D, B, dpc) -> same."""
    local = jnp.arange(dpc, dtype=jnp.int32)
    return jnp.where(local[None, None, :] < counts[:, None, None],
                     chunk_scores, NEG_INF)


def _scatter_global(chunk_vals: Array, starts: Array, n_docs: int
                    ) -> Array:
    """(D, B, dpc) NEG_INF-padded chunk values -> (B, n_docs) global
    rows.

    Chunks are contiguous but possibly uneven, so the flattened
    (D * dpc) position is NOT the global id — scatter through each
    chunk's start offset instead (padded slots land on a clipped
    position with NEG_INF and lose the scatter-max)."""
    d, b, dpc = chunk_vals.shape
    local = jnp.arange(dpc, dtype=jnp.int32)
    pos = starts[:, None] + local[None, :]              # (D, dpc)
    pos = jnp.clip(pos, 0, n_docs - 1).reshape(-1)
    flat = jnp.moveaxis(chunk_vals, 1, 0).reshape(b, -1)
    out = jnp.full((b, n_docs), NEG_INF, chunk_vals.dtype)
    return out.at[:, pos].max(flat)


@functools.partial(jax.jit, static_argnames=("k",))
def _vmap_retrieve(qv: Array, qi: Array, index: Shard2DIndex, k: int
                   ) -> Tuple[Array, Array]:
    """Single-device path: the whole grid under nested vmaps — sum
    over the term axis (the psum algebra), NEG_INF-mask chunk padding,
    then one global top-k over the flattened doc axis. Flattened
    positions are monotone in global id, so lax.top_k's lowest-index
    tie-break matches the unsharded scorer."""
    partials = _grid_map(
        lambda st, ln, pd, pv, lo, hi: _cell_partial(
            qv, qi, st, ln, pd, pv, lo, hi, index),
        index)                                      # (D, T, B, dpc)
    chunks = _mask_pad(jnp.sum(partials, axis=1),
                       index.chunk_counts, index.docs_per_chunk)
    b = qv.shape[0]
    flat = jnp.moveaxis(chunks, 1, 0).reshape(b, -1)    # (B, D*dpc)
    local = jnp.arange(index.docs_per_chunk, dtype=jnp.int32)
    gids = (index.chunk_starts[:, None] + local[None, :]).reshape(-1)
    vals, pos = jax.lax.top_k(flat, k)
    return vals, gids[pos].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "candidates"))
def _vmap_pruned_retrieve(queries: SparseRep, index: Shard2DIndex,
                          k: int, candidates: int, prune_margin: Array
                          ) -> Tuple[Array, Array, Array]:
    from repro.retrieval.engine.pruning import select_and_rescore

    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)
    ub_partials = _grid_map(
        lambda st, ln, pd, pv, ubs, lo, hi: _cell_ub_partial(
            qv, qi, st, ln, pd, pv, ubs, lo, hi, index),
        index, with_ubs=True)                       # (D, T, B, dpc)
    chunks = _mask_pad(jnp.sum(ub_partials, axis=1),
                       index.chunk_counts, index.docs_per_chunk)
    ub = _scatter_global(chunks, index.chunk_starts, index.n_docs)
    return select_and_rescore(ub, queries, index.doc_values,
                              index.doc_indices, index.vocab_size,
                              k, candidates, prune_margin)


def shard2d_retrieve(
    queries: SparseRep,
    index: Shard2DIndex,
    k: int = 10,
    *,
    mesh=None,
    plan: Optional[ShardPlan] = None,
    prune_margin: Optional[float] = None,
    candidates: Optional[int] = None,
) -> Tuple[Array, Array]:
    """Top-k over the 2D grid; ids are global doc ids, pinned
    id-identical to ``method="impact"`` at every grid shape.

    Exact by default: per-cell partials are psum'd over the term axis
    into exact chunk scores, per-chunk winners are all_gathered over
    the doc axis and re-top-k'd. With ``prune_margin`` the two-tier
    composition runs instead (module docstring) and needs forward rows
    (``keep_forward=True`` at build).

    ``mesh`` must carry both logical axes; ``plan.axis_order`` maps
    them onto the mesh's first two axis names (default: mesh axis 0 =
    doc, axis 1 = term). ``mesh=None`` computes the same thing under
    nested vmaps on one device.
    """
    k = min(k, index.n_docs)
    qv = queries.values.reshape(-1, queries.width).astype(jnp.float32)
    qi = queries.indices.reshape(-1, queries.width)

    prune = prune_margin is not None
    if prune:
        if not index.has_forward:
            raise ValueError(
                "shard2d_retrieve: pruning needs forward rows — build "
                "with shard2d_index(..., keep_forward=True)")
        if not 0.0 <= prune_margin <= 1.0:
            raise ValueError(f"prune_margin must be in [0, 1], got "
                             f"{prune_margin}")
        if candidates is None:
            candidates = max(4 * k, 64)
        candidates = min(max(candidates, k), index.n_docs)
        margin = jnp.float32(prune_margin)

    if mesh is None:
        if prune:
            vals, idx, _ = _vmap_pruned_retrieve(
                queries, index, k, candidates, margin)
            return vals, idx
        return _vmap_retrieve(qv, qi, index, k)

    order = plan.axis_order if plan is not None else ("doc", "term")
    if plan is not None and (plan.doc_shards, plan.term_shards) != (
            index.doc_shards, index.term_shards):
        raise ValueError(
            f"plan grid {plan.doc_shards}x{plan.term_shards} does not "
            f"match index grid {index.doc_shards}x{index.term_shards}")
    sizes = tuple(index.doc_shards if a == "doc" else index.term_shards
                  for a in order)
    mesh_axes = resolve_mesh_axes(mesh, None, sizes,
                                  what="shard2d_retrieve")
    doc_axis = mesh_axes[order.index("doc")]
    term_axis = mesh_axes[order.index("term")]

    from jax.sharding import PartitionSpec as P

    # stacked grid arrays split (doc, term) on their two leading dims;
    # the 1D range/chunk arrays split on their own axis only
    grid_spec = P(doc_axis, term_axis)
    in_specs = (grid_spec,) * 4 + (P(term_axis),) * 2 + (P(doc_axis),) * 2
    dpc = index.docs_per_chunk
    kk = min(k, dpc)

    if prune:
        doc_values, doc_indices = index.doc_values, index.doc_indices
        n_docs = index.n_docs

        def body(st, ln, pd, pv, ubs, lo, hi, cst, cct):
            from repro.retrieval.engine.pruning import select_and_rescore

            partial = _cell_ub_partial(
                qv, qi, st[0, 0], ln[0, 0], pd[0, 0], pv[0, 0],
                ubs[0, 0], lo[0], hi[0], index)       # (B, dpc)
            chunk_ub = jax.lax.psum(partial, term_axis)
            local = jnp.arange(dpc, dtype=jnp.int32)
            chunk_ub = jnp.where(local[None, :] < cct[0], chunk_ub,
                                 NEG_INF)
            all_ub = jax.lax.all_gather(chunk_ub, doc_axis, axis=0)
            all_st = jax.lax.all_gather(cst[0], doc_axis, axis=0)
            ub = _scatter_global(all_ub, all_st, n_docs)
            rep = SparseRep(qv, qi,
                            jnp.sum((qv > 0).astype(jnp.int32),
                                    axis=-1))
            vals, idx, _ = select_and_rescore(
                ub, rep, doc_values, doc_indices, index.vocab_size,
                k, candidates, margin)
            return vals, idx

        merged = shard_mapped(
            body, mesh, None, n_in=9,
            in_specs=(grid_spec,) * 4 + (grid_spec,)
            + (P(term_axis),) * 2 + (P(doc_axis),) * 2)
        vals, idx = merged(index.term_starts, index.term_lens,
                           index.postings_doc, index.postings_val,
                           index.term_ubs, index.term_lo,
                           index.term_hi, index.chunk_starts,
                           index.chunk_counts)
        return vals, idx.astype(jnp.int32)

    def body(st, ln, pd, pv, lo, hi, cst, cct):
        partial = _cell_partial(qv, qi, st[0, 0], ln[0, 0], pd[0, 0],
                                pv[0, 0], lo[0], hi[0], index)
        total = jax.lax.psum(partial, term_axis)      # exact chunk
        local = jnp.arange(dpc, dtype=jnp.int32)
        total = jnp.where(local[None, :] < cct[0], total, NEG_INF)
        lv, li = jax.lax.top_k(total, kk)
        gi = li + cst[0]                              # -> global ids
        all_v = jax.lax.all_gather(lv, doc_axis, axis=1, tiled=True)
        all_i = jax.lax.all_gather(gi, doc_axis, axis=1, tiled=True)
        mv, pos = jax.lax.top_k(all_v, k)
        return mv, jnp.take_along_axis(all_i, pos, axis=1)

    merged = shard_mapped(body, mesh, None, n_in=8, in_specs=in_specs)
    vals, idx = merged(index.term_starts, index.term_lens,
                       index.postings_doc, index.postings_val,
                       index.term_lo, index.term_hi,
                       index.chunk_starts, index.chunk_counts)
    return vals, idx.astype(jnp.int32)
