"""Deterministic synthetic data shards for every architecture family.

No datasets ship with the container, so the data pipeline generates
deterministic, seeded, *statistically plausible* batches:

* LSR pairs — (query tokens, positive doc tokens) with Zipfian token
  ids and variable lengths (padding + mask), mimicking MS-MARCO-style
  passages.
* LM tokens — Zipfian next-token streams for causal-LM training.
* RecSys clicks — power-law categorical ids per field (the hard case
  for embedding sharding), Gaussian dense features, Bernoulli labels.
* Molecules — random 3-D point clouds with distance-cutoff edges for
  DimeNet.
* Citation-style graphs — configurable power-law degree graphs for the
  full-graph / sampled GNN shapes.

Everything is host-side numpy (like a real input pipeline: CPU workers
feed the accelerator), seeded per (shard, step) so multi-host loaders
produce disjoint, reproducible streams.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np


def _rng(seed: int, shard: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, shard, step]))


def _zipf_ids(rng, size, vocab: int, a: float = 1.3) -> np.ndarray:
    """Zipf-distributed ids in [0, vocab) — heavy head like real text."""
    raw = rng.zipf(a, size=size)
    return np.clip(raw - 1, 0, vocab - 1).astype(np.int32)


def lsr_pair_batches(
    *,
    batch: int,
    q_len: int,
    d_len: int,
    vocab: int,
    seed: int = 0,
    shard: int = 0,
    min_frac: float = 0.3,
) -> Iterator[Dict[str, np.ndarray]]:
    """(query, positive-doc) token batches with masks, SPLADE-style."""
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        q_tok = _zipf_ids(rng, (batch, q_len), vocab)
        d_tok = _zipf_ids(rng, (batch, d_len), vocab)
        q_n = rng.integers(int(q_len * min_frac), q_len + 1, size=batch)
        d_n = rng.integers(int(d_len * min_frac), d_len + 1, size=batch)
        q_mask = (np.arange(q_len)[None] < q_n[:, None]).astype(np.int32)
        d_mask = (np.arange(d_len)[None] < d_n[:, None]).astype(np.int32)
        # overlap positives: splice some query tokens into the doc so
        # the contrastive task is learnable
        n_copy = max(1, q_len // 2)
        d_tok[:, :n_copy] = q_tok[:, :n_copy]
        yield {
            "q_tokens": q_tok, "q_mask": q_mask,
            "d_tokens": d_tok * d_mask, "d_mask": d_mask,
        }
        step += 1


def lsr_impact_corpus(
    *,
    n_docs: int,
    vocab: int,
    doc_nnz: int,
    n_queries: int = 0,
    q_nnz: int = 16,
    graded: int = 12,
    seed: int = 0,
    term_jitter: float = 0.04,
) -> Dict[str, np.ndarray]:
    """Synthetic LSR impact matrices with graded relevance structure —
    the retrieval-engine benchmark corpus.

    Two properties real LSR corpora have and pure-random matrices
    lack:

    * **Per-term concentrated impacts.** A term's weight is IDF-like
      across the documents activating it: term t gets a center ``c_t
      ~ U(0.5, 2.0)`` and background postings draw ``c_t * (1 +
      U(-j, +j))`` (``j = term_jitter``), so per-term affine
      quantization (``engine/quantize``) sees a tight range.
    * **Graded relevant documents.** Per query, ``graded`` planted
      docs share a strictly shrinking prefix of the query's terms
      (``q_nnz - 2i`` terms for grade i) at normal per-term impacts —
      so the top-``k`` ranking (for ``k <= graded - 2``) has
      two-whole-terms score gaps between consecutive grades, far
      above fp/quantization noise, making cross-method id-parity
      assertions meaningful rather than coin flips on near-ties.
      (TREC-style graded qrels, in synthetic form.)

    Documents activate ``doc_nnz`` uniform-random distinct terms
    (planted docs: the shared prefix + random fillers). Returns
    ``{"docs": (n_docs, vocab) f32[, "queries": (n_queries, vocab)
    f32, "qrels": (n_queries * graded, 3) f32]}`` — dense matrices
    (sparsify/index downstream) plus explicit ``(query, doc, grade)``
    judgment triples for the planted docs (grade ``graded - i`` for
    plant i, so higher grade = longer shared prefix = higher exact
    score; feed to ``repro.eval.Qrels.from_triples``).
    """
    if n_queries and n_docs < n_queries * graded:
        raise ValueError(f"need n_docs >= n_queries*graded = "
                         f"{n_queries * graded}, got {n_docs}")
    if n_queries and (doc_nnz < q_nnz or q_nnz < 2 * graded + 2):
        raise ValueError("planted docs need doc_nnz >= q_nnz and "
                         "q_nnz >= 2*graded + 2")
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.5, 2.0, size=vocab).astype(np.float32)

    def impacts(cols):
        jit = rng.uniform(1 - term_jitter, 1 + term_jitter,
                          size=cols.shape[0]).astype(np.float32)
        return centers[cols] * jit

    def fill(n, nnz):
        m = np.zeros((n, vocab), np.float32)
        rows = np.repeat(np.arange(n), nnz)
        cols = np.stack([rng.choice(vocab, size=nnz, replace=False)
                         for _ in range(n)]).ravel()
        m[rows, cols] = impacts(cols)
        return m

    docs = fill(n_docs, doc_nnz)
    out = {"docs": docs}
    if n_queries:
        queries = np.zeros((n_queries, vocab), np.float32)
        triples = []
        for b in range(n_queries):
            q_terms = rng.choice(vocab, size=q_nnz, replace=False)
            queries[b, q_terms] = impacts(q_terms)
            # fillers must avoid *every* query term, not just the
            # doc's own shared prefix — otherwise a low-grade plant
            # can randomly pick up dropped query terms and outscore a
            # higher grade, breaking the two-whole-term gap invariant
            pool = np.setdiff1d(np.arange(vocab), q_terms,
                                assume_unique=False)
            for i in range(graded):
                d = b * graded + i
                shared = q_terms[:q_nnz - 2 * i]
                docs[d] = 0.0
                docs[d, shared] = impacts(shared)
                cols = rng.choice(pool, size=doc_nnz - shared.shape[0],
                                  replace=False)
                docs[d, cols] = impacts(cols)
                triples.append((b, d, graded - i))
        out["queries"] = queries
        out["qrels"] = np.asarray(triples, np.float32)
    return out


def lm_token_batches(
    *, batch: int, seq_len: int, vocab: int, seed: int = 0, shard: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        tok = _zipf_ids(rng, (batch, seq_len + 1), vocab)
        yield {
            "tokens": tok[:, :-1],
            "labels": tok[:, 1:],
            "mask": np.ones((batch, seq_len), np.int32),
        }
        step += 1


def recsys_batches(
    *,
    batch: int,
    n_dense: int,
    n_sparse: int,
    table_sizes: Sequence[int],
    seq_len: int = 0,
    seed: int = 0,
    shard: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        out: Dict[str, np.ndarray] = {
            "label": rng.binomial(1, 0.25, size=batch).astype(np.float32),
        }
        if n_dense:
            out["dense"] = rng.normal(size=(batch, n_dense)).astype(
                np.float32)
        if seq_len:  # DIEN
            rows = table_sizes[0]
            out["hist_idx"] = _zipf_ids(rng, (batch, seq_len), rows)
            out["target_idx"] = _zipf_ids(rng, (batch,), rows)
        else:
            cols = [
                _zipf_ids(rng, (batch,), rows) for rows in table_sizes
            ]
            out["sparse_idx"] = np.stack(cols, axis=1)
        yield out
        step += 1


def make_synthetic_graph(
    n_nodes: int, n_edges: int, *, seed: int = 0,
    power_law: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Random (src, dst) edge lists; power-law dst to mimic citation
    hubs (the regime that makes triplet counting explode)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int64)
    if power_law:
        ranks = rng.zipf(1.5, size=n_edges)
        dst = np.clip(ranks - 1, 0, n_nodes - 1).astype(np.int64)
        dst = (dst * 2654435761 % n_nodes).astype(np.int64)  # de-cluster
    else:
        dst = rng.integers(0, n_nodes, size=n_edges).astype(np.int64)
    keep = src != dst
    return src[keep], dst[keep]


def molecule_batches(
    *,
    n_graphs: int,
    nodes_per_graph: int,
    edges_per_graph: int,
    n_atom_types: int = 95,
    cutoff: float = 5.0,
    seed: int = 0,
    shard: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Batched random molecules: 3-D positions, cutoff-radius edges
    (capped at edges_per_graph), graph-level scalar targets."""
    step = 0
    while True:
        rng = _rng(seed, shard, step)
        N = n_graphs * nodes_per_graph
        pos = rng.uniform(0, cutoff * 1.2,
                          size=(n_graphs, nodes_per_graph, 3))
        feats = rng.integers(0, n_atom_types, size=N).astype(np.int32)

        srcs, dsts = [], []
        for g in range(n_graphs):
            d = np.linalg.norm(
                pos[g][:, None] - pos[g][None], axis=-1)
            np.fill_diagonal(d, np.inf)
            cand = np.argwhere(d < cutoff)
            if len(cand) > edges_per_graph:
                sel = rng.choice(len(cand), edges_per_graph, replace=False)
                cand = cand[sel]
            base = g * nodes_per_graph
            srcs.append(cand[:, 0] + base)
            dsts.append(cand[:, 1] + base)
        src = np.concatenate(srcs).astype(np.int32)
        dst = np.concatenate(dsts).astype(np.int32)

        E_cap = n_graphs * edges_per_graph
        e_mask = np.zeros(E_cap, np.int32)
        e_mask[:len(src)] = 1
        src_p = np.zeros(E_cap, np.int32)
        dst_p = np.zeros(E_cap, np.int32)
        src_p[:len(src)] = src
        dst_p[:len(dst)] = dst

        yield {
            "positions": pos.reshape(N, 3).astype(np.float32),
            "node_feat": feats,
            "node_mask": np.ones(N, np.int32),
            "node_graph_id": np.repeat(
                np.arange(n_graphs, dtype=np.int32), nodes_per_graph),
            "edge_src": src_p, "edge_dst": dst_p, "edge_mask": e_mask,
            "target": rng.normal(size=(n_graphs, 1)).astype(np.float32),
        }
        step += 1
