"""Test-session bootstrap.

* Puts ``src/`` on ``sys.path`` so ``repro`` imports work without the
  caller exporting PYTHONPATH.
* Registers the deterministic ``hypothesis`` stand-in from
  ``tests/_hypothesis_stub.py`` when the real package is not installed
  (the container image has no hypothesis wheel and pip installs are
  forbidden). Tests import ``hypothesis`` unchanged either way.
"""

import os
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))

@pytest.fixture(autouse=True)
def _isolated_sparton_autotune_cache(tmp_path, monkeypatch):
    """Hermetic tests: block_*=None kernel paths must resolve against a
    fresh cache, never the developer's ~/.cache/sparton winners."""
    monkeypatch.setenv("SPARTON_AUTOTUNE_CACHE",
                       str(tmp_path / "sparton_autotune.json"))


try:
    import hypothesis  # noqa: F401  (prefer the real package)
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_stub import make_module

    mod = make_module()
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies
