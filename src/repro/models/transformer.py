"""Functional transformer stack (params = pytrees, apply = functions).

Covers the five assigned LM-family architectures plus the paper's own
SPLADE encoders:

* dense GQA decoders (llama3.2-3b, phi3-mini),
* local/global alternating attention + logit softcaps (gemma2-27b),
* MoE trunks (moonshot-v1-16b-a3b: 64e top-6; phi3.5-moe: 16e top-2),
* bidirectional encoders for SPLADE (bert / xlm-roberta backbones).

Layers are *stacked* (every leaf carries a leading ``n_layers`` dim)
and applied with ``lax.scan`` + optional ``jax.checkpoint`` so that the
HLO stays compact for 512-device SPMD compilation and activation
memory stays O(sqrt)-ish under remat.

Heads:
* ``lsr_encode``     — backbone + **Sparton head** (the paper): returns
  ``(B, V)`` sparse lexical vectors.
* ``causal_lm_logits`` / decode path — standard next-token logits.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.models.attention import (apply_rope, chunked_attention,
                                    decode_attention)
from repro.models.moe import (init_moe_params, moe_ffn,
                             moe_ffn_local_experts)

Array = jax.Array
MoeShard = Optional[Tuple[Tuple[str, ...], str]]  # (token_axes, expert_axis)


def _apply_moe(x2d: Array, mlp: Params, cfg: TransformerConfig,
               moe_shard: MoeShard) -> Tuple[Array, Array]:
    """MoE FFN: local (single device) or expert-parallel shard_map."""
    if moe_shard is None:
        return moe_ffn(
            x2d, mlp["router"], mlp["w_gate"], mlp["w_up"], mlp["w_down"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
    token_axes, expert_axis = moe_shard
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    body = functools.partial(
        moe_ffn_local_experts,
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        expert_axis=expert_axis, token_axes=token_axes)
    fn = shard_map(
        body, mesh=None,
        in_specs=(P(token_axes, None), P(None, None),
                  P(expert_axis, None, None), P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=(P(token_axes, None), P()),
    )
    return fn(x2d, mlp["router"], mlp["w_gate"], mlp["w_up"],
              mlp["w_down"])
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: TransformerConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab_size
    H, KV, dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_ff
    keys = jax.random.split(key, 12)
    sc_d = D ** -0.5
    sc_a = (H * dh) ** -0.5
    sc_f = F ** -0.5

    attn = {
        "wq": jax.random.normal(keys[0], (L, D, H * dh), dtype) * sc_d,
        "wk": jax.random.normal(keys[1], (L, D, KV * dh), dtype) * sc_d,
        "wv": jax.random.normal(keys[2], (L, D, KV * dh), dtype) * sc_d,
        "wo": jax.random.normal(keys[3], (L, H * dh, D), dtype) * sc_a,
    }
    if cfg.is_moe:
        mlp = init_moe_params(keys[4], L, D, F, cfg.n_experts, dtype)
    else:
        mlp = {
            "w_gate": jax.random.normal(keys[5], (L, D, F), dtype) * sc_d,
            "w_up": jax.random.normal(keys[6], (L, D, F), dtype) * sc_d,
            "w_down": jax.random.normal(keys[7], (L, F, D), dtype) * sc_f,
        }
    params: Params = {
        "embed": jax.random.normal(keys[8], (V, D), dtype) * sc_d,
        "layers": {
            "attn": attn,
            "mlp": mlp,
            "ln1": jnp.ones((L, D), dtype),
            "ln2": jnp.ones((L, D), dtype),
        },
        "final_norm": jnp.ones((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "E": jax.random.normal(keys[9], (V, D), dtype) * sc_d,
            "b": jnp.zeros((V,), jnp.float32),
        }
    else:
        params["lm_head"] = {"b": jnp.zeros((V,), jnp.float32)}
    return params


def head_weights(params: Params, cfg: TransformerConfig):
    E = params["embed"] if cfg.tie_embeddings else params["lm_head"]["E"]
    return E, params["lm_head"]["b"]


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def _layer(
    x: Array,                 # (B, S, D)
    lp: Params,               # one layer's params (leading L dim removed)
    cfg: TransformerConfig,
    *,
    positions: Array,         # (S,)
    mask: Array,              # (B, S)
    causal: bool,
    window: Optional[int],
    moe_shard: MoeShard = None,
) -> Tuple[Array, Array]:
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    cdtype = jnp.dtype(cfg.compute_dtype)

    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = (h @ lp["attn"]["wq"].astype(cdtype)).reshape(B, S, H, dh)
    k = (h @ lp["attn"]["wk"].astype(cdtype)).reshape(B, S, KV, dh)
    v = (h @ lp["attn"]["wv"].astype(cdtype)).reshape(B, S, KV, dh)
    pos2d = jnp.broadcast_to(positions[None], (B, S))
    q = apply_rope(q, pos2d, cfg.rope_theta)
    k = apply_rope(k, pos2d, cfg.rope_theta)
    attn_out = chunked_attention(
        q, k, v,
        q_positions=positions, k_positions=positions, kv_mask=mask,
        causal=causal, window=window,
        logit_softcap=cfg.attn_logit_softcap,
        chunk_size=cfg.attn_chunk,
        unroll=cfg.attn_unroll,
    )
    x = x + attn_out.reshape(B, S, H * dh) @ lp["attn"]["wo"].astype(cdtype)

    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        out, aux = _apply_moe(h.reshape(B * S, D), lp["mlp"], cfg,
                              moe_shard)
        x = x + out.reshape(B, S, D)
    else:
        g = h @ lp["mlp"]["w_gate"].astype(cdtype)
        u = h @ lp["mlp"]["w_up"].astype(cdtype)
        x = x + (jax.nn.silu(g) * u) @ lp["mlp"]["w_down"].astype(cdtype)
        aux = jnp.zeros((), jnp.float32)
    return x, aux


# ---------------------------------------------------------------------------
# trunk forward (scan over stacked layers)
# ---------------------------------------------------------------------------

def forward_hidden(
    params: Params,
    cfg: TransformerConfig,
    tokens: Array,            # (B, S) int32
    mask: Optional[Array] = None,
    *,
    causal: Optional[bool] = None,
    moe_shard: MoeShard = None,
    unroll: int = 1,
) -> Tuple[Array, Array]:
    """Returns (H (B, S, D) in compute dtype, aux_loss scalar).

    ``unroll``: lax.scan unroll factor over layers. The dry-run uses
    full unroll so ``cost_analysis()`` counts every layer (a rolled
    scan reports its body cost only once); runtime uses 1."""
    B, S = tokens.shape
    cdtype = jnp.dtype(cfg.compute_dtype)
    if mask is None:
        mask = jnp.ones((B, S), jnp.int32)
    if causal is None:
        causal = not cfg.bidirectional_encoder
    positions = jnp.arange(S, dtype=jnp.int32)

    x = jnp.take(params["embed"], tokens, axis=0).astype(cdtype)

    def scan_body(carry, xs):
        x, aux = carry
        lp, layer_idx = xs

        def run(window):
            return _layer(x, lp, cfg, positions=positions, mask=mask,
                          causal=causal, window=window,
                          moe_shard=moe_shard)

        if cfg.local_global_alternating and cfg.sliding_window:
            # even layers local (sliding window), odd layers global —
            # static branch impossible inside scan => lax.cond.
            x2, aux2 = jax.lax.cond(
                layer_idx % 2 == 0,
                lambda: run(cfg.sliding_window),
                lambda: run(None),
            )
        else:
            x2, aux2 = run(cfg.sliding_window)
        return (x2, aux + aux2), None

    body = scan_body
    if cfg.remat:
        body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)
    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], layer_ids), unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# heads
# ---------------------------------------------------------------------------

def lsr_encode(
    params: Params,
    cfg: TransformerConfig,
    tokens: Array,
    mask: Array,
    *,
    head_impl: Optional[str] = None,
) -> Tuple[Array, Array]:
    """SPLADE-style sparse encoding: backbone + Sparton head (Eq. 1).

    The head is built through the unified registry (``core.head_api``),
    so ``head_impl`` accepts any registered backend — including
    ``"kernel"`` — and defaults to the config's choice. Returns
    ((B, V) sparse lexical reps, aux_loss).
    """
    from repro.core.head_api import make_head

    spec = cfg.head_spec() if head_impl is None \
        else cfg.head_spec(impl=head_impl)
    head = make_head(spec)
    Hs, aux = forward_hidden(params, cfg, tokens, mask)
    E, b = head_weights(params, cfg)
    y = head(Hs, E.astype(Hs.dtype), b, mask)
    return y, aux


def causal_lm_logits(
    params: Params, cfg: TransformerConfig, tokens: Array,
    mask: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """(B, S, V) next-token logits (standard LM head, softcap applied)."""
    Hs, aux = forward_hidden(params, cfg, tokens, mask, causal=True)
    E, b = head_weights(params, cfg)
    logits = jnp.einsum("bsd,vd->bsv", Hs, E.astype(Hs.dtype)) + b
    if cfg.final_logit_softcap is not None:
        cap = cfg.final_logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits, aux


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int,
                  dtype=None) -> Dict[str, Array]:
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(
    params: Params,
    cfg: TransformerConfig,
    cache: Dict[str, Array],
    tokens: Array,        # (B, 1) int32 — the newest token
    positions: Array,     # (B,) int32 — its position (0-based)
    moe_shard: MoeShard = None,
) -> Tuple[Array, Dict[str, Array]]:
    """One autoregressive step. Returns ((B, V) logits, updated cache).

    The layer loop is *unrolled* (python loop, not scan) and the cache
    stays one stacked buffer updated in place per layer: with the cache
    donated, XLA chains the dynamic-update-slices on a single buffer —
    a scan would return stacked cache outputs and force a second full
    cache allocation (measured ~2.7x cache bytes in temps on the
    decode_32k dry-run cell).
    """
    B = tokens.shape[0]
    cdtype = jnp.dtype(cfg.compute_dtype)
    H, KV, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model

    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(cdtype)
    x = x[:, None, :]  # (B, 1, D)

    k_all, v_all = cache["k"], cache["v"]
    bidx = jnp.arange(B)

    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[layer], params["layers"])
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = (h @ lp["attn"]["wq"].astype(cdtype)).reshape(B, 1, H, dh)
        k = (h @ lp["attn"]["wk"].astype(cdtype)).reshape(B, 1, KV, dh)
        v = (h @ lp["attn"]["wv"].astype(cdtype)).reshape(B, 1, KV, dh)
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k = apply_rope(k, positions[:, None], cfg.rope_theta)
        # write new k/v at `positions` (in place on the stacked buffer)
        k_all = k_all.at[layer, bidx, positions].set(k[:, 0])
        v_all = v_all.at[layer, bidx, positions].set(v[:, 0])

        if cfg.local_global_alternating and cfg.sliding_window:
            window = cfg.sliding_window if layer % 2 == 0 else None
        else:
            window = cfg.sliding_window
        attn_out = decode_attention(
            q, k_all[layer], v_all[layer], positions=positions,
            window=window, logit_softcap=cfg.attn_logit_softcap)
        x = x + attn_out.reshape(B, 1, H * dh) @ lp["attn"]["wo"].astype(cdtype)

        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            out, _ = _apply_moe(h.reshape(B, D), lp["mlp"], cfg,
                                moe_shard)
            x = x + out.reshape(B, 1, D)
        else:
            g = h @ lp["mlp"]["w_gate"].astype(cdtype)
            u = h @ lp["mlp"]["w_up"].astype(cdtype)
            x = x + (jax.nn.silu(g) * u) @ lp["mlp"]["w_down"].astype(cdtype)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    E, b = head_weights(params, cfg)
    logits = (x[:, 0, :] @ E.astype(x.dtype).T) + b
    if cfg.final_logit_softcap is not None:
        cap = cfg.final_logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits, {"k": k_all, "v": v_all}
