"""Serving example: batched sparse encoding + two-stage retrieval.

1. Index a synthetic corpus with the Sparton head (document side).
2. Serve queries through the deadline/size micro-batching loop.
3. Retrieve top-k: dense scoring for small corpora and the fused
   streaming top-k (the Sparton-idea transfer) for the 1M-candidate
   regime — here demonstrated on the kernel's interpret mode.

Run:  PYTHONPATH=src python examples/serve_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.topk_score import topk_score
from repro.launch.steps import init_state, streaming_topk
from repro.runtime.serving import (BatchedEncoder, BatchPolicy, Request,
                                   ServingLoop, make_config_encoder,
                                   retrieve_topk)

CORPUS, QUERIES, K = 512, 24, 5

cfg = get_config("splade_bert").SMOKE
state, _ = init_state("splade_bert", jax.random.PRNGKey(0), smoke=True)
params = state["params"]

# The encoder comes from the config through the unified head factory
# (core.head_api.make_head) — head_impl, blocks and logit softcap are
# all taken from cfg instead of hardcoding one implementation here.
encode = make_config_encoder(params, cfg)


rng = np.random.default_rng(0)

# --- 1. index the corpus ---------------------------------------------
doc_tokens = rng.integers(1, cfg.vocab_size, size=(CORPUS, 24))
doc_tokens = doc_tokens.astype(np.int32)
doc_reps = np.asarray(encode(jnp.asarray(doc_tokens),
                             jnp.ones((CORPUS, 24), jnp.int32)))
print(f"indexed {CORPUS} docs; "
      f"mean active dims {np.mean((doc_reps > 0).sum(1)):.0f}"
      f" / {cfg.vocab_size}")

# --- 2. serve queries through the batching loop ----------------------
loop = ServingLoop(BatchedEncoder(
    encode, policy=BatchPolicy(max_batch=8, max_wait_s=0.002)))
t0 = time.monotonic()
for uid in range(QUERIES):
    # query uid re-encodes doc uid's tokens: exact-duplicate retrieval
    # sanity (untrained weights carry no prefix semantics)
    toks = doc_tokens[uid].copy()
    loop.submit(Request(uid=uid, tokens=toks))
    loop.tick()
loop.drain()
print(f"served {len(loop.completed)} queries in "
      f"{(time.monotonic() - t0) * 1e3:.1f} ms; "
      f"batch sizes {loop.batch_sizes}")

# --- 3a. retrieval (cosine top-k over the sparse reps; untrained
# dense reps have hub documents under raw dot) --------------------------
q = np.stack([loop.completed[u] for u in range(QUERIES)])
qn = q / np.maximum(np.linalg.norm(q, axis=1, keepdims=True), 1e-9)
dn = doc_reps / np.maximum(
    np.linalg.norm(doc_reps, axis=1, keepdims=True), 1e-9)
vals, idx = retrieve_topk(jnp.asarray(qn), jnp.asarray(dn), k=K)
hits = float(np.mean(np.asarray(idx)[:, 0] == np.arange(QUERIES)))
print(f"top-1 self-retrieval rate: {hits:.2f} (exact-duplicate queries)")

# --- 3b. the 1M-candidate regime: fused streaming top-k ---------------
cand = jax.random.normal(jax.random.PRNGKey(1), (20000, 64))
qv = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
v_stream, i_stream = streaming_topk(qv, cand, k=K, tile=4096)
v_kernel, i_kernel = topk_score(qv, cand, k=K, block_b=4, block_n=2048,
                                interpret=True)
assert np.allclose(np.asarray(v_stream), np.asarray(v_kernel), atol=1e-5)
print("streaming top-k == fused Pallas kernel (interpret):",
      np.array_equal(np.asarray(i_stream), np.asarray(i_kernel)))
print("done.")
