"""Segment reductions — the message-passing primitive on TPU.

JAX has no CSR/CSC sparse matmul (BCOO only), so all graph aggregation
in this framework is expressed as *edge-index gather -> segment
reduction*, which XLA lowers to sorted-scatter updates. These wrappers
add the conveniences the models need (mean with degree clamping, max
with argmax for sparton-style gradient routing, softmax over incoming
edges for attention-style aggregations).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def segment_sum(data: Array, segment_ids: Array, num_segments: int) -> Array:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_mean(data: Array, segment_ids: Array, num_segments: int) -> Array:
    s = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones((data.shape[0],), jnp.float32), segment_ids,
                      num_segments)
    return s / jnp.maximum(cnt, 1.0).reshape((-1,) + (1,) * (data.ndim - 1))


def segment_max(data: Array, segment_ids: Array, num_segments: int) -> Array:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments,
                               indices_are_sorted=False)


def segment_softmax(
    scores: Array, segment_ids: Array, num_segments: int
) -> Array:
    """Numerically-stable softmax within each segment (edge-softmax)."""
    seg_max = jax.ops.segment_max(scores, segment_ids,
                                  num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = scores - jnp.take(seg_max, segment_ids, axis=0)
    num = jnp.exp(shifted)
    den = segment_sum(num, segment_ids, num_segments)
    return num / jnp.maximum(jnp.take(den, segment_ids, axis=0), 1e-30)


def segment_max_with_argmax(
    data: Array,            # (N,) or (N, D)
    segment_ids: Array,     # (N,)
    num_segments: int,
) -> Tuple[Array, Array]:
    """Max + index-of-max per segment — the Sparton reduction pattern.

    The argmax lets gradients route to a single contributing element,
    exactly as the paper's backward routes through ``i_max``.
    """
    n = data.shape[0]
    if data.ndim == 1:
        m = segment_max(data, segment_ids, num_segments)
        hit = data >= jnp.take(m, segment_ids)
        idx = jnp.where(hit, jnp.arange(n), n)
        arg = jax.ops.segment_min(idx, segment_ids,
                                  num_segments=num_segments)
        return m, arg
    m = segment_max(data, segment_ids, num_segments)
    hit = data >= jnp.take(m, segment_ids, axis=0)
    idx = jnp.where(hit, jnp.arange(n)[:, None], n)
    arg = jax.ops.segment_min(idx, segment_ids, num_segments=num_segments)
    return m, arg


def gather_scatter(
    node_feats: Array,      # (N, D)
    edge_src: Array,        # (E,)
    edge_dst: Array,        # (E,)
    num_nodes: int,
    *,
    reduce: str = "sum",
) -> Array:
    """One hop of message passing: out[i] = reduce_{j->i} feats[j]."""
    msgs = jnp.take(node_feats, edge_src, axis=0)
    if reduce == "sum":
        return segment_sum(msgs, edge_dst, num_nodes)
    if reduce == "mean":
        return segment_mean(msgs, edge_dst, num_nodes)
    if reduce == "max":
        return segment_max(msgs, edge_dst, num_nodes)
    raise ValueError(f"unknown reduce {reduce!r}")
