"""Minimal deterministic stand-in for ``hypothesis``.

The container image has no ``hypothesis`` wheel and nothing may be pip
installed, so ``tests/conftest.py`` registers this module under the
``hypothesis`` name when the real package is absent. It implements just
the surface the test-suite uses — ``@given`` with keyword strategies,
``@settings(max_examples=, deadline=)``, ``st.integers`` and
``st.sampled_from`` — drawing examples from a PRNG seeded by the test
name, so every run replays the same example set (no shrinking, no
database; if the real hypothesis is installed it is used instead).
"""

from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)
        # No functools.wraps: copying __wrapped__ would make pytest
        # introspect fn's signature and demand fixtures for the
        # strategy parameters. Copy only the identity attributes.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # @settings is applied above @given: let it mark the wrapper
        return wrapper
    return deco


def make_module() -> types.ModuleType:
    """Build module objects registerable as ``hypothesis`` (+ ``.strategies``)."""
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.floats = floats

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__stub__ = True
    return mod
