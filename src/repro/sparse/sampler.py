"""Layered neighbor sampler (GraphSAGE-style fanout sampling).

Host-side numpy: production GNN systems sample on CPU workers and feed
fixed-shape index tensors to the accelerator; we do the same. The
sampler returns a *node-flattened subgraph* with per-layer edge lists,
padded to static shapes so the jitted train step never recompiles.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Compressed neighbor lists (out-edges)."""
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray,
                   n_nodes: int) -> "CSRGraph":
        order = np.argsort(src, kind="stable")
        s, d = src[order], dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, s + 1, 1)
        np.cumsum(indptr, out=indptr)
        return cls(indptr=indptr, indices=d.astype(np.int64))

    def neighbors(self, node: int) -> np.ndarray:
        return self.indices[self.indptr[node]:self.indptr[node + 1]]


@dataclasses.dataclass
class SampledBlock:
    """One sampled hop: edges from layer-l nodes to layer-(l+1) nodes."""
    src: np.ndarray      # (E_pad,) indices into the flat node array
    dst: np.ndarray      # (E_pad,)
    n_edges: int         # valid edges (rest is padding, src=dst=0 w/ mask 0)
    mask: np.ndarray     # (E_pad,) 1 = real edge


@dataclasses.dataclass
class SampledSubgraph:
    nodes: np.ndarray            # (N_pad,) original node ids
    n_nodes: int
    node_mask: np.ndarray        # (N_pad,)
    blocks: List[SampledBlock]
    seeds: np.ndarray            # (batch,) positions of seed nodes (= 0..B-1)


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: Sequence[int],
    *,
    rng: np.random.Generator,
    pad_nodes: int = 0,
    pad_edges_per_hop: Tuple[int, ...] = (),
) -> SampledSubgraph:
    """Fanout-sample `len(fanout)` hops from `seeds`.

    Node ids are remapped to a dense [0, n) range, seeds first — the
    model runs on the compact subgraph. Static padding keeps jit shapes
    stable across steps.
    """
    id_map = {}
    flat_nodes: List[int] = []

    def intern(n: int) -> int:
        if n not in id_map:
            id_map[n] = len(flat_nodes)
            flat_nodes.append(n)
        return id_map[n]

    for s in seeds:
        intern(int(s))
    frontier = list(range(len(seeds)))

    blocks: List[SampledBlock] = []
    for hop, k in enumerate(fanout):
        src_l, dst_l = [], []
        next_frontier = []
        for pos in frontier:
            node = flat_nodes[pos]
            nbrs = graph.neighbors(node)
            if len(nbrs) > k:
                nbrs = rng.choice(nbrs, size=k, replace=False)
            for nb in nbrs:
                p = intern(int(nb))
                src_l.append(p)
                dst_l.append(pos)
                next_frontier.append(p)
        n_e = len(src_l)
        cap = (pad_edges_per_hop[hop] if hop < len(pad_edges_per_hop)
               else n_e)
        if n_e > cap:
            src_l, dst_l = src_l[:cap], dst_l[:cap]
            n_e = cap
        src = np.zeros(cap, np.int32)
        dst = np.zeros(cap, np.int32)
        msk = np.zeros(cap, np.int32)
        src[:n_e] = src_l
        dst[:n_e] = dst_l
        msk[:n_e] = 1
        blocks.append(SampledBlock(src=src, dst=dst, n_edges=n_e, mask=msk))
        frontier = sorted(set(next_frontier))

    n = len(flat_nodes)
    cap_n = max(pad_nodes, n)
    nodes = np.zeros(cap_n, np.int64)
    nodes[:n] = flat_nodes
    node_mask = np.zeros(cap_n, np.int32)
    node_mask[:n] = 1
    return SampledSubgraph(
        nodes=nodes, n_nodes=n, node_mask=node_mask, blocks=blocks,
        seeds=np.arange(len(seeds), dtype=np.int32),
    )


def fanout_budget(batch_nodes: int, fanout: Sequence[int]) -> Tuple[int, Tuple[int, ...]]:
    """Static (node, per-hop-edge) budgets for input_specs()."""
    nodes = batch_nodes
    total_nodes = batch_nodes
    per_hop = []
    for k in fanout:
        edges = nodes * k
        per_hop.append(edges)
        nodes = edges
        total_nodes += edges
    return total_nodes, tuple(per_hop)
