"""Sparse substrate: segment ops, EmbeddingBag, neighbor sampler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import make_synthetic_graph
from repro.sparse.embedding_bag import embedding_bag, multi_table_lookup
from repro.sparse.sampler import CSRGraph, fanout_budget, sample_subgraph
from repro.sparse.segment import (gather_scatter, segment_max,
                                  segment_max_with_argmax, segment_mean,
                                  segment_softmax, segment_sum)


def test_segment_sum_vs_numpy():
    data = np.random.default_rng(0).normal(size=(20, 4)).astype(np.float32)
    ids = np.random.default_rng(1).integers(0, 5, size=20)
    out = segment_sum(jnp.asarray(data), jnp.asarray(ids), 5)
    ref = np.zeros((5, 4), np.float32)
    np.add.at(ref, ids, data)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)


def test_segment_mean_empty_segment_is_zero():
    data = jnp.ones((4, 2))
    ids = jnp.array([0, 0, 2, 2])
    out = segment_mean(data, ids, 4)
    np.testing.assert_allclose(np.asarray(out[1]), 0.0)
    np.testing.assert_allclose(np.asarray(out[3]), 0.0)
    np.testing.assert_allclose(np.asarray(out[0]), 1.0)


def test_segment_softmax_normalizes():
    scores = jnp.asarray(np.random.default_rng(2).normal(size=30),
                         dtype=jnp.float32)
    ids = jnp.asarray(np.random.default_rng(3).integers(0, 6, size=30))
    p = segment_softmax(scores, ids, 6)
    sums = segment_sum(p, ids, 6)
    present = np.asarray(segment_sum(jnp.ones(30), ids, 6)) > 0
    np.testing.assert_allclose(np.asarray(sums)[present], 1.0, atol=1e-5)


def test_segment_max_with_argmax_routes_to_first_max():
    data = jnp.array([1.0, 5.0, 5.0, 2.0, 7.0])
    ids = jnp.array([0, 0, 0, 1, 1])
    m, arg = segment_max_with_argmax(data, ids, 2)
    assert float(m[0]) == 5.0 and int(arg[0]) == 1  # first occurrence
    assert float(m[1]) == 7.0 and int(arg[1]) == 4


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 40), s=st.integers(1, 8),
       seed=st.integers(0, 2**16))
def test_property_segment_sum_total_preserved(n, s, seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=(n, 3)).astype(np.float32)
    ids = rng.integers(0, s, size=n)
    out = segment_sum(jnp.asarray(data), jnp.asarray(ids), s)
    np.testing.assert_allclose(float(jnp.sum(out)), float(data.sum()),
                               atol=1e-3)


def test_embedding_bag_combiners():
    table = jnp.asarray(np.random.default_rng(0).normal(size=(10, 4)),
                        dtype=jnp.float32)
    values = jnp.array([1, 2, 3, 7, 7])
    bags = jnp.array([0, 0, 1, 1, 1])
    out_sum = embedding_bag(table, values, bags, 3, combiner="sum")
    ref0 = np.asarray(table)[1] + np.asarray(table)[2]
    np.testing.assert_allclose(np.asarray(out_sum[0]), ref0, atol=1e-6)
    out_mean = embedding_bag(table, values, bags, 3, combiner="mean")
    np.testing.assert_allclose(np.asarray(out_mean[0]), ref0 / 2, atol=1e-6)
    out_max = embedding_bag(table, values, bags, 3, combiner="max")
    np.testing.assert_allclose(
        np.asarray(out_max[0]),
        np.maximum(np.asarray(table)[1], np.asarray(table)[2]), atol=1e-6)
    # empty bag 2 must be zeros for sum
    np.testing.assert_allclose(np.asarray(out_sum[2]), 0.0)


def test_embedding_bag_weighted():
    table = jnp.eye(4)
    out = embedding_bag(table, jnp.array([0, 1]), jnp.array([0, 0]), 1,
                        weights=jnp.array([2.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out[0]), [2, 3, 0, 0])


def test_multi_table_lookup():
    tables = [jnp.arange(8.0).reshape(4, 2) * (f + 1) for f in range(3)]
    idx = jnp.array([[0, 1, 2], [3, 0, 1]])
    out = multi_table_lookup(tables, idx)
    assert out.shape == (2, 3, 2)
    np.testing.assert_allclose(np.asarray(out[0, 1]),
                               np.asarray(tables[1][1]))


def test_csr_graph_and_sampler():
    src, dst = make_synthetic_graph(100, 1000, seed=4)
    g = CSRGraph.from_edges(src, dst, 100)
    assert g.n_nodes == 100
    # neighbors of node = its out-edges
    for node in [0, 5, 50]:
        nbrs = set(g.neighbors(node).tolist())
        expect = set(dst[src == node].tolist())
        assert nbrs == expect

    rng = np.random.default_rng(0)
    seeds = np.array([1, 2, 3, 4])
    total, per_hop = fanout_budget(4, (3, 2))
    sub = sample_subgraph(g, seeds, (3, 2), rng=rng,
                          pad_nodes=total, pad_edges_per_hop=per_hop)
    assert sub.nodes.shape[0] == total
    assert len(sub.blocks) == 2
    for hop, blk in enumerate(sub.blocks):
        assert blk.src.shape[0] == per_hop[hop]
        assert blk.mask.sum() == blk.n_edges
        # all real edges point into interned nodes
        assert (blk.src[:blk.n_edges] < sub.n_nodes).all()
        assert (blk.dst[:blk.n_edges] < sub.n_nodes).all()
    # seeds come first in the flat node array
    np.testing.assert_array_equal(sub.nodes[:4], seeds)


def test_gather_scatter_one_hop():
    feats = jnp.eye(4)
    src = jnp.array([0, 1, 2])
    dst = jnp.array([1, 2, 3])
    out = gather_scatter(feats, src, dst, 4, reduce="sum")
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(feats[0]))
    np.testing.assert_allclose(np.asarray(out[0]), 0.0)
