from repro.optim.optimizers import (
    adagrad,
    adamw,
    apply_updates,
    clip_by_global_norm,
    sgd_momentum,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    linear_warmup_linear_decay,
)
from repro.optim.accumulation import GradAccumulator, microbatch_grads
from repro.optim.compression import (
    decompress_int8,
    compress_int8,
    compressed_allreduce,
)
