"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the batched LSR encoding loop (backbone + Sparton head) over a
stream of synthetic requests and reports latency percentiles +
achieved batch sizes, then retrieves top-k against an in-memory corpus.
"""

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="splade_bert")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--corpus", type=int, default=1000)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--head-impl", default=None,
                    help="override the config's head backend (any "
                         "registered impl; see "
                         "repro.core.head_api.available_impls)")
    args = ap.parse_args(argv)

    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.launch.steps import init_state
    from repro.runtime.serving import (BatchedEncoder, BatchPolicy, Request,
                                       ServingLoop, make_config_encoder,
                                       retrieve_topk)

    mod = get_config(args.arch)
    cfg = mod.SMOKE
    if args.head_impl:
        cfg = dataclasses.replace(cfg, head_impl=args.head_impl)
    state, _ = init_state(args.arch, jax.random.PRNGKey(0), smoke=True)
    params = state["params"]

    # Built from the config via the unified head factory: head_impl and
    # final_logit_softcap are honored (they used to be silently dropped
    # here — a live correctness bug for gemma2-style softcapped configs).
    encode = make_config_encoder(params, cfg)

    loop = ServingLoop(BatchedEncoder(
        encode, policy=BatchPolicy(max_batch=16, max_wait_s=0.002)))

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for uid in range(args.requests):
        n = int(rng.integers(4, 24))
        loop.submit(Request(uid=uid, tokens=rng.integers(
            1, cfg.vocab_size, size=n).astype(np.int32)))
        loop.tick()
    loop.drain()
    dt = time.monotonic() - t0

    print(f"encoded {len(loop.completed)} requests in {dt*1e3:.1f} ms, "
          f"batches: {loop.batch_sizes}")

    # retrieval against a synthetic corpus
    corpus_tokens = rng.integers(
        1, cfg.vocab_size, size=(args.corpus, 16)).astype(np.int32)
    corpus_reps = np.asarray(encode(
        jnp.asarray(corpus_tokens),
        jnp.ones_like(jnp.asarray(corpus_tokens))))
    q = np.stack([loop.completed[u] for u in sorted(loop.completed)][:8])
    vals, idx = retrieve_topk(jnp.asarray(q), jnp.asarray(corpus_reps),
                              k=args.topk)
    print(f"retrieval: top-{args.topk} for {q.shape[0]} queries, "
          f"best scores {np.asarray(vals)[:, 0].round(2).tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
