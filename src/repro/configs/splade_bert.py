"""SPLADE on a BERT-base backbone — the paper's own model (Table 1, 3).

Bidirectional encoder, |V| = 30522 (bert-base-uncased), 12L/768/12H.
This is the exact operating point of the paper's Table 1 (B=320,
S=512 on H100) and the end-to-end training run of Table 3.
"""

from repro.configs.base import ShapeSpec, TransformerConfig

CONFIG = TransformerConfig(
    name="splade-bert",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=30522,
    bidirectional_encoder=True,
    tie_embeddings=True,
    # Pallas head blocks: autotuned per run shape (B=320/S=512 on the
    # paper's Table-1 point); pin ints here to override the tuner.
    head_block_b=None,
    head_block_s=None,
    head_block_v=None,
)

SMOKE = TransformerConfig(
    name="splade-bert-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=512,
    bidirectional_encoder=True,
    tie_embeddings=True,
    remat=False,
)

# the paper's measurement points
SHAPES = {
    "table1": ShapeSpec("table1", "train", seq_len=512, global_batch=320),
    "table3_384": ShapeSpec("table3_384", "train", seq_len=256,
                            global_batch=384),
    "table3_512": ShapeSpec("table3_512", "train", seq_len=256,
                            global_batch=512),
}
