"""phi3-mini-3.8b — dense decoder, RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (GQA kv=32 => MHA) d_ff=8192 vocab=32064. The
|V|~32k operating point matches SPLADE's (the paper's Table 1/3).
Pure full attention => long_500k skipped.
"""

from repro.configs.base import TransformerConfig, shapes_lm

CONFIG = TransformerConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    attn_chunk=2048,   # §Perf: -4% memory term vs 512

)

SMOKE = TransformerConfig(
    name="phi3-mini-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=False,
    remat=False,
)

SHAPES = shapes_lm(
    long_ok=False,
    long_skip_reason="pure full attention; 524k-token decode needs "
                     "sub-quadratic attention (assignment rule)",
)
