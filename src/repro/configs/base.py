"""Config dataclasses for every architecture family in the framework.

Configs are frozen dataclasses; each architecture module in
``repro/configs/`` exports ``CONFIG`` (the exact assigned config),
``SMOKE`` (a reduced same-family config for CPU smoke tests) and
``SHAPES`` (the assigned input-shape set). ``repro.configs.get_config``
is the registry entry point used by ``--arch <id>`` everywhere.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (architecture x input-shape) cell of the dry-run matrix."""

    name: str
    kind: str  # train | prefill | decode | full_graph | minibatch | serve | retrieval
    # LM shapes
    seq_len: int = 0
    global_batch: int = 0
    # GNN shapes
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    n_graphs: int = 0
    # RecSys shapes
    batch: int = 0
    n_candidates: int = 0
    # bookkeeping
    skip: bool = False
    skip_reason: str = ""


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    family: str  # "dense" | "moe"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # gemma-2 features
    sliding_window: Optional[int] = None   # local attention window
    local_global_alternating: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    # common
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    bidirectional_encoder: bool = False  # SPLADE-style encoders
    # execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    grad_accum_steps: int = 1
    # LSR head (the paper's technique)
    lsr_head: bool = True          # train objective: LSR contrastive
    # LSR objective weights (Unified-LSR: effectiveness is dominated by
    # these regularization choices — keep them per-config, not global)
    lambda_q: float = 5e-4         # FLOPS weight on query reps
    lambda_d: float = 3e-4         # FLOPS weight on doc reps
    l1_weight: float = 0.0         # optional L1 on both rep sides
    aux_weight: float = 1e-2       # MoE load-balance aux weight
    distill_weight: float = 0.0    # MarginMSE weight (needs distill batch)
    # Head backend, resolved against the head_api registry by
    # ``head_spec()``: "jax" is the legacy alias for "sparton"; any
    # registered name ("naive" | "tiled" | "sparton" | "kernel" | ...)
    # is valid.
    head_impl: str = "jax"
    # Pallas head block sizes. None = resolve per call shape via the
    # autotuner (kernels/autotune.py): cached measured winner if one
    # exists, else the analytic heuristic. Ints pin the blocks.
    head_block_b: Optional[int] = None
    head_block_s: Optional[int] = None
    head_block_v: Optional[int] = None
    head_vocab_tile: int = 4096    # pure-JAX streaming tile
    # Rep sparsification (Unified-LSR-style model knob): applied to the
    # (B, V) head output on-device by encoders built via
    # head_api.make_encoder. Both None = dense reps (the default).
    rep_topk: Optional[int] = None
    rep_threshold: Optional[float] = None
    rep_max_nnz: int = 256         # threshold-only static slot budget
    attn_unroll: int = 1           # KV-chunk scan unroll (cost probes)
    attn_chunk: int = 512          # KV chunk size (online softmax)

    def head_blocks(self, batch: int, seq_len: int,
                    dtype: Optional[str] = None
                    ) -> Tuple[int, int, int]:
        """Resolved Pallas head blocks for a run shape.

        Pinned config values win; unset (None) components come from the
        autotuner's cache/heuristic for (batch, seq_len, d_model, V).
        """
        pinned = (self.head_block_b, self.head_block_s, self.head_block_v)
        if all(p is not None for p in pinned):
            return pinned  # type: ignore[return-value]
        from repro.kernels.autotune import blocks_for_config

        # Partial pins are resolved *jointly* (pins fixed, free
        # components re-enumerated) so the combined triple still
        # respects the kernel VMEM budget.
        return blocks_for_config(self.vocab_size, self.d_model, batch,
                                 seq_len, dtype or self.compute_dtype,
                                 pinned=pinned)

    def head_spec(self, **overrides):
        """The config's head as a ``HeadSpec`` for ``make_head``.

        The single translation point from config fields to the unified
        head API: ``head_impl`` ("jax" → "sparton"), pinned/auto Pallas
        blocks, the streaming tile and ``final_logit_softcap`` all land
        in one spec. ``overrides`` replace individual fields (e.g.
        ``head_spec(impl="kernel")``).
        """
        from repro.core.head_api import HeadSpec

        spec = HeadSpec(
            impl=self.head_impl,
            block_b=self.head_block_b,
            block_s=self.head_block_s,
            block_v=self.head_block_v,
            vocab_tile=self.head_vocab_tile,
            logit_softcap=self.final_logit_softcap,
            rep_topk=self.rep_topk,
            rep_threshold=self.rep_threshold,
            rep_max_nnz=self.rep_max_nnz,
        )
        if overrides:
            spec = spec.replace(**overrides)
        if spec.impl == "jax":
            spec = spec.replace(impl="sparton")
        return spec

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + trunk + head)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        if self.is_moe:
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            mlp = 3 * d * f
        trunk = L * (attn + mlp + 2 * d)
        embed = V * d * (1 if self.tie_embeddings else 2)
        return trunk + embed

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE counts top_k experts)."""
        if not self.is_moe:
            return self.n_params
        d, f, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        mlp = self.top_k * 3 * d * f + d * self.n_experts
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp + 2 * d) + embed


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str
    family: str = "gnn"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_feat: int = 0                 # input node features (0 => atom types)
    n_atom_types: int = 95
    cutoff: float = 5.0
    envelope_exponent: int = 5
    max_triplets_per_edge: int = 0  # 0 => exact triplets
    n_targets: int = 1
    param_dtype: str = "float32"
    compute_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    family: str = "recsys"
    interaction: str = "dot"  # dot | cin | augru | concat
    n_dense: int = 0
    n_sparse: int = 26
    embed_dim: int = 128
    table_sizes: Tuple[int, ...] = ()
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    cin_layers: Tuple[int, ...] = ()
    # DIEN
    seq_len: int = 0
    gru_dim: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def total_rows(self) -> int:
        return sum(self.table_sizes)


def shapes_lm(long_ok: bool, long_skip_reason: str = "") -> Dict[str, ShapeSpec]:
    """The assigned LM-family shape set (4 cells)."""
    return {
        "train_4k": ShapeSpec("train_4k", "train", seq_len=4096,
                              global_batch=256),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768,
                                 global_batch=32),
        "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768,
                                global_batch=128),
        "long_500k": ShapeSpec(
            "long_500k", "decode", seq_len=524288, global_batch=1,
            skip=not long_ok, skip_reason=long_skip_reason,
        ),
    }


SHAPES_GNN: Dict[str, ShapeSpec] = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "full_graph",
                               n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": ShapeSpec("minibatch_lg", "minibatch",
                              n_nodes=232965, n_edges=114615892,
                              batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": ShapeSpec("ogb_products", "full_graph",
                              n_nodes=2449029, n_edges=61859140, d_feat=100),
    "molecule": ShapeSpec("molecule", "batched_graphs",
                          n_nodes=30, n_edges=64, n_graphs=128),
}

SHAPES_RECSYS: Dict[str, ShapeSpec] = {
    "train_batch": ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", batch=1,
                                n_candidates=1_000_000),
}
