"""Multi-corpus tenancy: many named corpora over one encoder.

One process, one jitted encoder, N tenants — each tenant owns its own
``CorpusEngine`` (its corpus), its own ``ServingLoop`` (its queue,
its adaptive batch cap), and its own ``DegradeController`` (its
ladder rung). The encoder is the only shared compute, and it is
stateless across batches — so isolation is structural, not policed:
a poison batch bisects inside the submitting tenant's loop, an OOM
halves *that* loop's cap, sustained pressure moves *that* tenant's
ladder. Nothing a tenant does can touch another tenant's counters.

What **is** shared is arbitrated explicitly:

* **Encoder time** — ``tick()`` dispatches at most one batch per call
  (the ``ServingLoop`` contract, lifted to the pool) and picks which
  tenant by stride scheduling: each tenant carries a virtual ``pass``
  that advances by ``dispatched / weight`` whenever it is served, and
  the dispatch-ready tenant with the smallest pass goes next. Under
  contention a weight-2 tenant therefore gets 2× the batches of a
  weight-1 tenant; an idle tenant's pass is clamped forward on its
  next dispatch so banked idle time can't starve everyone else.
* **Memory** — one byte budget across all tenants, metered by
  ``IndexBuilder.memory_bytes()``. ``add_docs`` refuses (raises
  ``QuotaExceeded``) when the pool is already over budget or the
  tenant is at its ``max_docs`` quota; an add may overshoot the
  budget by at most its own batch (checked before, metered after —
  mutations are never half-applied), after which compaction is tried
  once to reclaim tombstones before further adds are refused.
* **The result cache** — optionally one ``QueryResultCache`` across
  tenants (capacity is part of the memory story), namespaced by
  tenant tag so invalidation-by-churn is per-tenant too.

DESIGN.md §13.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.frontier.caches import (
    CachedEngine,
    HotPostingCache,
    QueryResultCache,
)
from repro.runtime.serving import (
    Admission,
    AdmissionPolicy,
    BatchedEncoder,
    CorpusEngine,
    DegradeController,
    DegradePolicy,
    Request,
    ServingLoop,
)

__all__ = ["QuotaExceeded", "TenantQuota", "TenantState", "TenantPool"]


class QuotaExceeded(RuntimeError):
    """A mutation was refused by a per-tenant or pool-wide limit."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant limits: scheduling ``weight`` (share of encoder
    time under contention) and ``max_docs`` (live-document cap;
    ``None`` = unlimited)."""
    weight: float = 1.0
    max_docs: Optional[int] = None

    def __post_init__(self):
        if not self.weight > 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")


@dataclasses.dataclass
class TenantState:
    """Everything one tenant owns. ``frontend`` is the search surface
    — the ``CachedEngine`` when the pool caches, else the engine."""
    name: str
    engine: CorpusEngine
    frontend: Any
    loop: ServingLoop
    quota: TenantQuota
    vpass: float = 0.0          # stride-scheduling virtual pass

    @property
    def live_docs(self) -> int:
        return int(self.engine.builder.stats()["n_alive"])

    def memory_bytes(self) -> int:
        return int(self.engine.builder.memory_bytes())


class TenantPool:
    """Named corpora multiplexed over one ``BatchedEncoder``.

    The per-request surface mirrors ``ServingLoop``/``CorpusEngine``
    with a leading tenant name: ``submit(name, req)``,
    ``take(name, uid)``, ``add_docs(name, docs)``,
    ``search(name, queries, k, **kw)``. ``tick()``/``drain()``
    schedule across tenants (module docstring).
    """

    def __init__(self, encoder: BatchedEncoder, *,
                 clock: Callable[[], float] = time.monotonic,
                 memory_budget_bytes: Optional[int] = None,
                 cache_bytes: int = 0,
                 hot_cache_bytes: int = 0,
                 continuous: bool = False):
        self.encoder = encoder
        self.clock = clock
        self.memory_budget_bytes = memory_budget_bytes
        self.hot_cache_bytes = int(hot_cache_bytes)
        self.continuous = continuous
        self.result_cache: Optional[QueryResultCache] = (
            QueryResultCache(cache_bytes) if cache_bytes > 0 else None)
        self._tenants: Dict[str, TenantState] = {}

    # -- membership ------------------------------------------------------

    def add_tenant(self, name: str, vocab_size: int, *,
                   quota: Optional[TenantQuota] = None,
                   admission: Optional[AdmissionPolicy] = None,
                   degrade_policy: Optional[DegradePolicy] = None,
                   **engine_kw) -> TenantState:
        """Provision a tenant: engine + (shared-cache) frontend + its
        own loop and ladder. ``engine_kw`` goes to ``CorpusEngine``
        (quantize / keep_forward / shard knobs)."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already exists")
        engine = CorpusEngine(self.encoder, vocab_size, **engine_kw)
        frontend: Any = engine
        if self.result_cache is not None:
            hot = (HotPostingCache(self.hot_cache_bytes)
                   if self.hot_cache_bytes > 0 else None)
            frontend = CachedEngine(engine,
                                    result_cache=self.result_cache,
                                    hot_cache=hot, tag=name)
        loop = ServingLoop(
            self.encoder, clock=self.clock, admission=admission,
            degrade=DegradeController(degrade_policy),
            continuous=self.continuous)
        st = TenantState(name=name, engine=engine, frontend=frontend,
                         loop=loop, quota=quota or TenantQuota())
        self._tenants[name] = st
        return st

    def tenant(self, name: str) -> TenantState:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(
                f"unknown tenant {name!r} "
                f"(have: {sorted(self._tenants)})") from None

    def names(self) -> List[str]:
        return sorted(self._tenants)

    # -- corpus mutations (quota-checked) --------------------------------

    def memory_bytes(self) -> int:
        total = sum(t.memory_bytes() for t in self._tenants.values())
        if self.result_cache is not None:
            total += self.result_cache.bytes_used
        for t in self._tenants.values():
            hot = getattr(t.frontend, "hot", None)
            if hot is not None:
                total += hot.bytes_pinned
        return total

    def _check_budget(self, st: TenantState, incoming: int) -> None:
        if (st.quota.max_docs is not None
                and st.live_docs + incoming > st.quota.max_docs):
            raise QuotaExceeded(
                f"tenant {st.name!r}: {st.live_docs} live + {incoming} "
                f"incoming docs exceeds max_docs={st.quota.max_docs}")
        budget = self.memory_budget_bytes
        if budget is not None and self.memory_bytes() > budget:
            # over from the previous add — try reclaiming tombstones
            # once before refusing (compaction is the only lever that
            # frees bytes without dropping live docs)
            st.engine.builder.flush(force_compact=True)
            if self.memory_bytes() > budget:
                raise QuotaExceeded(
                    f"pool over memory budget: {self.memory_bytes()} "
                    f"> {budget} bytes; remove docs or raise the "
                    f"budget before adding to tenant {st.name!r}")

    def add_docs(self, name: str, docs: Sequence[np.ndarray],
                 ids: Optional[Sequence[int]] = None) -> np.ndarray:
        st = self.tenant(name)
        self._check_budget(st, len(list(docs)))
        return st.frontend.add_docs(docs, ids=ids)

    def remove_docs(self, name: str, ids: Sequence[int]) -> int:
        return self.tenant(name).frontend.remove_docs(ids)

    # -- request path ----------------------------------------------------

    def submit(self, name: str, req: Request) -> Admission:
        return self.tenant(name).loop.submit(req)

    def take(self, name: str, uid: int) -> Any:
        return self.tenant(name).loop.take(uid)

    def search(self, name: str, queries, k: int = 10, **kw):
        st = self.tenant(name)
        d = st.loop.degrade
        merged = dict(d.search_kwargs()) if d is not None else {}
        merged.update(kw)
        return st.frontend.search(queries, k, **merged)

    # -- scheduling ------------------------------------------------------

    def _schedule_order(self) -> List[TenantState]:
        # name-tiebroken so equal passes schedule deterministically
        return sorted(self._tenants.values(),
                      key=lambda t: (t.vpass, t.name))

    def tick(self, *, force: bool = False) -> Tuple[str, int]:
        """One scheduling round: at most one batch dispatches, from
        the smallest-pass dispatch-ready tenant. Non-ready tenants
        still get their housekeeping tick (expiry shedding + degrade
        observation). Returns ``(tenant, batch_size)`` — ``("", 0)``
        when nothing dispatched."""
        order = self._schedule_order()
        ready = [t for t in order if t.loop.ready(force=force)]
        chosen = ready[0] if ready else None
        dispatched: Tuple[str, int] = ("", 0)
        for t in order:
            if t is chosen:
                n = t.loop.tick(force=force)
                if n:
                    # clamp forward: a long-idle tenant re-enters at
                    # the current minimum instead of cashing in banked
                    # pass to monopolize the encoder
                    floor = min(x.vpass for x in order)
                    t.vpass = max(t.vpass, floor) + n / t.quota.weight
                    dispatched = (t.name, n)
            elif not t.loop.ready(force=False):
                t.loop.tick()    # housekeeping only — cannot dispatch
        return dispatched

    def drain(self) -> None:
        """Force-dispatch round-robin-by-pass until every tenant's
        queue is empty. Terminates: each round with pending work
        dispatches or sheds at least one request somewhere."""
        while any(t.loop.pending for t in self._tenants.values()):
            before = sum(len(t.loop.pending)
                         for t in self._tenants.values())
            self.tick(force=True)
            after = sum(len(t.loop.pending)
                        for t in self._tenants.values())
            if after >= before:   # pragma: no cover
                raise RuntimeError("pool tick(force) made no progress")

    # -- observability ---------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        per = {}
        for name in self.names():
            t = self._tenants[name]
            d = {
                "weight": t.quota.weight,
                "vpass": round(t.vpass, 6),
                "live_docs": t.live_docs,
                "memory_bytes": t.memory_bytes(),
                **t.loop.stats(),
            }
            if isinstance(t.frontend, CachedEngine):
                hot = t.frontend.hot
                d["cache"] = {
                    "results": {
                        k: v for k, v in
                        t.frontend.results.stats().items()
                        if k in ("hits", "misses", "hit_rate")},
                    **({"hot": hot.stats()} if hot is not None else {}),
                }
            per[name] = d
        out: Dict[str, Any] = {
            "tenants": per,
            "n_tenants": len(per),
            "memory_bytes": self.memory_bytes(),
            "memory_budget_bytes": self.memory_budget_bytes,
        }
        if self.result_cache is not None:
            out["result_cache"] = self.result_cache.stats()
        return out
