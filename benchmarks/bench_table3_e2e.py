"""Paper Table 3: end-to-end LSR training efficiency — compiled-LM
head vs Sparton head at the same batch, plus Sparton at the enlarged
batch the freed memory allows.

CPU-scaled: a small SPLADE encoder trained for N steps on the
synthetic LSR pair stream; we report steps/s, projected epoch time,
XLA-planned peak memory, and the final in-batch InfoNCE retrieval
accuracy (the effectiveness proxy standing in for NDCG@10 — the real
metric needs BEIR, which does not ship in this container).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._common import csv_print
from repro.configs import get_config
from repro.data.synthetic import lsr_pair_batches
from repro.launch.steps import init_state
from repro.losses.contrastive import splade_loss
from repro.models import transformer as tfm
from repro.core.head_api import make_head
from repro.optim.optimizers import adamw, apply_updates

STEPS = 30


def _build_step(cfg, head):
    opt = adamw(3e-4)
    head_fn = make_head(cfg.head_spec(impl=head))

    def encode(params, toks, mask):
        H, _ = tfm.forward_hidden(params, cfg, toks, mask)
        E, b = tfm.head_weights(params, cfg)
        return head_fn(H, E.astype(H.dtype), b, mask)

    def loss_fn(params, batch):
        yq = encode(params, batch["q_tokens"], batch["q_mask"])
        yd = encode(params, batch["d_tokens"], batch["d_mask"])
        return splade_loss(yq, yd, lambda_q=1e-4, lambda_d=1e-4)

    grad_fn = jax.value_and_grad(loss_fn)

    def step(state, batch):
        loss, grads = grad_fn(state["params"], batch)
        updates, opt_state = opt.update(grads, state["opt"],
                                        state["params"], state["step"])
        params = apply_updates(state["params"], updates)
        return ({"params": params, "opt": opt_state,
                 "step": state["step"] + 1}, loss)

    return jax.jit(step, donate_argnums=(0,)), opt


def _retrieval_acc(params, cfg, n=32):
    """In-batch retrieval accuracy: does query i rank doc i first?

    Always evaluates with the config's default head so the accuracy
    column is measured identically across the per-head training rows.
    """
    gen = lsr_pair_batches(batch=n, q_len=16, d_len=24,
                           vocab=cfg.vocab_size, seed=99)
    b = next(gen)
    head_fn = make_head(cfg.head_spec())

    def encode(toks, mask):
        H, _ = tfm.forward_hidden(params, cfg, jnp.asarray(toks),
                                  jnp.asarray(mask))
        E, bb = tfm.head_weights(params, cfg)
        return head_fn(H, E.astype(H.dtype), bb, jnp.asarray(mask))

    yq = encode(b["q_tokens"], b["q_mask"])
    yd = encode(b["d_tokens"], b["d_mask"])
    scores = np.asarray(jnp.einsum("qv,dv->qd", yq, yd))
    return float((scores.argmax(1) == np.arange(n)).mean())


def run(csv: bool = True):
    cfg = get_config("splade_bert").SMOKE
    rows = []
    for head, batch in [("naive", 8), ("sparton", 8), ("sparton", 16)]:
        state, _ = init_state("splade_bert", jax.random.PRNGKey(0),
                              smoke=True)
        step, _ = _build_step(cfg, head)
        gen = lsr_pair_batches(batch=batch, q_len=16, d_len=24,
                               vocab=cfg.vocab_size, seed=0)
        losses = []
        t0 = None
        for i in range(STEPS):
            raw = next(gen)
            bt = {k: jnp.asarray(v) for k, v in raw.items()}
            state, loss = step(state, bt)
            if i == 2:
                jax.block_until_ready(state)
                t0 = time.perf_counter()  # skip compile
            losses.append(float(loss))
        jax.block_until_ready(state)
        dt = time.perf_counter() - t0
        steps_per_s = (STEPS - 3) / dt
        acc = _retrieval_acc(state["params"], cfg)
        rows.append((head, batch, STEPS, round(steps_per_s, 2),
                     round(losses[2], 3), round(losses[-1], 3),
                     round(acc, 3)))
    if csv:
        csv_print(("head", "batch", "steps", "steps_per_s", "loss_start",
                   "loss_end", "inbatch_acc@1"), rows)
    return rows


if __name__ == "__main__":
    run()
