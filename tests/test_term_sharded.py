"""Term-partitioned (vocab-sharded) index tests (DESIGN.md §9).

The acceptance anchors:

* ``method="term_sharded"`` returns top-k ids identical to
  ``method="impact"`` on the graded bench corpus at 1/2/4 shards —
  the partial-sum merge algebra must be invisible in the results;
* parity holds for the awkward routings: uneven vocab splits, shards
  whose range holds no active terms, and queries whose active terms
  all land on one shard (every other shard contributes an all-zero
  partial);
* the two-tier MaxScore composition (per-shard ceilings summed, exact
  rescore from forward rows) is id-identical at ``prune_margin=0``;
* the ``shard_map``+``psum`` path on a forced multi-host-device mesh
  matches the single-device scorer (subprocess, like
  ``test_engine``'s doc-sharded twin; device count from
  ``REPRO_SHARD_TEST_DEVICES`` — CI's multidevice job runs it 4-wide).
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lsr_impact_corpus
from repro.retrieval import (IndexBuilder, build_inverted_index,
                             choose_shard_axis, retrieve,
                             sparsify_threshold, sparsify_topk,
                             term_shard_index, term_sharded_retrieve)

K = 10
BENCH = dict(n_docs=1024, vocab=1024, doc_nnz=32, n_queries=8,
             q_nnz=28)


@pytest.fixture(scope="module")
def graded():
    data = lsr_impact_corpus(**BENCH)
    q = sparsify_topk(jnp.asarray(data["queries"]), BENCH["q_nnz"])
    d = sparsify_topk(jnp.asarray(data["docs"]), BENCH["doc_nnz"])
    vals, idx = retrieve(q, build_inverted_index(d, BENCH["vocab"]), K,
                         method="impact")
    return {"q": q, "d": d, "vals": np.asarray(vals),
            "idx": np.asarray(idx)}


def _small(rng, n, nnz, vocab, lo=0, hi=None):
    """Random sparse rows whose active terms lie in [lo, hi)."""
    hi = vocab if hi is None else hi
    m = np.zeros((n, vocab), np.float32)
    for r in range(n):
        cols = lo + rng.choice(hi - lo, size=nnz, replace=False)
        m[r, cols] = rng.uniform(0.1, 2.0, size=nnz)
    return m


def _rep(m, nnz=8):
    return sparsify_threshold(jnp.asarray(m), 0.0, max_nnz=nnz)


# ---------------------------------------------------------------------------
# build: vocab_range remapping, boundaries, validation
# ---------------------------------------------------------------------------

def test_build_vocab_range_remaps_term_ids():
    rng = np.random.default_rng(0)
    m = _small(rng, 20, 6, 64)
    rep = _rep(m)
    full = build_inverted_index(rep, 64)
    part = build_inverted_index(rep, 64, vocab_range=(16, 40))
    assert part.vocab_size == 24 and part.n_docs == 20
    # local posting lists are the global lists of terms [16, 40)
    fl = np.asarray(full.term_lens)
    pl = np.asarray(part.term_lens)
    np.testing.assert_array_equal(pl, fl[16:40])
    for t in np.flatnonzero(pl > 0):
        fs = np.asarray(full.term_starts)[16 + t]
        ps = np.asarray(part.term_starts)[t]
        np.testing.assert_array_equal(
            np.asarray(part.postings_doc)[ps:ps + pl[t]],
            np.asarray(full.postings_doc)[fs:fs + pl[t]])


def test_build_vocab_range_validation():
    rng = np.random.default_rng(1)
    rep = _rep(_small(rng, 4, 4, 32))
    with pytest.raises(ValueError, match="vocab_range"):
        build_inverted_index(rep, 32, vocab_range=(8, 40))
    with pytest.raises(ValueError, match="keep_forward"):
        build_inverted_index(rep, 32, vocab_range=(0, 16),
                             keep_forward=True)


def test_term_shard_index_boundaries_validation(graded):
    with pytest.raises(ValueError, match="n_shards"):
        term_shard_index(graded["d"], BENCH["vocab"], 0)
    with pytest.raises(ValueError, match="exceeds vocab"):
        term_shard_index(graded["d"], 4, 5)
    with pytest.raises(ValueError, match="strictly increasing"):
        term_shard_index(graded["d"], BENCH["vocab"], 2,
                         boundaries=[0, 512, 512, BENCH["vocab"]])
    with pytest.raises(ValueError, match="strictly increasing"):
        term_shard_index(graded["d"], BENCH["vocab"], 2,
                         boundaries=[0, BENCH["vocab"]])


# ---------------------------------------------------------------------------
# exact retrieval parity (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
def test_term_sharded_matches_impact(graded, n_shards):
    tidx = term_shard_index(graded["d"], BENCH["vocab"], n_shards)
    vals, idx = retrieve(graded["q"], tidx, K, method="term_sharded")
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)


def test_term_sharded_auto_dispatch_and_type_errors(graded):
    tidx = term_shard_index(graded["d"], BENCH["vocab"], 2)
    _, idx = retrieve(graded["q"], tidx, K)      # auto -> term_sharded
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    with pytest.raises(ValueError, match="TermShardedIndex"):
        retrieve(graded["q"], build_inverted_index(
            graded["d"], BENCH["vocab"]), K, method="term_sharded")


def test_term_sharded_uneven_vocab_split(graded):
    """Wildly uneven cuts (one shard owns most of the vocab) must not
    change results — padding to the widest shard is score-neutral."""
    v = BENCH["vocab"]
    tidx = term_shard_index(graded["d"], v, 3,
                            boundaries=[0, 17, v - 64, v])
    assert tidx.local_vocab == v - 64 - 17
    vals, idx = retrieve(graded["q"], tidx, K)
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)


def test_term_sharded_empty_shards():
    """Shards whose vocab range holds no active terms contribute an
    all-zero partial — ids must match the unsharded scorer."""
    rng = np.random.default_rng(2)
    # all activity in terms [32, 64): shards over [0,32) are empty
    D = _small(rng, 40, 6, 128, lo=32, hi=64)
    Q = _small(rng, 3, 5, 128, lo=32, hi=64)
    d, q = _rep(D), _rep(Q)
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 128), 5,
                            method="impact")
    # width cuts requested explicitly: ranges of 32 terms (the default
    # mass-balanced cuts would shrink the empty ranges away)
    tidx = term_shard_index(d, 128, 4, balance="width")
    assert int((np.asarray(tidx.term_lens).sum(axis=1) == 0).sum()) == 3
    vals, idx = retrieve(q, tidx, 5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               atol=1e-4)


def test_term_sharded_query_on_single_shard():
    """Queries whose active terms all land on one shard: every other
    shard's routed query is fully masked (nnz 0)."""
    rng = np.random.default_rng(3)
    D = _small(rng, 50, 8, 96)              # docs span the vocab
    Q = _small(rng, 4, 6, 96, lo=0, hi=32)  # queries only in shard 0
    d, q = _rep(D), _rep(Q)
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 96), 6,
                            method="impact")
    tidx = term_shard_index(d, 96, 3)
    vals, idx = retrieve(q, tidx, 6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(v_ref),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# pruning composition (per-shard ceilings -> summed -> exact rescore)
# ---------------------------------------------------------------------------

def test_term_sharded_pruned_parity_at_safe_margin(graded):
    tidx = term_shard_index(graded["d"], BENCH["vocab"], 3,
                            keep_forward=True)
    vals, idx = term_sharded_retrieve(graded["q"], tidx, K,
                                      prune_margin=0.0)
    np.testing.assert_array_equal(np.asarray(idx), graded["idx"])
    np.testing.assert_allclose(np.asarray(vals), graded["vals"],
                               atol=1e-4)
    # the dispatcher routes margins > 0 into the pruned composition
    # and keeps the clear graded winner
    _, idx_aggr = retrieve(graded["q"], tidx, K,
                           method="term_sharded", prune_margin=0.5)
    np.testing.assert_array_equal(np.asarray(idx_aggr)[:, 0],
                                  graded["idx"][:, 0])


def test_term_sharded_pruned_requires_forward(graded):
    tidx = term_shard_index(graded["d"], BENCH["vocab"], 2)
    with pytest.raises(ValueError, match="forward"):
        term_sharded_retrieve(graded["q"], tidx, K, prune_margin=0.0)
    with pytest.raises(ValueError, match="prune_margin"):
        term_sharded_retrieve(
            graded["q"],
            term_shard_index(graded["d"], BENCH["vocab"], 2,
                             keep_forward=True),
            K, prune_margin=1.5)


# ---------------------------------------------------------------------------
# axis planner (deprecated string shim — the ShardPlan planner's own
# tests live in tests/test_shard2d.py)
# ---------------------------------------------------------------------------

def test_choose_shard_axis_shim_matches_old_heuristic():
    with pytest.warns(DeprecationWarning, match="plan_placement"):
        # big postings, small vocab: the replicated directory is cheap
        assert choose_shard_axis(10**9, 4096, 4) == "doc"
    with pytest.warns(DeprecationWarning):
        # huge vocab, sparse postings: the directory dominates a shard
        assert choose_shard_axis(10**6, 250_000, 4) == "term"
    with pytest.warns(DeprecationWarning):
        # with an HBM budget: doc iff a doc shard fits
        assert choose_shard_axis(10**8, 4096, 4,
                                 per_device_bytes=10**8) == "doc"
    with pytest.warns(DeprecationWarning):
        assert choose_shard_axis(10**9, 4096, 4,
                                 per_device_bytes=10**8) == "term"


# ---------------------------------------------------------------------------
# incremental builder + serving integration
# ---------------------------------------------------------------------------

def test_builder_term_sharded_base(graded):
    b = IndexBuilder(BENCH["vocab"], term_shards=3)
    b.add(graded["d"])
    vals, ext = b.search(graded["q"], K)
    np.testing.assert_array_equal(ext, graded["idx"])
    np.testing.assert_allclose(vals, graded["vals"], atol=1e-4)
    assert b.stats()["term_shards"] == 3
    # tombstoning zeroes postings in place across all shards
    victim = int(ext[0, 0])
    b.remove([victim])
    _, ext2 = b.search(graded["q"], K)
    assert victim not in ext2
    with pytest.raises(ValueError, match="exclusive"):
        IndexBuilder(BENCH["vocab"], term_shards=2, quantize=True)


def test_builder_term_sharded_base_serves_pruned_search(graded):
    """search(method='pruned') on a term-sharded base must route to
    the term-sharded two-tier composition instead of crashing on the
    InvertedIndex-only pruned path (safe margin: ids == impact)."""
    b = IndexBuilder(BENCH["vocab"], term_shards=2, keep_forward=True)
    b.add(graded["d"])
    vals, ext = b.search(graded["q"], K, method="pruned",
                         prune_margin=0.0)
    np.testing.assert_array_equal(ext, graded["idx"])
    np.testing.assert_allclose(vals, graded["vals"], atol=1e-4)
    # aggressive margin flows into the composition and keeps the
    # clear graded winner
    _, ext_aggr = b.search(graded["q"], K, method="pruned",
                           prune_margin=0.5)
    np.testing.assert_array_equal(ext_aggr[:, 0], graded["idx"][:, 0])


def test_builder_term_sharded_base_with_raw_delta():
    """Base term-sharded, delta raw: the merged search must equal a
    frozen unsharded build over all rows."""
    rng = np.random.default_rng(4)
    D = _small(rng, 60, 8, 128)
    Q = _small(rng, 4, 6, 128)
    q = _rep(Q)
    v_ref, i_ref = retrieve(q, build_inverted_index(_rep(D), 128), 7,
                            method="impact")
    b = IndexBuilder(128, term_shards=2, merge_frac=0.5)
    b.add(_rep(D[:48]))
    b.flush()
    b.add(_rep(D[48:]))
    vals, ext = b.search(q, 7)
    assert b.stats()["delta_docs"] == 12    # delta kept, not merged
    np.testing.assert_array_equal(ext, np.asarray(i_ref))
    np.testing.assert_allclose(vals, np.asarray(v_ref), atol=1e-4)


def test_corpus_engine_term_axis():
    from repro.retrieval import sparsify_topk as topk
    from repro.runtime.serving import (BatchedEncoder, BatchPolicy,
                                       CorpusEngine)

    def encode(tokens, mask):
        B = tokens.shape[0]
        out = np.zeros((B, 32), np.float32)
        for i in range(B):
            for t, m in zip(np.asarray(tokens[i]), np.asarray(mask[i])):
                if m:
                    out[i, int(t) % 32] += 1
        return topk(jnp.asarray(out), 4)

    eng = CorpusEngine(
        BatchedEncoder(encode, policy=BatchPolicy(max_batch=8)), 32,
        shard_axis="term", n_shards=2)
    eng.add_docs([np.array([d, d, d], np.int32) for d in range(6)])
    q = topk(jnp.asarray(np.eye(32, dtype=np.float32)[[3]] * 5), 4)
    _, ext = eng.search(q, 2)
    assert ext[0, 0] == 3
    assert eng.stats()["term_shards"] == 2
    with pytest.raises(ValueError, match="shard_axis"):
        CorpusEngine(BatchedEncoder(encode), 32, shard_axis="vocab")


# ---------------------------------------------------------------------------
# shard_map + psum path (subprocess, forced host devices)
# ---------------------------------------------------------------------------

_TERM_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    n = int(os.environ.get("REPRO_SHARD_TEST_DEVICES", "2"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}")
    import jax, jax.numpy as jnp, numpy as np
    from repro.data.synthetic import lsr_impact_corpus
    from repro.retrieval import (build_inverted_index, retrieve,
                                 sparsify_topk, term_shard_index,
                                 term_sharded_retrieve)

    assert jax.device_count() >= n, jax.devices()
    data = lsr_impact_corpus(n_docs=192, vocab=256, doc_nnz=16,
                             n_queries=4, q_nnz=14, graded=6)
    q = sparsify_topk(jnp.asarray(data["queries"]), 14)
    d = sparsify_topk(jnp.asarray(data["docs"]), 16)
    k = 4
    v_ref, i_ref = retrieve(q, build_inverted_index(d, 256), k,
                            method="impact")

    tidx = term_shard_index(d, 256, n, keep_forward=True)
    mesh = jax.make_mesh((n,), ("model",))
    # exact: per-shard partial sums all-reduced via psum
    v_sm, i_sm = term_sharded_retrieve(q, tidx, k, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i_sm), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(v_sm), np.asarray(v_ref),
                               atol=1e-4)
    # pruned composition: per-shard ceilings psum'd, exact rescore
    v_pr, i_pr = term_sharded_retrieve(q, tidx, k, mesh=mesh,
                                       prune_margin=0.0)
    np.testing.assert_array_equal(np.asarray(i_pr), np.asarray(i_ref))
    # the retrieve() dispatcher threads the mesh through
    v_d, i_d = retrieve(q, tidx, k, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_ref))
    # shard-count / mesh-size mismatch is a loud error
    try:
        term_sharded_retrieve(
            q, term_shard_index(d, 256, n + 1), k, mesh=mesh)
        raise SystemExit("mismatch not rejected")
    except ValueError as e:
        assert "must equal mesh axis" in str(e), e
    print("ALL_TERM_SHARDED_PASSED")
""")


def test_term_sharded_multi_device_subprocess():
    """psum merge on a forced multi-host-device mesh == the unsharded
    impact scorer, for both the exact and pruned tiers (device count
    from REPRO_SHARD_TEST_DEVICES; CI's multidevice job sets 4)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _TERM_SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}")
    assert "ALL_TERM_SHARDED_PASSED" in proc.stdout
