"""DimeNet — directional message passing GNN [arXiv:2003.03123].

Kernel regime: *triplet gather* (B.3 of the kernel taxonomy) — messages
live on directed edges and are updated by aggregating over (k->j->i)
triplets with a joint radial x angular basis. Message passing is
expressed as ``jnp.take`` + ``jax.ops.segment_sum`` over index lists
(JAX has no CSR SpMM; see repro/sparse/segment.py).

Structure per the paper: radial Bessel basis with polynomial envelope,
spherical (distance x angle) basis on triplets, embedding block, 6
interaction blocks with an ``n_bilinear``-rank bilinear sbf layer, and
per-block output projections summed into node outputs. The spherical
basis uses sin-Bessel x cos(l*angle) products (structurally matching
n_spherical x n_radial; exact spherical Bessel roots are a tabulated
detail with no systems impact — noted in DESIGN.md).

Graph regimes supported (the assigned shapes):
* molecules (batched small graphs; graph-level targets, exact triplets)
* full-graph (cora-size and ogb-products-size; node-level targets,
  synthetic coordinates, capped triplets per edge)
* sampled minibatch (fanout sampler; flattened hop-block edges)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DimeNetConfig
from repro.sparse.segment import segment_sum

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# bases
# ---------------------------------------------------------------------------

def envelope(d_scaled: Array, p: int) -> Array:
    """Polynomial cutoff envelope u(d) from the paper (eq. 8)."""
    a = -(p + 1) * (p + 2) / 2
    b = p * (p + 2)
    c = -p * (p + 1) / 2
    e = (1.0 / jnp.maximum(d_scaled, 1e-9)
         + a * d_scaled ** (p - 1) + b * d_scaled ** p
         + c * d_scaled ** (p + 1))
    return jnp.where(d_scaled < 1.0, e, 0.0)


def radial_basis(d: Array, cfg: DimeNetConfig) -> Array:
    """(E,) distances -> (E, n_radial) enveloped sin-Bessel basis."""
    ds = d / cfg.cutoff
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    env = envelope(ds, cfg.envelope_exponent)
    return (env[:, None] * jnp.sqrt(2.0 / cfg.cutoff)
            * jnp.sin(n[None, :] * jnp.pi * ds[:, None]))


def spherical_basis(d: Array, angle: Array, cfg: DimeNetConfig) -> Array:
    """(T,) in-edge distances + (T,) angles -> (T, n_sph * n_rad)."""
    ds = d / cfg.cutoff
    env = envelope(ds, cfg.envelope_exponent)
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    rad = env[:, None] * jnp.sin(n[None, :] * jnp.pi * ds[:, None])
    l = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(l[None, :] * angle[:, None])
    return (rad[:, None, :] * ang[:, :, None]).reshape(
        d.shape[0], cfg.n_spherical * cfg.n_radial)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense(key, din, dout, dtype):
    return {
        "w": jax.random.normal(key, (din, dout), dtype) * din ** -0.5,
        "b": jnp.zeros((dout,), dtype),
    }


def _apply(layer, x):
    return x @ layer["w"] + layer["b"]


def init_params(key: jax.Array, cfg: DimeNetConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_hidden
    n_sbf = cfg.n_spherical * cfg.n_radial
    ks = iter(jax.random.split(key, 12 + 10 * cfg.n_blocks))

    params: Params = {
        "embed_nodes": (
            jax.random.normal(next(ks), (cfg.n_atom_types, d), dtype) * 0.1
            if cfg.d_feat == 0 else _dense(next(ks), cfg.d_feat, d, dtype)
        ),
        "embed_rbf": _dense(next(ks), cfg.n_radial, d, dtype),
        "embed_msg": _dense(next(ks), 3 * d, d, dtype),
        "blocks": [],
        "out_final": _dense(next(ks), d, cfg.n_targets, dtype),
    }
    for _ in range(cfg.n_blocks):
        blk = {
            "rbf_gate": _dense(next(ks), cfg.n_radial, d, dtype),
            "sbf_proj": _dense(next(ks), n_sbf, cfg.n_bilinear, dtype),
            "w_bilinear": jax.random.normal(
                next(ks), (cfg.n_bilinear, d, d), dtype) * d ** -0.5,
            "msg_in": _dense(next(ks), d, d, dtype),
            "msg_out": _dense(next(ks), 2 * d, d, dtype),
            "out_rbf": _dense(next(ks), cfg.n_radial, d, dtype),
            "out_node": _dense(next(ks), d, d, dtype),
        }
        params["blocks"].append(blk)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _geometry(batch: Dict[str, Array]) -> Tuple[Array, Array, Array]:
    """Edge distances + triplet (in-edge distance, angle)."""
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    vec = jnp.take(pos, src, axis=0) - jnp.take(pos, dst, axis=0)
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-12)

    t_in, t_out = batch["t_in"], batch["t_out"]
    v_in = jnp.take(vec, t_in, axis=0)       # k - j (in-edge k->j)
    v_out = -jnp.take(vec, t_out, axis=0)    # i - j (out-edge j->i)
    d_in = jnp.take(dist, t_in)
    cosang = jnp.sum(v_in * v_out, axis=-1) / jnp.maximum(
        d_in * jnp.sqrt(jnp.sum(v_out * v_out, axis=-1) + 1e-12), 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0 + 1e-7, 1.0 - 1e-7))
    return dist, d_in, angle


def forward_dense_triplets(
    params: Params, cfg: DimeNetConfig, batch: Dict[str, Array],
    shard_axes: Optional[Tuple[str, ...]] = None,
) -> Array:
    """Dense-(E, K) triplet layout + distributed gather/scatter —
    the §Perf-optimized path for capped-triplet graphs.

    With ``max_triplets_per_edge = K``, triplets are laid out as a
    dense ``t_in_dense (E, K)`` index matrix (mask for short rows).
    The per-triplet aggregation to edges becomes a LOCAL sum over K
    (no segment scatter), and all cross-shard row accesses (edge
    messages by ``t_in_dense``, node features by ``src``/``dst``,
    edge-to-node aggregation) go through the all_to_all-based
    ``repro.sparse.distributed`` ops instead of partitioner-inserted
    all-gathers. Measured on ogb_products: 439 GB -> see §Perf.
    """
    from repro.sparse.distributed import (distributed_segment_sum_local,
                                          distributed_take_local)

    src, dst = batch["edge_src"], batch["edge_dst"]
    e_mask = batch["edge_mask"].astype(jnp.float32)
    tk_mask = batch["t_mask_dense"].astype(jnp.float32)  # (E, K)
    t_in = batch["t_in_dense"]                           # (E, K)
    n_nodes = batch["node_mask"].shape[0]
    E, K = t_in.shape

    if shard_axes:
        from jax.sharding import PartitionSpec as P

        def row_sharded(x):
            return jax.lax.with_sharding_constraint(
                x, P(shard_axes, *([None] * (x.ndim - 1))))

        def take_rows(table, idx, wire_dtype=None):
            # wire_dtype=bf16 halves a2a wire+buffers on TPU, but the
            # CPU backend legalizes bf16 back to f32 (measured: no
            # delta, +converts) -> off by default in the dry-run
            from repro.compat import shard_map
            flat = idx.reshape(-1)
            fn = shard_map(
                lambda t, i: distributed_take_local(
                    t, i, axis_names=shard_axes)[0],
                mesh=None,
                in_specs=(P(shard_axes, None), P(shard_axes)),
                out_specs=P(shard_axes, None), check_vma=False)
            src = table if wire_dtype is None else \
                table.astype(wire_dtype)
            out = fn(src, flat).astype(table.dtype)
            return out.reshape(idx.shape + (table.shape[-1],))

        def scatter_rows(vals, idx, n_rows, wire_dtype=None):
            from repro.compat import shard_map
            # rows per shard must divide; specs pad to 512
            fn = shard_map(
                lambda v, i: distributed_segment_sum_local(
                    v, i, n_rows // _n_shards(shard_axes),
                    axis_names=shard_axes)[0],
                mesh=None,
                in_specs=(P(shard_axes, None), P(shard_axes)),
                out_specs=P(shard_axes, None), check_vma=False)
            v = vals if wire_dtype is None else vals.astype(wire_dtype)
            return fn(v, idx).astype(vals.dtype)
    else:
        def row_sharded(x):
            return x

        def take_rows(table, idx):
            return jnp.take(table, idx, axis=0)

        def scatter_rows(vals, idx, n_rows):
            return segment_sum(vals, idx, n_rows)

    # geometry: per-edge local; per-triplet via gather of edge rows
    pos_src = take_rows(batch["positions"], src)
    pos_dst = take_rows(batch["positions"], dst)
    vec = pos_src - pos_dst                                   # (E, 3)
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-12)
    vec_in = take_rows(vec, t_in)                             # (E, K, 3)
    v_out = -vec[:, None, :]                                  # (E, 1, 3)
    d_in = jnp.sqrt(jnp.sum(vec_in * vec_in, axis=-1) + 1e-12)
    cosang = jnp.sum(vec_in * v_out, axis=-1) / jnp.maximum(
        d_in * dist[:, None], 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1.0 + 1e-7, 1.0 - 1e-7))

    rbf = row_sharded(radial_basis(dist, cfg) * e_mask[:, None])
    sbf = spherical_basis(d_in.reshape(-1), angle.reshape(-1), cfg)
    sbf = row_sharded(
        sbf.reshape(E, K, -1) * tk_mask[..., None])           # (E,K,nsbf)

    if cfg.d_feat == 0:
        h = jnp.take(params["embed_nodes"], batch["node_feat"], axis=0)
    else:
        h = jax.nn.silu(_apply(params["embed_nodes"], batch["node_feat"]))
    h = row_sharded(h)

    rbf_e = jax.nn.silu(_apply(params["embed_rbf"], rbf))
    h_src = take_rows(h, src)
    h_dst = take_rows(h, dst)
    m = jax.nn.silu(_apply(params["embed_msg"], jnp.concatenate(
        [h_src, h_dst, rbf_e], axis=-1)))                     # (E, d)
    m = row_sharded(m)

    node_out = row_sharded(jnp.zeros((n_nodes, cfg.d_hidden), m.dtype))

    def block_fn(blk, m, node_out):
        x_kj = jax.nn.silu(_apply(blk["msg_in"], m))          # (E, d)
        x_t = take_rows(x_kj, t_in)                           # (E, K, d)
        s = _apply(blk["sbf_proj"], sbf)                      # (E, K, nb)
        # bilinear + K-sum in one local einsum — no triplet scatter
        xt2 = jnp.einsum("ekb,ekd,bdf->ef",
                         s * tk_mask[..., None], x_t, blk["w_bilinear"])
        agg = row_sharded(xt2)                                # (E, d)
        gate = jax.nn.silu(_apply(blk["rbf_gate"], rbf))
        upd = jax.nn.silu(_apply(
            blk["msg_out"], jnp.concatenate([m * gate, agg], axis=-1)))
        m = row_sharded(m + upd)
        contrib = m * jax.nn.silu(_apply(blk["out_rbf"], rbf))
        node_agg = scatter_rows(contrib * e_mask[:, None], dst, n_nodes)
        node_out = node_out + jax.nn.silu(_apply(blk["out_node"],
                                                 node_agg))
        return m, node_out

    # NOTE (§Perf, hypothesis refuted): jax.checkpoint per block made
    # the peak WORSE here (36.9 -> 47.2 GB on ogb_products): the block
    # closure (sbf, rbf, masks) is saved per block anyway and the
    # backward re-runs the distributed gathers, doubling the live
    # all_to_all buffers. Blocks therefore run un-remat'ed.
    for blk in params["blocks"]:
        m, node_out = block_fn(blk, m, node_out)

    return _apply(params["out_final"], node_out)


def _n_shards(axes: Tuple[str, ...]) -> int:
    mesh = jax.sharding.get_abstract_mesh()
    n = 1
    for ax in axes:
        n *= mesh.shape[ax]
    return n


def forward(
    params: Params, cfg: DimeNetConfig, batch: Dict[str, Array],
    shard_axes: Optional[Tuple[str, ...]] = None,
) -> Array:
    """Returns node-level outputs (N, n_targets).

    ``shard_axes``: when running under a mesh with edge/triplet/node
    counts divisible by the device count, per-edge and per-triplet
    intermediates (and the segment-sum outputs) are constrained to be
    row-sharded over these axes. Without the constraints the SPMD
    partitioner replicates every segment_sum output — at ogb-products
    scale that is a 31 GB/device tensor per block (measured ~430 GB
    peak on the baseline dry-run).

    Batches carrying the dense ``t_in_dense (E, K)`` triplet layout
    dispatch to ``forward_dense_triplets`` (the §Perf-optimized path).
    """
    if "t_in_dense" in batch:
        return forward_dense_triplets(params, cfg, batch,
                                      shard_axes=shard_axes)
    if shard_axes:
        from jax.sharding import PartitionSpec as P

        def row_sharded(x):
            spec = P(shard_axes, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(x, spec)
    else:
        def row_sharded(x):
            return x

    src, dst = batch["edge_src"], batch["edge_dst"]
    e_mask = batch["edge_mask"].astype(jnp.float32)
    t_mask = batch["t_mask"].astype(jnp.float32)
    n_nodes = batch["node_mask"].shape[0]
    n_edges = src.shape[0]

    dist, d_in, angle = _geometry(batch)
    rbf = row_sharded(radial_basis(dist, cfg) * e_mask[:, None])
    sbf = row_sharded(spherical_basis(d_in, angle, cfg) * t_mask[:, None])

    if cfg.d_feat == 0:
        h = jnp.take(params["embed_nodes"], batch["node_feat"], axis=0)
    else:
        h = jax.nn.silu(_apply(params["embed_nodes"], batch["node_feat"]))
    h = row_sharded(h)

    rbf_e = jax.nn.silu(_apply(params["embed_rbf"], rbf))
    m = jax.nn.silu(_apply(params["embed_msg"], jnp.concatenate(
        [jnp.take(h, src, axis=0), jnp.take(h, dst, axis=0), rbf_e],
        axis=-1)))                                          # (E, d)
    m = row_sharded(m)

    node_out = jnp.zeros((n_nodes, cfg.d_hidden), m.dtype)
    t_in, t_out = batch["t_in"], batch["t_out"]
    for blk in params["blocks"]:
        # directional aggregation over triplets
        x_kj = jax.nn.silu(_apply(blk["msg_in"], m))        # (E, d)
        x_t = row_sharded(jnp.take(x_kj, t_in, axis=0))     # (T, d)
        s = _apply(blk["sbf_proj"], sbf)                    # (T, nb)
        # bilinear: (T, nb) x (T, d) x (nb, d, d) -> (T, d)
        xt2 = jnp.einsum("tb,td,bde->te", s, x_t, blk["w_bilinear"])
        agg = row_sharded(
            segment_sum(xt2 * t_mask[:, None], t_out, n_edges))
        gate = jax.nn.silu(_apply(blk["rbf_gate"], rbf))
        upd = jax.nn.silu(_apply(
            blk["msg_out"], jnp.concatenate([m * gate, agg], axis=-1)))
        m = row_sharded(m + upd)
        # per-block output: edges -> nodes
        contrib = m * jax.nn.silu(_apply(blk["out_rbf"], rbf))
        node_agg = row_sharded(
            segment_sum(contrib * e_mask[:, None], dst, n_nodes))
        node_out = node_out + jax.nn.silu(_apply(blk["out_node"], node_agg))

    return _apply(params["out_final"], node_out)            # (N, n_targets)


def forward_graph(
    params: Params, cfg: DimeNetConfig, batch: Dict[str, Array],
    n_graphs: int,
    shard_axes: Optional[Tuple[str, ...]] = None,
) -> Array:
    """Graph-level readout: sum node outputs per graph id."""
    node_out = forward(params, cfg, batch, shard_axes=shard_axes)
    node_out = node_out * batch["node_mask"].astype(node_out.dtype)[:, None]
    return segment_sum(node_out, batch["node_graph_id"], n_graphs)
