"""Checkpoint store: atomic writes, roundtrip, async, resume, GC."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    load_checkpoint, save_checkpoint)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 3)),
                   "layers": [jnp.ones((2,)), jnp.zeros((3,))]},
        "opt": {"mu": {"w": jnp.zeros((4, 3))}},
        "step": jnp.array(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    state = _state()
    path = save_checkpoint(str(tmp_path), 7, state)
    assert path and os.path.isdir(path)
    template = jax.tree.map(jnp.zeros_like, state)
    restored, step = load_checkpoint(str(tmp_path), template)
    assert step == 7
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b)),
        state, restored)


def test_latest_step_and_gc(tmp_path):
    state = _state()
    for s in (10, 20, 30, 40):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    assert latest_step(str(tmp_path)) == 40
    # keep=2: only the last two survive
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [30, 40]


def test_tmp_dirs_are_not_trusted(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 5, state)
    # a crashed writer leaves a .tmp dir; resume must ignore it
    os.makedirs(tmp_path / "step_000000099.tmp")
    assert latest_step(str(tmp_path)) == 5


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), {"w": jnp.zeros((4,))})


def test_missing_leaf_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3,))})
    with pytest.raises(KeyError):
        load_checkpoint(str(tmp_path), {"w": jnp.zeros((3,)),
                                        "extra": jnp.zeros((2,))})


def test_non_writer_process_skips(tmp_path):
    out = save_checkpoint(str(tmp_path), 1, _state(), process_index=1)
    assert out is None
    assert latest_step(str(tmp_path)) is None


def test_async_checkpointer(tmp_path):
    ckpt = AsyncCheckpointer(str(tmp_path), keep=5)
    state = _state()
    for s in (1, 2, 3):
        ckpt.save(s, state)
    ckpt.close()
    assert latest_step(str(tmp_path)) == 3
    template = jax.tree.map(jnp.zeros_like, state)
    restored, step = load_checkpoint(str(tmp_path), template)
    assert step == 3
