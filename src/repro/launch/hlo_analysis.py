"""HLO analysis: collective byte accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective
traffic; we parse the *post-partitioning, per-device* HLO text
(``compiled.as_text()``) and account every ``all-gather`` /
``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op.

Byte conventions (per device, per step):
* operand_bytes — sum of input-shape bytes (what the assignment asks
  to sum; the payload a device *injects*),
* wire_bytes    — ring-algorithm traffic estimate per device:
    all-gather:        (n-1)/n x result_bytes
    reduce-scatter:    (n-1)/n x operand_bytes
    all-reduce:        2 (n-1)/n x operand_bytes
    all-to-all:        (n-1)/n x operand_bytes
    collective-permute: operand_bytes
  where n = replica-group size parsed from the op.

Roofline terms (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (3D torus, per direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    """Largest replica group size on the op line (n for ring factors)."""
    m = re.search(r"replica_groups=\{([^}]*)\}", line)
    if m:
        groups = m.group(1)
        best = 1
        for g in re.findall(r"\{([\d,]+)\}", "{" + groups + "}"):
            best = max(best, g.count(",") + 1)
        return best
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota group format [n_groups, group_size]
        return int(m.group(2))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    op_counts: Dict[str, int]
    operand_bytes: Dict[str, int]     # per op kind
    wire_bytes: Dict[str, int]
    total_operand_bytes: int = 0
    total_wire_bytes: int = 0

    def rows(self) -> List[Tuple[str, int, int, int]]:
        return [(k, self.op_counts[k], self.operand_bytes[k],
                 self.wire_bytes[k]) for k in sorted(self.op_counts)]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = defaultdict(int)
    op_bytes: Dict[str, int] = defaultdict(int)
    wire: Dict[str, int] = defaultdict(int)

    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", ls)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                kind = c
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # bytes counted at -start
        shapes = _SHAPE_RE.findall(rhs)
        if not shapes:
            continue
        # result shape(s) come first (possibly a tuple), operands inside
        paren = rhs.find(f"{kind}(")
        if paren == -1:
            paren = rhs.find("(")
        result_shapes = _SHAPE_RE.findall(rhs[:paren])
        operand_shapes = _SHAPE_RE.findall(rhs[paren:])
        result_b = sum(_shape_bytes(d, s) for d, s in result_shapes)
        operand_b = sum(_shape_bytes(d, s) for d, s in operand_shapes)
        if operand_b == 0:
            operand_b = result_b
        n = _group_size(ls)
        ring = (n - 1) / max(n, 1)

        counts[kind] += 1
        op_bytes[kind] += operand_b
        if kind == "all-gather":
            wire[kind] += int(ring * result_b)
        elif kind == "all-reduce":
            wire[kind] += int(2 * ring * operand_b)
        elif kind == "reduce-scatter":
            wire[kind] += int(ring * operand_b)
        elif kind == "all-to-all":
            wire[kind] += int(ring * operand_b)
        else:  # collective-permute
            wire[kind] += operand_b

    stats = CollectiveStats(dict(counts), dict(op_bytes), dict(wire))
    stats.total_operand_bytes = sum(op_bytes.values())
    stats.total_wire_bytes = sum(wire.values())
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float                 # total HLO flops (per device)
    hbm_bytes: float             # per device
    collective_operand_bytes: float
    collective_wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0     # 6*N*D useful flops per device
    useful_ratio: float = 0.0

    def table_row(self) -> str:
        return (f"{self.compute_s:.3e},{self.memory_s:.3e},"
                f"{self.collective_s:.3e},{self.bottleneck},"
                f"{self.useful_ratio:.3f}")


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll: CollectiveStats,
    *,
    model_flops: float = 0.0,
    n_links: int = 3,  # v5e 2D/3D torus: ~3 usable link pairs per chip
) -> Roofline:
    """All inputs are per-device quantities (post-partitioning HLO)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll.total_wire_bytes / (ICI_BW * n_links)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    r = Roofline(
        flops=flops, hbm_bytes=hbm_bytes,
        collective_operand_bytes=coll.total_operand_bytes,
        collective_wire_bytes=coll.total_wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
    )
    if model_flops:
        r.model_flops = model_flops
        r.useful_ratio = model_flops / max(flops, 1.0)
    return r


def cost_analysis_terms(compiled) -> Tuple[float, float]:
    """(flops, bytes accessed) from compiled.cost_analysis(), robust to
    backend differences."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    if "bytes accessed" in ca:
        mem = float(ca["bytes accessed"])
    else:
        mem = float(sum(v for k, v in ca.items()
                        if k.startswith("bytes accessed")))
    return flops, mem


def memory_analysis_bytes(compiled) -> Optional[Dict[str, float]]:
    """Per-device memory breakdown from compiled.memory_analysis()."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes"):
        if hasattr(ma, key):
            out[key] = float(getattr(ma, key))
    peak = (out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    out["peak_estimate_bytes"] = peak
    return out
